"""SLO-miss root-cause example — run the Mooncake long-context tail with
the telemetry plane on, then ask it *why* each policy missed: every missed
request's lost slack is pinned to the flow span with the largest network
excess and its bottleneck link, ranked into a per-policy top-3
(stage, link) table. One missed tight-SLO request's full timeline
(compute spans, per-stage network flows, lifecycle instants) is exported
as Chrome trace-event JSON — open it at ``ui.perfetto.dev``.

    PYTHONPATH=src python examples/telemetry_root_cause.py \
        --rps 16 --requests 120 --trace-out miss_timeline.json
"""
import argparse

from repro.core import TelemetrySpec, make_policy
from repro.core.kvstore import KVStoreSpec, TierSpec
from repro.simcluster.hw import A100, Gb, HW
from repro.simcluster.papermodels import PAPER_MODELS
from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
from repro.simcluster.trace import ArrivalSpec, WORKLOADS, generate_trace

#: the benchmark suite's 16-unit sp Mooncake cluster: 50 Gbps/GPU NIC share
#: so long-context KV movement, not compute, is the binding constraint
HW_50G = HW("a100-50g", flops=A100.flops, hbm_bw=A100.hbm_bw,
            nic_bw=50 * Gb, scaleup_bw=A100.scaleup_bw)
STORE = KVStoreSpec(
    block_tokens=256, pooled_nodes=2, wb_deadline_scale=8.0,
    tiers=(TierSpec("hbm", capacity=2e9),
           TierSpec("dram", capacity=4e9, fetch_bw=12e9, scope="unit",
                    writeback=True),
           TierSpec("remote", capacity=64e9, fetch_bw=6.25e9, scope="pooled",
                    writeback=True)))
SLO_MIX = {"tight": 0.2, "standard": 0.5, "loose": 0.3}


def _spec() -> ClusterSpec:
    return ClusterSpec(model=PAPER_MODELS["mixtral-8x7b"], n_units=16,
                       par=ParallelismSpec(mode="sp", sp=4),
                       gpus_per_server=4, topology="fattree",
                       hosts_per_rack=8, layer_groups=8, decode_ratio=0.5,
                       hw=HW_50G, kvstore=STORE, telemetry=TelemetrySpec())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rps", type=float, default=16.0)
    ap.add_argument("--requests", type=int, default=120)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default="miss_timeline.json",
                    help="Chrome trace of one missed tight-SLO request")
    args = ap.parse_args()

    trace = generate_trace(WORKLOADS["mooncake-tail"], args.requests,
                           rps=args.rps, seed=args.seed, warmup=24,
                           arrival=ArrivalSpec(process="mmpp"),
                           slo_mix=SLO_MIX)
    print(f"mooncake-tail @ {args.rps} rps, {args.requests} requests, "
          f"tiered store on, SLO mix {SLO_MIX}\n")

    sample = None
    for pol in ("fs", "sjf", "edf", "karuna", "mfs"):
        sim = ClusterSim(_spec(), make_policy(pol), seed=args.seed)
        s = sim.run(trace).summary()
        tel = sim.telemetry
        rep = tel.slo_miss_report(top=3)
        tight = tel.slo_miss_report(slo_class="tight")
        cov = "n/a" if rep["coverage"] is None else f"{rep['coverage']:.0%}"
        print(f"{pol:8s} attainment={s['slo_attainment']:.1%}  "
              f"missed={rep['n_missed']} (tight={tight['n_missed']})  "
              f"link-attributed={cov}")
        for c in rep["causes"]:
            where = c["link_name"] if c["link"] is not None else c["stage"]
            print(f"         {c['n']:3d}x  {c['stage']:9s} @ {where:12s} "
                  f"slack_lost={c['slack_lost']:7.2f}s")
        share = tel.contended_stage_share()
        if share:
            print("         contended-link bytes: "
                  + "  ".join(f"{st}={v:.0%}" for st, v in share.items()))
        # keep one missed tight request's timeline (prefer the mfs arm's)
        picked = next((r["rid"] for r in tight["requests"]
                       if r.get("link") is not None), None)
        if picked is not None and (sample is None or pol == "mfs"):
            sample = (pol, picked, tel)
        print()

    if sample is not None:
        pol, rid, tel = sample
        tel.save_chrome_trace(args.trace_out, rids={rid})
        bd = tel.ttft_breakdown(rid)
        print(f"wrote {args.trace_out}: rid={rid} ({pol} arm), "
              f"ttft={bd['ttft']:.2f}s = queue {bd['queue']:.2f} "
              f"+ s1 stall {bd['stall_s1']:.2f} + compute {bd['compute']:.2f} "
              f"+ coll wait {bd['coll_wait']:.2f} "
              f"+ p2d tail {bd['p2d_tail']:.2f} "
              f"+ first decode {bd['first_decode']:.2f}")
        print("open it at ui.perfetto.dev (or chrome://tracing)")


if __name__ == "__main__":
    main()
