"""End-to-end driver — disaggregated serving with batched requests.

Runs a REAL reduced SmolLM on CPU behind the DisaggServer orchestrator:
prefix-cache reuse (Stage 1), per-layer-group P2D transfers with TTFT
deadlines (Stage 3), every transfer scheduled through the pluggable policy
(MFS by default), decode via slotted continuous batching. Compares SLO
attainment across policies on the same request stream.

    PYTHONPATH=src python examples/serve_disagg.py [--requests 16]
"""
import argparse

import jax
import numpy as np

from repro.configs import SMOKES
from repro.core import make_policy
from repro.models.lm import build_model
from repro.serving import DisaggConfig, DisaggServer, ServeRequest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = SMOKES[args.arch]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    # agent-style stream: hot shared prefixes + fresh suffixes
    prefixes = [rng.integers(0, cfg.vocab, size=(32,)) for _ in range(3)]
    reqs = []
    for i in range(args.requests):
        if rng.uniform() < 0.6:
            toks = np.concatenate([prefixes[rng.integers(3)],
                                   rng.integers(0, cfg.vocab, size=(12,))])
        else:
            toks = rng.integers(0, cfg.vocab, size=(44,))
        reqs.append(ServeRequest(rid=i, arrival=i * 2e-4, tokens=toks,
                                 max_new=4))

    for pol in ("mfs", "fs", "edf", "karuna"):
        srv = DisaggServer(model, params, policy=make_policy(pol),
                           cfg=DisaggConfig(n_prefill_units=2, n_pages=512))
        res = srv.serve(reqs)
        slo = sum(r.met_slo for r in res) / len(res)
        reuse = sum(r.reused_tokens for r in res)
        mean_ttft = np.mean([r.ttft for r in res]) * 1e3
        print(f"{pol:8s} SLO={slo:6.1%}  mean TTFT={mean_ttft:7.3f} ms  "
              f"reused {reuse} tokens across {len(res)} requests")
    sample = res[0]
    print(f"\nsample completion rid={sample.rid}: first_token="
          f"{sample.first_token} continuation={sample.tokens}")


if __name__ == "__main__":
    main()
