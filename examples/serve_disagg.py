"""End-to-end driver — disaggregated serving with batched requests.

Runs a REAL reduced SmolLM on CPU behind the DisaggServer orchestrator,
which drives the shared MsFlow runtime at full MFS fidelity: prefix-cache
reuse as per-layer-group Stage-1 flows, queued multi-request prefill
batching, per-layer-group P2D transfers with TTFT deadlines (Stage 3),
RMLQ promotion at layer boundaries/ticks, and Algorithm 1 overload control
(RED ordering + soft pruning + scavenger readmission) — every transfer
scheduled through the pluggable policy. Decode is slotted continuous
batching (real tokens).

The model is tiny, so the virtual fabric is throttled (``--nic-bw``) to
put the toy stream into the contended regime the paper studies; per-policy
output reports SLO attainment plus how often the MFS machinery acted
(promotions, prunes).

    PYTHONPATH=src python examples/serve_disagg.py [--requests 16]
"""
import argparse

import jax
import numpy as np

from repro.configs import SMOKES
from repro.core import Stage, make_policy
from repro.models.lm import build_model
from repro.serving import DisaggConfig, DisaggServer, ServeRequest
from repro.simcluster.hw import HW, TPU_V5E


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--nic-bw", type=float, default=2e6,
                    help="modeled NIC bytes/s (small => contention)")
    ap.add_argument("--slo-scale", type=float, default=3.0,
                    help="SLO = scale x contention-free TTFT; tighten "
                         "(e.g. 1.0) to push Algorithm 1 into pruning")
    args = ap.parse_args()

    cfg = SMOKES[args.arch]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    hw = HW("throttled", flops=TPU_V5E.flops, hbm_bw=TPU_V5E.hbm_bw,
            nic_bw=args.nic_bw, scaleup_bw=TPU_V5E.scaleup_bw,
            mfu=TPU_V5E.mfu)

    # agent-style stream: a warm wave registers three hot prefixes in the
    # index, then a burst of follow-ups (shared prefix + fresh suffix)
    # overloads the throttled fabric — the one-to-many victim contention
    # regime of §6.3.
    prefixes = [rng.integers(0, cfg.vocab, size=(96,)) for _ in range(3)]
    reqs = [ServeRequest(rid=i, arrival=i * 0.05, tokens=p, max_new=4)
            for i, p in enumerate(prefixes)]
    for i in range(args.requests):
        if rng.uniform() < 0.6:
            toks = np.concatenate([prefixes[rng.integers(3)],
                                   rng.integers(0, cfg.vocab, size=(12,))])
        else:
            toks = rng.integers(0, cfg.vocab, size=(44,))
        reqs.append(ServeRequest(rid=3 + i, arrival=0.15 + i * 1e-3,
                                 tokens=toks, max_new=4))

    for pol in ("mfs", "fs", "edf", "karuna"):
        srv = DisaggServer(model, params, policy=make_policy(pol),
                           cfg=DisaggConfig(n_prefill_units=2, n_pages=512,
                                            hw=hw, slo_scale=args.slo_scale))
        res = srv.serve(reqs)
        rt = srv.runtime
        slo = sum(r.met_slo for r in res) / len(res)
        reuse = sum(r.reused_tokens for r in res)
        mean_ttft = np.mean([r.ttft for r in res]) * 1e3
        promoted = rt.promoted_count(Stage.P2D)
        print(f"{pol:8s} SLO={slo:6.1%}  mean TTFT={mean_ttft:7.3f} ms  "
              f"reused {reuse:3d} tokens  promoted {promoted:2d} P2D flows  "
              f"pruned {rt.n_pruned} requests")
    sample = res[0]
    print(f"\nsample completion rid={sample.rid}: first_token="
          f"{sample.first_token} continuation={sample.tokens}")


if __name__ == "__main__":
    main()
