"""Large-scale simulation example — the paper's §6.3 methodology at your
fingertips: pick a model, workload and request rate; compare TTFT SLO
attainment across all five policies (+ the clairvoyant LLF oracle ceiling).

    PYTHONPATH=src python examples/simulate_cluster.py \
        --model dbrx --workload qwen-conv --rps 11 --requests 128
"""
import argparse

from repro.core import make_policy
from repro.simcluster.papermodels import PAPER_MODELS
from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
from repro.simcluster.trace import WORKLOADS, generate_trace

PARALLELISM = {
    "mixtral-8x7b": ParallelismSpec(mode="ep", ep=8),
    "mixtral-8x22b": ParallelismSpec(mode="ep", tp=4, ep=8),
    "dbrx": ParallelismSpec(mode="ep", tp=2, ep=16),
    "grok": ParallelismSpec(mode="ep", tp=4, ep=8),
    "qwen3-coder": ParallelismSpec(mode="ep", tp=1, ep=32),
    "llama3-8b": ParallelismSpec(mode="sp", tp=4, sp=4),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dbrx", choices=sorted(PAPER_MODELS))
    ap.add_argument("--workload", default="qwen-conv",
                    choices=sorted(WORKLOADS))
    ap.add_argument("--rps", type=float, default=11.0)
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--units", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = ClusterSpec(model=PAPER_MODELS[args.model],
                       par=PARALLELISM[args.model], n_units=args.units)
    trace = generate_trace(WORKLOADS[args.workload], args.requests,
                           rps=args.rps, seed=args.seed, warmup=16)
    print(f"{args.model} on {args.workload} @ {args.rps} rps, "
          f"{args.requests} requests\n")
    print(f"{'policy':12s} {'SLO':>7s} {'TTFT p50':>10s} {'TTFT p99':>10s} "
          f"{'CCT slow':>9s} {'earliness':>10s} {'pruned':>6s}")
    for pol in ("fs", "sjf", "edf", "karuna", "mfs", "llf-oracle"):
        sim = ClusterSim(spec, make_policy(pol), seed=args.seed)
        s = sim.run(trace).summary()
        print(f"{pol:12s} {s['slo_attainment']:7.1%} "
              f"{s['ttft_p50']*1e3:9.2f}ms {s['ttft_p99']*1e3:9.2f}ms "
              f"{s['cct_slowdown']:9.2f} {s['pos_earliness']:10.4f} "
              f"{s['pruned']:6d}")


if __name__ == "__main__":
    main()
