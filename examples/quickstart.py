"""Quickstart — the MFS scheduler in 60 lines.

Builds the paper's Table-1 scenario by hand, runs it under four
stage-agnostic baselines and under MFS, and prints who met their deadline.
No model weights involved: the scheduler is pure control plane.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import MFSScheduler, Stage, make_policy
from repro.netsim.toy import make_flow, run_toy

# Three requests contending for one bottleneck link (paper Table 1):
#   name: (flow size, downstream remain-time, request TTFT deadline)
REQUESTS = {"A": (2.0, 9.0, 18.0), "B": (4.0, 6.0, 12.0), "C": (3.0, 0.0, 7.0)}


def run(policy_name: str) -> None:
    flows = {}
    for rid, (name, (size, remain, dr)) in enumerate(REQUESTS.items()):
        # MFS sees the *materialised* flow deadline (D_r - downstream remain)
        # - the paper's key observation; stage-agnostic baselines only have
        # the request-level deadline.
        deadline = dr - remain if policy_name == "mfs" else dr
        flows[name] = make_flow(Stage.P2D, size=size, deadline=deadline,
                                rid=rid)
    policy = (MFSScheduler() if policy_name == "mfs"
              else make_policy(policy_name))
    finish = run_toy(list(flows.values()), policy)

    print(f"\n--- {policy_name.upper()} ---")
    for name, f in flows.items():
        size, remain, dr = REQUESTS[name]
        done = finish[f.fid] + remain          # flow done + downstream work
        verdict = "MET " if done <= dr + 1e-6 else "MISS"
        print(f"  req {name}: flow finished t={finish[f.fid]:5.2f}  "
              f"request done t={done:5.2f}  deadline {dr:5.1f}  [{verdict}]")


if __name__ == "__main__":
    for pol in ("fs", "sjf", "edf", "karuna", "mfs"):
        run(pol)
    print("\nMFS (Defer-and-Promote over the RMLQ) is the only policy that"
          "\nmeets all three deadlines - compare with Table 1/2 of the paper.")
