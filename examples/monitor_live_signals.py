"""Online monitor-plane walkthrough — live signals while the run is hot.

Runs a small overloaded cluster with the monitor attached and shows the
three things the plane is for:

1. **Live progress** — ``MonitorSpec.sample_every`` fires ``on_sample``
   every N finished requests; the callback reads streaming estimators
   (rolling attainment, throughput, TTFT p99) off the same `Monitor`
   mid-run. `benchmarks/largescale.py --progress` is the same hook.
2. **The signal bus** — after (or during) the run, any signal can be
   read by name: per-link utilization and contended share, per-stage
   slack-loss rates, quantile sketches per SLO class, and the live
   queue/laxity signals the admission detectors consume.
3. **Detectors on the bus** — the ``queue_depth`` admission detector is
   attached to the bus automatically; its trips are byte-identical to
   the legacy in-detector computation (tests/test_monitor.py), so you
   can migrate control loops onto the bus without re-tuning them.

The monitor is strictly passive: run this with ``--monitor-off`` and the
final metrics match exactly.

    PYTHONPATH=src python examples/monitor_live_signals.py \
        --rps 48 --requests 150
"""
import argparse

from repro.core import MonitorSpec, make_policy
from repro.core.router import AdmissionSpec, RouterSpec
from repro.simcluster.papermodels import PAPER_MODELS
from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
from repro.simcluster.trace import ArrivalSpec, WORKLOADS, generate_trace

SLO_MIX = {"tight": 0.2, "standard": 0.5, "loose": 0.3}


def _spec(monitor: bool) -> ClusterSpec:
    return ClusterSpec(
        model=PAPER_MODELS["mixtral-8x7b"], n_units=2,
        par=ParallelismSpec(mode="ep", ep=8),
        router=RouterSpec(admission=AdmissionSpec(
            detector="queue_depth",
            detector_kw={"high": 10, "low": 3},
            shed_classes=("loose",))),
        monitor=MonitorSpec(sample_every=25) if monitor else None)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rps", type=float, default=48.0)
    ap.add_argument("--requests", type=int, default=150)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--monitor-off", action="store_true",
                    help="run without the monitor (prints final metrics "
                         "only — compare to verify passivity)")
    args = ap.parse_args()

    trace = generate_trace(
        WORKLOADS["qwen-conv"], args.requests, rps=args.rps, seed=args.seed,
        warmup=12, slo_mix=SLO_MIX,
        arrival=ArrivalSpec(process="mmpp", burst_factor=8.0,
                            burst_frac=0.15, dwell=2.0))
    sim = ClusterSim(_spec(not args.monitor_off), make_policy("mfs"))

    if sim.monitor is not None:
        # 1. live progress: streaming estimators mid-run, on the event clock
        def progress(mon):
            s = mon.snapshot()
            print(f"  [live] done={s['n_done']:4d} shed={s['n_shed']:3d} "
                  f"attain={s['attainment']:.3f} "
                  f"rate={s['done_rate']:.1f}/s "
                  f"ttft_p99={s['ttft_p99']:.3f}s")

        sim.monitor.on_sample = progress

    m = sim.run(trace)
    print(f"final: attainment={m.slo_attainment():.4f} "
          f"admitted={m.admitted_attainment():.4f} shed={len(m.shed)}")
    if sim.monitor is None:
        return

    # 2. read the bus by name
    bus = sim.monitor.bus
    print("\nsignal bus (end of run):")
    for name, key in (("slo.attainment.cum", None),
                      ("throughput.done", None), ("shed.rate", None),
                      ("ttft.p50", "all"), ("ttft.p99", "all"),
                      ("ttft.p99", "tight"),
                      ("queue.requests.cluster", None),
                      ("laxity.debt", None)):
        v = bus.read(name, key)
        label = f"{name}[{key}]" if key is not None else name
        print(f"  {label:28s} = {v:.4f}")

    # worst links by contended share (rolling window)
    top = sorted(((lid, bus.read("link.contended_share", lid))
                  for lid in sim.monitor.links_seen()),
                 key=lambda kv: -kv[1])[:3]
    print("most contended links (rolling contended-share):")
    for lid, share in top:
        util = bus.read("link.util", lid)
        print(f"  link {lid:4d}: contended={share:.3f} util={util:.3f}")

    # 3. the detector rode the bus the whole run
    det = sim.runtime.admission.detector
    print(f"\nadmission detector: bus-backed={det.bus is not None} "
          f"signal={det.bus_signal!r} trips={det.n_trips} "
          f"tripped={det.tripped}")


if __name__ == "__main__":
    main()
