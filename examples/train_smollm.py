"""End-to-end training driver — a ~100M-class SmolLM variant for a few
hundred steps on CPU with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_smollm.py [--steps 200]

The config is the assigned smollm-360m family at width 256 (~15M params so
a few hundred CPU steps stay minutes, not hours — pass --width 960 for the
real 360M). Demonstrates: jitted train_step with donation, AdamW + clip +
warmup, deterministic data stream, checkpoint every 50 steps, and a
simulated mid-run failure + resume proving bit-identical continuation.
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import ARCHS
from repro.launch.train import run as train_run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--width", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_smollm")
    args = ap.parse_args()

    base = ARCHS["smollm-360m"]
    cfg = dataclasses.replace(
        base, name="smollm-ex", d_model=args.width,
        n_heads=max(1, args.width // 64), n_kv=max(1, args.width // 192),
        d_ff=args.width * 8 // 3, vocab=8192, n_layers=12)
    print(f"training {cfg.name}: {cfg.params()/1e6:.1f}M params, "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}")

    import repro.launch.train as T
    # monkey-patch arch lookup to inject the custom width
    T.SMOKES = dict(T.SMOKES)
    T.SMOKES["smollm-ex"] = cfg

    half = args.steps // 2
    _, losses = T.run("smollm-ex", steps=half, batch=args.batch,
                      seq=args.seq, ckpt_dir=args.ckpt, ckpt_every=50,
                      log_every=20)
    print(f"\n-- simulated failure at step {half}; relaunching --\n")
    _, more = T.run("smollm-ex", steps=args.steps, batch=args.batch,
                    seq=args.seq, ckpt_dir=args.ckpt, ckpt_every=50,
                    resume=True, log_every=20)
    losses += more
    print(f"\nloss: start {losses[0]:.3f} -> end {losses[-1]:.3f} "
          f"({len(losses)} logged steps, resumed across a failure)")
    assert losses[-1] < losses[0], "training did not make progress"


if __name__ == "__main__":
    main()
