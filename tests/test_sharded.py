"""Sharded-vs-single-device numerical equivalence.

The check needs a fresh jax process with 8 virtual CPU devices (XLA_FLAGS
must be set before jax initialises), so it runs as a subprocess.
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow      # multi-minute compile in a subprocess

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


def test_sharded_equivalence_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "sharded_check.py")],
        capture_output=True, text=True, env=env, timeout=900)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-2000:])
    assert proc.returncode == 0, "sharded equivalence check failed"
