"""Max-flow optimality yardstick: Dinic correctness on known graphs,
construction-order determinism, the named-node FlowGraph wrapper, the
fixed-route and routing-free (disagg) throughput bounds, the attainment
ceiling, and a small end-to-end sanity run asserting the ceiling actually
upper-bounds what a scheduler attains."""
import math

import pytest

from repro.core.maxflow import (Dinic, FlowGraph, attainment_ceiling,
                                disagg_bound, fixed_route_rate)


# ------------------------------------------------------------------- dinic
def test_dinic_classic_graph():
    """CLRS-style 6-node network with known max flow 23."""
    g = Dinic(6)
    s, t = 0, 5
    for u, v, c in [(0, 1, 16), (0, 2, 13), (1, 2, 10), (2, 1, 4),
                    (1, 3, 12), (3, 2, 9), (2, 4, 14), (4, 3, 7),
                    (3, 5, 20), (4, 5, 4)]:
        g.add_edge(u, v, c)
    assert g.max_flow(s, t) == pytest.approx(23.0)


def test_dinic_bottleneck_path_and_disconnected():
    g = Dinic(3)
    g.add_edge(0, 1, 5.0)
    g.add_edge(1, 2, 2.5)
    assert g.max_flow(0, 2) == pytest.approx(2.5)
    h = Dinic(3)
    h.add_edge(0, 1, 5.0)       # no edge into node 2
    assert h.max_flow(0, 2) == 0.0
    assert h.max_flow(0, 0) == math.inf


def test_dinic_float_capacities_and_determinism():
    """Same construction sequence => identical flow value AND identical
    residual state (pure function of insertion order)."""

    def build():
        g = Dinic(4)
        g.add_edge(0, 1, 1.37e9)
        g.add_edge(0, 2, 2.11e9)
        g.add_edge(1, 3, 0.9e9)
        g.add_edge(2, 3, 1.7e9)
        g.add_edge(1, 2, 0.5e9)
        return g

    a, b = build(), build()
    fa, fb = a.max_flow(0, 3), b.max_flow(0, 3)
    assert fa == fb == pytest.approx(0.9e9 + 1.7e9)   # sink-side min-cut
    assert a._cap == b._cap     # bit-identical residuals


def test_dinic_rejects_negative_capacity():
    g = Dinic(2)
    with pytest.raises(ValueError):
        g.add_edge(0, 1, -1.0)


def test_flowgraph_named_nodes():
    g = FlowGraph()
    g.edge("S", "a", 3.0)
    g.edge("S", "b", 2.0)
    g.edge("a", "T", 2.0)
    g.edge("b", "T", 5.0)
    assert g.max_flow() == pytest.approx(4.0)
    assert g.node("S") == 0     # first-mention order


# ------------------------------------------------------------------ bounds
def test_fixed_route_rate_min_over_links():
    caps = [10e9, 10e9, 4e9]
    rate, lid = fixed_route_rate({0: 1e9, 2: 1e9}, caps)
    assert rate == pytest.approx(4.0) and lid == 2
    rate, lid = fixed_route_rate({}, caps)
    assert rate == math.inf and lid is None
    rate, lid = fixed_route_rate({1: 0.0}, caps)   # zero demand: unconstrained
    assert rate == math.inf and lid is None


def test_disagg_bound_compute_vs_network_limits():
    # network effectively infinite: bound = total compute
    r = disagg_bound(unit_rates=[5.0, 5.0], unit_out_caps=[1e12, 1e12],
                     out_bytes=1e3, decode_in_caps=[1e12], in_bytes=1e3)
    assert r == pytest.approx(10.0)
    # one unit NIC-starved: its contribution clips to cap/bytes
    r = disagg_bound(unit_rates=[5.0, 5.0], unit_out_caps=[2e3, 1e12],
                     out_bytes=1e3, decode_in_caps=[1e12], in_bytes=1e3)
    assert r == pytest.approx(2.0 + 5.0)
    # aggregate decode ingress is the min-cut
    r = disagg_bound(unit_rates=[5.0, 5.0], unit_out_caps=[1e12, 1e12],
                     out_bytes=1e3, decode_in_caps=[3e3, 3e3], in_bytes=2e3)
    assert r == pytest.approx(3.0)
    # zero byte demand: purely compute-bound
    r = disagg_bound(unit_rates=[4.0], unit_out_caps=[1.0], out_bytes=0.0,
                     decode_in_caps=[1.0], in_bytes=0.0)
    assert r == pytest.approx(4.0)


def test_disagg_bound_mixes_resources_in_one_cut():
    """The min-cut may take one unit's compute edge and another's NIC edge
    — strictly tighter than min(total compute, total network)."""
    r = disagg_bound(unit_rates=[1.0, 10.0], unit_out_caps=[1e12, 3e3],
                     out_bytes=1e3, decode_in_caps=[1e12], in_bytes=1e3)
    assert r == pytest.approx(1.0 + 3.0)
    total_compute = 11.0
    total_net = (1e12 + 3e3) / 1e3
    assert r < min(total_compute, total_net)


def test_attainment_ceiling():
    assert attainment_ceiling(10.0, 20.0) == 1.0
    assert attainment_ceiling(20.0, 10.0) == pytest.approx(0.5)
    assert attainment_ceiling(20.0, 10.0, feasible_frac=0.8) \
        == pytest.approx(0.4)
    assert attainment_ceiling(0.0, 5.0, feasible_frac=0.7) == 0.7
    assert attainment_ceiling(5.0, math.inf) == 1.0


# ----------------------------------------------- ceiling >= attained (e2e)
@pytest.mark.slow
def test_ceiling_upper_bounds_attained_on_a_small_sim():
    """Tiny overload run: the routing-free bound applied through
    ``attainment_ceiling`` must sit at or above what every policy attains
    (the whole point of a yardstick)."""
    from repro.core import make_policy
    from repro.simcluster.papermodels import PAPER_MODELS
    from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
    from repro.simcluster.trace import WORKLOADS, generate_trace
    import numpy as np

    rate = 40.0
    spec = ClusterSpec(model=PAPER_MODELS["mixtral-8x7b"],
                       par=ParallelismSpec(mode="ep", ep=8), n_units=2)
    trace = generate_trace(WORKLOADS["qwen-conv"], 80, rps=rate, seed=0,
                           warmup=8)
    sim = ClusterSim(spec, make_policy("mfs"))
    items = sim.build_items(trace)
    # compute-side throughput: units / mean single-request prefill time
    comp = [sim.profile.group_compute_time([it], g)
            for it in items for g in range(len(sim.profile.plan))]
    per_req = sum(comp) / len(items)
    unit_rate = 1.0 / per_req
    r_star = disagg_bound(
        unit_rates=[unit_rate] * spec.n_units,
        unit_out_caps=[spec.par.gpus * spec.hw.nic_bw] * spec.n_units,
        out_bytes=1.0, decode_in_caps=[1e18], in_bytes=1.0)
    # deadlines materialize at arrival time; rebuild them from the
    # calibrated fixed-mode base exactly as _on_arrival does
    base = sim.runtime._slo_base
    feas = float(np.mean([
        sim.profile.ideal_ttft(it)
        <= (it.slo_scale if it.slo_scale > 0 else spec.slo_scale) * base
        + 1e-9 for it in items]))
    ceiling = attainment_ceiling(rate, r_star, feas)
    for pol in ("fs", "sjf", "edf", "mfs"):
        m = ClusterSim(spec, make_policy(pol)).run(trace)
        assert m.slo_attainment() <= ceiling + 1e-9
