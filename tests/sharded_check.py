"""Numerical sharded-vs-single-device equivalence (run as a SUBPROCESS by
test_sharded.py — needs its own jax process to pin 8 virtual devices).

Checks, on a (2 data x 4 model) CPU mesh:
  * dense GQA (smollm):   loss + prefill logits match unsharded
  * MoE classic EP:       dispatch/combine all_to_all path matches local
  * MoE 2D EP:            combined ("data","model") dispatch matches local
  * MoE decode:           psum-over-EP-axes path matches local
  * MLA (dsv3 smoke):     loss matches
Exit code 0 = all pass.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import sys

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import SMOKES
from repro.models.lm import build_model
from repro.models.sharding import ShardCtx

TOL = 3e-2          # bf16 params; collective reductions reorder sums


def _check(name, a, b, tol=TOL):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    scale = max(1e-6, float(np.max(np.abs(a))))
    err = float(np.max(np.abs(a - b))) / scale
    status = "OK " if err < tol else "FAIL"
    print(f"{status} {name:42s} rel_err={err:.2e}")
    return err < tol


def main() -> int:
    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    ok = True

    # ---------------- dense GQA ----------------
    cfg = SMOKES["smollm-360m"]
    ref_model = build_model(cfg, ShardCtx())
    params = ref_model.init(key)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    want_loss = ref_model.loss(params, batch)
    want_logits, _ = ref_model.prefill(params, {"tokens": toks})

    sh_model = build_model(cfg, ShardCtx(mesh=mesh))
    got_loss = jax.jit(sh_model.loss)(params, batch)
    got_logits, _ = jax.jit(sh_model.prefill)(params, {"tokens": toks})
    ok &= _check("dense loss (2x4 mesh)", want_loss, got_loss)
    ok &= _check("dense prefill logits", want_logits, got_logits)

    # ---------------- MoE: classic EP over ("model",) ----------------
    cfg = SMOKES["deepseek-moe-16b"]
    ref_model = build_model(cfg, ShardCtx())
    params = ref_model.init(key)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    want = ref_model.loss(params, batch)
    ep_model = build_model(cfg, ShardCtx(mesh=mesh, ep_axes=("model",)))
    got = jax.jit(ep_model.loss)(params, batch)
    ok &= _check("MoE classic EP loss (a2a over model)", want, got,
                 tol=6e-2)   # capacity-dropped tokens may differ slightly

    # ---------------- MoE: 2D EP over ("data","model") ----------------
    ep2_model = build_model(cfg, ShardCtx(mesh=mesh,
                                          ep_axes=("data", "model")))
    got2 = jax.jit(ep2_model.loss)(params, batch)
    ok &= _check("MoE 2D EP loss (a2a over data+model)", want, got2,
                 tol=6e-2)

    # ---------------- MoE decode: psum path ----------------
    _, cache = ref_model.prefill(params, {"tokens": toks})
    tok = toks[:, :1]
    want_d, _ = ref_model.decode_step(params, _grow(cache), tok, 16)
    got_d, _ = jax.jit(ep_model.decode_step)(params, _grow(cache), tok, 16)
    ok &= _check("MoE decode (psum over EP axes)", want_d, got_d)

    # ---------------- MLA (dsv3 smoke) ----------------
    cfg = SMOKES["deepseek-v3-671b"]
    ref_model = build_model(cfg, ShardCtx())
    params = ref_model.init(key)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    batch = {"tokens": toks, "labels": toks, "labels2": toks}
    want = ref_model.loss(params, batch)
    sh_model = build_model(cfg, ShardCtx(mesh=mesh))
    got = jax.jit(sh_model.loss)(params, batch)
    ok &= _check("MLA + MoE + MTP loss", want, got, tol=6e-2)

    return 0 if ok else 1


def _grow(cache):
    def f(path, leaf):
        name = str(getattr(path[-1], "key", ""))
        if name in ("k", "v", "c", "kr"):
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, 8)
            return jnp.pad(leaf, pad)
        return leaf
    return jax.tree_util.tree_map_with_path(f, cache)


if __name__ == "__main__":
    sys.exit(main())
