"""Router + admission-control plane: registry resolution, per-policy
placement semantics, overload-detector hysteresis, admission shedding /
deferral (store pins and decode slots must be released), bit-identity of
the default ``kv_affinity`` policy against the historical routing rule
(store on and off), and sim<->serve routing-decision parity per policy."""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import make_policy
from repro.core.kvstore import KVStoreSpec, TierSpec
from repro.core.router import (AdmissionController, AdmissionSpec,
                               KVAffinityRouter, LaxityDebtDetector,
                               LeastBacklogRouter, OverloadDetector,
                               QueueDepthDetector, RoundRobinRouter,
                               RouterPolicy, RouterSpec,
                               SessionAffinityRouter, kv_affinity_score,
                               make_detector, make_router, register_router,
                               _ROUTERS)
from repro.simcluster.papermodels import PAPER_MODELS
from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
from repro.simcluster.trace import WORKLOADS, generate_trace


# ------------------------------------------------------------- test fixtures
class _FakeView:
    """Minimal RoutingView stand-in for policy/detector unit tests."""

    def __init__(self, backlogs=(0.0, 0.0), queued=(), now=0.0,
                 queued_item_lists=None):
        self.backlogs = list(backlogs)
        self._queued = list(queued) or [0] * len(self.backlogs)
        self.now = now
        self.kvstore = None
        self._items = queued_item_lists or [[] for _ in self.backlogs]

    @property
    def n_units(self):
        return len(self.backlogs)

    def queued(self, unit):
        return self._queued[unit]

    def queued_items(self, unit):
        return iter(self._items[unit])

    def total_queued(self):
        return sum(self._queued)

    def session_key(self, item):
        pid = getattr(item.payload, "prefix_id", None)
        if pid is not None:
            return ("prefix", int(pid))
        return ("rid", int(item.rid))


def _item(rid=0, n_tokens=100, reuse=0, owner=-1, prefix_id=None,
          slo_class="standard", deferrals=0):
    return SimpleNamespace(rid=rid, n_tokens=n_tokens, reuse=reuse,
                           owner_unit=owner, slo_class=slo_class,
                           deferrals=deferrals,
                           payload=SimpleNamespace(prefix_id=prefix_id))


def _spec(**kw):
    kw.setdefault("par", ParallelismSpec(mode="ep", ep=8))
    kw.setdefault("n_units", 2)
    return ClusterSpec(model=PAPER_MODELS["mixtral-8x7b"], **kw)


def _kv_spec(blocks=256, block_tokens=256):
    m = PAPER_MODELS["mixtral-8x7b"]
    bpt = m.kv_bytes_per_token_layer(2, 0) * m.n_layers
    cap = blocks * block_tokens * bpt
    return KVStoreSpec(block_tokens=block_tokens, tiers=(
        TierSpec("hbm", capacity=cap),
        TierSpec("remote", capacity=8 * cap, fetch_bw=12e9, scope="pooled",
                 writeback=True)))


def _record_placements(sim):
    """Wrap the runtime's router so every placement decision is recorded
    as rid -> unit (works for any policy, both hosts)."""
    placed = {}
    orig = sim.runtime.router.place

    def place(item, view):
        u = orig(item, view)
        placed[item.rid] = u
        return u

    sim.runtime.router.place = place
    return placed


# ------------------------------------------------------------------ registry
def test_registry_resolves_all_shipped_policies():
    for name, cls in (("kv_affinity", KVAffinityRouter),
                      ("round_robin", RoundRobinRouter),
                      ("session_affinity", SessionAffinityRouter),
                      ("least_backlog", LeastBacklogRouter)):
        r = make_router(name)
        assert isinstance(r, cls) and r.name == name


def test_registry_unknown_names_raise_with_choices():
    with pytest.raises(KeyError, match="unknown router policy 'nope'"):
        make_router("nope")
    with pytest.raises(KeyError, match="kv_affinity"):
        make_router("nope")          # message lists the registered names
    with pytest.raises(KeyError, match="unknown overload detector"):
        make_detector("nope")
    with pytest.raises(KeyError, match="queue_depth"):
        make_detector("nope")


def test_register_router_extends_the_registry():
    class PinnedRouter(RouterPolicy):
        name = "pinned-test"

        def place(self, item, view):
            return 0

    try:
        register_router(PinnedRouter)
        assert isinstance(make_router("pinned-test"), PinnedRouter)
        m = ClusterSim(_spec(router=RouterSpec(policy="pinned-test")),
                       make_policy("mfs")).run(
            generate_trace(WORKLOADS["qwen-conv"], 12, rps=8.0, seed=0))
        assert m.summary()["n"] == 12
    finally:
        _ROUTERS.pop("pinned-test", None)


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="session key"):
        SessionAffinityRouter(key="user")
    with pytest.raises(ValueError, match="signal"):
        QueueDepthDetector(signal="watts")
    with pytest.raises(ValueError, match="scope"):
        QueueDepthDetector(scope="rack")
    with pytest.raises(ValueError, match="low <= high"):
        QueueDepthDetector(high=4, low=8)
    with pytest.raises(ValueError, match="admission mode"):
        AdmissionSpec(mode="drop")
    assert isinstance(RouterSpec().build(), KVAffinityRouter)
    assert RouterSpec().build_admission() is None


# ---------------------------------------------------- per-policy placement
def test_round_robin_cycles_and_resets():
    r = make_router("round_robin")
    v = _FakeView(backlogs=[0.0, 0.0, 0.0])
    assert [r.place(_item(rid=i), v) for i in range(7)] \
        == [0, 1, 2, 0, 1, 2, 0]
    r.reset()
    assert r.place(_item(), v) == 0


def test_least_backlog_is_argmin_with_lowest_id_tiebreak():
    r = make_router("least_backlog")
    assert r.place(_item(), _FakeView(backlogs=[30.0, 10.0, 20.0])) == 1
    assert r.place(_item(), _FakeView(backlogs=[5.0, 5.0, 5.0])) == 0


def test_kv_affinity_weighs_reuse_against_backlog():
    r = make_router("kv_affinity")
    # 2:1 weighting: 40 reusable tokens on unit 1 outweigh a 50-token
    # backlog deficit (80 - 50 > 0 - 0 is false -> strict compare keeps 1)
    assert r.place(_item(reuse=40, owner=1),
                   _FakeView(backlogs=[0.0, 50.0])) == 1
    # ... but not a 100-token one
    assert r.place(_item(reuse=40, owner=1),
                   _FakeView(backlogs=[0.0, 100.0])) == 0
    # no owner (serving-path miss): no unit gets credit -> least backlog
    assert r.place(_item(reuse=40, owner=-1),
                   _FakeView(backlogs=[9.0, 2.0])) == 1
    # exact tie keeps the lowest unit (strict > in the scan)
    assert r.place(_item(), _FakeView(backlogs=[7.0, 7.0])) == 0
    assert kv_affinity_score(40, 50.0) == pytest.approx(30.0)


def test_session_affinity_is_sticky_and_spreads():
    r = make_router("session_affinity")
    v = _FakeView(backlogs=[0.0] * 4)
    units = [r.place(_item(rid=i), v) for i in range(64)]
    # same session key -> same unit, across calls and instances
    assert units == [make_router("session_affinity").place(_item(rid=i), v)
                     for i in range(64)]
    assert len(set(units)) >= 3          # rendezvous spreads sessions
    # backlog-blind: placement ignores load entirely
    assert r.place(_item(rid=7), _FakeView(backlogs=[1e9, 1e9, 1e9, 1e9])) \
        == units[7]


def test_session_affinity_prefix_key_colocates_lineages():
    r = make_router("session_affinity", key="prefix")
    v = _FakeView(backlogs=[0.0] * 4)
    a = [r.place(_item(rid=i, prefix_id=11), v) for i in range(8)]
    assert len(set(a)) == 1              # one lineage -> one unit
    b = {r.place(_item(rid=i, prefix_id=i), v) for i in range(32)}
    assert len(b) >= 3                   # distinct lineages spread


# ------------------------------------------------------- overload detectors
def test_queue_depth_detector_hysteresis_trip_and_recover():
    d = QueueDepthDetector(high=10, low=4)
    seq = [3, 9, 10, 7, 5, 4, 2, 10]
    got = []
    for q in seq:
        got.append(d.update(_FakeView(queued=[q], backlogs=[0.0]), 0))
    #          3      9      10    7     5     4      2      10
    assert got == [False, False, True, True, True, False, False, True]
    assert d.n_trips == 2
    d.reset()
    assert not d.tripped and d.n_trips == 0


def test_queue_depth_detector_scopes_and_signals():
    v = _FakeView(backlogs=[100.0, 300.0], queued=[2, 6])
    assert QueueDepthDetector(signal="requests",
                              scope="cluster").signal(v, 0) == 8
    assert QueueDepthDetector(signal="requests",
                              scope="unit").signal(v, 1) == 6
    assert QueueDepthDetector(signal="tokens",
                              scope="cluster").signal(v, 0) == 400.0
    assert QueueDepthDetector(signal="tokens",
                              scope="unit").signal(v, 0) == 100.0


def test_laxity_debt_detector_sums_already_lost_slack():
    items = [SimpleNamespace(ideal_ttft=1.0, deadline=10.5),   # 0.5 late
             SimpleNamespace(ideal_ttft=0.2, deadline=12.0),   # feasible
             SimpleNamespace(ideal_ttft=2.0, deadline=11.0)]   # 1.0 late
    v = _FakeView(backlogs=[0.0], now=10.0, queued_item_lists=[items])
    assert LaxityDebtDetector().signal(v, 0) == pytest.approx(1.5)
    d = LaxityDebtDetector(high=1.0, low=0.1)
    assert d.update(v, 0) is True        # 1.5 >= high
    v2 = _FakeView(backlogs=[0.0], now=10.0, queued_item_lists=[[]])
    assert d.update(v2, 0) is False      # queue drained -> recovered


def test_admission_controller_defer_then_shed():
    ctl = AdmissionController(AdmissionSpec(
        detector="queue_depth", detector_kw=dict(high=0.0, low=-1.0),
        mode="defer", max_defers=2))
    v = _FakeView(queued=[0], backlogs=[0.0])      # always tripped (v >= 0)
    assert ctl.decide(_item(slo_class="tight"), v, 0) == "admit"
    assert ctl.decide(_item(slo_class="standard"), v, 0) == "admit"
    it = _item(slo_class="loose")
    assert ctl.decide(it, v, 0) == "defer"
    it.deferrals = 2                               # retry budget exhausted
    assert ctl.decide(it, v, 0) == "shed"
    assert ctl.n_deferred == 1 and ctl.n_shed == 1


# ----------------------------------- bit-identity vs. the historical rule
def _legacy_oracle_check(sim):
    """Assert every placement equals a verbatim copy of the pre-plane
    routing loop (2:1 hit-weighted affinity vs. token backlog, strict >,
    ascending scan) evaluated on the same view. Returns a counter."""
    orig = sim.runtime.router.place
    checked = [0]

    def place(item, view):
        if view.kvstore is not None:
            aff = view.kvstore.peek_affinity(
                view.chain_keys(item), max(0, item.n_tokens - 1),
                view.n_units)
        else:
            aff = [item.reuse if u == item.owner_unit else 0
                   for u in range(view.n_units)]
        best, best_score = 0, -float("inf")
        for u in range(view.n_units):
            score = 2.0 * aff[u] - view.backlogs[u]
            if score > best_score:
                best, best_score = u, score
        got = orig(item, view)
        assert got == best, (item.rid, got, best)
        checked[0] += 1
        return got

    sim.runtime.router.place = place
    return checked


@pytest.mark.parametrize("store", [False, True])
def test_default_router_matches_legacy_rule(store):
    trace = generate_trace(WORKLOADS["qwen-agent"], 40, rps=16.0, seed=3)
    spec = _spec(kvstore=_kv_spec() if store else None)
    sim = ClusterSim(spec, make_policy("mfs"))
    checked = _legacy_oracle_check(sim)
    m = sim.run(trace)
    assert checked[0] >= 40 and m.summary()["n"] == 40


@pytest.mark.parametrize("store", [False, True])
def test_explicit_default_spec_is_bit_identical(store):
    """router=None and an explicit default RouterSpec() must produce
    byte-identical runs on a fixed seed, store on and off."""
    kv = _kv_spec() if store else None
    trace = generate_trace(WORKLOADS["qwen-agent"], 32, rps=12.0, seed=1)
    runs = []
    for router in (None, RouterSpec()):
        sim = ClusterSim(_spec(kvstore=kv, router=router),
                         make_policy("mfs"))
        placed = _record_placements(sim)
        m = sim.run(trace)
        runs.append((placed, m))
    (pa, ma), (pb, mb) = runs
    assert pa == pb and len(pa) >= 32
    assert ma.ttft == mb.ttft
    assert ma.summary() == mb.summary()
    assert "n_shed" not in ma.summary()      # admission off: legacy keys only


# ---------------------------------------------------------------- admission
def _admission_spec(**kw):
    kw.setdefault("detector", "queue_depth")
    kw.setdefault("detector_kw", dict(high=0.0, low=-1.0))  # always tripped
    return AdmissionSpec(**kw)


def test_shedding_releases_store_pins_and_decode_slots():
    """Shed requests must hold nothing: KV-store pins taken by the routing
    resolve are dropped, and no decode session is ever admitted for them."""
    from repro.core.decode import DecodePoolSpec, DecodeSpec

    trace = generate_trace(WORKLOADS["qwen-agent"], 48, rps=24.0, seed=2,
                           decode_lens=True,
                           slo_mix={"tight": 0.2, "standard": 0.4,
                                    "loose": 0.4})
    spec = _spec(
        kvstore=_kv_spec(),
        decode=DecodeSpec(pools=(DecodePoolSpec(name="default",
                                                slots_per_ep=8),),
                          mean_out=16),
        router=RouterSpec(admission=_admission_spec()))
    sim = ClusterSim(spec, make_policy("mfs"))
    m = sim.run(trace)

    shed = set(m.shed)
    assert shed and all(c == "loose" for c in m.shed.values())
    served = {r.rid for r in trace} - shed
    assert set(m.ttft) == served             # everyone else still finishes
    assert shed.isdisjoint(m.tpot)           # no decode slot ever held
    assert sim.kvstore.summary()["pinned_blocks"] == 0   # pins released
    assert len(sim.runtime.flows) == 0
    assert m.decode_stats["live_sessions"] == 0
    s = m.summary()
    assert s["n_shed"] == len(shed) and s["n_deferred"] == 0
    # all-arrivals attainment counts shed as misses; admitted-only doesn't
    assert s["slo_attainment"] <= s["admitted_attainment"] + 1e-12
    assert "loose" in s["attainment_by_class"]


def test_defer_retries_on_original_slo_clock_then_serves():
    """A defer-mode controller under a transient queue build-up must retry
    sheddable requests (not reject them) and serve everyone once the
    detector recovers — with deadlines still derived from the original
    arrival, so deferral burns the SLO budget."""
    trace = generate_trace(WORKLOADS["qwen-conv"], 36, rps=96.0, seed=5,
                           slo_mix={"tight": 0.0, "standard": 0.3,
                                    "loose": 0.7})
    adm = AdmissionSpec(detector="queue_depth",
                        detector_kw=dict(high=6, low=2), mode="defer",
                        defer_delay=0.05, max_defers=50)
    base = ClusterSim(_spec(), make_policy("mfs"))
    m0 = base.run(trace)
    sim = ClusterSim(_spec(router=RouterSpec(admission=adm)),
                     make_policy("mfs"))
    m = sim.run(trace)
    assert m.n_deferred > 0
    assert not m.shed and set(m.ttft) == set(m0.ttft)   # everyone served
    # the deferred requests kept their original-arrival deadline budget
    assert m.deadline == m0.deadline
    assert m.summary()["n_deferred"] == m.n_deferred


def test_shedding_protects_admitted_attainment_under_burst():
    """Overload burst: shedding loose traffic must not hurt — and should
    help — the TTFT attainment of what was admitted."""
    from repro.simcluster.trace import ArrivalSpec

    trace = generate_trace(WORKLOADS["qwen-conv"], 72, rps=56.0, seed=7,
                           arrival=ArrivalSpec(process="mmpp",
                                               burst_factor=8.0,
                                               burst_frac=0.15, dwell=2.0),
                           slo_mix={"tight": 0.2, "standard": 0.4,
                                    "loose": 0.4})
    adm = AdmissionSpec(detector="queue_depth",
                        detector_kw=dict(high=10, low=3))
    base = ClusterSim(_spec(), make_policy("mfs")).run(trace)
    ctrl = ClusterSim(_spec(router=RouterSpec(admission=adm)),
                      make_policy("mfs")).run(trace)
    assert ctrl.shed                     # the burst actually tripped it
    assert ctrl.admitted_attainment() >= base.slo_attainment() - 1e-12


# --------------------------------------------------- sim <-> serve parity
@pytest.mark.parametrize("policy,params", [
    ("kv_affinity", {}),
    ("round_robin", {}),
    ("least_backlog", {}),
    ("session_affinity", {"key": "rid"}),
])
def test_sim_and_serve_place_identically(policy, params):
    """Matched 2-unit configs + matched disjoint-prefix request streams:
    every policy must pick the same unit for the same rid on both hosts
    (the routing decision lives in the shared runtime, keyed only on
    host-parity-exact state)."""
    import jax
    from repro.configs import SMOKES
    from repro.models.lm import build_model
    from repro.serving import DisaggConfig, DisaggServer, ServeRequest
    from repro.simcluster.hw import A100
    from repro.simcluster.trace import Request

    cfg = SMOKES["smollm-360m"]
    model = build_model(cfg)
    params_model = model.init(jax.random.PRNGKey(0))
    rspec = RouterSpec(policy=policy, params=params)

    rng = np.random.default_rng(0)
    lens = [40, 28, 36, 24, 32]
    arrivals = [0.0, 0.01, 0.02, 0.03, 0.04]

    srv = DisaggServer(model, params_model, cfg=DisaggConfig(
        n_prefill_units=2, gpus_per_unit=1, layer_groups=2, hw=A100,
        n_pages=256, router=rspec))
    res = srv.serve([ServeRequest(rid=i, arrival=t,
                                  tokens=rng.integers(0, cfg.vocab,
                                                      size=(n,)),
                                  max_new=1)
                     for i, (t, n) in enumerate(zip(arrivals, lens))])
    serve_units = {r.rid: r.unit for r in res}

    sim = ClusterSim(ClusterSpec(
        model=cfg, par=ParallelismSpec(mode="ep", ep=1), n_units=2,
        gpus_per_server=1, layer_groups=2, slo_mode="per-request", hw=A100,
        router=rspec), make_policy("mfs"))
    placed = _record_placements(sim)
    # disjoint prefixes + reuse_len=0 -> the same no-affinity routing state
    # the serving path's cold PrefixIndex produces
    sim.run([Request(rid=i, arrival=t, prompt_len=n, reuse_len=0,
                     prefix_id=1000 + i)
             for i, (t, n) in enumerate(zip(arrivals, lens))])

    assert placed == serve_units
    if policy == "round_robin":
        assert [serve_units[i] for i in range(5)] == [0, 1, 0, 1, 0]
