"""Serving substrate tests: paged KV store + prefix index, continuous
batching decode, and the end-to-end disaggregated orchestrator."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import SMOKES
from repro.core import make_policy
from repro.models.lm import build_model
from repro.serving import (DecodeBatch, DisaggConfig, DisaggServer,
                           PagedStore, PrefixIndex, ServeRequest,
                           ServingEngine, cache_has_state)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smollm():
    cfg = SMOKES["smollm-360m"]
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


# ------------------------------------------------------------------ paged KV
def test_paged_roundtrip(smollm):
    cfg, model, params = smollm
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, 24)), jnp.int32)
    _, cache = model.prefill(params, {"tokens": toks})
    store = PagedStore(page_size=8, n_pages=32)
    pages = store.put(cache, 24)
    assert len(pages) == 3
    got = store.gather(pages, 24)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_paged_refcounting(smollm):
    cfg, model, params = smollm
    rng = np.random.default_rng(1)
    store = PagedStore(page_size=8, n_pages=8)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, 16)), jnp.int32)
    _, cache = model.prefill(params, {"tokens": toks})
    pages = store.put(cache, 16)
    free0 = store.alloc.n_free
    store.retain(pages)
    store.release(pages)
    assert store.alloc.n_free == free0         # still held by first ref
    store.release(pages)
    assert store.alloc.n_free == free0 + len(pages)


def test_prefix_index_page_aligned_match(smollm):
    cfg, model, params = smollm
    rng = np.random.default_rng(2)
    store = PagedStore(page_size=8, n_pages=64)
    index = PrefixIndex(store)
    base = rng.integers(0, cfg.vocab, size=(24,))
    _, cache = model.prefill(
        params, {"tokens": jnp.asarray(base[None], jnp.int32)})
    pages = store.put(cache, 24)
    index.insert_paged(base, pages, owner_unit=0, per_token_bytes=100.0)
    # same 24-token prefix, new suffix -> matches the full 24 (3 pages)
    query = np.concatenate([base, rng.integers(0, cfg.vocab, size=(10,))])
    e = index.match(query)
    assert e is not None and e.n_tokens == 24
    # diverges inside page 2 -> only the first 8-token page matches
    query2 = base.copy()
    query2[9] = (query2[9] + 1) % cfg.vocab
    e2 = index.match(query2)
    assert e2 is not None and e2.n_tokens == 8
    # completely different -> no match
    assert index.match(rng.integers(0, cfg.vocab, size=(24,))) is None


def test_snapshot_regime_for_ssm():
    cfg = SMOKES["mamba2-1.3b"]
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab, size=(20,))
    _, cache = model.prefill(
        params, {"tokens": jnp.asarray(toks[None], jnp.int32)})
    assert cache_has_state(cache)
    store = PagedStore(page_size=8, n_pages=8)
    index = PrefixIndex(store)
    index.insert_snapshot(toks, cache, owner_unit=1)
    q = np.concatenate([toks, rng.integers(0, cfg.vocab, size=(5,))])
    e = index.match(q)
    assert e is not None and e.n_tokens == 20 and e.owner_unit == 1
    got = index.fetch(e)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- continuous batching
@pytest.mark.parametrize("arch", ["smollm-360m", "recurrentgemma-9b",
                                  "deepseek-moe-16b"])
@pytest.mark.slow
def test_decode_batch_matches_single_sequence(arch):
    """Slotted batched decode produces the same greedy tokens as prefilling
    the whole continuation (teacher-forced check)."""
    cfg = SMOKES[arch]
    model = build_model(cfg)
    params = model.init(KEY)
    eng = ServingEngine(model, params)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, size=(n,)) for n in (12, 19)]
    db = DecodeBatch(model, params, capacity=64, max_slots=4)
    first, caches = {}, {}
    for rid, p in enumerate(prompts):
        first[rid], caches[rid], _ = eng.prefill(p)
        db.add(rid, caches[rid], len(p), first[rid], max_new=3)
    batched = {rid: [first[rid]] for rid in first}
    while db.n_active:
        for rid, t in db.step().items():
            batched[rid].append(t)
    # reference: greedy continuation via teacher-forced full prefill
    for rid, p in enumerate(prompts):
        seq = list(p)
        want = []
        for _ in range(3):
            lg, _ = model.prefill(
                params, {"tokens": jnp.asarray(np.asarray(seq)[None],
                                               jnp.int32)})
            t = int(jnp.argmax(lg[0, -1]))
            want.append(t)
            seq.append(t)
        assert batched[rid][:3] == want, (arch, rid, batched[rid], want)


def test_decode_batch_slot_recycling(smollm):
    cfg, model, params = smollm
    eng = ServingEngine(model, params)
    db = DecodeBatch(model, params, capacity=32, max_slots=2)
    rng = np.random.default_rng(5)
    for rid in range(4):                       # 4 requests through 2 slots
        p = rng.integers(0, cfg.vocab, size=(8 + rid,))
        t, c, _ = eng.prefill(p)
        db.add(rid, c, len(p), t, max_new=2)
        while db.n_active == db.max_slots:
            db.step()
    while db.n_active:
        db.step()
    assert len(db._free) == db.max_slots


# ------------------------------------------------------------- orchestrator
def test_disagg_server_end_to_end(smollm):
    cfg, model, params = smollm
    rng = np.random.default_rng(6)
    shared = rng.integers(0, cfg.vocab, size=(32,))
    reqs = []
    for i in range(6):
        if i % 2 == 0:
            toks = np.concatenate(
                [shared, rng.integers(0, cfg.vocab, size=(10,))])
        else:
            toks = rng.integers(0, cfg.vocab, size=(40,))
        reqs.append(ServeRequest(rid=i, arrival=i * 1e-4, tokens=toks,
                                 max_new=3))
    srv = DisaggServer(model, params,
                       cfg=DisaggConfig(n_prefill_units=2, n_pages=128))
    res = srv.serve(reqs)
    assert len(res) == 6
    assert all(r.ttft > 0 for r in res)
    assert all(len(r.tokens) >= 1 for r in res)
    # prefix reuse kicked in for the later shared-prefix requests
    assert any(r.reused_tokens >= 32 for r in res)
    # determinism of the data plane: same tokens => same first token for the
    # two requests that share the full input... (rid0 vs rid2 share only the
    # prefix, so just check reuse didn't corrupt outputs: finite + in-vocab)
    assert all(0 <= t < cfg.vocab for r in res for t in r.tokens)


def test_disagg_reuse_is_exact(smollm):
    """A request served via Stage-1 reuse produces the same first token as
    the identical request served cold."""
    cfg, model, params = smollm
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab, size=(32,))
    sfx = rng.integers(0, cfg.vocab, size=(8,))
    toks = np.concatenate([shared, sfx])
    cold = DisaggServer(model, params,
                        cfg=DisaggConfig(n_prefill_units=1, n_pages=64))
    r_cold = cold.serve([ServeRequest(0, 0.0, toks, max_new=1)])[0]
    warm = DisaggServer(model, params,
                        cfg=DisaggConfig(n_prefill_units=1, n_pages=64))
    warm.serve([ServeRequest(0, 0.0, np.concatenate(
        [shared, rng.integers(0, cfg.vocab, size=(6,))]), max_new=1)])
    r_warm = warm.serve([ServeRequest(1, 1.0, toks, max_new=1)])[0]
    assert r_warm.reused_tokens >= 32
    assert r_warm.first_token == r_cold.first_token


@pytest.mark.slow
def test_disagg_policies_all_run(smollm):
    cfg, model, params = smollm
    rng = np.random.default_rng(8)
    reqs = [ServeRequest(i, i * 1e-4,
                         rng.integers(0, cfg.vocab, size=(24,)), max_new=1)
            for i in range(4)]
    for pol in ("mfs", "fs", "sjf", "edf", "karuna"):
        srv = DisaggServer(model, params, policy=make_policy(pol),
                           cfg=DisaggConfig(n_prefill_units=2))
        res = srv.serve(reqs)
        assert len(res) == 4


def test_gather_slice_stitches_to_full_gather(smollm):
    """Chunk-sliced materialisation (chunked prefill's data-plane mirror):
    concatenating token slices along the token axis must reproduce the
    monolithic gather exactly, including page-misaligned slice bounds."""
    cfg, model, params = smollm
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, 29)), jnp.int32)
    _, cache = model.prefill(params, {"tokens": toks})
    store = PagedStore(page_size=8, n_pages=32)
    pages = store.put(cache, 29)
    full = store.gather(pages, 29)
    for bounds in ([0, 13, 29], [0, 8, 16, 29], [0, 29]):
        slices = [store.gather_slice(pages, a, b)
                  for a, b in zip(bounds, bounds[1:])]
        got = slices[0] if len(slices) == 1 else jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=2), *slices)
        for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        store.gather_slice(pages, 5, 5)


def test_chunked_disagg_reuse_is_exact(smollm):
    """Chunked prefill on the serve path: reuse results must stay exactly
    equal to a cold run — the sliced prefix materialisation feeds the real
    engine the same pages."""
    from repro.core.stages import ChunkSpec
    from repro.simcluster.hw import A100

    cfg, model, params = smollm
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab, size=(24,))
    suffix = rng.integers(0, cfg.vocab, size=(9,))
    full = np.concatenate([prefix, suffix])

    cold = DisaggServer(model, params, cfg=DisaggConfig(
        n_prefill_units=1, gpus_per_unit=1, layer_groups=2, hw=A100,
        n_pages=64, page_size=8))
    want = cold.serve([ServeRequest(rid=0, arrival=0.0, tokens=full,
                                    max_new=1)])[0]

    srv = DisaggServer(model, params, cfg=DisaggConfig(
        n_prefill_units=1, gpus_per_unit=1, layer_groups=2, hw=A100,
        n_pages=64, page_size=8,
        chunk=ChunkSpec(chunk_tokens=8)))
    res = srv.serve([
        ServeRequest(rid=0, arrival=0.0, tokens=prefix, max_new=1),
        ServeRequest(rid=1, arrival=0.05, tokens=full, max_new=1),
    ])
    assert res[1].reused_tokens == 24          # page-aligned prefix hit
    assert res[1].first_token == want.first_token
