"""Trace-generator properties: arrival-process shapes (Poisson / Gamma /
MMPP) at a fixed mean rate, multi-tenant SLO class mixes, and seeded
reproducibility — the knobs behind the paper-scale bursty sweeps."""
import numpy as np
import pytest

from repro.simcluster.trace import (ArrivalSpec, SLO_CLASSES, WORKLOADS,
                                    generate_trace)

SPEC = WORKLOADS["qwen-conv"]


def _arrivals(tr):
    return np.array([r.arrival for r in tr])


@pytest.mark.parametrize("arrival", [
    None,
    ArrivalSpec(process="poisson"),
    ArrivalSpec(process="gamma", cv=3.0),
    ArrivalSpec(process="mmpp", burst_factor=10.0, burst_frac=0.1),
])
def test_reproducible_under_fixed_seed(arrival):
    a = generate_trace(SPEC, 64, rps=8.0, seed=11, warmup=4, arrival=arrival,
                       slo_mix={"tight": 0.3, "standard": 0.7})
    b = generate_trace(SPEC, 64, rps=8.0, seed=11, warmup=4, arrival=arrival,
                       slo_mix={"tight": 0.3, "standard": 0.7})
    assert [(r.rid, r.arrival, r.prompt_len, r.reuse_len, r.prefix_id,
             r.slo_class, r.slo_scale) for r in a] == \
           [(r.rid, r.arrival, r.prompt_len, r.reuse_len, r.prefix_id,
             r.slo_class, r.slo_scale) for r in b]


def test_default_is_poisson_and_backcompat():
    """No arrival spec == Poisson, with per-request SLO deferred to the
    cluster default (slo_scale 0)."""
    explicit = generate_trace(SPEC, 32, rps=4.0, seed=5,
                              arrival=ArrivalSpec(process="poisson"))
    default = generate_trace(SPEC, 32, rps=4.0, seed=5)
    assert _arrivals(explicit).tolist() == _arrivals(default).tolist()
    assert all(r.slo_scale == 0.0 and r.slo_class == "standard"
               for r in default)


@pytest.mark.parametrize("proc,kw", [
    ("poisson", {}),
    ("gamma", {"cv": 2.5}),
    ("mmpp", {"burst_factor": 8.0, "burst_frac": 0.1}),
])
def test_mean_rate_is_preserved(proc, kw):
    """Burstiness is a shape change only: the long-run rate stays ``rps``
    so attainment-vs-rate curves remain comparable across processes."""
    tr = generate_trace(SPEC, 20_000, rps=8.0, seed=0,
                        arrival=ArrivalSpec(process=proc, **kw))
    arr = _arrivals(tr)
    assert len(arr) / arr[-1] == pytest.approx(8.0, rel=0.08)
    assert np.all(np.diff(arr) >= 0)


def test_gamma_cv_is_honored():
    tr = generate_trace(SPEC, 20_000, rps=8.0, seed=0,
                        arrival=ArrivalSpec(process="gamma", cv=3.0))
    gaps = np.diff(_arrivals(tr))
    assert gaps.std() / gaps.mean() == pytest.approx(3.0, rel=0.1)


def test_mmpp_is_burstier_than_poisson():
    """MMPP concentrates arrivals: the busiest 1-second windows hold far
    more requests than under Poisson at the same mean rate."""
    def peak_window(tr):
        arr = _arrivals(tr)
        counts = np.histogram(arr, bins=np.arange(0, arr[-1] + 1.0))[0]
        return counts.max()
    poisson = generate_trace(SPEC, 5000, rps=8.0, seed=0)
    mmpp = generate_trace(SPEC, 5000, rps=8.0, seed=0,
                          arrival=ArrivalSpec(process="mmpp",
                                              burst_factor=10.0,
                                              burst_frac=0.05))
    assert peak_window(mmpp) > 1.5 * peak_window(poisson)


def test_slo_mix_is_honored():
    mix = {"tight": 0.2, "standard": 0.5, "loose": 0.3}
    tr = generate_trace(SPEC, 10_000, rps=8.0, seed=2, slo_mix=mix)
    frac = {c: sum(1 for r in tr if r.slo_class == c) / len(tr) for c in mix}
    for c, p in mix.items():
        assert frac[c] == pytest.approx(p, abs=0.02)
    for r in tr:
        assert r.slo_scale == SLO_CLASSES[r.slo_class]


def test_invalid_inputs_raise():
    with pytest.raises(ValueError):
        generate_trace(SPEC, 8, rps=1.0,
                       arrival=ArrivalSpec(process="weibull"))
    with pytest.raises(ValueError):
        generate_trace(SPEC, 8, rps=1.0, slo_mix={"gold": 1.0})
