"""KV-reuse plane: tiered-store accounting (insert/evict/capacity, LRU,
pins), partial-chain hits, the prompt-minus-one hit bound, WB deadline
derivation + MFS band rules, cache-aware routing, capacity-responsive hit
rates, and sim<->serve multi-source Stage-1 parity."""
import numpy as np
import pytest

from repro.core import Stage, make_policy
from repro.core.arbiter import MFSScheduler
from repro.core.kvstore import (HitPlan, KVStore, KVStoreSpec, TierSpec,
                                chain_keys, content_chain, kv_route)
from repro.core.msflow import Flow, new_flow_id
from repro.simcluster.papermodels import PAPER_MODELS
from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
from repro.simcluster.trace import (Request, WORKLOADS, WorkloadSpec,
                                    generate_trace, prefix_chain)

BT = 16           # block_tokens used by the unit tests
BB = float(BT)    # block bytes at bytes_per_token=1.0


def _store(hbm_blocks=4, dram_blocks=0, remote_blocks=8, **kw):
    tiers = [TierSpec("hbm", capacity=hbm_blocks * BB)]
    if dram_blocks:
        tiers.append(TierSpec("dram", capacity=dram_blocks * BB,
                              fetch_bw=4.0, writeback=True))
    if remote_blocks:
        tiers.append(TierSpec("remote", capacity=remote_blocks * BB,
                              fetch_bw=2.0, scope="pooled", writeback=True))
    spec = KVStoreSpec(block_tokens=BT, tiers=tuple(tiers), **kw)
    return KVStore(spec, bytes_per_token=1.0,
                   unit_eps=[[0], [1]], store_eps=[4], nic_bw=8.0)


def _admit(store, rid, unit, keys, now=0.0, finish_wb=True):
    """Resolve + admit one synthetic request; optionally land its WBs."""
    class _Item:
        pass
    it = _Item()
    it.rid, it.unit = rid, unit
    store.resolve(keys, 10 ** 9, unit, rid)
    flows = store.admit(it, now)
    if finish_wb:
        for f in flows:
            store.on_wb_done(f)
    return flows


# ----------------------------------------------------------- chain structure
def test_prefix_chain_shares_ancestor_spans():
    spec = WorkloadSpec("t", mean_prompt=4096, reuse_mean=0.5,
                        chain_branch=4, chain_node_tokens=512)
    # prefixes 5 and 6 are siblings under parent 1, under root 0
    a = prefix_chain(5, 2000, spec)
    b = prefix_chain(6, 2000, spec)
    assert a[:2] == b[:2] == ((0, 512), (1, 512))
    assert a[2][0] == 5 and b[2][0] == 6          # leaves diverge
    assert sum(t for _, t in a) == 2000           # leaf takes the remainder
    ka, kb = chain_keys(a, BT), chain_keys(b, BT)
    shared = 2 * (512 // BT)
    assert ka[:shared] == kb[:shared] and ka[shared] != kb[shared]


def test_generated_traces_carry_chains_without_extra_draws():
    base = generate_trace(WORKLOADS["qwen-agent"], 50, rps=10, seed=3)
    again = generate_trace(WORKLOADS["qwen-agent"], 50, rps=10, seed=3)
    assert all(r.prefix_chain for r in base)
    for r, r2 in zip(base, again):
        assert (r.arrival, r.prompt_len, r.reuse_len, r.prefix_chain) == \
            (r2.arrival, r2.prompt_len, r2.reuse_len, r2.prefix_chain)
        assert sum(t for _, t in r.prefix_chain) == r.reuse_len


# ------------------------------------------------------- capacity accounting
def test_insert_evict_capacity_accounting():
    store = _store(hbm_blocks=2, remote_blocks=8)
    keys = chain_keys(((0, 4 * BT),), BT)          # 4 blocks
    _admit(store, 0, 0, keys)
    # origin tier held to capacity: LRU evicted down to 2 blocks
    assert store.resident_bytes("hbm") == 2 * BB
    assert store.stats["evictions"] == 2
    # the pooled tier received every block via writeback
    assert store.resident_bytes("remote") == 4 * BB
    assert store.stats["wb_flows"] == 1 and store.stats["wb_done"] == 1
    # LRU order: the two *youngest* blocks survived in HBM
    assert [store.blocks[k] for k in keys[2:]] == [{(0, 0), (1, -1)}] * 2
    assert all((0, 0) not in store.blocks[k] for k in keys[:2])


def test_pinned_blocks_survive_eviction_pressure():
    store = _store(hbm_blocks=1, remote_blocks=0)
    k1 = chain_keys(((1, BT),), BT)
    k2 = chain_keys(((2, BT),), BT)
    _admit(store, 0, 0, k1)
    plan = store.resolve(k1, 10 ** 9, 0, rid=7)    # pins k1's block for rid 7
    assert plan.tokens == BT
    _admit(store, 1, 0, k2)                        # wants the only HBM slot
    # the pinned block was NOT evicted from under the in-flight fetch
    assert (0, 0) in store.blocks[k1[0]]
    assert store.stats["failed_inserts"] >= 1
    store.release(7)
    _admit(store, 2, 0, k2)                        # now the LRU slot frees
    assert (0, 0) in store.blocks[k2[0]]


# ------------------------------------------------------------- hit resolution
def test_partial_chain_hit_across_tiers_is_multi_source():
    store = _store(hbm_blocks=1, remote_blocks=8)
    keys = chain_keys(((3, 2 * BT),), BT)
    _admit(store, 0, 0, keys)                      # HBM keeps only block 1
    plan = store.resolve(keys, 10 ** 9, 0, rid=1)
    assert plan.tokens == 2 * BT
    assert [(s.tier, s.tokens) for s in plan.segments] == \
        [("remote", BT), ("hbm", BT)]
    # pooled segments fetch from the store endpoints at the tier bandwidth
    assert plan.segments[0].src_eps == (4,)
    assert plan.segments[0].tier_cap == 2.0
    # local HBM segments fetch from the owner unit uncapped
    assert plan.segments[1].src_eps == (0,)
    assert plan.segments[1].tier_cap is None


def test_local_copies_preferred_over_tier_order():
    store = _store(hbm_blocks=4, dram_blocks=4, remote_blocks=8)
    keys = chain_keys(((5, BT),), BT)
    _admit(store, 0, 1, keys)                      # resident on unit 1 + pool
    # unit 1 serves from its own HBM; unit 0 prefers the pooled store over
    # a cross-unit HBM fetch only when ranked worse — locality wins first
    local = store.resolve(keys, 10 ** 9, 1, rid=2)
    assert local.segments[0].tier == "hbm" and local.segments[0].loc == 1
    remote = store.resolve(keys, 10 ** 9, 0, rid=3)
    assert remote.segments[0].loc != 0             # nothing local to unit 0


def test_hit_never_exceeds_prompt_minus_one_suffix_token():
    """Regression: a full store must never return a hit covering the whole
    prompt — at least one suffix token is always computed."""
    store = _store(hbm_blocks=64, remote_blocks=64)
    keys = chain_keys(((6, 8 * BT),), BT)
    _admit(store, 0, 0, keys)                      # everything resident
    for prompt_len in (BT + 1, 2 * BT, 4 * BT + 3, 8 * BT):
        plan = store.resolve(keys, prompt_len - 1, 0, rid=100 + prompt_len)
        assert plan.tokens <= prompt_len - 1
    # serve-path guard sits in the chain itself: 2*BT tokens -> 1 block
    toks = np.arange(2 * BT)
    assert len(content_chain(toks, BT)) == 1


# ---------------------------------------------------------------- writebacks
def test_wb_flow_deadline_derivation_and_shape():
    store = _store(hbm_blocks=8, dram_blocks=8, remote_blocks=8)
    keys = chain_keys(((7, 3 * BT),), BT)
    flows = _admit(store, 5, 1, keys, now=2.0, finish_wb=False)
    assert {f.stage for f in flows} == {Stage.WB}
    by_dst = {f.dst: f for f in flows}
    dram, remote = by_dst[1], by_dst[4]            # local loopback vs pool
    assert dram.src == dram.dst == 1               # host-local writeback
    assert remote.src == 1 and remote.dst == 4
    for f, bw in ((dram, 4.0), (remote, 2.0)):
        assert f.size == 3 * BB and f.tier_cap == bw
        # loose derived deadline: now + scale x tier-bandwidth ideal
        assert f.deadline == pytest.approx(2.0 + 8.0 * f.size / bw)
    # duplicate admission while the WB is in flight emits nothing new
    assert _admit(store, 6, 1, keys, finish_wb=False) == []
    for f in flows:
        store.on_wb_done(f)
    assert store.summary()["pinned_blocks"] == 0


class _ArbView:
    now = 0.0

    def bottleneck(self, flow):
        return 1.0, 0.0

    def mlu_inputs(self, flow, level):
        return 1.0, 0.0

    def l_curr(self, unit):
        return 0

    def computing(self, rid):
        return False

    def red_rank(self, rid):
        return 0

    def downstream_estimate(self, flow):
        return 0.0


def test_wb_band_below_d2d_and_barred_from_level1():
    sched = MFSScheduler()
    view = _ArbView()
    # identical critical-but-feasible urgency: MLU = 100/150 = 0.67 >= U
    mk = lambda stage, rid: Flow(new_flow_id(), rid, 0, stage, 100.0, src=0,
                                 dst=1, target_layer=0, n_layers=4,
                                 deadline=150.0)
    p2d, d2d, wb = mk(Stage.P2D, 0), mk(Stage.D2D, 1), mk(Stage.WB, 2)
    for f in (p2d, d2d, wb):
        sched.on_flow_submitted(f, view)
    sched.assign([p2d, d2d, wb], view, ("tick",))
    assert p2d.level == 1                   # critical reservation (I3)
    assert wb.level >= 2                    # WB never enters level 1
    # band order at equal level: P2D > D2D > WB
    assert (p2d.priority_key[1], d2d.priority_key[1], wb.priority_key[1]) \
        == (1, 2, 3)
    assert p2d.priority_key < d2d.priority_key < wb.priority_key


# ---------------------------------------------------------------- routing
def test_cache_aware_routing_weighs_affinity_against_backlog():
    store = _store(hbm_blocks=8, remote_blocks=8)
    keys = chain_keys(((9, 4 * BT),), BT)
    _admit(store, 0, 1, keys)                      # resident on unit 1
    unit, plan = kv_route(store, keys, 10 ** 9, [0.0, 0.0], rid=1)
    assert unit == 1 and plan.tokens == 4 * BT     # affinity wins ties
    # a deep backlog on the owning unit outweighs the hit affinity
    unit2, plan2 = kv_route(store, keys, 10 ** 9,
                            [0.0, 10 * 4 * BT], rid=2)
    assert unit2 == 0
    assert plan2.tokens == 4 * BT                  # pooled copies still hit
    assert all(s.loc != 0 for s in plan2.segments)


# ----------------------------------------------------------- sim end-to-end
def _kv_cluster(kv, **kw):
    kw.setdefault("par", ParallelismSpec(mode="ep", ep=2))
    kw.setdefault("n_units", 2)
    kw.setdefault("layer_groups", 4)
    return ClusterSpec(model=PAPER_MODELS["mixtral-8x7b"], kvstore=kv, **kw)


def _kv_spec(cap_blocks, bpt, block_tokens=256):
    cap = cap_blocks * block_tokens * bpt
    return KVStoreSpec(block_tokens=block_tokens, tiers=(
        TierSpec("hbm", capacity=cap),
        TierSpec("remote", capacity=8 * cap, fetch_bw=12e9, scope="pooled",
                 writeback=True)))


def test_hit_rate_responds_to_store_capacity():
    trace = generate_trace(WORKLOADS["qwen-agent"], 80, rps=20, seed=1)
    rates = {}
    bpt = PAPER_MODELS["mixtral-8x7b"].kv_bytes_per_token_layer(2, 0) \
        * PAPER_MODELS["mixtral-8x7b"].n_layers
    for label, blocks in (("tiny", 2), ("big", 4096)):
        sim = ClusterSim(_kv_cluster(_kv_spec(blocks, bpt)),
                         make_policy("mfs"))
        m = sim.run(trace)
        rates[label] = m.kv_hit_rate()
        assert len(sim.runtime.flows) == 0         # incl. WB flows drained
        assert sim.kvstore.summary()["pinned_blocks"] == 0
    assert rates["big"] > rates["tiny"]            # capacity-bounded hits
    assert rates["big"] > 0.2


def test_store_off_keeps_legacy_reuse_model():
    """Without a KVStoreSpec the sim must keep the pre-sampled reuse path:
    no store, no WB flows, no kv metrics — the legacy sweep contract."""
    trace = generate_trace(WORKLOADS["qwen-conv"], 30, rps=20, seed=0)
    sim = ClusterSim(_kv_cluster(None), make_policy("mfs"))
    m = sim.run(trace)
    assert sim.kvstore is None
    assert not m.kv_prompt_tokens and "kv_hit_rate" not in m.summary()


# ------------------------------------------------- sim <-> serve S1 parity
def test_sim_and_serve_emit_identical_multisource_s1():
    """Matched configs + a store engineered so the second request's hit
    spans two tiers (HBM evicted the first block, the pooled tier kept it):
    both hosts must emit identical multi-source Stage-1 flow sequences and
    identical WB flows — same sizes, groups, deadlines."""
    import jax
    from repro.configs import SMOKES
    from repro.models.lm import build_model
    from repro.serving import DisaggConfig, DisaggServer, ServeRequest
    from repro.simcluster.hw import A100

    cfg = SMOKES["smollm-360m"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    bpt = sum(cfg.kv_bytes_per_token_layer(2, l) for l in range(cfg.n_layers))
    kv = KVStoreSpec(block_tokens=16, tiers=(
        TierSpec("hbm", capacity=16 * bpt),        # exactly one block
        TierSpec("remote", capacity=1e12, fetch_bw=2e9, scope="pooled",
                 writeback=True)))

    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab, size=(32,))
    sufa = rng.integers(0, cfg.vocab, size=(16,))
    sufb = rng.integers(0, cfg.vocab, size=(12,))

    srv = DisaggServer(model, params, cfg=DisaggConfig(
        n_prefill_units=1, gpus_per_unit=1, layer_groups=2, hw=A100,
        n_pages=128, page_size=16, kvstore=kv))
    srv.runtime.trace_stages = True
    res = srv.serve([
        ServeRequest(rid=0, arrival=0.0,
                     tokens=np.concatenate([prefix, sufa]), max_new=1),
        ServeRequest(rid=1, arrival=0.05,
                     tokens=np.concatenate([prefix, sufb]), max_new=1),
    ])
    assert res[1].reused_tokens == 32              # live multi-tier hit
    assert srv.kvstore.stats["hit_tokens_remote"] == 16
    assert srv.kvstore.stats["hit_tokens_hbm"] == 16

    sim = ClusterSim(ClusterSpec(
        model=cfg, par=ParallelismSpec(mode="ep", ep=1), n_units=1,
        gpus_per_server=1, layer_groups=2, slo_mode="per-request", hw=A100,
        kvstore=kv), make_policy("mfs"))
    sim.runtime.trace_stages = True
    sim.run([
        Request(rid=0, arrival=0.0, prompt_len=48, reuse_len=32,
                prefix_id=7, prefix_chain=((7, 32),)),
        Request(rid=1, arrival=0.05, prompt_len=44, reuse_len=32,
                prefix_id=7, prefix_chain=((7, 32),)),
    ])
    assert sim.kvstore.stats["hit_tokens_remote"] == 16
    assert sim.kvstore.stats["hit_tokens_hbm"] == 16

    def trace_of(log):
        return [(r, stage, group, size, deadline)
                for r, stage, group, size, deadline in log]

    got, want = trace_of(srv.runtime.stage_log), trace_of(sim.runtime.stage_log)
    assert len(got) == len(want) > 0
    # multi-source: request 1 fetches each group from TWO sources, and the
    # WB replication flows appear in the shared log on both hosts
    s1 = [e for e in got if e[0] == 1 and e[1] == Stage.KV_REUSE]
    assert len(s1) == 4                            # 2 segments x 2 groups
    assert {e[1] for e in got} >= {Stage.KV_REUSE, Stage.P2D, Stage.WB}
    for (r_a, s_a, g_a, sz_a, dl_a), (r_b, s_b, g_b, sz_b, dl_b) \
            in zip(got, want):
        assert (r_a, s_a, g_a) == (r_b, s_b, g_b)
        assert sz_a == pytest.approx(sz_b, rel=1e-12)
        if dl_a is None or dl_b is None:
            assert dl_a == dl_b
        else:
            assert dl_a == pytest.approx(dl_b, rel=1e-12)


def test_decode_plane_holds_and_releases_store_pins():
    """With both planes attached, hit pins survive prefill admission (live
    sessions keep their prefix blocks un-evictable) and drain to zero once
    every session finishes or is evicted."""
    from repro.core.decode import DecodePoolSpec, DecodeSpec

    bpt = PAPER_MODELS["mixtral-8x7b"].kv_bytes_per_token_layer(2, 0) \
        * PAPER_MODELS["mixtral-8x7b"].n_layers
    spec = _kv_cluster(_kv_spec(4096, bpt), decode=DecodeSpec(
        pools=(DecodePoolSpec(name="default", slots_per_ep=4),),
        mean_out=32, trigger_delta=2, max_inflight=4, auto_evict=True))
    trace = generate_trace(WORKLOADS["qwen-agent"], 40, rps=20, seed=2,
                           warmup=8, decode_lens=True)
    sim = ClusterSim(spec, make_policy("mfs"))
    m = sim.run(trace)
    assert m.decode_stats["live_sessions"] == 0
    assert len(sim.runtime.flows) == 0
    assert sim.kvstore.summary()["pinned_blocks"] == 0
    assert m.kv_hit_rate() > 0


# ------------------------------------------------- hot-block replication
def test_hot_block_replication_spreads_victim_unit_s1_share():
    """Popularity-driven replication must push hot chain blocks to more
    units' DRAM so Zipf-hot prefixes stop funneling every sibling request's
    Stage-1 fetch through the one victim unit that produced them."""
    trace = generate_trace(WORKLOADS["qwen-agent"], 120, rps=50, seed=4)

    def drive(hot_threshold):
        store = KVStore(
            KVStoreSpec(block_tokens=256, hot_threshold=hot_threshold,
                        hot_copies=3, tiers=(
                            TierSpec("hbm", capacity=1e12),
                            TierSpec("dram", capacity=1e12, fetch_bw=12e9,
                                     writeback=True))),
            bytes_per_token=1e4, unit_eps=[[0], [1], [2], [3]], nic_bw=25e9)
        backlogs = [0.0] * 4
        cross = {u: 0 for u in range(4)}   # fetch tokens sourced from unit u
        for r in trace:
            keys = chain_keys(r.prefix_chain, 256)
            u, plan = kv_route(store, keys, r.prompt_len - 1, backlogs, r.rid)
            for seg in plan.segments:
                if seg.loc != u:            # a real cross-unit S1 fetch
                    cross[seg.loc] += seg.tokens

            class _It:
                pass
            it = _It()
            it.rid, it.unit, it.n_tokens = r.rid, u, r.prompt_len
            pending = store.admit(it, 0.0)
            while pending:                  # land WBs + follow-on pushes
                nxt = []
                for f in pending:
                    nxt.extend(store.on_wb_done(f))
                pending = nxt
            # round-robin the backlog so routing spreads across units
            backlogs[u] += r.prompt_len
            m = min(backlogs)
            backlogs = [b - m for b in backlogs]
        return store, cross

    store_off, cross_off = drive(hot_threshold=0)
    store_on, cross_on = drive(hot_threshold=2)
    assert store_off.stats["hot_push_flows"] == 0
    assert store_on.stats["hot_push_flows"] > 0
    # victim unit = the unit sourcing the most cross-unit fetch tokens in
    # the replication-off run; replication must cut its share
    tot_off = max(sum(cross_off.values()), 1)
    tot_on = max(sum(cross_on.values()), 1)
    victim = max(cross_off, key=cross_off.get)
    share_off = cross_off[victim] / tot_off
    share_on = cross_on[victim] / tot_on
    assert share_on < share_off
    # and overall cross-unit S1 volume drops (more local hits)
    assert sum(cross_on.values()) < sum(cross_off.values())


def test_hot_replication_bounded_by_hot_copies():
    """A hot block is pushed until ``hot_copies`` units hold one locally,
    then the pushing stops (no replication storm)."""
    store = KVStore(
        KVStoreSpec(block_tokens=BT, hot_threshold=1, hot_copies=2, tiers=(
            TierSpec("hbm", capacity=64 * BB),
            TierSpec("dram", capacity=64 * BB, fetch_bw=4.0,
                     writeback=True))),
        bytes_per_token=1.0, unit_eps=[[0], [1], [2]], nic_bw=8.0)
    keys = chain_keys(((0, 2 * BT),), BT)
    _admit(store, 0, 0, keys)                     # cold admission, no pops
    store.resolve(keys, 10 ** 9, 0, 1)            # heat the blocks
    store.release(1)
    flows = _admit(store, 2, 0, keys)             # hot now: push copies
    assert store.stats["hot_push_flows"] > 0
    for k in keys:
        assert len({loc for t, loc in store.blocks[k]
                    if store.spec.tiers[t].scope == "unit"}) == 2
    # already at the copy target: another hot admission pushes nothing
    before = store.stats["hot_push_flows"]
    store.resolve(keys, 10 ** 9, 0, 3)
    store.release(3)
    _admit(store, 4, 0, keys)
    assert store.stats["hot_push_flows"] == before


def test_hot_counter_ewma_decay_halves_per_halflife():
    """With ``hot_halflife`` set, a block's popularity counter is an EWMA:
    one half-life after the last touch its value has halved, so stale hits
    stop counting toward the hot threshold."""
    store = _store(hot_halflife=4.0)
    store._bump_pop("k", 0.0)
    store._bump_pop("k", 0.0)
    assert store._pop_value("k", 0.0) == pytest.approx(2.0)
    assert store._pop_value("k", 4.0) == pytest.approx(1.0)   # one half-life
    assert store._pop_value("k", 12.0) == pytest.approx(0.25)
    # a fresh bump folds the decayed value in, then restarts the clock
    store._bump_pop("k", 4.0)
    assert store._pop_value("k", 4.0) == pytest.approx(2.0)
    assert store._pop_value("k", 8.0) == pytest.approx(1.0)
    assert store._pop_value("missing", 1.0) == 0.0


def test_hot_counter_legacy_raw_counts_at_zero_halflife():
    """``hot_halflife=0`` (the default) keeps the legacy raw counts:
    popularity never decays, bit-identical to pre-EWMA stores."""
    store = _store()                       # default hot_halflife=0.0
    store._bump_pop("k", 0.0)
    store._bump_pop("k", 1.0)
    assert store._pop_value("k", 10_000.0) == pytest.approx(2.0)


def test_ewma_decay_gates_hot_replication():
    """The same two-touch heat that trips replication with raw counts must
    NOT trip it when a long gap decayed the counter below threshold."""
    def drive(hot_halflife, gap):
        store = KVStore(
            KVStoreSpec(block_tokens=BT, hot_threshold=2, hot_copies=2,
                        hot_halflife=hot_halflife, tiers=(
                            TierSpec("hbm", capacity=64 * BB),
                            TierSpec("dram", capacity=64 * BB, fetch_bw=4.0,
                                     writeback=True))),
            bytes_per_token=1.0, unit_eps=[[0], [1], [2]], nic_bw=8.0)
        keys = chain_keys(((0, 2 * BT),), BT)
        _admit(store, 0, 0, keys)                  # cold admission
        store.resolve(keys, 10 ** 9, 0, 1, now=0.0)   # heat: pop -> 1
        store.release(1)
        store.resolve(keys, 10 ** 9, 0, 2, now=0.0)   # heat: pop -> 2
        store.release(2)
        _admit(store, 3, 0, keys, now=gap)         # admit after the gap
        return store.stats["hot_push_flows"]

    assert drive(hot_halflife=0.0, gap=100.0) > 0     # raw counts: still hot
    assert drive(hot_halflife=1.0, gap=100.0) == 0    # decayed: cold again


# ---------------------------------------------- store-aware SLO calibration
def test_steady_state_reuse_replay():
    store = _store(hbm_blocks=4096, remote_blocks=4096)
    a = chain_keys(((0, 4 * BT),), BT)
    b = chain_keys(((0, 4 * BT), (1, 2 * BT)), BT)   # extends a
    exp = store.steady_state_reuse([(a, 10 ** 6), (a, 10 ** 6),
                                    (b, 10 ** 6), (b, 3 * BT + 1)])
    # cold, full hit, partial (a's span only), capped at whole blocks
    assert exp == [0, 4 * BT, 4 * BT, 3 * BT]
    # read-only: live store state untouched
    assert not store.blocks and store.stats["lookups"] == 0


def test_steady_state_reuse_respects_capacity():
    store = _store(hbm_blocks=1, dram_blocks=1, remote_blocks=2)
    # total capacity = (1 + 1) blocks x 2 units + 2 pooled = 6 blocks
    chains = [chain_keys(((n, 4 * BT),), BT) for n in range(3)]
    entries = [(c, 10 ** 6) for c in chains] * 2
    exp = store.steady_state_reuse(entries)
    assert exp[:3] == [0, 0, 0]
    # 12-block working set > 6-block shadow LRU: the second pass cannot
    # fully hit (chain 0 was evicted by the time it repeats)
    assert exp[3] < 4 * BT


def test_fixed_mode_calibration_is_store_aware():
    """With the store attached, the fixed-mode SLO base must come from the
    expected steady-state hit replay — not the trace's pre-sampled
    reuse_len — so store-on vs store-off attainment is comparable."""
    import copy

    bpt = PAPER_MODELS["mixtral-8x7b"].kv_bytes_per_token_layer(2, 0) \
        * PAPER_MODELS["mixtral-8x7b"].n_layers
    trace = generate_trace(WORKLOADS["qwen-agent"], 40, rps=20, seed=5)
    kv = _kv_spec(4096, bpt)
    sim = ClusterSim(_kv_cluster(kv, slo_mode="fixed"), make_policy("fs"))
    sim.run([copy.copy(r) for r in trace])
    base_on = sim.runtime._slo_base

    sim_off = ClusterSim(_kv_cluster(None, slo_mode="fixed"),
                         make_policy("fs"))
    sim_off.run([copy.copy(r) for r in trace])
    base_off = sim_off.runtime._slo_base

    # expected base: replay the chains through a fresh store's shadow LRU
    from repro.core.stages import PrefillItem
    probe = ClusterSim(_kv_cluster(kv, slo_mode="fixed"), make_policy("fs"))
    entries = [(chain_keys(r.prefix_chain, kv.block_tokens),
                r.prompt_len - 1) for r in trace]
    expected = probe.kvstore.steady_state_reuse(entries)
    want = float(np.mean([probe.profile.ideal_ttft(PrefillItem(
        rid=-1, arrival=0.0, n_tokens=r.prompt_len,
        reuse=min(e, r.prompt_len - 1)))
        for r, e in zip(trace, expected)]))
    assert base_on == pytest.approx(want, rel=1e-12)
    # the legacy base assumes the pre-sampled reuse is free; the
    # steady-state base is more conservative (cold starts are real)
    assert base_on != pytest.approx(base_off, rel=1e-6)
    assert base_on > base_off


def test_hot_replication_counts_inflight_pushes_toward_copy_target():
    """A second hot admission while a push is still in flight must not
    overshoot hot_copies: the in-flight copy counts toward the target."""
    store = KVStore(
        KVStoreSpec(block_tokens=BT, hot_threshold=1, hot_copies=2, tiers=(
            TierSpec("hbm", capacity=64 * BB),
            TierSpec("dram", capacity=64 * BB, fetch_bw=4.0,
                     writeback=True))),
        bytes_per_token=1.0, unit_eps=[[0], [1], [2], [3]], nic_bw=8.0)
    keys = chain_keys(((0, 2 * BT),), BT)
    _admit(store, 0, 0, keys)                     # cold admission, no pops
    store.resolve(keys, 10 ** 9, 0, 1)            # heat the blocks
    store.release(1)
    first = _admit(store, 2, 0, keys, finish_wb=False)   # push IN FLIGHT
    pushes = [f for f in first if store._wb[f.fid][1] ==
              store._hot_tier and store._wb[f.fid][2] >= 0]
    assert pushes, "hot push did not fire"
    store.resolve(keys, 10 ** 9, 0, 3)
    store.release(3)
    second = _admit(store, 4, 0, keys, finish_wb=False)  # concurrent hot admit
    assert [f for f in second
            if store._wb.get(f.fid, (None, -1, -1))[1] == store._hot_tier
            and store._wb[f.fid][2] >= 0] == [], \
        "second admission pushed past hot_copies while first was in flight"
    for f in first + second:                      # land everything
        store.on_wb_done(f)
    for k in keys:
        assert len({loc for t, loc in store.blocks[k]
                    if store.spec.tiers[t].scope == "unit"}) == 2
