"""Optional-hypothesis shim shared by the property-test modules.

``pytest.importorskip`` at module scope would kill a whole test module;
this shim keeps unit tests active and degrades each property test to a
clean skip when hypothesis is absent. The stubs swallow the strategy
expressions and replace each test with a zero-argument skipper so pytest
never sees phantom fixture parameters.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _NullStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None
    st = _NullStrategies()
