"""Online monitor plane: monitor-on vs monitor-off bit-identity (the
zero-overhead guard, mirroring the telemetry plane's), quantile-sketch
determinism (order/host independence + bin-tolerance accuracy), rolling
trailing-window semantics, byte-identical trip/recover parity of the
bus-migrated ``queue_depth``/``laxity_debt`` detectors against the legacy
in-detector computation, ProbeFanout single-append stage-log semantics,
live-signal sanity and the ``--progress`` sampling hook."""
import math
from types import SimpleNamespace

import pytest

from repro.core import make_policy
from repro.core.monitor import (FixedBinSketch, Monitor, MonitorSpec,
                                ProbeFanout, RollingWindow, SignalBus)
from repro.core.router import (AdmissionSpec, LaxityDebtDetector,
                               QueueDepthDetector, RouterSpec)
from repro.core.telemetry import TelemetrySpec
from repro.simcluster.papermodels import PAPER_MODELS
from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
from repro.simcluster.trace import WORKLOADS, generate_trace


def _spec(**kw):
    kw.setdefault("par", ParallelismSpec(mode="ep", ep=8))
    kw.setdefault("n_units", 2)
    return ClusterSpec(model=PAPER_MODELS["mixtral-8x7b"], **kw)


def _trace(n=40, rps=10.0, seed=0, workload="qwen-conv", **kw):
    return generate_trace(WORKLOADS[workload], n, rps=rps, seed=seed,
                          warmup=8, **kw)


def _run(spec, policy="mfs", trace=None, seed=0):
    trace = trace if trace is not None else _trace(seed=seed)
    sim = ClusterSim(spec, make_policy(policy), seed=seed)
    m = sim.run(trace)
    return sim, m


# ----------------------------------------------------------- bit-identity
@pytest.mark.parametrize("policy", ["mfs", "sjf"])
def test_monitor_on_vs_off_bit_identical(policy):
    """The monitor is a pure observer: enabling it must not move a single
    float anywhere in the run (exact equality, not approx)."""
    trace = _trace()
    _, m0 = _run(_spec(), policy, trace)
    sim1, m1 = _run(_spec(monitor=MonitorSpec()), policy, trace)
    assert m0.ttft == m1.ttft
    assert m0.deadline == m1.deadline
    assert m0.stall_time == m1.stall_time
    assert m0.summary() == m1.summary()
    assert sim1.monitor is not None and sim1.monitor.n_done == len(m1.ttft)


def test_monitor_plus_telemetry_bit_identical_and_single_stage_log():
    """Telemetry + monitor together (ProbeFanout): still bit-identical, and
    the legacy stage log is appended exactly once per flow — identical rows
    to a telemetry-only run."""
    trace = _trace()
    sim0 = ClusterSim(_spec(telemetry=TelemetrySpec()), make_policy("mfs"))
    sim0.runtime.trace_stages = True
    m0 = sim0.run(trace)
    sim1 = ClusterSim(_spec(telemetry=TelemetrySpec(),
                            monitor=MonitorSpec()), make_policy("mfs"))
    sim1.runtime.trace_stages = True
    m1 = sim1.run(trace)
    assert isinstance(sim1.runtime._probe, ProbeFanout)
    assert m0.ttft == m1.ttft and m0.summary() == m1.summary()
    assert list(sim0.runtime.stage_log) == list(sim1.runtime.stage_log)
    # ...and the monitor saw every one of those submits
    assert sum(sim1.monitor.stage_submitted.values()) \
        == len(sim1.telemetry.flow_spans)


def test_monitor_only_backs_the_stage_log():
    """Monitor without telemetry: trace_stages output must not depend on
    which collector backs the append site."""
    trace = _trace()
    sim0 = ClusterSim(_spec(), make_policy("mfs"))
    sim0.runtime.trace_stages = True
    sim0.run(trace)
    sim1 = ClusterSim(_spec(monitor=MonitorSpec()), make_policy("mfs"))
    sim1.runtime.trace_stages = True
    sim1.run(trace)
    assert list(sim0.runtime.stage_log) == list(sim1.runtime.stage_log)


# ------------------------------------------------------------ the sketch
def test_sketch_is_order_independent_and_host_parity_exact():
    """Same multiset of observations, any order, any instance: identical
    counts and bit-identical quantiles (no RNG, no merge error)."""
    vals = [0.001 * (i % 97 + 1) * (1.7 ** (i % 11)) for i in range(500)]
    a, b = FixedBinSketch(), FixedBinSketch()
    for v in vals:
        a.observe(v)
    for v in reversed(vals):
        b.observe(v)
    assert a.counts == b.counts and a.n == b.n == len(vals)
    for q in (0.0, 0.5, 0.9, 0.99, 1.0):
        qa, qb = a.quantile(q), b.quantile(q)
        assert qa == qb                       # exact, not approx
    # edges are a pure function of (lo, hi, bins)
    assert a.edges == FixedBinSketch().edges


def test_sketch_quantiles_within_one_bin_of_truth():
    """The reported quantile is the upper edge of the true value's bin:
    conservative, and within one log-spaced bin ratio of the truth."""
    vals = sorted(0.002 * 1.013 ** i for i in range(400))
    sk = FixedBinSketch(lo=1e-4, hi=1e3, bins=256)
    for v in vals:
        sk.observe(v)
    ratio = (sk.hi / sk.lo) ** (1.0 / 256)
    for q in (0.1, 0.5, 0.9, 0.99):
        true = vals[min(len(vals) - 1, int(q * len(vals)))]
        est = sk.quantile(q)
        assert true <= est <= true * ratio * (1 + 1e-9)


def test_sketch_edge_cases():
    sk = FixedBinSketch()
    assert math.isnan(sk.quantile(0.5))       # empty
    sk.observe(0.0)                           # below lo: clamps to bin 0
    sk.observe(1e9)                           # above hi: clamps to last bin
    assert sk.quantile(0.0) == sk.edges[0] and sk.quantile(1.0) == sk.hi
    with pytest.raises(ValueError):
        FixedBinSketch(lo=0.0)
    with pytest.raises(ValueError):
        FixedBinSketch(lo=1.0, hi=0.5)


# ---------------------------------------------------------- rolling window
def test_rolling_window_expires_exactly():
    w = RollingWindow(window=1.0, buckets=4)   # bucket_dt = 0.25
    w.add(0.0, 1.0)
    w.add(0.5, 2.0)
    assert w.sum(0.5) == 3.0
    # bucket [0, 0.25) expires once t - window >= 0.25
    assert w.sum(1.2) == 3.0
    assert w.sum(1.25) == 2.0
    assert w.sum(2.5) == 0.0
    w.add(3.0, 4.0)
    assert w.rate(3.0) == 4.0 / 1.0


# ------------------------------------------------------------------ the bus
def test_bus_read_unknown_signal_raises_with_names():
    bus = SignalBus()
    bus.register("a.b", lambda key: 1.0, "help")
    assert bus.has("a.b") and bus.read("a.b") == 1.0
    assert bus.describe()["a.b"] == "help"
    with pytest.raises(KeyError, match="a.b"):
        bus.read("nope")


# ----------------------------------------------- detector bus migration
class _FakeView:
    def __init__(self, backlogs=(0.0, 0.0), queued=(0, 0), now=0.0,
                 items=None):
        self.backlogs = list(backlogs)
        self._queued = list(queued)
        self.now = now
        self._items = items or [[] for _ in self.backlogs]

    @property
    def n_units(self):
        return len(self.backlogs)

    def queued(self, unit):
        return self._queued[unit]

    def queued_items(self, unit):
        return iter(self._items[unit])

    def total_queued(self):
        return sum(self._queued)


def test_detectors_read_bus_byte_identically():
    """A bus-attached detector must return the exact float the legacy
    in-detector expression computes, for every scope/signal variant."""
    items = [[SimpleNamespace(ideal_ttft=0.5, deadline=0.3),
              SimpleNamespace(ideal_ttft=0.1, deadline=5.0)],
             [SimpleNamespace(ideal_ttft=1.0, deadline=0.25)]]
    view = _FakeView(backlogs=(123.0, 45.0), queued=(2, 1), now=1.0,
                     items=items)
    mon = Monitor(MonitorSpec())
    mon.bind(lambda: view.now, topo=None)
    mon.bind_live(view)
    for kw in (dict(signal="requests", scope="cluster"),
               dict(signal="requests", scope="unit"),
               dict(signal="tokens", scope="cluster"),
               dict(signal="tokens", scope="unit")):
        legacy = QueueDepthDetector(**kw)
        bused = QueueDepthDetector(**kw)
        bused.attach_bus(mon.bus)
        assert bused.bus is mon.bus
        for u in range(view.n_units):
            assert bused.signal(view, u) == legacy.signal(view, u)
    legacy, bused = LaxityDebtDetector(), LaxityDebtDetector()
    bused.attach_bus(mon.bus)
    assert bused.signal(view, 0) == legacy.signal(view, 0) \
        == max(0.0, 1.0 + 0.5 - 0.3) + max(0.0, 1.0 + 1.0 - 0.25)


def test_attach_bus_is_a_noop_without_the_signal():
    """Detectors only migrate when the bus actually carries their signal —
    an empty bus (no bind_live) leaves the legacy path in place."""
    det = QueueDepthDetector()
    det.attach_bus(SignalBus())
    assert det.bus is None
    view = _FakeView(queued=(3, 4))
    assert det.signal(view, 0) == 7.0


def _trip_log(sim):
    """Record every (now, tripped) detector decision, any detector type."""
    det = sim.runtime.admission.detector
    log = []
    orig = det.update

    def update(view, unit):
        out = orig(view, unit)
        log.append((view.now, out))
        return out

    det.update = update
    return log


def test_migrated_detector_trips_at_byte_identical_times():
    """End-to-end: an admission run with the monitor attached (detector on
    the bus) must shed/defer the same requests and flip the detector at
    byte-identical event times as the legacy in-detector computation."""
    from repro.simcluster.trace import ArrivalSpec

    trace = _trace(n=72, rps=56.0, seed=7,
                   arrival=ArrivalSpec(process="mmpp", burst_factor=8.0,
                                       burst_frac=0.15, dwell=2.0),
                   slo_mix={"tight": 0.2, "standard": 0.4, "loose": 0.4})
    adm = AdmissionSpec(detector="queue_depth",
                        detector_kw=dict(high=10, low=3))
    sim0 = ClusterSim(_spec(router=RouterSpec(admission=adm)),
                      make_policy("mfs"))
    log0 = _trip_log(sim0)
    m0 = sim0.run(trace)
    sim1 = ClusterSim(_spec(router=RouterSpec(admission=adm),
                            monitor=MonitorSpec()), make_policy("mfs"))
    log1 = _trip_log(sim1)
    m1 = sim1.run(trace)
    assert sim1.runtime.admission.detector.bus is sim1.monitor.bus
    assert m0.shed and log0 == log1           # byte-identical decisions
    assert m0.shed == m1.shed and m0.ttft == m1.ttft
    assert m0.summary() == m1.summary()
    assert sim1.monitor.n_shed == len(m1.shed)


def test_migrated_laxity_detector_trips_at_byte_identical_times():
    trace = _trace(n=60, rps=48.0, seed=3,
                   slo_mix={"tight": 0.2, "standard": 0.4, "loose": 0.4})
    adm = AdmissionSpec(detector="laxity_debt",
                        detector_kw=dict(high=0.4, low=0.1))
    sim0 = ClusterSim(_spec(router=RouterSpec(admission=adm)),
                      make_policy("mfs"))
    log0 = _trip_log(sim0)
    m0 = sim0.run(trace)
    sim1 = ClusterSim(_spec(router=RouterSpec(admission=adm),
                            monitor=MonitorSpec()), make_policy("mfs"))
    log1 = _trip_log(sim1)
    m1 = sim1.run(trace)
    assert log0 == log1
    assert m0.summary() == m1.summary()


# ------------------------------------------------------------ live signals
def test_streaming_signals_are_sane_after_a_run():
    sim, m = _run(_spec(monitor=MonitorSpec()))
    mon = sim.monitor
    assert mon.n_done == len(m.ttft) and mon.n_admitted == len(m.ttft)
    att = mon.bus.read("slo.attainment.cum")
    assert att == pytest.approx(m.admitted_attainment())
    assert 0.0 <= mon.rolling_attainment() <= 1.0
    p50 = mon.bus.read("ttft.p50", "all")
    p99 = mon.bus.read("ttft.p99", "all")
    assert 0.0 < p50 <= p99
    # the conservative sketch bound brackets the true percentile
    import numpy as np
    true_p50 = float(np.percentile(list(m.ttft.values()), 50))
    ratio = (mon.spec.sketch_hi / mon.spec.sketch_lo) \
        ** (1.0 / mon.spec.sketch_bins)
    assert true_p50 <= p50 * (1 + 1e-9) and p50 <= true_p50 * ratio * 1.01
    assert mon.stage_submitted.get("P2D", 0) > 0
    # per-link rolling utilization lands in [0, 1]
    for lid in range(len(sim.topo.capacity)):
        u = mon.bus.read("link.util", lid)
        c = mon.bus.read("link.contended_share", lid)
        assert 0.0 <= u <= 1.0 + 1e-9 and 0.0 <= c <= 1.0 + 1e-9
    snap = mon.snapshot()
    assert snap["n_done"] == mon.n_done and snap["t"] > 0.0


def test_tpot_sketch_fills_with_a_decode_plane():
    from repro.core.decode import DecodePoolSpec, DecodeSpec

    trace = _trace(n=32, rps=8.0, seed=1, workload="qwen-agent",
                   decode_lens=True)
    spec = _spec(decode=DecodeSpec(pools=(DecodePoolSpec(
        name="default", slots_per_ep=8),), mean_out=24),
        monitor=MonitorSpec())
    sim, m = _run(spec, trace=trace)
    mon = sim.monitor
    assert m.tpot and mon.tpot_sketch["all"].n > 0
    p90 = mon.bus.read("tpot.p90", "all")
    assert p90 > 0.0 and not math.isnan(p90)


def test_progress_sampling_hook_fires():
    spec = _spec(monitor=MonitorSpec(sample_every=5))
    sim = ClusterSim(spec, make_policy("mfs"))
    seen = []
    sim.monitor.on_sample = lambda mon: seen.append(mon.n_done)
    m = sim.run(_trace())
    assert seen and seen == [5 * (i + 1) for i in range(len(seen))]
    assert len(seen) == len(m.ttft) // 5


def test_serving_path_threads_the_monitor():
    """DisaggConfig.monitor reaches the shared runtime on the serving host
    too (config threading, not a full serve run)."""
    from repro.serving.disagg import DisaggConfig
    import dataclasses
    fields = {f.name for f in dataclasses.fields(DisaggConfig)}
    assert "monitor" in fields
