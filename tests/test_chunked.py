"""Chunked prefill: sub-group (group, chunk) stage emission.

Covers the ISSUE-5 chunk semantics: ``chunk_tokens=0`` must reproduce the
legacy group-granular schedule bit-for-bit, per-chunk emission must
preserve per-request volume/deadline totals, the RLI/downstream estimate
must tighten monotonically as the chunk front advances, chunk-boundary
recompute must interact correctly with Algorithm-1 pruning, and the
cluster simulator and the real-JAX serving path must emit identical
chunk-level stage traces for matched configs.
"""
import numpy as np
import pytest

from repro.core import Stage, make_policy
from repro.core.stages import ChunkPlan, ChunkSpec, PrefillItem
from repro.simcluster.hw import A100, HW
from repro.simcluster.papermodels import PAPER_MODELS
from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
from repro.simcluster.trace import WORKLOADS, Request, generate_trace

MODEL = PAPER_MODELS["mixtral-8x7b"]


def _spec(chunk=None, **kw):
    kw.setdefault("par", ParallelismSpec(mode="ep", ep=2))
    kw.setdefault("n_units", 2)
    kw.setdefault("gpus_per_server", 2)
    kw.setdefault("layer_groups", 4)
    kw.setdefault("hw", A100)
    return ClusterSpec(model=MODEL, chunk=chunk, **kw)


# ------------------------------------------------------------- plan algebra
def test_chunk_plan_cuts_the_batch_token_string():
    items = [PrefillItem(rid=0, arrival=0, n_tokens=900, reuse=100),
             PrefillItem(rid=1, arrival=0, n_tokens=500, reuse=500),
             PrefillItem(rid=2, arrival=0, n_tokens=300, reuse=0)]
    plan = ChunkPlan.build(items, 256)
    assert plan is not None
    # new tokens: 800 + 1 (fully reused floor) + 300 = 1101 -> 5 chunks
    assert plan.n_chunks == 5
    for i, it in enumerate(items):
        new = max(1, it.n_tokens - it.reuse)
        assert sum(plan.new_tokens[c][i] for c in range(plan.n_chunks)) == new
        # prior_new counts exactly the tokens earlier chunks computed
        acc = 0
        for c in range(plan.n_chunks):
            if plan.new_tokens[c][i]:
                assert plan.prior_new[c][i] == acc
            acc += plan.new_tokens[c][i]
        assert plan.first_chunk[i] <= plan.last_chunk[i]
        # P2D ship totals telescope to the full prompt
        assert sum(plan.ship_tokens(i, it, c)
                   for c in range(plan.n_chunks)) == it.n_tokens
    # every chunk except possibly the last is exactly the token budget
    for c in range(plan.n_chunks - 1):
        assert sum(plan.new_tokens[c]) == 256


def test_chunk_plan_disabled():
    items = [PrefillItem(rid=0, arrival=0, n_tokens=128, reuse=0)]
    assert ChunkPlan.build(items, 0) is None


# ------------------------------------------------- chunk off == legacy, bit
def test_chunk_off_is_bit_identical_to_legacy():
    """ChunkSpec(chunk_tokens=0) and chunk=None must take the exact legacy
    code path: identical stage logs (sizes, deadlines) and TTFTs."""
    trace = generate_trace(WORKLOADS["qwen-conv"], 30, rps=40.0, seed=0,
                           warmup=4)
    logs, ttfts = [], []
    for chunk in (None, ChunkSpec(chunk_tokens=0)):
        sim = ClusterSim(_spec(chunk), make_policy("mfs"))
        sim.runtime.trace_stages = True
        m = sim.run(trace)
        logs.append(list(sim.runtime.stage_log))
        ttfts.append(dict(m.ttft))
    assert logs[0] == logs[1]
    assert ttfts[0] == ttfts[1]


# ------------------------------------------------------- emission totals
def test_chunked_emission_preserves_per_request_totals():
    """Per-chunk S1/S2/S3 must telescope to the legacy per-request group
    totals: same P2D bytes and deadline per rid, same S1 fetch bytes, more
    (smaller) flows."""
    trace = [Request(rid=0, arrival=0.0, prompt_len=1500, reuse_len=600,
                     prefix_id=0),
             Request(rid=1, arrival=0.0, prompt_len=700, reuse_len=0,
                     prefix_id=1)]
    out = {}
    for name, chunk in (("legacy", None), ("chunked", ChunkSpec(256))):
        sim = ClusterSim(_spec(chunk, n_units=1), make_policy("fs"))
        sim.runtime.trace_stages = True
        sim.run([Request(**{k: getattr(r, k) for k in
                            ("rid", "arrival", "prompt_len", "reuse_len",
                             "prefix_id")}) for r in trace])
        out[name] = list(sim.runtime.stage_log)

    def totals(log, stage):
        t = {}
        for rid, s, g, size, dl in log:
            if s == stage:
                t[(rid, g)] = t.get((rid, g), 0.0) + size
        return t

    for stage in (Stage.KV_REUSE, Stage.P2D):
        leg, chk = totals(out["legacy"], stage), totals(out["chunked"], stage)
        assert set(leg) == set(chk)
        for k in leg:
            assert chk[k] == pytest.approx(leg[k], rel=1e-9), (stage, k)
    # deadlines are identical per request (chunk P2D carries the same
    # derived TTFT deadline as the group it belongs to)
    leg_dl = {(r, g): dl for r, s, g, _, dl in out["legacy"] if s == Stage.P2D}
    for r, s, g, _, dl in out["chunked"]:
        if s == Stage.P2D:
            assert dl == pytest.approx(leg_dl[(r, g)], rel=1e-12)
    # and chunking actually split something
    n_leg = sum(1 for e in out["legacy"] if e[1] == Stage.P2D)
    n_chk = sum(1 for e in out["chunked"] if e[1] == Stage.P2D)
    assert n_chk > n_leg


# --------------------------------------------------------- RLI tightening
def test_chunked_downstream_estimate_tightens_monotonically():
    """The downstream estimate seen by policies must be monotonically <=
    the group-granular estimate and non-increasing across the chunk front
    within a group (sharper laxity -> earlier MFS promotion)."""
    req = [Request(rid=0, arrival=0.0, prompt_len=2048, reuse_len=0,
                   prefix_id=0)]
    est = {}
    for name, chunk in (("legacy", None), ("chunked", ChunkSpec(256))):
        sim = ClusterSim(_spec(chunk, n_units=1), make_policy("fs"))
        rec = []
        orig = sim.runtime.policy.on_flow_submitted
        def spy(flow, view, _orig=orig, _rec=rec):
            if flow.stage == Stage.P2D:
                _rec.append((flow.target_layer,
                             view.downstream_estimate(flow)))
            return _orig(flow, view)
        sim.runtime.policy.on_flow_submitted = spy
        sim.run(req)
        est[name] = rec
    leg = dict(est["legacy"])           # one estimate per group
    by_group = {}
    for g, e in est["chunked"]:
        by_group.setdefault(g, []).append(e)
    assert set(by_group) == set(leg)
    for g, chain in by_group.items():
        assert len(chain) > 1           # chunking split the group
        # monotone non-increasing across chunks of one group
        assert all(a >= b - 1e-15 for a, b in zip(chain, chain[1:]))
        # never looser than the group-granular estimate...
        assert max(chain) <= leg[g] * (1 + 1e-9) + 1e-15
        # ...and strictly tighter once the chunk front has advanced
        assert chain[-1] < leg[g] * (1 - 1e-6)


# ---------------------------------------- chunk-boundary recompute / prune
def test_chunked_prune_recomputes_only_undelivered_chunks():
    """Under overload, Algorithm-1 pruning demotes Stage-1 chunk flows to
    the scavenger class; the batch must proceed, charging recompute for the
    undelivered chunk bytes only — every request still completes, and the
    total recompute charged never exceeds the whole-reuse legacy bound."""
    slow = HW("slow", flops=A100.flops, hbm_bw=A100.hbm_bw,
              nic_bw=2e7, scaleup_bw=A100.scaleup_bw)
    reqs = [Request(rid=i, arrival=i * 1e-4, prompt_len=1024,
                    reuse_len=512, prefix_id=(i + 1) % 2)
            for i in range(6)]
    sim = ClusterSim(_spec(ChunkSpec(128), hw=slow, slo_scale=1.0,
                           slo_mode="per-request"), make_policy("mfs"))
    charged = []
    orig = sim.profile.recompute_time
    sim.profile.recompute_time = \
        lambda reuse, frac, g: charged.append((reuse, frac, g)) \
        or orig(reuse, frac, g)
    m = sim.run(reqs)
    assert sim.runtime.n_pruned > 0
    assert len(m.ttft) == len(reqs)     # soft: nothing dropped
    assert charged, "pruning never charged a recompute"
    for reuse, frac, g in charged:
        # per-chunk accounting: each pruned chunk flow pays its own share
        # of the group fetch, never more than the whole reuse
        assert 0.0 < frac <= 1.0 + 1e-9
    # fractions per (group) sum to at most the whole fetch per request
    by_g = {}
    for reuse, frac, g in charged:
        by_g[g] = by_g.get(g, 0.0) + frac
    assert all(v <= len(reqs) + 1e-9 for v in by_g.values())


# ----------------------------------------------------- sim <-> serve parity
@pytest.mark.slow
def test_sim_and_serve_emit_identical_chunk_stage_traces():
    """Chunk-level parity: matched configs, chunking ON — both hosts must
    emit identical (stage, group, size, deadline) sequences, with several
    P2D flows per group."""
    import jax

    from repro.configs import SMOKES
    from repro.models.lm import build_model
    from repro.serving import DisaggConfig, DisaggServer, ServeRequest

    cfg = SMOKES["smollm-360m"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab, size=(32,))
    suffix = rng.integers(0, cfg.vocab, size=(12,))

    srv = DisaggServer(model, params, cfg=DisaggConfig(
        n_prefill_units=1, gpus_per_unit=1, layer_groups=2, hw=A100,
        n_pages=128, chunk=ChunkSpec(chunk_tokens=16)))
    srv.runtime.trace_stages = True
    res = srv.serve([
        ServeRequest(rid=0, arrival=0.0, tokens=prefix, max_new=1),
        ServeRequest(rid=1, arrival=0.05,
                     tokens=np.concatenate([prefix, suffix]), max_new=1),
    ])
    assert res[1].reused_tokens == 32

    spec = ClusterSpec(model=cfg, par=ParallelismSpec(mode="ep", ep=1),
                       n_units=1, gpus_per_server=1, layer_groups=2,
                       slo_mode="per-request", hw=A100,
                       chunk=ChunkSpec(chunk_tokens=16))
    sim = ClusterSim(spec, make_policy("mfs"))
    sim.runtime.trace_stages = True
    sim.run([
        Request(rid=0, arrival=0.0, prompt_len=32, reuse_len=0, prefix_id=0),
        Request(rid=1, arrival=0.05, prompt_len=44, reuse_len=32, prefix_id=0),
    ])

    def trace(log, rid):
        return [(stage, group, size, deadline)
                for r, stage, group, size, deadline in log if r == rid]

    for rid in (0, 1):
        got, want = trace(srv.runtime.stage_log, rid), \
            trace(sim.runtime.stage_log, rid)
        assert len(got) == len(want) > 0
        for (s_a, g_a, sz_a, dl_a), (s_b, g_b, sz_b, dl_b) in zip(got, want):
            assert (s_a, g_a) == (s_b, g_b)
            assert sz_a == pytest.approx(sz_b, rel=1e-12)
            if dl_a is None or dl_b is None:
                assert dl_a == dl_b
            else:
                assert dl_a == pytest.approx(dl_b, rel=1e-12)
    # chunking really split the emission: rid 0 (32 tokens, 16-token chunks)
    # must ship two P2D flows per group
    p2d_per_group = {}
    for s, g, _, _ in trace(srv.runtime.stage_log, 0):
        if s == Stage.P2D:
            p2d_per_group[g] = p2d_per_group.get(g, 0) + 1
    assert set(p2d_per_group.values()) == {2}
