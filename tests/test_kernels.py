"""Per-kernel validation: Pallas (interpret=True) vs the pure-jnp oracles,
swept over shapes and dtypes per the brief."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels.ssd_scan import ssd_chunked
from repro.kernels.rglru import rglru_scan
from repro.kernels import ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------- flash attn
@pytest.mark.parametrize("B,T,S,H,D", [
    (2, 64, 64, 4, 64),
    (1, 200, 200, 3, 128),
    (2, 17, 300, 2, 64),      # ragged + chunked-prefill offset
    (1, 128, 128, 2, 96),     # non-128 head dim
    (1, 257, 257, 1, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(B, T, S, H, D, dtype):
    q = jnp.asarray(RNG.normal(size=(B, T, H, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, H, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, H, D)), dtype)
    qoff = S - T
    out = flash_attention(q, k, v, causal=True, q_offset=qoff, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=qoff)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_window(window):
    q = jnp.asarray(RNG.normal(size=(1, 128, 2, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 128, 2, 64)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_attention_noncausal():
    q = jnp.asarray(RNG.normal(size=(2, 64, 2, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 80, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 80, 2, 64)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_blockwise_xla_path_matches_full():
    """The XLA blockwise scan (dry-run lowering path) is exact."""
    q = jnp.asarray(RNG.normal(size=(1, 300, 2, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 300, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 300, 2, 64)), jnp.float32)
    a = ref.blockwise_attention_ref(q, k, v, causal=True, block_q=64,
                                    block_k=64)
    b = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# -------------------------------------------------------------- decode attn
@pytest.mark.parametrize("B,S,H,D", [
    (2, 256, 4, 64), (3, 1000, 5, 128), (1, 128, 16, 64), (2, 513, 2, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, S, H, D, dtype):
    q = jnp.asarray(RNG.normal(size=(B, H, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, H, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, H, D)), dtype)
    lengths = jnp.asarray(RNG.integers(1, S + 1, size=(B,)), jnp.int32)
    out = decode_attention(q, k, v, lengths, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_decode_attention_length_one():
    q = jnp.asarray(RNG.normal(size=(2, 4, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 64, 4, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 64, 4, 64)), jnp.float32)
    lengths = jnp.asarray([1, 64], jnp.int32)
    out = decode_attention(q, k, v, lengths, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------- ssd
@pytest.mark.parametrize("Bz,T,H,hd,N,chunk", [
    (2, 64, 4, 64, 32, 32),
    (1, 100, 2, 64, 128, 32),    # ragged T
    (2, 256, 8, 64, 64, 128),
    (1, 32, 2, 128, 64, 16),
])
@pytest.mark.parametrize("with_init", [False, True])
def test_ssd_chunked(Bz, T, H, hd, N, chunk, with_init):
    x = jnp.asarray(RNG.normal(size=(Bz, T, H, hd)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(Bz, T, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(Bz, T, N)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, size=(Bz, T, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    D = jnp.asarray(RNG.normal(size=(H,)), jnp.float32)
    s0 = (jnp.asarray(RNG.normal(size=(Bz, H, hd, N)), jnp.float32)
          if with_init else None)
    y, sf = ssd_chunked(x, Bm, Cm, dt, A, D, init_state=s0, chunk=chunk,
                        interpret=True)
    yr, sr = ref.ssd_ref(x, Bm, Cm, dt, A, D, init_state=s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr),
                               atol=1e-4, rtol=1e-4)


def test_ssd_state_chains_across_calls():
    """Splitting a sequence across two kernel calls == one long call."""
    Bz, T, H, hd, N = 1, 64, 2, 64, 32
    x = jnp.asarray(RNG.normal(size=(Bz, T, H, hd)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(Bz, T, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(Bz, T, N)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, size=(Bz, T, H)), jnp.float32)
    A = -jnp.ones((H,), jnp.float32)
    D = jnp.zeros((H,), jnp.float32)
    y_full, s_full = ssd_chunked(x, Bm, Cm, dt, A, D, chunk=32,
                                 interpret=True)
    h = T // 2
    y1, s1 = ssd_chunked(x[:, :h], Bm[:, :h], Cm[:, :h], dt[:, :h], A, D,
                         chunk=32, interpret=True)
    y2, s2 = ssd_chunked(x[:, h:], Bm[:, h:], Cm[:, h:], dt[:, h:], A, D,
                         init_state=s1, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


# -------------------------------------------------------------------- rglru
@pytest.mark.parametrize("B,T,W", [(2, 64, 256), (1, 200, 100), (3, 33, 512)])
@pytest.mark.parametrize("with_init", [False, True])
def test_rglru(B, T, W, with_init):
    a = jnp.asarray(RNG.uniform(0.7, 0.999, size=(B, T, W)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(B, T, W)), jnp.float32)
    s0 = (jnp.asarray(RNG.normal(size=(B, W)), jnp.float32)
          if with_init else None)
    h, sf = rglru_scan(a, x, init_state=s0, chunk=64, interpret=True)
    hr, sr = ref.rglru_ref(a, x, init_state=s0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr), atol=1e-4)


def test_rglru_decay_semantics():
    """a == 0 wipes history; a == 1 accumulates exactly."""
    B, T, W = 1, 16, 128
    x = jnp.ones((B, T, W), jnp.float32)
    h0, _ = rglru_scan(jnp.zeros((B, T, W)), x, chunk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(h0), np.ones((B, T, W)), atol=1e-6)
    h1, s1 = rglru_scan(jnp.ones((B, T, W)), x, chunk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(h1[0, -1]),
                               np.full((W,), T, np.float32), atol=1e-5)


# ---------------------------------------------------- flash custom-VJP (XLA)
@pytest.mark.slow
def test_flash_xla_forward_and_grads():
    """The production non-TPU flash path (custom VJP) matches the oracle in
    both value and gradients."""
    from repro.kernels.flash_xla import flash_attention_xla
    for (B, T, S, H, D, causal, window, qoff) in [
            (2, 128, 128, 2, 64, True, 0, 0),
            (1, 200, 300, 2, 64, True, 0, 100),
            (1, 256, 256, 2, 64, True, 64, 0)]:
        q = jnp.asarray(RNG.normal(size=(B, T, H, D)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(B, S, H, D)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(B, S, H, D)), jnp.float32)
        scale = 1.0 / np.sqrt(D)
        f = lambda *a: flash_attention_xla(*a, scale, causal, window,
                                           qoff, 64, 64)
        g = lambda *a: ref.flash_attention_ref(
            *a, causal=causal, window=window, q_offset=qoff)
        np.testing.assert_allclose(np.asarray(f(q, k, v)),
                                   np.asarray(g(q, k, v)), atol=3e-5)
        do = jnp.asarray(RNG.normal(size=(B, T, H, D)), jnp.float32)
        gf = jax.grad(lambda *a: jnp.sum(f(*a) * do), (0, 1, 2))(q, k, v)
        gg = jax.grad(lambda *a: jnp.sum(g(*a) * do), (0, 1, 2))(q, k, v)
        for a, b in zip(gf, gg):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-4)


# --------------------------------------------------------- SSD dual (train)
@pytest.mark.slow
def test_ssd_dual_matches_recurrence():
    """The chunked dual (matmul) form — the memory-safe train path — is the
    same map as the sequential recurrence, values and grads."""
    rng = np.random.default_rng(3)
    for (Bz, T, H, hd, N, init) in [(2, 64, 4, 32, 32, False),
                                    (1, 100, 2, 64, 64, True)]:
        x = jnp.asarray(rng.normal(size=(Bz, T, H, hd)), jnp.float32)
        Bm = jnp.asarray(rng.normal(size=(Bz, T, N)) * 0.5, jnp.float32)
        Cm = jnp.asarray(rng.normal(size=(Bz, T, N)) * 0.5, jnp.float32)
        dt = jnp.asarray(rng.uniform(0.001, 0.1, (Bz, T, H)), jnp.float32)
        A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
        D = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
        s0 = (jnp.asarray(rng.normal(size=(Bz, H, hd, N)), jnp.float32)
              if init else None)
        y1, s1 = ref.ssd_ref(x, Bm, Cm, dt, A, D, init_state=s0)
        y2, s2 = ref.ssd_dual(x, Bm, Cm, dt, A, D, init_state=s0, chunk=32)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   atol=1e-3, rtol=1e-3)
        g1 = jax.grad(lambda xx: jnp.sum(
            ref.ssd_ref(xx, Bm, Cm, dt, A, D, init_state=s0)[0] ** 2))(x)
        g2 = jax.grad(lambda xx: jnp.sum(
            ref.ssd_dual(xx, Bm, Cm, dt, A, D, init_state=s0,
                         chunk=32)[0] ** 2))(x)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   atol=1e-2, rtol=1e-2)


@pytest.mark.slow
def test_decode_step_time_calibrated_against_kernel_roofline():
    """`StageProfile.decode_step_time` (the smooth analytic model the decode
    plane schedules with) must track the roofline derived from the decode
    kernel's ACTUAL tiling (`decode_attention_cost`: 128-lane head padding,
    block_k KV padding, compute-skipped tail blocks, counted attention
    flops) within a tight relative error — including context lengths that
    straddle block boundaries, where the kernel pays for padding the model
    ignores."""
    from repro.kernels.decode_attention import decode_attention_cost
    from repro.core.stages import GroupPlan, ParallelismSpec, StageProfile
    from repro.simcluster.hw import A100
    from repro.simcluster.papermodels import PAPER_MODELS

    # the cost mirror must track the real kernel's launch math: run the
    # kernel once (interpret) at an off-block context and check the mirror
    # counted exactly the touched KV blocks
    B, H, D, S = 2, 4, 64, 300
    q = jnp.zeros((B, H, D), jnp.float32)
    k = v = jnp.zeros((B, S, H, D), jnp.float32)
    out = decode_attention(q, k, v, jnp.array([300, 10], jnp.int32),
                           interpret=True, block_k=256)
    assert out.shape == (B, H, D)
    fl, by = decode_attention_cost(1, H, D, 300, block_k=256, dtype_bytes=4)
    # ctx=300 pads to 2 x 256-blocks of 128-lane-padded heads
    assert by == 2 * 2 * 256 * H * 128 * 4 + 2 * H * 128 * 4
    assert fl == 2 * 4.0 * H * 128 * 256

    m = PAPER_MODELS["mixtral-8x7b"]
    prof = StageProfile(m, A100, ParallelismSpec(mode="ep", ep=4),
                        GroupPlan.build(m.n_layers, 8))
    errs = []
    for n in (1, 4, 16, 64):
        for ctx in (200, 1000, 3000, 4096, 20000):
            a = prof.decode_step_time(n, ctx)
            r = prof.decode_step_roofline(n, ctx)
            errs.append(abs(a - r) / r)
            # padding and attention flops only ever ADD work
            assert r >= a * (1 - 1e-9)
    assert max(errs) < 0.15, f"decode model error {max(errs):.3f}"
