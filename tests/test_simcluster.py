"""Cluster-simulator integration: every policy runs every workload family,
MFS dominates stage-agnostic baselines under engineered contention, and the
metrics match the paper's definitions (SLO = 3x low-load TTFT)."""
import numpy as np
import pytest

from repro.core import make_policy
from repro.simcluster.papermodels import PAPER_MODELS
from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
from repro.simcluster.trace import WORKLOADS, generate_trace


def _spec(model="mixtral-8x7b", mode="ep", **kw):
    par = (ParallelismSpec(mode="ep", ep=8) if mode == "ep"
           else ParallelismSpec(mode="sp", tp=2, sp=2))
    return ClusterSpec(model=PAPER_MODELS[model], par=par, **kw)


def _run(policy, spec, workload="qwen-agent", n=48, rps=8.0, seed=0, **kw):
    trace = generate_trace(WORKLOADS[workload], n_requests=n, rps=rps,
                           seed=seed, warmup=8)
    sim = ClusterSim(spec, make_policy(policy), seed=seed, **kw)
    return sim.run(trace)


@pytest.mark.parametrize("policy", ["fs", "sjf", "edf", "karuna", "mfs",
                                    "llf-oracle"])
def test_all_policies_complete(policy):
    m = _run(policy, _spec(), n=32)
    s = m.summary()
    assert s["n"] == 32
    assert 0.0 <= s["slo_attainment"] <= 1.0
    assert s["ttft_mean"] > 0


@pytest.mark.parametrize("workload", sorted(WORKLOADS))
def test_all_workloads_run(workload):
    m = _run("mfs", _spec(), workload=workload, n=24, rps=4.0)
    assert m.summary()["n"] == 24


def test_sp_mode_runs():
    m = _run("mfs", _spec(model="llama3-8b", mode="sp"),
             workload="mooncake-agent", n=16, rps=2.0)
    assert m.summary()["n"] == 16


def test_contention_free_is_lower_bound():
    """w/o contention TTFT <= w/ contention TTFT per request (Fig 5)."""
    spec = _spec()
    m_free = _run("fs", spec, n=32, rps=12.0, contention_free=True)
    m_cont = _run("fs", spec, n=32, rps=12.0)
    assert m_free.summary()["ttft_mean"] <= m_cont.summary()["ttft_mean"] + 1e-9


@pytest.mark.slow
def test_mfs_beats_stage_agnostic_under_contention():
    """Engineered hot-prefix overload: MFS's SLO attainment must match or
    beat every stage-agnostic baseline, and its CCT slowdown must be lowest
    (the paper's central claim, Figs 9-13)."""
    spec = _spec(n_units=2)
    att, cct = {}, {}
    for pol in ("fs", "sjf", "edf", "karuna", "mfs"):
        m = _run(pol, spec, workload="qwen-agent", n=64, rps=16.0)
        s = m.summary()
        att[pol] = s["slo_attainment"]
        cct[pol] = s["cct_slowdown"]
    best_baseline = max(att[p] for p in ("fs", "sjf", "edf", "karuna"))
    assert att["mfs"] >= best_baseline - 1e-9, (att, cct)
    assert cct["mfs"] <= min(cct[p] for p in ("fs", "sjf", "edf")) + 1e-9


@pytest.mark.slow
def test_mfs_close_to_llf_oracle():
    """MFS approximates LLF: within 10% attainment of the clairvoyant
    oracle on the default workload."""
    spec = _spec(n_units=2)
    a_mfs = _run("mfs", spec, n=64, rps=12.0).summary()["slo_attainment"]
    a_llf = _run("llf-oracle", spec, n=64,
                 rps=12.0).summary()["slo_attainment"]
    assert a_mfs >= a_llf - 0.10


@pytest.mark.slow
def test_runtime_state_stays_bounded_on_long_traces():
    """State GC: runtime memory must be O(active requests), not O(history) —
    the peak live-flow count over a 400-request trace stays far below the
    total number of submitted flows, and nothing is retained at the end."""
    spec = _spec(n_units=2)
    trace = generate_trace(WORKLOADS["qwen-agent"], n_requests=400, rps=24.0,
                           seed=0, warmup=8)
    sim = ClusterSim(spec, make_policy("mfs"))
    rt = sim.runtime
    peak = {"flows": 0, "submit_level": 0, "red_ranks": 0}
    orig = sim.on_request_done
    def spy(item, bs):
        peak["flows"] = max(peak["flows"], len(rt.flows))
        peak["submit_level"] = max(peak["submit_level"], len(rt.submit_level))
        peak["red_ranks"] = max(peak["red_ranks"], len(rt.red_ranks))
        orig(item, bs)
    sim.on_request_done = spy
    m = sim.run(trace)
    assert m.summary()["n"] == 400
    assert peak["flows"] > 0
    # hundreds of requests x ~20 flows each; live set must stay way below
    assert peak["flows"] < 2000, peak
    # flows and submit_level entries are created and evicted together
    assert peak["submit_level"] == peak["flows"], peak
    # end-of-run: everything evicted
    assert len(rt.flows) == 0
    assert len(rt.submit_level) == 0
    assert len(rt.red_ranks) == 0
    assert len(rt.batch_of_request) == 0
    assert not rt.pruned_rids


def test_stage_log_is_bounded():
    """Tracing keeps only the most recent ``stage_log_limit`` entries."""
    spec = _spec()
    trace = generate_trace(WORKLOADS["qwen-agent"], n_requests=32, rps=16.0,
                           seed=0)
    sim = ClusterSim(spec, make_policy("fs"))
    sim.runtime.trace_stages = True
    sim.runtime.stage_log = type(sim.runtime.stage_log)(maxlen=50)
    sim.run(trace)
    assert len(sim.runtime.stage_log) == 50


def test_per_request_slo_classes_scale_deadlines():
    """A tight-class request gets a proportionally tighter deadline than a
    loose-class one, in both fixed and per-request SLO modes."""
    from repro.simcluster.trace import SLO_CLASSES
    for slo_mode in ("fixed", "per-request"):
        spec = _spec(slo_mode=slo_mode)
        trace = generate_trace(WORKLOADS["qwen-conv"], n_requests=40, rps=4.0,
                               seed=1, slo_mix={"tight": 0.5, "loose": 0.5})
        sim = ClusterSim(spec, make_policy("fs"))
        m = sim.run(trace)
        budget = {r.rid: m.deadline[r.rid] for r in trace if r.rid in m.deadline}
        by_cls = {"tight": [], "loose": []}
        for r in trace:
            if r.rid in budget:
                by_cls[r.slo_class].append(budget[r.rid] /
                                           (m.ideal_ttft[r.rid]
                                            if slo_mode == "per-request" else 1.0))
        if slo_mode == "per-request":
            # budget / own ideal == the class scale exactly
            assert np.allclose(by_cls["tight"], SLO_CLASSES["tight"])
            assert np.allclose(by_cls["loose"], SLO_CLASSES["loose"])
        else:
            # fixed base: loose budgets are exactly 4x tight budgets
            ratio = np.mean(by_cls["loose"]) / np.mean(by_cls["tight"])
            assert ratio == pytest.approx(
                SLO_CLASSES["loose"] / SLO_CLASSES["tight"])


def test_deterministic_given_seed():
    a = _run("mfs", _spec(), n=24, seed=3).summary()
    b = _run("mfs", _spec(), n=24, seed=3).summary()
    assert a == b


def test_slo_definition_scales_with_budget():
    """slo_scale=3 (paper default) attains at least as much as slo_scale=1."""
    tight = ClusterSpec(model=PAPER_MODELS["mixtral-8x7b"],
                        par=ParallelismSpec(mode="ep", ep=8), slo_scale=1.0)
    loose = ClusterSpec(model=PAPER_MODELS["mixtral-8x7b"],
                        par=ParallelismSpec(mode="ep", ep=8), slo_scale=3.0)
    a_t = _run("mfs", tight, n=32, rps=10.0).summary()["slo_attainment"]
    a_l = _run("mfs", loose, n=32, rps=10.0).summary()["slo_attainment"]
    assert a_l >= a_t
