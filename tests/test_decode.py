"""Decode plane: D2D deadline derivation, rebalancer trigger/hysteresis,
pool routing + per-pool P2D deadlines, eviction releasing pool slots
(O(active) invariant), the MFS decode arm (D2D band/reservation rules),
and sim<->serve parity extended to decode events."""
import numpy as np
import pytest

from repro.core import Stage, make_policy
from repro.core.arbiter import MFSScheduler
from repro.core.decode import (DecodePlane, DecodePoolSpec, DecodeSession,
                               DecodeSpec, partition_pools)
from repro.core.msflow import Flow, new_flow_id
from repro.core.stages import PrefillItem
from repro.netsim.events import EventQueue
from repro.core.runtime import RuntimeHost
from repro.simcluster.papermodels import PAPER_MODELS
from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
from repro.simcluster.trace import Request, WORKLOADS, generate_trace


# ----------------------------------------------------------------- fixtures
class _StubProfile:
    """Duck-typed StageProfile surface the plane needs (no JAX, no model)."""

    kv_dtype_bytes = 2
    plan = (0, 1)                         # len() == 2 groups

    class model:                          # noqa: N801 — attribute namespace
        @staticmethod
        def state_bytes(b):
            return 0.0

    def kv_bytes_per_token(self):
        return 1000.0

    def decode_step_time(self, n_seqs, mean_ctx):
        return 0.01


class _StubRT:
    """Just enough MsFlowRuntime surface for plane unit tests."""

    class _Net:
        flows = {}

    class _Policy:
        def on_flow_completed(self, f, view):
            pass

    def __init__(self):
        self.evq = EventQueue()
        self.host = RuntimeHost()
        self.flows = {}
        self.submitted = []
        self.net = self._Net()
        self.policy = self._Policy()
        self.view = None

    def _submit(self, f):
        self.flows[f.fid] = f
        self.submitted.append(f)

    def _evict_flow(self, f):
        self.flows.pop(f.fid, None)


def _plane(pools=None, eps=(0, 1), **kw):
    pools = pools or (DecodePoolSpec(name="default", slots_per_ep=2),)
    kw.setdefault("trigger_delta", 2)
    kw.setdefault("release_delta", 1)
    kw.setdefault("min_migrate_remaining", 2)
    spec = DecodeSpec(pools=pools, **kw)
    plane = DecodePlane(spec, _StubProfile(),
                        partition_pools(pools, list(eps)))
    rt = _StubRT()
    plane.bind(rt)
    return plane, rt


def _item(rid, out=10, tokens=100, pool="", slo_scale=0.0):
    return PrefillItem(rid=rid, arrival=0.0, n_tokens=tokens, pool=pool,
                       out_tokens=out, slo_scale=slo_scale)


# ---------------------------------------------------------- pool partitioning
def test_partition_pools_by_weight():
    pools = (DecodePoolSpec(name="a", weight=3.0),
             DecodePoolSpec(name="b", weight=1.0))
    out = partition_pools(pools, list(range(8)))
    assert out["a"] == [0, 1, 2, 3, 4, 5]
    assert out["b"] == [6, 7]
    # every pool gets at least one endpoint even when outweighed
    tiny = partition_pools((DecodePoolSpec(name="a", weight=100.0),
                            DecodePoolSpec(name="b", weight=1e-6)),
                           [0, 1])
    assert tiny == {"a": [0], "b": [1]}
    with pytest.raises(ValueError):
        partition_pools(pools, [0])      # fewer endpoints than pools


def test_pick_pool_class_pinning_and_weighted_fallback():
    pools = (DecodePoolSpec(name="tightpool", classes=("tight",)),
             DecodePoolSpec(name="open", weight=1.0))
    plane, _ = _plane(pools=pools, eps=(0, 1))
    tight = _item(1)
    tight.payload = Request(rid=1, arrival=0.0, prompt_len=10, reuse_len=0,
                            prefix_id=0, slo_class="tight")
    assert plane.pick_pool(tight) == "tightpool"
    # unpinned classes never land in a class-pinned pool
    for rid in range(20):
        it = _item(rid)
        it.payload = Request(rid=rid, arrival=0.0, prompt_len=10, reuse_len=0,
                             prefix_id=0, slo_class="standard")
        assert plane.pick_pool(it) == "open"
    # deterministic: same rid -> same pool
    it = _item(7)
    assert plane.pick_pool(it) == plane.pick_pool(it)


# ------------------------------------------------------- deadline derivation
def test_d2d_deadline_from_next_token_budget():
    plane, _ = _plane()
    sess = DecodeSession(rid=0, pool="default", ep=0, prompt_tokens=100,
                         out_tokens=32, tpot_budget=0.05, started=10.0,
                         last_token=10.0, tokens_done=4)
    # ahead of budget: token 5 is due at started + 4 budgets = 10.2
    assert plane.d2d_deadline(sess, now=10.05) == pytest.approx(10.2)
    # behind budget: never less than one token budget from now
    assert plane.d2d_deadline(sess, now=10.30) == pytest.approx(10.35)


# -------------------------------------------------- rebalancer + hysteresis
def test_rebalancer_trigger_and_hysteresis():
    plane, rt = _plane(pools=(DecodePoolSpec(name="default",
                                             slots_per_ep=8),))
    # rids all even -> sticky admission lands every session on ep 0
    plane.admit(_item(0), now=0.0)
    assert not rt.submitted                 # delta 1 < trigger 2
    plane.admit(_item(2), now=0.1)
    # delta 2 hit the high-water mark: migrate down to release_delta
    assert len(rt.submitted) == 1
    f = rt.submitted[0]
    assert f.stage == Stage.D2D and f.src == 0 and f.dst == 1
    sess = plane.sessions[f.rid]
    assert sess.state == "migrating"
    assert f.deadline == pytest.approx(plane.d2d_deadline(sess, 0.1))
    # hysteresis: back under the trigger, a new admission (delta 1 again
    # counting the in-flight migration) does not re-trigger
    plane.admit(_item(4), now=0.2)
    assert len(rt.submitted) == 1
    # migration lands: session resumes on the destination
    plane.on_d2d_done(f, now=0.3)
    assert sess.ep == 1 and sess.state == "active"
    assert plane.incoming[1] == 0


def test_migration_size_covers_context_kv():
    plane, rt = _plane(pools=(DecodePoolSpec(name="default",
                                             slots_per_ep=8),))
    plane.admit(_item(0, tokens=50), now=0.0)
    plane.admit(_item(2, tokens=50), now=0.0)
    f = rt.submitted[0]
    sess = plane.sessions[f.rid]
    assert f.size == pytest.approx(sess.ctx_tokens * 1000.0)


# --------------------------------------------------- eviction releases slots
def test_evicted_decode_requests_release_pool_slots():
    plane, rt = _plane(pools=(DecodePoolSpec(name="default", slots_per_ep=2),),
                       eps=(0,), rebalance=False)
    for rid in (0, 1, 2):
        plane.admit(_item(rid), now=0.0)
    assert len(plane.active[0]) == 2
    assert plane.queued_on[0] == 1          # third waits for its endpoint
    victim = next(iter(plane.active[0]))
    assert plane.evict(victim, now=0.1)
    # the freed slot went to the queued session; O(active) state everywhere
    assert len(plane.active[0]) == 2
    assert plane.queued_on[0] == 0 and not plane.queued["default"]
    assert victim not in plane.sessions
    for rid in list(plane.sessions):
        plane.evict(rid, now=0.2)
    assert not plane.sessions and not plane.active[0]
    assert plane.stats["evicted"] == 3


def test_evicting_migrating_session_cancels_d2d_flow():
    plane, rt = _plane(pools=(DecodePoolSpec(name="default",
                                             slots_per_ep=8),))
    plane.admit(_item(0), now=0.0)
    plane.admit(_item(2), now=0.0)
    f = rt.submitted[0]
    assert f.fid in rt.flows
    assert plane.evict(f.rid, now=0.1)
    assert f.fid not in rt.flows            # cancelled + evicted
    assert plane.incoming[1] == 0 and plane._inflight["default"] == 0
    assert f.rid not in plane.sessions


# ------------------------------------------------------------- MFS decode arm
class _ArbView:
    now = 0.0

    def bottleneck(self, flow):
        return 1.0, 0.0

    def mlu_inputs(self, flow, level):
        return 1.0, 0.0

    def l_curr(self, unit):
        return 0

    def computing(self, rid):
        return False

    def red_rank(self, rid):
        return 0

    def downstream_estimate(self, flow):
        return 0.0


def test_d2d_band_below_p2d_and_barred_from_level1():
    sched = MFSScheduler()
    view = _ArbView()
    # identical critical-but-feasible urgency: MLU = 100/150 = 0.67 >= U
    p2d = Flow(new_flow_id(), 0, 0, Stage.P2D, 100.0, src=0, dst=1,
               target_layer=0, n_layers=4, deadline=150.0)
    d2d = Flow(new_flow_id(), 1, -1, Stage.D2D, 100.0, src=0, dst=1,
               target_layer=0, n_layers=4, deadline=150.0)
    sched.on_flow_submitted(p2d, view)
    sched.on_flow_submitted(d2d, view)
    sched.assign([p2d, d2d], view, ("tick",))
    assert p2d.level == 1                   # critical reservation (I3)
    assert d2d.level >= 2                   # D2D never enters level 1
    # the D2D band defers to last-stage P2D at any equal level
    assert d2d.priority_key[1] == 2 and p2d.priority_key[1] == 1
    assert p2d.priority_key < d2d.priority_key


# ------------------------------------------------------------ sim integration
def _sim_spec(**kw):
    kw.setdefault("par", ParallelismSpec(mode="ep", ep=4))
    kw.setdefault("n_units", 2)
    kw.setdefault("layer_groups", 4)
    return ClusterSpec(model=PAPER_MODELS["mixtral-8x7b"], **kw)


def test_sim_decode_plane_end_to_end():
    pools = (DecodePoolSpec(name="interactive", weight=2.0, slots_per_ep=4,
                            tpot_budget=0.04, classes=("tight", "standard")),
             DecodePoolSpec(name="bulk", weight=1.0, slots_per_ep=4,
                            tpot_budget=0.12, classes=("loose",)))
    spec = _sim_spec(decode=DecodeSpec(pools=pools, mean_out=48,
                                       trigger_delta=2, max_inflight=4))
    trace = generate_trace(WORKLOADS["qwen-agent"], n_requests=40, rps=10.0,
                           seed=0, warmup=8,
                           slo_mix={"tight": 0.3, "standard": 0.4,
                                    "loose": 0.3},
                           decode_lens=True)
    sim = ClusterSim(spec, make_policy("mfs"))
    m = sim.run(trace)
    s = m.summary()
    assert s["n"] == 40
    # every request decoded to completion; plane state fully drained
    assert s["decode_live_sessions"] == 0
    assert len(sim.runtime.flows) == 0
    assert sim.decode_plane.n_active() == 0
    assert s["decode_finished"] == s["decode_admitted"]
    # TPOT/TBT metrics recorded per pool and per class
    assert 0.0 <= s["tpot_attainment"] <= 1.0
    assert set(s["tpot_by_pool"]) <= {"interactive", "bulk"}
    by_cls = m.tpot_attainment_by_class()
    assert set(by_cls) <= {"tight", "standard", "loose"}
    # class pinning routed loose traffic to the bulk pool
    for rid, pool in m.pool_of.items():
        if rid >= 0 and m.slo_class.get(rid) == "loose":
            assert pool == "bulk"


def test_decode_plane_off_keeps_legacy_state():
    spec = _sim_spec()
    trace = generate_trace(WORKLOADS["qwen-agent"], n_requests=16, rps=8.0,
                           seed=1, warmup=4)
    sim = ClusterSim(spec, make_policy("fs"))
    m = sim.run(trace)
    assert sim.decode_plane is None
    assert not m.tpot and not m.pool_of and not m.decode_stats
    assert "tpot_attainment" not in m.summary()


def test_per_pool_slo_scale_differentiates_p2d_deadlines():
    """Classless requests inherit the pool-default TTFT scale: the same
    request routed to a looser pool gets a proportionally looser deadline
    (fixed SLO mode: budget = scale x workload base)."""
    pools = (DecodePoolSpec(name="fast", slo_scale=2.0, classes=("tight",)),
             DecodePoolSpec(name="slow", slo_scale=8.0, classes=("loose",)))
    spec = _sim_spec(decode=DecodeSpec(pools=pools, mean_out=4),
                     slo_mode="fixed")
    reqs = [Request(rid=0, arrival=0.0, prompt_len=256, reuse_len=0,
                    prefix_id=0, slo_class="tight", out_len=4),
            Request(rid=1, arrival=0.0, prompt_len=256, reuse_len=0,
                    prefix_id=1, slo_class="loose", out_len=4)]
    sim = ClusterSim(spec, make_policy("fs"))
    m = sim.run(reqs)
    assert m.pool_of[0] == "fast" and m.pool_of[1] == "slow"
    assert m.deadline[1] / m.deadline[0] == pytest.approx(8.0 / 2.0)


def test_decode_rebalancing_bounded_migrations_and_tpot_recorded():
    """Engineered imbalance: sticky admission + heterogeneous output lengths
    force migrations; every finished session still reports a TPOT and the
    runtime evicts all D2D flows."""
    spec = _sim_spec(decode=DecodeSpec(
        pools=(DecodePoolSpec(name="default", slots_per_ep=2),),
        mean_out=64, out_sigma=1.0, trigger_delta=2, release_delta=1,
        max_inflight=4, min_migrate_remaining=2))
    trace = generate_trace(WORKLOADS["qwen-agent"], n_requests=48, rps=24.0,
                           seed=3, warmup=8, decode_lens=True)
    sim = ClusterSim(spec, make_policy("mfs"))
    m = sim.run(trace)
    assert m.decode_stats["migrations"] > 0
    assert m.decode_stats["live_sessions"] == 0
    # every finished session reported a TPOT (warm-up rids included)
    assert len(m.tpot) == m.decode_stats["finished"]
    assert all(v >= 0.0 for v in m.tpot.values())


def test_trace_decode_lens_is_separate_stream():
    """Sampling output lengths must not perturb the base trace draws."""
    a = generate_trace(WORKLOADS["qwen-conv"], 32, rps=8.0, seed=5)
    b = generate_trace(WORKLOADS["qwen-conv"], 32, rps=8.0, seed=5,
                       decode_lens=True)
    for ra, rb in zip(a, b):
        assert (ra.arrival, ra.prompt_len, ra.reuse_len, ra.prefix_id) == \
            (rb.arrival, rb.prompt_len, rb.reuse_len, rb.prefix_id)
        assert ra.out_len == 0 and rb.out_len >= 1


# ------------------------------------------------ decode-side auto-eviction
class _EvictRT(_StubRT):
    """Stub runtime with just enough net + kvstore surface for the
    auto-evict rule (bottleneck feasibility, flow cancellation, block
    release)."""

    class _Topo:
        capacity = {0: 10.0}               # exclusive service at 10 B/s

        def route(self, src, dst, fid):
            return (0,)

    class _EvictNet:
        def __init__(self):
            self.flows = {}
            self.routes = {}
            self.topo = _EvictRT._Topo()

        def remove(self, flow):
            self.flows.pop(flow.fid, None)

    class _Store:
        def __init__(self):
            self.released = []

        def release(self, rid):
            self.released.append(rid)

    def __init__(self):
        super().__init__()
        self.net = self._EvictNet()
        self.kvstore = self._Store()


def _migrating(plane, rt, rid, pool, src, dst, deadline, payload=None):
    f = Flow(new_flow_id(), rid, -1, Stage.D2D, 100.0, src=src, dst=dst,
             target_layer=0, n_layers=2, deadline=deadline)
    sess = DecodeSession(rid=rid, pool=pool, ep=src, prompt_tokens=50,
                         out_tokens=20,
                         tpot_budget=plane.pools[pool].tpot_budget,
                         started=0.0, last_token=0.0, payload=payload)
    sess.state = "migrating"
    sess.migrate_dst = dst
    sess.d2d_fid = f.fid
    plane.sessions[rid] = sess
    plane.incoming[dst] += 1
    plane._inflight[pool] += 1
    rt.flows[f.fid] = f
    rt.net.flows[f.fid] = f
    return sess, f


def test_auto_evict_requeues_infeasible_migration_on_source():
    """A non-loose session whose migration deadline went infeasible keeps
    its KV where it is: the D2D is abandoned (flow cancelled, reserved
    slots released) and the session re-queues on its source endpoint,
    flagged so the rebalancer cannot immediately re-pick it."""
    plane, _ = _plane(auto_evict=True)
    rt = _EvictRT()
    plane.bind(rt)
    sess, f = _migrating(plane, rt, rid=1, pool="default", src=0, dst=1,
                         deadline=1e9)                 # 100 B at 10 B/s
    assert plane.auto_evict(0.5) == 0                  # ample time: untouched
    # 100 B cannot arrive by t=1.0 even at the bottleneck's full 10 B/s
    f.deadline = 1.0
    assert plane.auto_evict(0.5) == 1
    assert f.fid not in rt.net.flows                   # D2D cancelled
    assert plane.incoming[1] == 0 and plane._inflight["default"] == 0
    assert sess.rid in plane.sessions                  # re-admitted
    assert sess.pool == "default" and sess.ep == 0 and sess.no_migrate
    assert sess.state in ("active", "queued")
    assert plane.stats["abandoned"] == 1
    assert plane.stats["evicted"] == 0                 # nothing dropped


def test_auto_evict_spills_loose_sessions_to_bulk_pool():
    pools = (DecodePoolSpec(name="interactive", slots_per_ep=2,
                            tpot_budget=0.03),
             DecodePoolSpec(name="bulk", slots_per_ep=2, tpot_budget=0.12))
    plane, _ = _plane(pools=pools, eps=(0, 1, 2, 3), auto_evict=True)
    rt = _EvictRT()
    plane.bind(rt)
    loose = Request(rid=2, arrival=0.0, prompt_len=50, reuse_len=0,
                    prefix_id=0, slo_class="loose")
    sess, f = _migrating(plane, rt, rid=2, pool="interactive", src=0, dst=1,
                         deadline=0.1, payload=loose)
    assert plane.auto_evict(5.0) == 1                  # deadline long gone
    assert sess.pool == "bulk"                         # spilled
    assert sess.ep in plane.pool_eps["bulk"]
    assert sess.tpot_budget == pytest.approx(0.12)     # relaxed budget
    assert plane.stats["spilled"] == 1
    # the abandoning evict() released the pins; the session itself lives on
    assert rt.kvstore.released == [2]
    assert sess.rid in plane.sessions


def test_auto_evict_drops_loose_without_spill_and_releases_kv():
    plane, _ = _plane(auto_evict=True)                 # single pool: no spill
    rt = _EvictRT()
    plane.bind(rt)
    loose = Request(rid=3, arrival=0.0, prompt_len=50, reuse_len=0,
                    prefix_id=0, slo_class="loose")
    sess, f = _migrating(plane, rt, rid=3, pool="default", src=0, dst=1,
                         deadline=0.1, payload=loose)
    assert plane.auto_evict(5.0) == 1
    assert sess.rid not in plane.sessions              # dropped for good
    assert plane.stats["dropped"] == 1 and plane.stats["evicted"] == 1
    assert rt.kvstore.released == [3]                  # blocks back to store


def test_auto_evict_end_to_end_smoke():
    """Auto-eviction enabled on a contended sim run: the plane must drain
    (no leaked sessions/flows) and the rule must not drop non-loose work."""
    spec = _sim_spec(decode=DecodeSpec(
        pools=(DecodePoolSpec(name="interactive", slots_per_ep=2,
                              tpot_budget=0.02,
                              classes=("tight", "standard")),
               DecodePoolSpec(name="bulk", slots_per_ep=4, tpot_budget=0.2,
                              classes=("loose",))),
        mean_out=64, out_sigma=1.0, trigger_delta=2, release_delta=1,
        max_inflight=4, min_migrate_remaining=2, auto_evict=True))
    trace = generate_trace(WORKLOADS["qwen-agent"], n_requests=48, rps=24.0,
                           seed=3, warmup=8, decode_lens=True,
                           slo_mix={"tight": 0.3, "standard": 0.3,
                                    "loose": 0.4})
    sim = ClusterSim(spec, make_policy("mfs"))
    m = sim.run(trace)
    st = m.decode_stats
    assert st["live_sessions"] == 0 and len(sim.runtime.flows) == 0
    assert st["finished"] + st["dropped"] == st["admitted"]
