"""Per-architecture smoke tests (reduced configs, CPU) + serving-path
consistency: suffix prefill == full prefill, prefill+decode == longer
prefill, MoE decode gather == ragged path."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SMOKES
from repro.models.lm import build_model

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)
ALL_ARCHS = sorted(SMOKES)


def _batch(cfg, B=2, T=16, rng=RNG):
    toks = rng.integers(0, cfg.vocab, size=(B, T + 1))
    out = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
           "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    if cfg.family == "vlm":
        out = {"inputs_embeds": jnp.asarray(
                   rng.normal(size=(B, T, cfg.d_model)), jnp.bfloat16),
               "labels": out["labels"]}
    if cfg.enc_layers:
        out["src_embeds"] = jnp.asarray(
            rng.normal(size=(B, 8, cfg.d_model)), jnp.bfloat16)
    if cfg.mtp:
        out["labels2"] = out["labels"]
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.slow
def test_smoke_train_step(arch):
    """One forward/train step on CPU: correct shapes, finite, grads flow."""
    cfg = SMOKES[arch]
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert jnp.isfinite(loss), arch
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = SMOKES[arch]
    model = build_model(cfg)
    params = model.init(KEY)
    b = _batch(cfg, B=2, T=12)
    b.pop("labels"); b.pop("labels2", None)
    logits, cache = model.prefill(params, b)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    logits2, cache2 = model.decode_step(
        params, cache, jnp.zeros((2, 1), jnp.int32), 12)
    assert logits2.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_instantiates_abstractly(arch):
    """The FULL config builds an abstract param tree (no allocation) with
    the advertised parameter count."""
    cfg = ARCHS[arch]
    model = build_model(cfg)
    abstract = jax.eval_shape(model.init, KEY)
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abstract))
    expect = cfg.params()
    assert abs(n - expect) / expect < 0.35, (n, expect)


@pytest.mark.parametrize("arch", ["smollm-360m", "qwen1.5-32b",
                                  "deepseek-v3-671b", "mamba2-1.3b",
                                  "recurrentgemma-9b", "seamless-m4t-medium"])
def test_suffix_prefill_matches_full(arch):
    """prefill(prefix) -> prefill(suffix, caches, pos) == prefill(full):
    the data plane of Stage-1 KV reuse is exact."""
    cfg = SMOKES[arch]
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, 24)), jnp.int32)
    extra = {}
    if cfg.enc_layers:
        extra["src_embeds"] = jnp.asarray(
            rng.normal(size=(1, 8, cfg.d_model)), jnp.bfloat16)
    P = 16
    full, _ = model.prefill(params, {"tokens": toks, **extra})
    _, pre = model.prefill(params, {"tokens": toks[:, :P], **extra})
    sfx, _ = model.prefill(params, {"tokens": toks[:, P:], **extra},
                           caches=pre, pos=P)
    scale = float(jnp.max(jnp.abs(full.astype(jnp.float32)))) + 1e-9
    err = float(jnp.max(jnp.abs((full - sfx).astype(jnp.float32))))
    assert err / scale < 2e-2, (arch, err, scale)


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-1.3b",
                                  "recurrentgemma-9b", "deepseek-v3-671b"])
def test_decode_consistent_with_prefill(arch):
    """prefill(t[:n]) + decode(t[n]) logits == prefill(t[:n+1]) logits."""
    cfg = SMOKES[arch]
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, 17)), jnp.int32)
    n = 16
    # decode path needs cache capacity > n: prefill gives exactly n slots for
    # attention archs, so append via suffix-prefill instead for them; the
    # recurrent/ssm archs decode against O(1) state directly.
    want, _ = model.prefill(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :n]})
    if cfg.family in ("ssm",):
        got, _ = model.decode_step(params, cache, toks[:, n:], n)
    else:
        got, _ = model.prefill(params, {"tokens": toks[:, n:]},
                               caches=cache, pos=n)
    scale = float(jnp.max(jnp.abs(want.astype(jnp.float32)))) + 1e-9
    err = float(jnp.max(jnp.abs((want - got).astype(jnp.float32))))
    assert err / scale < 2e-2, (arch, err)


def test_moe_gather_matches_ragged():
    from repro.models.blocks import _moe_local, _moe_token_gather, moe_init
    from repro.models.sharding import ShardCtx
    cfg = SMOKES["deepseek-moe-16b"]
    p = moe_init(jax.random.PRNGKey(1), cfg, ShardCtx())
    x = jnp.asarray(RNG.normal(size=(3, 2, cfg.d_model)), jnp.float32)
    a = _moe_local(p, x, cfg).astype(jnp.float32)
    b = _moe_token_gather(p, x, cfg).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)


def test_int8_kv_decode_close_to_bf16():
    """int8 KV decode (the qwen1.5 decode_32k policy) stays close to bf16."""
    cfg = SMOKES["qwen1.5-32b"]
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, 8)), jnp.int32)
    _, cache_bf16 = model.prefill(params, {"tokens": toks})

    def convert(c, to_int8):
        def f(path, leaf):
            name = str(getattr(path[-1], "key", ""))
            if name in ("k", "v") and to_int8:
                from repro.models.blocks import _kv_store
                return _kv_store(leaf, jnp.int8)
            return leaf
        return jax.tree_util.tree_map_with_path(f, c)

    # pad capacity by re-building: decode writes at pos=8 so capacity 8 is
    # full; grow caches to 16 slots
    def grow(c):
        def f(path, leaf):
            name = str(getattr(path[-1], "key", ""))
            if name in ("k", "v"):
                pad = [(0, 0)] * leaf.ndim
                pad[2] = (0, 8)
                return jnp.pad(leaf, pad)
            return leaf
        return jax.tree_util.tree_map_with_path(f, c)

    cache_bf16 = grow(cache_bf16)
    cache_int8 = convert(cache_bf16, True)
    tok = toks[:, -1:]
    lg_a, _ = model.decode_step(params, cache_bf16, tok, 8)
    lg_b, _ = model.decode_step(params, cache_int8, tok, 8)
    a = jax.nn.softmax(lg_a.astype(jnp.float32)[0, -1])
    b = jax.nn.softmax(lg_b.astype(jnp.float32)[0, -1])
    assert float(jnp.sum(jnp.abs(a - b))) < 0.25   # total-variation distance
    assert int(jnp.argmax(a)) == int(jnp.argmax(b))
