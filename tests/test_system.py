"""End-to-end system behaviour: the full stack wired together —
launcher-level serving with MFS over the virtual fabric, the paper's
headline ordering, and the dry-run cell planner covering the assigned
matrix."""
import numpy as np
import pytest

import jax

from repro.configs import ARCHS, SHAPES, SMOKES
from repro.core import make_policy
from repro.launch.serve import make_requests, run as serve_run
from repro.launch.specs import SKIP_REASONS, input_specs, plan_cells


def test_assigned_matrix_is_complete():
    """10 archs x 4 shapes = 40 cells; 8 documented long_500k skips."""
    cells = plan_cells()
    assert len(cells) == 40
    assert len(ARCHS) == 10 and len(SHAPES) == 4
    skips = [c for c in cells if c.skip]
    assert len(skips) == 8
    assert all(c.shape.name == "long_500k" for c in skips)
    runnable = {(c.arch, c.shape.name) for c in cells if not c.skip}
    assert ("mamba2-1.3b", "long_500k") in runnable
    assert ("recurrentgemma-9b", "long_500k") in runnable


def test_input_specs_all_cells():
    """input_specs produces weak-type-correct stand-ins for every cell."""
    for cell in plan_cells():
        if cell.skip:
            continue
        spec = input_specs(cell.arch, cell.shape.name)
        assert spec, (cell.arch, cell.shape.name)
        for name, s in spec.items():
            assert isinstance(s, jax.ShapeDtypeStruct)
            assert all(d > 0 for d in s.shape), (name, s)
        cfg = ARCHS[cell.arch]
        if cell.shape.kind != "decode":
            if cfg.family == "vlm":
                assert "inputs_embeds" in spec     # stubbed patch frontend
            if cfg.family == "audio":
                assert "src_embeds" in spec        # stubbed frame frontend


@pytest.mark.slow
def test_serve_launcher_policies_end_to_end():
    summary = serve_run("smollm-360m", n_requests=6, rps=500.0,
                        policies=("mfs", "fs"), verbose=False)
    assert set(summary) == {"mfs", "fs"}
    for s in summary.values():
        assert 0.0 <= s["slo_attainment"] <= 1.0
        assert s["reuse_fraction"] >= 0.0


def test_paper_headline_ordering_micro():
    """The one-line version of the paper: under the Table-1 contention,
    MFS meets every deadline; every stage-agnostic baseline misses some."""
    from repro.core import MFSScheduler, Stage
    from repro.netsim.toy import make_flow, run_toy
    reqs = {"A": (2.0, 9.0, 18.0), "B": (4.0, 6.0, 12.0), "C": (3.0, 0.0, 7.0)}

    def misses(policy_name):
        flows = {}
        for rid, (nm, (size, remain, dr)) in enumerate(reqs.items()):
            dl = dr - remain if policy_name == "mfs" else dr
            flows[nm] = make_flow(Stage.P2D, size=size, deadline=dl, rid=rid)
        pol = MFSScheduler() if policy_name == "mfs" \
            else make_policy(policy_name)
        finish = run_toy(list(flows.values()), pol)
        return sum(finish[f.fid] + reqs[nm][1] > reqs[nm][2] + 1e-6
                   for nm, f in flows.items())

    assert misses("mfs") == 0
    for base in ("fs", "sjf", "edf", "karuna"):
        assert misses(base) >= 1, base


def test_smoke_configs_match_families():
    for name, cfg in SMOKES.items():
        assert cfg.family == ARCHS[name].family, name
        assert cfg.n_layers <= ARCHS[name].n_layers
        assert cfg.vocab <= ARCHS[name].vocab
