"""Telemetry plane: span lifecycle completeness, partial traces for
shed/deferred/pruned requests, sim<->serve span parity, RMLQ decision-audit
consistency with ``promoted_count``, Perfetto export schema, the
zero-overhead (bit-identical scheduling) guarantee, and the stage-log
dropped-rows counter."""
import warnings

import numpy as np
import pytest

from repro.core import Stage, make_policy
from repro.core.telemetry import StageLog, Telemetry, TelemetrySpec
from repro.simcluster.hw import A100, HW
from repro.simcluster.papermodels import PAPER_MODELS
from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
from repro.simcluster.trace import WORKLOADS, generate_trace


def _spec(**kw):
    kw.setdefault("par", ParallelismSpec(mode="ep", ep=8))
    kw.setdefault("n_units", 2)
    kw.setdefault("telemetry", TelemetrySpec())
    return ClusterSpec(model=PAPER_MODELS["mixtral-8x7b"], **kw)


def _run(spec=None, policy="mfs", n=40, rps=10.0, seed=0, workload="qwen-conv",
         **trace_kw):
    trace = generate_trace(WORKLOADS[workload], n, rps=rps, seed=seed,
                           warmup=8, **trace_kw)
    sim = ClusterSim(spec if spec is not None else _spec(),
                     make_policy(policy), seed=seed)
    m = sim.run(trace)
    return sim, m


# ------------------------------------------------------------ span lifecycle
def test_span_lifecycle_completeness():
    """Every emitted flow opens exactly one span and closes it; with
    trace_stages on, the telemetry-backed stage log matches the spans
    row-for-row; every served request's TTFT decomposes exactly."""
    sim, m = _run()
    tel = sim.telemetry
    assert tel is not None
    s = tel.summary()
    assert s["flow_spans"] > 0 and s["open_spans"] == 0
    assert all(v == 0 for v in s["dropped"].values())
    for sp in tel.flow_spans.values():
        assert sp.end_state in ("done", "cancelled", "pruned")
        assert sp.finished is not None and sp.finished >= sp.created
        assert sp.idle >= 0 and sp.xfer >= 0
        # local (src == dst) flows ride an empty route: no line rate
        assert sp.line_cap > 0 or sp.src == sp.dst
    # every measured request: served, with an exact TTFT decomposition
    for rid in (r for r in m.ttft if r >= 0):
        tr = tel.requests[rid]
        assert tr.status == "served"
        kinds = [k for (_, k, _) in tr.events]
        assert kinds[0] == "arrive" and "admit" in kinds \
            and "batch" in kinds and "first_token" in kinds
        bd = tel.ttft_breakdown(rid)
        total = (bd["queue"] + bd["stall_s1"] + bd["compute"]
                 + bd["coll_wait"] + bd["p2d_tail"] + bd["first_decode"])
        assert total == pytest.approx(bd["ttft"], rel=1e-6, abs=1e-9)
        assert "P2D" in bd["stages"]


def test_stage_log_backed_by_telemetry_matches_legacy_rows():
    """With telemetry on AND trace_stages on, the legacy stage_log rows are
    produced by the telemetry probe — identical to the telemetry-off log."""
    trace = generate_trace(WORKLOADS["qwen-conv"], 24, rps=8.0, seed=1,
                           warmup=4)
    logs = []
    for tel_spec in (None, TelemetrySpec()):
        sim = ClusterSim(_spec(telemetry=tel_spec), make_policy("mfs"))
        sim.runtime.trace_stages = True
        sim.run(trace)
        logs.append(list(sim.runtime.stage_log))
    assert logs[0] == logs[1] and len(logs[0]) > 0


# ------------------------------------------------------------ partial traces
def test_partial_trace_shed_and_attribution():
    """Shed requests produce a well-formed partial trace (arrive -> route ->
    shed, no batch) and the miss report attributes them to admission."""
    from repro.core.router import AdmissionSpec, RouterSpec

    spec = _spec(router=RouterSpec(admission=AdmissionSpec(
        detector="queue_depth", detector_kw=dict(high=0.0, low=-1.0))))
    sim, m = _run(spec=spec, n=48, rps=24.0, seed=2, workload="qwen-agent",
                  slo_mix={"tight": 0.2, "standard": 0.4, "loose": 0.4})
    tel = sim.telemetry
    shed = [r for r in m.shed if r >= 0]
    assert shed
    for rid in shed:
        tr = tel.requests[rid]
        assert tr.status == "shed" and tr.batch == -1 and not tr.flows
        kinds = [k for (_, k, _) in tr.events]
        assert kinds[-1] == "shed" and "batch" not in kinds
        rec = tel.attribute_miss(rid)
        assert rec["stage"] == "admission" and rec["link"] is None
    rep = tel.slo_miss_report()
    assert rep["n_missed"] >= len(shed)
    assert any(c["stage"] == "admission" for c in rep["causes"])


def test_partial_trace_deferred_then_served():
    """Deferred requests record every defer round and still complete."""
    from repro.core.router import AdmissionSpec, RouterSpec

    adm = AdmissionSpec(detector="queue_depth",
                        detector_kw=dict(high=6, low=2), mode="defer",
                        defer_delay=0.05, max_defers=50)
    sim, m = _run(spec=_spec(router=RouterSpec(admission=adm)), n=36,
                  rps=96.0, seed=5,
                  slo_mix={"tight": 0.0, "standard": 0.3, "loose": 0.7})
    tel = sim.telemetry
    assert m.n_deferred > 0
    deferred = [t for t in tel.requests.values() if t.n_deferrals > 0]
    assert deferred
    for tr in deferred:
        kinds = [k for (_, k, _) in tr.events]
        assert kinds.count("defer") == tr.n_deferrals
        # each retry re-routes: one route event per arrival attempt
        assert kinds.count("route") == tr.n_deferrals + 1
        assert tr.status == "served"


# ----------------------------------------------------------- serve-path JAX
@pytest.fixture(scope="module")
def smollm():
    import jax
    from repro.configs import SMOKES
    from repro.models.lm import build_model
    cfg = SMOKES["smollm-360m"]
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def test_sim_serve_span_parity(smollm):
    """Matched 2-request, single-unit config: the telemetry flow spans
    (stage, group, size, deadline) must agree between ClusterSim and the
    real-JAX DisaggServer — same emitter, same runtime, same collector."""
    from repro.serving import DisaggConfig, DisaggServer, ServeRequest
    from repro.simcluster.trace import Request

    cfg, model, params = smollm
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab, size=(32,))
    suffix = rng.integers(0, cfg.vocab, size=(12,))

    srv = DisaggServer(model, params, cfg=DisaggConfig(
        n_prefill_units=1, gpus_per_unit=1, layer_groups=2, hw=A100,
        n_pages=128, telemetry=TelemetrySpec()))
    srv.serve([
        ServeRequest(rid=0, arrival=0.0, tokens=prefix, max_new=1),
        ServeRequest(rid=1, arrival=0.05,
                     tokens=np.concatenate([prefix, suffix]), max_new=1),
    ])

    sim = ClusterSim(ClusterSpec(
        model=cfg, par=ParallelismSpec(mode="ep", ep=1), n_units=1,
        gpus_per_server=1, layer_groups=2, slo_mode="per-request", hw=A100,
        telemetry=TelemetrySpec()), make_policy("mfs"))
    sim.run([
        Request(rid=0, arrival=0.0, prompt_len=32, reuse_len=0, prefix_id=0),
        Request(rid=1, arrival=0.05, prompt_len=44, reuse_len=32,
                prefix_id=0),
    ])

    def spans(tel, rid):
        return [(sp.stage, sp.group, sp.size, sp.deadline)
                for sp in tel.flow_spans.values() if sp.rid == rid]

    got, want = spans(srv.telemetry, 1), spans(sim.telemetry, 1)
    assert len(got) == len(want) > 0
    assert {s for s, *_ in got} == {Stage.KV_REUSE, Stage.P2D}
    for (s_a, g_a, sz_a, dl_a), (s_b, g_b, sz_b, dl_b) in zip(got, want):
        assert (s_a, g_a) == (s_b, g_b)
        assert sz_a == pytest.approx(sz_b, rel=1e-12)
        if dl_a is None or dl_b is None:
            assert dl_a == dl_b
        else:
            assert dl_a == pytest.approx(dl_b, rel=1e-12)
    # both hosts decompose the request's TTFT the same way
    for key in ("queue", "stall_s1", "compute", "coll_wait", "p2d_tail"):
        a = srv.telemetry.ttft_breakdown(1)[key]
        b = sim.telemetry.ttft_breakdown(1)[key]
        assert a == pytest.approx(b, rel=1e-9, abs=1e-12)


def test_partial_trace_pruned_serve_path(smollm):
    """Algorithm-1 pruning on the serving path: pruned requests carry the
    pruned lifecycle event and their scavenged flows close as pruned
    spans (well-formed partial traces, never left open)."""
    from repro.core.arbiter import MFSScheduler
    from repro.serving import DisaggConfig, DisaggServer, ServeRequest

    cfg, model, params = smollm
    slow_nic = HW("slow", flops=A100.flops, hbm_bw=A100.hbm_bw,
                  nic_bw=2e5, scaleup_bw=A100.scaleup_bw)
    srv = DisaggServer(model, params, policy=MFSScheduler(),
                       cfg=DisaggConfig(n_prefill_units=2, gpus_per_unit=1,
                                        layer_groups=2, hw=slow_nic,
                                        slo_scale=1.0, n_pages=256,
                                        telemetry=TelemetrySpec()))
    rng = np.random.default_rng(1)
    reqs = [ServeRequest(rid=i, arrival=i * 1e-5,
                         tokens=rng.integers(0, cfg.vocab,
                                             size=(64 + 8 * i,)),
                         max_new=1)
            for i in range(5)]
    srv.serve(reqs)
    rt, tel = srv.runtime, srv.telemetry
    assert rt.n_pruned > 0
    pruned = [tr for tr in tel.requests.values()
              if any(k == "pruned" for (_, k, _) in tr.events)]
    assert len(pruned) >= 1
    assert {sp.end_state for sp in tel.flow_spans.values()} \
        <= {"done", "cancelled", "pruned"}
    # the Algorithm-1 audit recorded the pruning decisions (the per-flow
    # scavenge record only appears when the rid had live flows to demote
    # at decision time; the red_run entry always carries the pruned set)
    red = tel.audit_events("red_run")
    audited_pruned = set().union(*(ev["pruned"] for ev in red))
    assert {tr.rid for tr in pruned} <= audited_pruned


# --------------------------------------------------------------- audit chain
def test_rmlq_audit_matches_promoted_count():
    """The audited per-flow level history reproduces the runtime's
    promotion counters exactly, and promote decisions carry the MLU/RLI
    inputs that drove them."""
    sim, m = _run(rps=16.0)
    tel, rt = sim.telemetry, sim.runtime
    assert tel.rmlq_promoted_count() == rt.promoted_count() > 0
    for st in (Stage.KV_REUSE, Stage.P2D):
        assert tel.rmlq_promoted_count(st) == rt.promoted_count(st)
    promotes = tel.audit_events("promote")
    assert promotes
    for ev in promotes:
        assert ev["to"] < ev["from"]
        assert "inputs" in ev
        assert ("mlu" in ev["inputs"]) or ("rli" in ev["inputs"])
    # Algorithm-1 re-evaluations were audited too
    assert len(tel.audit_events("red_run")) == rt.n_red_runs > 0
    inserts = tel.audit_events("insert")
    assert len(inserts) == len(tel.flow_spans)
    # level-1 entries are flagged as the critical reservation (I3)
    for ev in inserts + promotes:
        if ev["to"] == 1:
            assert ev.get("reserved") is True
        else:
            assert "reserved" not in ev


# ------------------------------------------------------------ perfetto export
def test_perfetto_export_schema(tmp_path):
    """Chrome trace-event JSON: every event carries name/ph/ts/pid/tid,
    complete events carry a non-negative dur, async b/e pairs balance."""
    import json

    sim, m = _run(n=24, rps=8.0)
    tel = sim.telemetry
    path = tmp_path / "trace.json"
    tel.save_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert evs
    opened = {}
    for ev in evs:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(ev)
        assert isinstance(ev["ts"], (int, float)) and ev["ts"] >= 0
        assert ev["ph"] in ("X", "b", "e", "i", "M")
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
        elif ev["ph"] == "b":
            opened[(ev["pid"], ev["id"])] = opened.get(
                (ev["pid"], ev["id"]), 0) + 1
        elif ev["ph"] == "e":
            opened[(ev["pid"], ev["id"])] -= 1
    assert all(v == 0 for v in opened.values())
    # a filtered export contains only the requested request's lane
    one = [r for r in m.ttft if r >= 0][0]
    sub = tel.to_chrome_trace(rids={one})["traceEvents"]
    assert 0 < len(sub) < len(evs)
    for ev in sub:
        rid = (ev.get("args") or {}).get("rid", ev.get("id"))
        if ev.get("cat") in ("request", "lifecycle",
                             "net.KV_REUSE", "net.P2D"):
            assert rid == one


# ------------------------------------------------------------- zero overhead
def test_telemetry_is_bit_identical_on_vs_off():
    """The collector is a pure observer: enabling it must not change a
    single scheduling outcome — TTFTs, stage traces and summaries are
    identical with telemetry on and off."""
    trace = generate_trace(WORKLOADS["qwen-conv"], 32, rps=12.0, seed=3,
                           warmup=8)
    runs = []
    for tel_spec in (None, TelemetrySpec()):
        sim = ClusterSim(_spec(telemetry=tel_spec), make_policy("mfs"),
                         seed=3)
        sim.runtime.trace_stages = True
        m = sim.run(trace)
        runs.append((m, list(sim.runtime.stage_log)))
    (m0, log0), (m1, log1) = runs
    assert m0.ttft == m1.ttft            # exact float equality
    assert m0.deadline == m1.deadline
    assert m0.stall_time == m1.stall_time
    assert log0 == log1
    assert m0.summary() == m1.summary()


def test_link_telemetry_accounting():
    """Per-link byte-time integrates to at most capacity x wall-clock and
    the per-stage shares on every link sum to one."""
    sim, _ = _run(rps=16.0)
    tel = sim.telemetry
    assert tel.link_byte_time                 # something was sampled
    span = tel._t_end - tel._t0
    for lid, bt in tel.link_byte_time.items():
        assert bt <= sim.topo.capacity[lid] * span * (1 + 1e-9)
    for row in tel.link_report(top=5):
        if row["stage_share"]:
            assert sum(row["stage_share"].values()) == pytest.approx(1.0)
    share = tel.contended_stage_share()
    if share:
        assert sum(share.values()) == pytest.approx(1.0)


# ----------------------------------------------------------- stage-log bound
def test_stage_log_counts_drops_and_warns_once():
    log = StageLog(maxlen=4)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        for i in range(7):
            log.append((i, Stage.P2D, 0, 1.0, None))
    assert len(log) == 4 and log.dropped == 3
    assert list(log)[0][0] == 3              # oldest rows were the casualties
    assert sum(issubclass(x.category, RuntimeWarning) for x in w) == 1


def test_stage_log_drops_surface_in_metrics_summary():
    trace = generate_trace(WORKLOADS["qwen-conv"], 24, rps=8.0, seed=1,
                           warmup=4)
    sim = ClusterSim(_spec(telemetry=None), make_policy("mfs"))
    sim.runtime.trace_stages = True
    sim.runtime.stage_log = StageLog(maxlen=8)   # force the bound
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        m = sim.run(trace)
    assert m.stage_log_dropped > 0
    assert m.summary()["stage_log_dropped"] == m.stage_log_dropped
    # ... and stays OUT of the summary when no truncation happened
    sim2, m2 = _run(n=12, rps=4.0)
    assert "stage_log_dropped" not in m2.summary()
