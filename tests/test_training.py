"""Training substrate: loss goes down, checkpoint/restore resumes
bit-identically (fault-tolerance contract), optimizer math."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import SMOKES
from repro.launch.train import run as train_run, synthetic_batch
from repro.models.lm import build_model
from repro.training.checkpoint import (latest_step, restore_checkpoint,
                                       save_checkpoint)
from repro.training.optim import AdamWConfig, adamw_init, adamw_update
from repro.training.trainer import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup=1, weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params, cfg)
    f = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(f)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(f(params)) < 1e-2


def test_adamw_grad_clipping_reported():
    cfg = AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params, cfg)
    grads = {"w": jnp.full((4,), 100.0)}
    _, _, gnorm = adamw_update(grads, state, params, cfg)
    assert float(gnorm) == pytest.approx(200.0)


@pytest.mark.slow
def test_train_loss_decreases():
    """~60 steps on a tiny fixed dataset: loss must drop measurably."""
    cfg = SMOKES["smollm-360m"]
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3, warmup=10)
    state = init_train_state(model, KEY, opt)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    batch = synthetic_batch(cfg, batch=4, seq=32, seed=0, step=0)
    first = None
    for i in range(60):
        state, metrics = step_fn(state, batch)   # overfit one batch
        if first is None:
            first = float(metrics["loss"])
    last = float(metrics["loss"])
    assert last < first - 1.0, (first, last)


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg = SMOKES["smollm-360m"]
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3)
    step_fn = jax.jit(make_train_step(model, opt))
    state = init_train_state(model, KEY, opt)
    # run 4 steps, checkpoint at 2
    states = [state]
    for step in range(4):
        batch = synthetic_batch(cfg, 2, 16, seed=7, step=step)
        state, _ = step_fn(state, batch)
        states.append(state)
        if step == 1:
            save_checkpoint(str(tmp_path), 2, state)
    assert latest_step(str(tmp_path)) == 2
    # restore and replay steps 2..3 -> bit-identical final params
    abstract = jax.eval_shape(lambda k: init_train_state(model, k, opt), KEY)
    resumed = restore_checkpoint(str(tmp_path), 2, abstract)
    for step in range(2, 4):
        batch = synthetic_batch(cfg, 2, 16, seed=7, step=step)
        resumed, _ = step_fn(resumed, batch)
    for a, b in zip(jax.tree.leaves(states[-1].params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    """A bogus temp dir never shadows the newest complete checkpoint."""
    cfg = SMOKES["smollm-360m"]
    model = build_model(cfg)
    state = init_train_state(model, KEY)
    save_checkpoint(str(tmp_path), 5, state)
    os.makedirs(tmp_path / "step_00000009")     # incomplete: no manifest
    assert latest_step(str(tmp_path)) == 5


@pytest.mark.slow
def test_launcher_end_to_end(tmp_path):
    """launch.train drives a real (tiny) run with checkpointing."""
    _, losses = train_run("smollm-360m", steps=6, batch=2, seq=16,
                          ckpt_dir=str(tmp_path), ckpt_every=3,
                          log_every=0)
    assert len(losses) == 6
    assert np.isfinite(losses).all()
    assert latest_step(str(tmp_path)) == 6
    # elastic restart: resume from ckpt and continue
    _, more = train_run("smollm-360m", steps=8, batch=2, seq=16,
                        ckpt_dir=str(tmp_path), resume=True, log_every=0)
    assert len(more) == 2                        # 6 -> 8
