"""Exact reproduction of the paper's didactic scenarios.

* Fig 6  (intra-request, ingress): FS/SJF/EDF delay Layer-2's start to T=3;
  Defer-and-Promote advances it to T=2 (-33%).
* Fig 7  (intra-request, egress): FS/SJF/EDF finish Layer-2 at T=4;
  Defer-and-Promote at T=3 (-25%).
* Table 1/2 (inter-request): FS and SJF miss the urgent Flow-B deadline,
  EDF (raw request deadlines) completes loose flows unnecessarily early and
  still misses B, Karuna paces to the *request* deadline and misses the
  downstream slack; Defer-and-Promote meets all three just-in-time.

Baselines see raw request-level deadlines — "application-level deadlines do
not directly translate to individual network flow deadlines" (§6.3) — while
MFS sees materialised flow deadlines (D_r minus downstream remain): that
translation IS the paper's key observation (§3.2).
"""
import pytest

from repro.core import Stage, make_policy, MFSScheduler
from repro.core.urgency import MLUConfig
from repro.netsim.toy import make_flow, run_toy


# ------------------------------------------------------------- Fig 6 (ingress)
def _fig6_flows():
    coll = make_flow(Stage.COLLECTIVE, size=2.0)             # blocks layer 2
    p2d = make_flow(Stage.P2D, size=1.0, deadline=10.0)      # loose deadline
    return coll, p2d


@pytest.mark.parametrize("policy,expected_T", [
    ("fs", 3.0), ("sjf", 3.0), ("edf", 3.0)])
def test_fig6_baselines_delay_layer2(policy, expected_T):
    coll, p2d = _fig6_flows()
    finish = run_toy([coll, p2d], make_policy(policy))
    assert finish[coll.fid] == pytest.approx(expected_T, abs=0.05)


def test_fig6_defer_and_promote_advances_layer2():
    coll, p2d = _fig6_flows()
    finish = run_toy([coll, p2d], MFSScheduler())
    assert finish[coll.fid] == pytest.approx(2.0, abs=0.05)   # T=3 -> T=2
    assert finish[p2d.fid] <= 10.0                            # still on time


# ------------------------------------------------------------- Fig 7 (egress)
def _fig7_flows():
    coll = make_flow(Stage.COLLECTIVE, size=3.0)             # layer-2 collective
    p2d = make_flow(Stage.P2D, size=1.0, deadline=10.0)
    return coll, p2d


@pytest.mark.parametrize("policy,expected_T", [
    ("fs", 4.0), ("sjf", 4.0), ("edf", 4.0)])
def test_fig7_baselines_delay_layer2_end(policy, expected_T):
    coll, p2d = _fig7_flows()
    finish = run_toy([coll, p2d], make_policy(policy))
    assert finish[coll.fid] == pytest.approx(expected_T, abs=0.05)


def test_fig7_defer_and_promote_finishes_earlier():
    coll, p2d = _fig7_flows()
    finish = run_toy([coll, p2d], MFSScheduler())
    assert finish[coll.fid] == pytest.approx(3.0, abs=0.05)   # T=4 -> T=3
    assert finish[p2d.fid] <= 10.0


# ------------------------------------------------- Table 1/2 (inter-request)
# Flow: (size, downstream remain time, request deadline)
_TABLE1 = {"A": (2.0, 9.0, 18.0), "B": (4.0, 6.0, 12.0), "C": (3.0, 0.0, 7.0)}


def _table1_flows(materialised: bool):
    """Baselines see request deadlines; MFS sees materialised flow
    deadlines D_r - remain (the §3.2 deadline-translation observation)."""
    out = {}
    for i, (name, (size, remain, dr)) in enumerate(_TABLE1.items()):
        deadline = (dr - remain) if materialised else dr
        out[name] = make_flow(Stage.P2D, size=size, deadline=deadline, rid=i)
    return out


def _request_completion(finish, flows):
    return {name: finish[f.fid] + _TABLE1[name][1]
            for name, f in flows.items()}


def _misses(done):
    return {n for n, t in done.items() if t > _TABLE1[n][2] + 1e-6}


@pytest.mark.parametrize("policy,expected_missing", [
    ("fs", {"B", "C"}),       # dilutes everyone; urgent B and C both late
    ("sjf", {"B"}),           # small-first starves the urgent large flow
    ("edf", {"B"}),           # raw deadlines: C served first, B too late
    ("karuna", {"A", "B"}),   # paces to request deadlines: every flow with
                              # downstream remain-time lands exactly late
])
def test_table1_baselines_miss_deadlines(policy, expected_missing):
    flows = _table1_flows(materialised=False)
    finish = run_toy(list(flows.values()), make_policy(policy))
    assert _misses(_request_completion(finish, flows)) == expected_missing


def test_table1_edf_completes_loose_flow_early():
    """EDF serves C (raw deadline 7) first: done at T=3 although its request
    only needs it by 7 — 4 units of earliness burned at the bottleneck."""
    flows = _table1_flows(materialised=False)
    finish = run_toy(list(flows.values()), make_policy("edf"))
    assert finish[flows["C"].fid] == pytest.approx(3.0, abs=0.05)


def test_table1_defer_and_promote_meets_all_just_in_time():
    flows = _table1_flows(materialised=True)
    finish = run_toy(list(flows.values()), MFSScheduler(MLUConfig(K=8)))
    done = _request_completion(finish, flows)
    assert _misses(done) == set()
    # just-in-time: total positive earliness below EDF's (which burns >= 4)
    earliness = sum(_TABLE1[n][2] - t for n, t in done.items())
    assert earliness <= 3.0 + 1e-6
