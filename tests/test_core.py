"""Unit + property tests for the paper's core: MLU/RLI urgency, the RMLQ
invariants (I1-I4), RED, and Algorithm 1."""
import math

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (BatchLoad, Flow, MLUConfig, RMLQ, Stage,
                        geometric_thresholds, inter_request_schedule, mlu,
                        mlu_level, new_flow_id, red_score, rli_level)
from repro.core.msflow import FlowState
from repro.core.red import partition_by_max_gap


def _flow(stage=Stage.P2D, deadline=1.0, size=100.0):
    return Flow(fid=new_flow_id(), rid=0, unit=0, stage=stage, size=size,
                src=0, dst=1, target_layer=0, n_layers=8, deadline=deadline)


# ------------------------------------------------------------------ urgency
def test_mlu_basic():
    # 100 bytes, 1s budget, 200 B/s clean link -> needs half the link
    assert mlu(100, 1.0, 200.0) == pytest.approx(0.5)
    # background load halves effective capacity -> needs all of it
    assert mlu(100, 1.0, 200.0, rho=0.5) == pytest.approx(1.0)
    assert mlu(0.0, 1.0, 200.0) == 0.0
    assert math.isinf(mlu(100, 0.0, 200.0))
    assert math.isinf(mlu(100, -1.0, 200.0))


def test_geometric_ladder():
    qs = geometric_thresholds(8, E=4.0, U=0.5)
    assert len(qs) == 7
    for a, b in zip(qs, qs[1:]):
        assert a / b == pytest.approx(4.0)      # constant ratio = minimal
    assert qs[0] == pytest.approx(0.125)        # U * E^-1


def test_mlu_level_bands():
    cfg = MLUConfig(K=8, E=4.0, U=0.5)
    assert mlu_level(0.9, cfg) == 1             # critical
    assert mlu_level(0.5, cfg) == 1
    assert mlu_level(0.2, cfg) == 2             # within [Q_1, U)
    assert mlu_level(1e-9, cfg) == cfg.K        # ample laxity
    # infeasible flows are NOT promoted (Black-Hole guard)
    assert mlu_level(1.5, cfg) == cfg.K
    assert mlu_level(math.inf, cfg) == cfg.K


@given(st.floats(min_value=1e-9, max_value=1.0),
       st.floats(min_value=1e-9, max_value=0.999))
def test_mlu_level_monotone_in_urgency(v, smaller_frac):
    """More urgency never maps to a lower priority (level never increases)."""
    cfg = MLUConfig()
    lo = mlu_level(v * smaller_frac, cfg)
    hi = mlu_level(v, cfg)
    assert hi <= lo


def test_rli_level():
    cfg = MLUConfig(K=8)
    assert rli_level(0, cfg) == 2               # Stage-2: top of implicit band
    assert rli_level(1, cfg) == 3
    assert rli_level(100, cfg) == cfg.K         # capped at lowest queue (I4)
    assert rli_level(-3, cfg) == 2


# --------------------------------------------------------------------- RMLQ
def test_rmlq_monotone_promotion():
    q = RMLQ(MLUConfig(K=8))
    f = _flow()
    q.insert(f, 6)
    assert f.level == 6
    assert q.promote(f, 3) is True
    assert f.level == 3
    # I1: demotion requests are ignored
    assert q.promote(f, 7) is False
    assert f.level == 3


def test_rmlq_level1_reserved_for_explicit():
    q = RMLQ(MLUConfig(K=8))
    implicit = _flow(stage=Stage.COLLECTIVE, deadline=None)
    q.insert(implicit, 1)
    assert implicit.level == 2                  # I3: clamped out of level 1
    q.promote(implicit, 1)
    assert implicit.level == 2
    explicit = _flow(stage=Stage.P2D, deadline=5.0)
    q.insert(explicit, 1)
    assert explicit.level == 1


def test_rmlq_scavenger_cycle():
    q = RMLQ(MLUConfig(K=8))
    f = _flow()
    q.insert(f, 4)
    q.demote_to_scavenger(f)
    assert f.level == q.K + 1
    assert f.state == FlowState.PRUNED
    q.readmit(f, 5)
    assert f.level == 5
    assert f.state == FlowState.ACTIVE


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 10), st.booleans()),
                min_size=1, max_size=40))
def test_rmlq_invariants_random_ops(ops):
    """Random insert/promote sequences preserve I1 + I3 + I4."""
    cfg = MLUConfig(K=8)
    q = RMLQ(cfg)
    flows = []
    for level, explicit in ops:
        f = _flow(stage=Stage.P2D if explicit else Stage.KV_REUSE,
                  deadline=1.0 if explicit else None)
        q.insert(f, level)
        flows.append((f, f.level))
    for f, initial in flows:
        assert 1 <= f.level <= cfg.K
        if not f.explicit_deadline:
            assert f.level >= 2                 # I3
        q.promote(f, f.level - 3)
        assert f.level <= initial               # I1 over the whole history


# ---------------------------------------------------------------------- RED
def test_red_partition():
    tight, loose = partition_by_max_gap([1.0, 1.1, 5.0, 5.2])
    assert tight == [1.0, 1.1]
    assert loose == [5.0, 5.2]


def test_red_counters_piggyback():
    """One tight outlier among many loose peers must NOT hijack the batch."""
    outlier_batch = [1.0] + [10.0] * 9          # f = 0.1
    uniform_batch = [5.0] * 10
    red_outlier = red_score(outlier_batch)
    red_uniform = red_score(uniform_batch)
    # plain EDF would order outlier_batch (min 1.0) first; RED does not
    assert red_outlier > red_uniform
    assert red_outlier == pytest.approx(0.1 * 1.0 + 0.9 * 10.0)


def test_red_all_tight_degenerates_to_edf():
    assert red_score([3.0, 3.0, 3.0]) == 3.0
    assert red_score([2.0]) == 2.0


@given(st.lists(st.floats(min_value=0.0, max_value=1e6), min_size=1,
                max_size=30))
def test_red_bounded_by_batch_extremes(ds):
    r = red_score(ds)
    assert min(ds) - 1e-6 <= r <= max(ds) + 1e-6


# -------------------------------------------------------------- Algorithm 1
def _mk_batch(bid, loads, deadlines, compute=0.0):
    return BatchLoad(bid=bid,
                     request_loads={r: np.asarray(l, np.float64)
                                    for r, l in loads.items()},
                     deadlines=deadlines, compute_time=compute)


def test_alg1_feasible_batches_untouched():
    bw = np.array([100.0, 100.0])
    b1 = _mk_batch(1, {1: [10, 0], 2: [0, 10]}, {1: 1.0, 2: 1.0})
    b2 = _mk_batch(2, {3: [10, 10]}, {3: 2.0})
    out = inter_request_schedule([b1, b2], bw)
    assert out.order == [1, 2]
    assert out.pruned == []


def test_alg1_prunes_black_hole():
    """An infeasible heavy request is pruned so viable peers survive."""
    bw = np.array([100.0])
    # rid 1 alone needs 10s on the port; deadline is 1s -> doomed
    b = _mk_batch(1, {1: [1000.0], 2: [20.0]}, {1: 1.0, 2: 1.0})
    out = inter_request_schedule([b], bw)
    assert (1, 1) in out.pruned
    assert (1, 2) not in out.pruned
    assert out.finish_estimates[1] <= 1.0 + 1e-9


def test_alg1_respects_drop_budget():
    bw = np.array([1.0])
    b = _mk_batch(1, {r: [100.0] for r in range(10)},
                  {r: 0.1 for r in range(10)})
    out = inter_request_schedule([b], bw, drop_budget=3)
    assert len(out.pruned) == 3


def test_alg1_order_is_red_order():
    bw = np.array([1e9])
    tightish = _mk_batch(1, {1: [1.0]}, {1: 5.0})
    urgent = _mk_batch(2, {2: [1.0]}, {2: 1.0})
    out = inter_request_schedule([tightish, urgent], bw)
    assert out.order == [2, 1]


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 5), st.integers(1, 4),
       st.floats(min_value=0.5, max_value=50.0))
def test_alg1_admitted_set_is_feasible(n_batches, n_req, deadline):
    """Property: after pruning, every batch's worst-case finish estimate
    meets its loose-min deadline (or the drop budget was exhausted)."""
    rng = np.random.default_rng(42)
    bw = np.array([10.0, 10.0])
    batches = []
    for b in range(n_batches):
        loads = {b * 10 + r: rng.uniform(0, 30, size=2) for r in range(n_req)}
        dls = {b * 10 + r: deadline * (1 + 0.1 * r) for r in range(n_req)}
        batches.append(_mk_batch(b, loads, dls))
    out = inter_request_schedule(batches, bw, drop_budget=10**9)
    for b in batches:
        remaining = [r for r in b.request_loads if (b.bid, r) not in out.pruned]
        if remaining:
            assert out.finish_estimates[b.bid] <= b.loose_min + 1e-6
