"""Shared-runtime integration: the cluster simulator and the real-JAX
serving path drive the SAME stage-emission / event-loop code (§5's
pluggability claim), so a matched single-request, single-unit config must
produce identical stage traces on both; the full MFS policy surface (RMLQ
promotion, Algorithm 1 RED + pruning) must run on the serving path."""
import inspect

import numpy as np
import pytest

import jax

from repro.configs import SMOKES
from repro.core import Stage, make_policy
from repro.core.arbiter import MFSScheduler
from repro.models.lm import build_model
from repro.serving import DisaggConfig, DisaggServer, ServeRequest
from repro.serving import disagg as disagg_mod
from repro.simcluster import sim as sim_mod
from repro.simcluster.hw import A100, HW
from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
from repro.simcluster.trace import Request

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smollm():
    cfg = SMOKES["smollm-360m"]
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


def _sim_spec(cfg, **kw):
    kw.setdefault("par", ParallelismSpec(mode="ep", ep=1))
    kw.setdefault("n_units", 1)
    kw.setdefault("gpus_per_server", 1)
    kw.setdefault("layer_groups", 2)
    kw.setdefault("slo_mode", "per-request")
    kw.setdefault("hw", A100)
    return ClusterSpec(model=cfg, **kw)


# ------------------------------------------------------------------- parity
def test_sim_and_serve_emit_identical_stage_traces(smollm):
    """Matched config, matched request stream: (stage, group, size,
    deadline) must agree exactly between ClusterSim and DisaggServer —
    both are the same StageEmitter driven by the same MsFlowRuntime."""
    cfg, model, params = smollm
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab, size=(32,))
    suffix = rng.integers(0, cfg.vocab, size=(12,))

    srv = DisaggServer(model, params, cfg=DisaggConfig(
        n_prefill_units=1, gpus_per_unit=1, layer_groups=2, hw=A100,
        n_pages=128))
    srv.runtime.trace_stages = True
    res = srv.serve([
        ServeRequest(rid=0, arrival=0.0, tokens=prefix, max_new=1),
        ServeRequest(rid=1, arrival=0.05,
                     tokens=np.concatenate([prefix, suffix]), max_new=1),
    ])
    assert res[1].reused_tokens == 32      # Stage-1 really exercised

    sim = ClusterSim(_sim_spec(cfg), make_policy("mfs"))
    sim.runtime.trace_stages = True
    sim.run([
        Request(rid=0, arrival=0.0, prompt_len=32, reuse_len=0, prefix_id=0),
        Request(rid=1, arrival=0.05, prompt_len=44, reuse_len=32, prefix_id=0),
    ])

    def trace(log, rid):
        return [(stage, group, size, deadline)
                for r, stage, group, size, deadline in log if r == rid]

    got = trace(srv.runtime.stage_log, 1)
    want = trace(sim.runtime.stage_log, 1)
    assert len(got) == len(want) > 0
    # per-layer-group Stage 1 (KV reuse) and Stage 3 (P2D) both present
    assert {s for s, *_ in got} == {Stage.KV_REUSE, Stage.P2D}
    for (s_a, g_a, sz_a, dl_a), (s_b, g_b, sz_b, dl_b) in zip(got, want):
        assert (s_a, g_a) == (s_b, g_b)
        assert sz_a == pytest.approx(sz_b, rel=1e-12)
        if dl_a is None or dl_b is None:
            assert dl_a == dl_b
        else:
            assert dl_a == pytest.approx(dl_b, rel=1e-12)


def test_sim_and_serve_emit_identical_decode_events(smollm):
    """Decode-plane parity: matched configs must produce identical decode
    event streams (admit / token / finish / D2D migration) on both hosts —
    the plane is the same code driven by the same runtime clock."""
    from repro.core.decode import DecodePoolSpec, DecodeSpec

    cfg, model, params = smollm
    dspec = DecodeSpec(pools=(DecodePoolSpec(name="default", slots_per_ep=4),),
                       trigger_delta=2, release_delta=1,
                       min_migrate_remaining=2)
    rng = np.random.default_rng(0)
    # even rids + simultaneous arrivals -> one prefill batch admits three
    # sessions onto the same sticky endpoint -> the rebalancer must fire
    rids, arrivals, toks = [0, 2, 4], [0.0, 0.0, 0.0], [32, 36, 40]

    srv = DisaggServer(model, params, cfg=DisaggConfig(
        n_prefill_units=1, gpus_per_unit=1, layer_groups=2, hw=A100,
        n_pages=128, n_decode_units=2, decode=dspec))
    srv.decode_plane.trace = True
    srv.serve([ServeRequest(rid=r, arrival=t,
                            tokens=rng.integers(0, cfg.vocab, size=(n,)),
                            max_new=6)
               for r, t, n in zip(rids, arrivals, toks)])

    sim = ClusterSim(_sim_spec(cfg, decode_ratio=2.0, decode=dspec),
                     make_policy("mfs"))
    sim.decode_plane.trace = True
    sim.run([Request(rid=r, arrival=t, prompt_len=n, reuse_len=0,
                     prefix_id=0, out_len=6)
             for r, t, n in zip(rids, arrivals, toks)])

    a = list(srv.decode_plane.event_log)
    b = list(sim.decode_plane.event_log)
    assert [e[:4] for e in a] == [e[:4] for e in b]     # kind/rid/ep/extra
    for ea, eb in zip(a, b):
        assert ea[4] == pytest.approx(eb[4], rel=1e-9)  # event times
    kinds = {e[0] for e in a}
    assert {"admit", "token", "finish", "d2d", "migrated"} <= kinds
    assert srv.decode_plane.stats["migrations"] == \
        sim.decode_plane.stats["migrations"] > 0


def test_no_duplicated_orchestration_code():
    """The hosts must stay thin: no per-host stage emission or SchedView."""
    for mod in (sim_mod, disagg_mod):
        src = inspect.getsource(mod)
        assert "_emit_stage" not in src, mod.__name__
        assert "class _View" not in src, mod.__name__
        assert "def downstream_estimate" not in src, mod.__name__


# ------------------------------------------- MFS fidelity on the JAX path
def test_serve_path_runs_rmlq_promotion_and_red(smollm):
    """Under engineered decode-downlink contention the real-JAX path must
    exercise the full MFS machinery: RED ranks assigned by Algorithm 1 and
    at least one P2D flow promoted through the RMLQ (level decreased)."""
    cfg, model, params = smollm
    slow_nic = HW("slow", flops=A100.flops, hbm_bw=A100.hbm_bw,
                  nic_bw=1e6, scaleup_bw=A100.scaleup_bw)
    srv = DisaggServer(model, params, policy=MFSScheduler(),
                       cfg=DisaggConfig(n_prefill_units=2, gpus_per_unit=1,
                                        layer_groups=2, hw=slow_nic,
                                        slo_scale=10.0, n_pages=256))
    rng = np.random.default_rng(1)
    reqs = [ServeRequest(rid=i, arrival=i * 1e-5,
                         tokens=rng.integers(0, cfg.vocab, size=(64 + 8 * i,)),
                         max_new=1)
            for i in range(5)]
    res = srv.serve(reqs)
    assert len(res) == 5 and all(r.ttft > 0 for r in res)
    rt = srv.runtime
    assert rt.n_red_runs > 0, "Algorithm 1 (RED ordering) never ran on serve path"
    assert rt.promoted_count(Stage.P2D) > 0, \
        "no P2D flow was ever promoted through the RMLQ"


def test_serve_path_soft_pruning(smollm):
    """Overloading the admission check must demote (not drop) requests:
    every request still completes, and the prune counter moves."""
    cfg, model, params = smollm
    slow_nic = HW("slow", flops=A100.flops, hbm_bw=A100.hbm_bw,
                  nic_bw=2e5, scaleup_bw=A100.scaleup_bw)
    srv = DisaggServer(model, params, policy=MFSScheduler(),
                       cfg=DisaggConfig(n_prefill_units=2, gpus_per_unit=1,
                                        layer_groups=2, hw=slow_nic,
                                        slo_scale=1.0, n_pages=256))
    rng = np.random.default_rng(2)
    shared = rng.integers(0, cfg.vocab, size=(32,))
    reqs = [ServeRequest(rid=i, arrival=i * 1e-4,
                         tokens=np.concatenate(
                             [shared, rng.integers(0, cfg.vocab, size=(16,))]),
                         max_new=1)
            for i in range(6)]
    res = srv.serve(reqs)
    assert len(res) == 6
    assert all(len(r.tokens) >= 1 for r in res)   # soft: nothing dropped
    assert srv.runtime.n_pruned > 0


# --------------------------------------------------- TTFT-recording fix
def test_kv_light_group_requests_still_finish(smollm):
    """Regression: a super-layer group that emits no P2D flow (zero KV
    bytes) must not leave the request's TTFT unrecorded forever."""
    cfg, _, _ = smollm
    sim = ClusterSim(_sim_spec(cfg), make_policy("fs"))
    orig = sim.profile.kv_bytes_group
    sim.profile.kv_bytes_group = lambda g: 0.0 if g == 0 else orig(g)
    m = sim.run([Request(rid=0, arrival=0.0, prompt_len=64, reuse_len=0,
                         prefix_id=0)])
    assert m.ttft.get(0) is not None and m.ttft[0] > 0


def test_fully_local_p2d_requests_finish(smollm):
    """Degenerate limit: all groups KV-free (pure-state model slice) —
    completion must fall back to prefill_done instead of deadlocking."""
    cfg, _, _ = smollm
    sim = ClusterSim(_sim_spec(cfg), make_policy("mfs"))
    sim.profile.kv_bytes_group = lambda g: 0.0
    m = sim.run([Request(rid=0, arrival=0.0, prompt_len=48, reuse_len=0,
                         prefix_id=0)])
    assert m.ttft.get(0) is not None and m.ttft[0] > 0
