"""Fluid network model properties: strict priority, max-min fairness,
rate caps, conservation; event queue determinism."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import Stage, new_flow_id
from repro.core.msflow import Flow
from repro.netsim.events import EventQueue
from repro.netsim.fluid import FluidNet
from repro.netsim.topology import FatTree, SingleToR
from repro.netsim.toy import OneLink


def _flow(src=0, dst=1, size=100.0, key=(0,), cap=None, stage=Stage.P2D):
    f = Flow(fid=new_flow_id(), rid=0, unit=0, stage=stage, size=size,
             src=src, dst=dst, target_layer=0, n_layers=4, deadline=None)
    f.priority_key = key
    f.rate_cap = cap
    return f


def test_strict_priority_preempts():
    net = FluidNet(OneLink(1.0))
    hi = _flow(key=(0,))
    lo = _flow(key=(1,))
    net.add(hi); net.add(lo)
    net.reallocate()
    assert hi.rate == pytest.approx(1.0)
    assert lo.rate == pytest.approx(0.0)


def test_maxmin_within_group():
    net = FluidNet(OneLink(1.0))
    flows = [_flow(key=(0,)) for _ in range(4)]
    for f in flows:
        net.add(f)
    net.reallocate()
    for f in flows:
        assert f.rate == pytest.approx(0.25)


def test_rate_cap_respected_and_leftover_shared():
    net = FluidNet(OneLink(1.0))
    capped = _flow(key=(0,), cap=0.2)
    other = _flow(key=(0,))
    net.add(capped); net.add(other)
    net.reallocate()
    assert capped.rate == pytest.approx(0.2)
    assert other.rate == pytest.approx(0.8)


def test_completion_times_exact():
    net = FluidNet(OneLink(2.0))
    f = _flow(size=10.0, key=(0,))
    net.add(f)
    net.reallocate()
    nxt = net.next_completion()
    assert nxt[0] == pytest.approx(5.0)
    done = net.advance(5.0)
    assert done == [f]
    assert f.finished == pytest.approx(5.0)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.floats(0.1, 50.0)),
                min_size=1, max_size=12))
def test_conservation_no_link_oversubscribed(flows_spec):
    """Property: allocations never exceed any link capacity and every flow
    with a clear path makes progress."""
    topo = SingleToR(4, nic_bw=1.0, gpus_per_server=2, scaleup_bw=2.0)
    net = FluidNet(topo)
    flows = []
    for prio, size in flows_spec:
        f = _flow(src=np.random.randint(0, 4), dst=np.random.randint(0, 4),
                  size=size, key=(prio,))
        flows.append(f)
        net.add(f)
    net.reallocate()
    usage = {}
    for f in flows:
        for lid in net.routes[f.fid]:
            usage[lid] = usage.get(lid, 0.0) + f.rate
    for lid, u in usage.items():
        assert u <= topo.capacity[lid] + 1e-6
    # top-priority group always gets positive aggregate rate
    top = min(tuple(f.priority_key) for f in flows)
    assert sum(f.rate for f in flows if tuple(f.priority_key) == top) > 0


def test_fat_tree_ecmp_routes_consistent():
    topo = FatTree(racks=2, hosts_per_rack=4, nic_bw=1.0,
                   gpus_per_server=2, scaleup_bw=4.0)
    r1 = topo.route(0, 7, fid=42)
    r2 = topo.route(0, 7, fid=42)
    assert r1 == r2                              # per-flow deterministic
    assert len(r1) == 4                          # host-leaf-spine-leaf-host
    same_rack = topo.route(0, 3, fid=1)
    assert len(same_rack) == 2
    same_server = topo.route(0, 1, fid=1)
    assert len(same_server) == 2                 # scale-up fabric


def test_victim_unit_ingress_contention():
    """Many senders -> one victim endpoint: its downlink is the bottleneck
    (§2.2 inter-request contention)."""
    topo = SingleToR(4, nic_bw=1.0, gpus_per_server=1)
    net = FluidNet(topo)
    flows = [_flow(src=s, dst=0, size=10.0, key=(0,)) for s in (1, 2, 3)]
    for f in flows:
        net.add(f)
    net.reallocate()
    for f in flows:
        assert f.rate == pytest.approx(1.0 / 3.0)


def test_event_queue_fifo_and_epoch():
    q = EventQueue()
    q.push(1.0, "a", None)
    q.push(1.0, "b", None)
    q.push(0.5, "c", None)
    assert q.pop()[1] == "c"
    assert q.pop()[1] == "a"                     # FIFO tie-break
    assert q.pop()[1] == "b"
    with pytest.raises(ValueError):
        q.push(0.1, "late", None)                # scheduling into the past
