"""Fluid network model properties: strict priority, max-min fairness,
rate caps, conservation; event queue determinism."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import Stage, new_flow_id
from repro.core.msflow import Flow
from repro.netsim.events import EventQueue
from repro.netsim.fluid import FluidNet
from repro.netsim.topology import FatTree, SingleToR
from repro.netsim.toy import OneLink


def _flow(src=0, dst=1, size=100.0, key=(0,), cap=None, stage=Stage.P2D):
    f = Flow(fid=new_flow_id(), rid=0, unit=0, stage=stage, size=size,
             src=src, dst=dst, target_layer=0, n_layers=4, deadline=None)
    f.priority_key = key
    f.rate_cap = cap
    return f


def test_strict_priority_preempts():
    net = FluidNet(OneLink(1.0))
    hi = _flow(key=(0,))
    lo = _flow(key=(1,))
    net.add(hi); net.add(lo)
    net.reallocate()
    assert hi.rate == pytest.approx(1.0)
    assert lo.rate == pytest.approx(0.0)


def test_maxmin_within_group():
    net = FluidNet(OneLink(1.0))
    flows = [_flow(key=(0,)) for _ in range(4)]
    for f in flows:
        net.add(f)
    net.reallocate()
    for f in flows:
        assert f.rate == pytest.approx(0.25)


def test_rate_cap_respected_and_leftover_shared():
    net = FluidNet(OneLink(1.0))
    capped = _flow(key=(0,), cap=0.2)
    other = _flow(key=(0,))
    net.add(capped); net.add(other)
    net.reallocate()
    assert capped.rate == pytest.approx(0.2)
    assert other.rate == pytest.approx(0.8)


def test_completion_times_exact():
    net = FluidNet(OneLink(2.0))
    f = _flow(size=10.0, key=(0,))
    net.add(f)
    net.reallocate()
    nxt = net.next_completion()
    assert nxt[0] == pytest.approx(5.0)
    done = net.advance(5.0)
    assert done == [f]
    assert f.finished == pytest.approx(5.0)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.floats(0.1, 50.0)),
                min_size=1, max_size=12))
def test_conservation_no_link_oversubscribed(flows_spec):
    """Property: allocations never exceed any link capacity and every flow
    with a clear path makes progress."""
    topo = SingleToR(4, nic_bw=1.0, gpus_per_server=2, scaleup_bw=2.0)
    net = FluidNet(topo)
    flows = []
    for prio, size in flows_spec:
        f = _flow(src=np.random.randint(0, 4), dst=np.random.randint(0, 4),
                  size=size, key=(prio,))
        flows.append(f)
        net.add(f)
    net.reallocate()
    usage = {}
    for f in flows:
        for lid in net.routes[f.fid]:
            usage[lid] = usage.get(lid, 0.0) + f.rate
    for lid, u in usage.items():
        assert u <= topo.capacity[lid] + 1e-6
    # top-priority group always gets positive aggregate rate
    top = min(tuple(f.priority_key) for f in flows)
    assert sum(f.rate for f in flows if tuple(f.priority_key) == top) > 0


def test_fat_tree_ecmp_routes_consistent():
    topo = FatTree(racks=2, hosts_per_rack=4, nic_bw=1.0,
                   gpus_per_server=2, scaleup_bw=4.0)
    r1 = topo.route(0, 7, fid=42)
    r2 = topo.route(0, 7, fid=42)
    assert r1 == r2                              # per-flow deterministic
    assert len(r1) == 4                          # host-leaf-spine-leaf-host
    same_rack = topo.route(0, 3, fid=1)
    assert len(same_rack) == 2
    same_server = topo.route(0, 1, fid=1)
    assert len(same_server) == 2                 # scale-up fabric


def test_victim_unit_ingress_contention():
    """Many senders -> one victim endpoint: its downlink is the bottleneck
    (§2.2 inter-request contention)."""
    topo = SingleToR(4, nic_bw=1.0, gpus_per_server=1)
    net = FluidNet(topo)
    flows = [_flow(src=s, dst=0, size=10.0, key=(0,)) for s in (1, 2, 3)]
    for f in flows:
        net.add(f)
    net.reallocate()
    for f in flows:
        assert f.rate == pytest.approx(1.0 / 3.0)


def test_remove_purges_link_accounting():
    """Regression: cancelling a flow (e.g. pruned Stage-1 recompute) must
    release its rate from the link accounting immediately — otherwise
    ``bottleneck`` / ``bottleneck_protected`` rho stays inflated until the
    next reallocation."""
    net = FluidNet(OneLink(1.0))
    a = _flow(key=(0,))
    b = _flow(key=(0,))
    probe = _flow(key=(1,))
    for f in (a, b, probe):
        net.add(f)
    net.reallocate()
    assert a.rate == pytest.approx(0.5)
    net.remove(a)                      # cancelled, NOT followed by reallocate
    assert a.rate == 0.0
    _, rho = net.bottleneck(probe)
    assert rho == pytest.approx(0.5)   # only b's rate remains
    _, rho_p = net.bottleneck_protected(probe, lambda f: True)
    assert rho_p == pytest.approx(0.5)
    assert net._link_rate[0] == pytest.approx(0.5)


def test_completed_flows_release_bandwidth_accounting():
    """Flows finished by ``advance`` stop counting toward rho as well."""
    net = FluidNet(OneLink(1.0))
    small = _flow(size=1.0, key=(0,))
    big = _flow(size=100.0, key=(0,))
    probe = _flow(key=(1,))
    for f in (small, big, probe):
        net.add(f)
    net.reallocate()
    done = net.advance(2.0)            # small (1.0 bytes at 0.5) finishes
    assert done == [small]
    _, rho = net.bottleneck(probe)
    assert rho == pytest.approx(0.5)


def _random_churn(seed, incremental, n_flows=60, n_events=120):
    """Drive one FluidNet through a random add/remove/rekey/recap sequence;
    returns the rate vector after every reallocation."""
    rng = np.random.default_rng(seed)
    topo = FatTree(racks=2, hosts_per_rack=4, nic_bw=1.0,
                   gpus_per_server=2, scaleup_bw=4.0)
    net = FluidNet(topo, incremental=incremental)
    flows = []
    fid = 0
    def mk():
        nonlocal fid
        fid += 1
        f = _flow(src=int(rng.integers(0, topo.n_nodes)),
                  dst=int(rng.integers(0, topo.n_nodes)),
                  size=float(rng.uniform(1, 50)),
                  key=(int(rng.integers(0, 4)),),
                  cap=float(rng.uniform(0.05, 0.5))
                  if rng.uniform() < 0.3 else None)
        f.fid = 10_000 * (seed + 1) + fid       # deterministic across modes
        return f
    out = []
    for _ in range(n_flows):
        f = mk(); flows.append(f); net.add(f)
    for _ in range(n_events):
        op = rng.integers(0, 4)
        if op == 0 or not flows:
            f = mk(); flows.append(f); net.add(f)
        elif op == 1:
            f = flows.pop(int(rng.integers(len(flows)))); net.remove(f)
        elif op == 2:
            f = flows[int(rng.integers(len(flows)))]
            f.priority_key = (int(rng.integers(0, 4)),)
        else:
            f = flows[int(rng.integers(len(flows)))]
            f.rate_cap = float(rng.uniform(0.05, 0.5)) \
                if rng.uniform() < 0.5 else None
        net.reallocate()
        out.append(sorted((f.fid, f.rate) for f in flows))
    return out


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_incremental_matches_full(seed):
    """Dirty-group incremental reallocation must produce BIT-IDENTICAL rates
    to the from-scratch allocation under arbitrary churn (adds, removals,
    key changes, cap changes)."""
    inc = _random_churn(seed, incremental=True)
    full = _random_churn(seed, incremental=False)
    assert inc == full                 # exact float equality, every epoch


def _wide_group_churn(warmstart, n_flows=128, n_events=60):
    """One wide single-key group (vectorized fill) under per-event
    membership churn; returns rates after every reallocation."""
    rng = np.random.default_rng(7)
    topo = FatTree(racks=2, hosts_per_rack=4, nic_bw=1.0,
                   gpus_per_server=2, scaleup_bw=4.0)
    net = FluidNet(topo)
    net.warmstart = warmstart
    fid = [0]
    def mk():
        fid[0] += 1
        f = _flow(src=int(rng.integers(0, topo.n_nodes)),
                  dst=int(rng.integers(0, topo.n_nodes)),
                  size=float(rng.uniform(1, 50)), key=(0,),
                  cap=float(rng.uniform(0.05, 0.5))
                  if rng.uniform() < 0.2 else None)
        f.fid = 500_000 + fid[0]
        return f
    flows = [mk() for _ in range(n_flows)]
    for f in flows:
        net.add(f)
    net.reallocate()
    out = [sorted((f.fid, f.rate) for f in flows)]
    for _ in range(n_events):
        victim = flows.pop(int(rng.integers(len(flows))))
        net.remove(victim)
        nf = mk()
        flows.append(nf)
        net.add(nf)
        net.reallocate()
        out.append(sorted((f.fid, f.rate) for f in flows))
    return out, net.stats


def test_warmstart_matches_cold():
    """Warm-started within-group fills (patched incidence structure) must
    produce BIT-IDENTICAL rates to cold from-scratch builds, and must
    actually take the patch path under pure membership churn."""
    warm, wstats = _wide_group_churn(True)
    cold, cstats = _wide_group_churn(False)
    assert warm == cold                # exact float equality, every epoch
    assert wstats["vec_patches"] > 0
    assert cstats["vec_patches"] == 0


def test_incremental_skips_clean_groups():
    """A reallocation with nothing changed must re-fill nothing; churn in
    the lowest-priority group must not re-fill the more urgent groups."""
    topo = SingleToR(8, nic_bw=1.0, gpus_per_server=2, scaleup_bw=2.0)
    net = FluidNet(topo)
    hi = [_flow(src=0, dst=4, key=(0,)) for _ in range(3)]
    lo = [_flow(src=1, dst=5, key=(9,)) for _ in range(3)]   # disjoint NICs
    for f in hi + lo:
        net.add(f)
    net.reallocate()
    fills0 = net.stats["group_fills"]
    net.reallocate()                   # no change at all -> zero fills
    assert net.stats["group_fills"] == fills0
    extra = _flow(src=1, dst=5, key=(9,))
    net.add(extra)
    net.reallocate()                   # dirty: only the (9,) group
    assert net.stats["group_fills"] == fills0 + 1
    for f in hi:
        assert f.rate == pytest.approx(1.0 / 3.0)


def test_next_completion_heap_matches_scan():
    """The lazy-invalidation heap must return the same prediction as a
    linear scan across rate changes, removals and partial progress."""
    rng = np.random.default_rng(3)
    topo = SingleToR(4, nic_bw=1.0, gpus_per_server=2, scaleup_bw=2.0)
    net = FluidNet(topo)
    flows = [_flow(src=int(rng.integers(0, 4)), dst=int(rng.integers(0, 4)),
                   size=float(rng.uniform(5, 50)),
                   key=(int(rng.integers(0, 3)),)) for _ in range(12)]
    for f in flows:
        net.add(f)
    t = 0.0
    for step in range(40):
        if step % 7 == 3 and net.flows:
            victim = next(iter(net.flows.values()))
            net.remove(victim)
        for f in net.flows.values():
            if rng.uniform() < 0.2:
                f.priority_key = (int(rng.integers(0, 3)),)
        net.reallocate()
        nxt = net.next_completion()
        best = min(((net.now + max(f.remaining / f.rate, 1e-12), f.fid)
                    for f in net.flows.values() if f.rate > 0.0),
                   default=None)
        if best is None:
            assert nxt is None
            break
        assert nxt is not None
        assert nxt[0] == pytest.approx(best[0], rel=1e-9)
        t = min(best[0], t + 0.5)
        net.advance(t)


def test_class_rates_tag_shared_links():
    """Per-link flow-class breakdown: a shared downlink reports how much
    bandwidth P2D vs D2D is actually holding."""
    net = FluidNet(OneLink(1.0))
    p2d = _flow(key=(0,), stage=Stage.P2D)
    d2d = _flow(key=(0,), stage=Stage.D2D)
    net.add(p2d); net.add(d2d)
    net.reallocate()
    by_class = net.class_rates(0)
    assert by_class[Stage.P2D] == pytest.approx(0.5)
    assert by_class[Stage.D2D] == pytest.approx(0.5)
    agg = net.class_utilization()
    assert agg[Stage.D2D] == pytest.approx(0.5)
    assert net.class_utilization(lids=[99]) == {}


def test_event_queue_fifo_and_epoch():
    q = EventQueue()
    q.push(1.0, "a", None)
    q.push(1.0, "b", None)
    q.push(0.5, "c", None)
    assert q.pop()[1] == "c"
    assert q.pop()[1] == "a"                     # FIFO tie-break
    assert q.pop()[1] == "b"
    with pytest.raises(ValueError):
        q.push(0.1, "late", None)                # scheduling into the past
