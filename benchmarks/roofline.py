"""Roofline analysis (§g) — derives the three roofline terms per
(arch x shape) cell from the dry-run's compiled artifacts.

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip)

Sources: ``compiled.cost_analysis()`` (flops / bytes accessed) and the
partitioned-HLO collective parse, both recorded by repro.launch.dryrun.
The SPMD module IS the per-chip program, so no further division by chips.
The dry-run is run with ``--unroll`` for this table: XLA's cost analysis
counts a ``scan`` body once regardless of trip count, so only unrolled
lowering yields exact per-step counts.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI.

Reported per cell: all three terms (seconds), the dominant term,
MODEL_FLOPS (6ND train / 2ND prefill / 2N/token decode), the useful-compute
ratio MODEL_FLOPS / (HLO_FLOPs x chips), and a rule-generated note on what
would move the dominant term.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from .common import emit

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def load_dryrun(out_dir: str = "experiments",
                mesh: str = "single_pod_16x16") -> List[Dict]:
    """Prefer the unrolled (exact-count) record, then the optimized scan
    record, then the baseline scan one."""
    for tag in ("_unroll", "_opt", ""):
        path = os.path.join(out_dir, f"dryrun_{mesh}{tag}.json")
        if os.path.exists(path):
            with open(path) as f:
                return json.load(f)
    raise FileNotFoundError(
        f"no dryrun json for mesh {mesh} in {out_dir}; run "
        "`python -m repro.launch.dryrun --mesh single --unroll`")


def terms(rec: Dict, chips: int = 256) -> Optional[Dict]:
    if rec["status"] != "ok":
        return None
    ca = rec.get("cost_analysis", {})
    flops = ca.get("flops", -1.0)
    bts = ca.get("bytes_accessed", -1.0)
    coll = rec["collectives"]["total_bytes"]
    t_c = flops / PEAK_FLOPS
    t_m = bts / HBM_BW
    t_n = coll / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
              key=lambda kv: kv[1])[0]
    model_fl = rec.get("model_flops", 0.0)
    ratio = model_fl / max(flops * chips, 1e-9)
    note = {
        "compute": ("compute-bound: raise useful-FLOP ratio (less remat "
                    "recompute / padding) or grow per-chip batch"),
        "memory": ("HBM-bound: shrink resident/streamed bytes — fused or "
                   "chunked loss, tighter activation policy, int8 KV, "
                   "no KV-head expansion"),
        "collective": ("collective-bound: reshard to cut gather/scatter "
                       "volume, overlap collectives with compute, or move "
                       "the collective to a cheaper axis"),
    }[dom]
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "bound": dom, "model_flops": model_fl,
        "useful_ratio": ratio, "note": note,
        "collective_breakdown": {
            k: v for k, v in rec["collectives"].items()
            if isinstance(v, dict) and v["count"] > 0},
        "args_gb_per_dev": rec.get("memory_analysis", {}).get(
            "argument_size_in_bytes", 0) / 1e9,
        "temp_gb_per_dev": rec.get("memory_analysis", {}).get(
            "temp_size_in_bytes", 0) / 1e9,
    }


def main(quick: bool = False, out_dir: str = "experiments"):
    rows = []
    recs = load_dryrun(out_dir)
    table = []
    for rec in recs:
        t = terms(rec)
        if t is None:
            continue
        table.append(t)
        frac = t["useful_ratio"]
        emit(rows,
             f"roofline.{t['arch']}.{t['shape']}",
             f"{max(t['compute_s'], t['memory_s'], t['collective_s']):.4f}s",
             f"bound={t['bound']} compute={t['compute_s']:.4f}s "
             f"memory={t['memory_s']:.4f}s coll={t['collective_s']:.4f}s "
             f"useful={frac:.2f}")
    with open(os.path.join(out_dir, "roofline.json"), "w") as f:
        json.dump(table, f, indent=1)
    # headline: worst cells per bound class
    for bound in ("compute", "memory", "collective"):
        cells = [t for t in table if t["bound"] == bound]
        if cells:
            worst = max(cells, key=lambda t: max(
                t["compute_s"], t["memory_s"], t["collective_s"]))
            emit(rows, f"roofline.worst_{bound}_bound",
                 f"{worst['arch']}/{worst['shape']}", worst["note"])
    return rows


if __name__ == "__main__":
    main()
