"""Bench regression gate: diff a fresh sweep against the committed
artifacts and print a drift table.

Two modes:

``compare`` (default)
    python -m benchmarks.bench_compare BASELINE.json FRESH.json [--quick]
    Walks both JSON trees, pairs numeric leaves by path, and flags every
    leaf whose drift exceeds its metric-class tolerance. Attainment-like
    fractions compare by absolute difference; everything else by
    relative difference. ``--quick`` widens the tolerances: the CI quick
    sweep runs fewer requests/rates than the committed full sweep, so
    its numbers legitimately sit off the committed ones and the gate is
    a *drift* report, not an equality check (the CI step is
    non-blocking either way — the table is for humans).

``--sections-identical``
    python -m benchmarks.bench_compare --sections-identical A.json B.json \
        [--ignore yardstick ...]
    Byte-identity check for the ``--only <arm>`` merge workflow: every
    top-level section except the ignored ones must serialize identically
    in both files. Automates the "all legacy sections byte-identical"
    acceptance check that used to be done by eyeballing a diff.

Exit status: 0 = within tolerance / identical, 1 = drift or divergence
(callers decide whether that blocks; CI wires it with
``continue-on-error``).
"""
from __future__ import annotations

import json
import sys
from typing import Any, Dict, Iterator, List, Optional, Tuple

#: per-metric-class tolerances, (full, quick). Matched by substring on the
#: leaf path; first hit wins, ``DEFAULT_TOL`` otherwise.
ABS_CLASSES = ("attainment", "ttft", "tpot", "coverage", "hit_rate",
               "share", "tier_mix", "slo_mix", "curves", "feasible",
               "frac_of_ceiling", "tbt")
TOLERANCES: Tuple[Tuple[str, float, float], ...] = (
    ("overhead", 0.05, 0.08),      # wall-clock ratios are the noisiest
    ("wall", float("inf"), float("inf")),   # never gate on wall-clock
    ("attainment", 0.05, 0.20),
    ("coverage", 0.10, 0.25),
    ("hit_rate", 0.05, 0.15),
    ("share", 0.10, 0.25),
    ("ratio", 0.15, 0.40),
    ("gain", 0.15, 0.40),
    ("rate", 0.10, 0.30),
    ("bytes", 0.10, 0.35),
)
DEFAULT_TOL = (0.10, 0.30)


def _leaves(node: Any, path: str = "") -> Iterator[Tuple[str, Any]]:
    if isinstance(node, dict):
        for k in node:
            yield from _leaves(node[k], f"{path}.{k}" if path else str(k))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from _leaves(v, f"{path}[{i}]")
    else:
        yield path, node


def _tolerance(path: str, quick: bool) -> float:
    low = path.lower()
    for key, full, qk in TOLERANCES:
        if key in low:
            return qk if quick else full
    return DEFAULT_TOL[1] if quick else DEFAULT_TOL[0]


def _drift(path: str, a: float, b: float) -> float:
    """Absolute drift for bounded fractions, relative otherwise."""
    low = path.lower()
    if any(k in low for k in ABS_CLASSES):
        return abs(b - a)
    return abs(b - a) / max(abs(a), 1e-12)


def compare(baseline: Dict, fresh: Dict, quick: bool = False,
            out=sys.stdout) -> int:
    """Print the drift table; return the number of out-of-tolerance leaves
    (missing/new paths are reported but don't count as drift — quick
    sweeps legitimately drop rates/arms)."""
    base = dict(_leaves(baseline))
    new = dict(_leaves(fresh))
    n_bad = n_num = 0
    lines: List[str] = []
    for path in sorted(base.keys() | new.keys()):
        if path not in new:
            lines.append(f"  - {path}: only in baseline")
            continue
        if path not in base:
            lines.append(f"  + {path}: only in fresh")
            continue
        a, b = base[path], new[path]
        if isinstance(a, bool) or isinstance(b, bool) \
                or not isinstance(a, (int, float)) \
                or not isinstance(b, (int, float)):
            if a != b:
                lines.append(f"  ~ {path}: {a!r} -> {b!r}")
            continue
        n_num += 1
        d = _drift(path, float(a), float(b))
        tol = _tolerance(path, quick)
        if d > tol:
            n_bad += 1
            lines.append(f"  ! {path}: {a:.4g} -> {b:.4g} "
                         f"(drift {d:.3f} > tol {tol:.3f})")
    mode = "quick" if quick else "full"
    print(f"bench_compare: {n_num} numeric leaves, {n_bad} over "
          f"{mode}-sweep tolerance", file=out)
    for ln in lines:
        print(ln, file=out)
    if not lines:
        print("  (no drift, no schema changes)", file=out)
    return n_bad


def sections_identical(a: Dict, b: Dict, ignore: Tuple[str, ...] = (),
                       out=sys.stdout) -> List[str]:
    """Return the top-level sections (minus ``ignore``) that differ —
    serialized comparison, so float formatting counts, which is exactly
    the byte-identity the ``--only`` merge promises."""
    diff = []
    for key in sorted(set(a) | set(b)):
        if key in ignore:
            continue
        sa = json.dumps(a.get(key), sort_keys=True)
        sb = json.dumps(b.get(key), sort_keys=True)
        if sa != sb:
            diff.append(key)
    status = "IDENTICAL" if not diff else "DIVERGED: " + ", ".join(diff)
    print(f"bench_compare: legacy sections {status} "
          f"(ignored: {', '.join(ignore) or 'none'})", file=out)
    return diff


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    quick = "--quick" in argv
    if quick:
        argv.remove("--quick")
    if "--sections-identical" in argv:
        argv.remove("--sections-identical")
        ignore: List[str] = []
        while "--ignore" in argv:
            i = argv.index("--ignore")
            ignore.append(argv[i + 1])
            del argv[i:i + 2]
        with open(argv[0]) as fh:
            a = json.load(fh)
        with open(argv[1]) as fh:
            b = json.load(fh)
        return 1 if sections_identical(a, b, tuple(ignore)) else 0
    with open(argv[0]) as fh:
        baseline = json.load(fh)
    with open(argv[1]) as fh:
        fresh = json.load(fh)
    return 1 if compare(baseline, fresh, quick=quick) else 0


if __name__ == "__main__":
    sys.exit(main())
