"""Fig 13/14 — where MFS's gains come from: collective completion time
(expert/sequence parallel) and request earliness, per policy, at a
calibrated contended load.

Paper: MFS cuts DBRX EP-collective CCT by ~52% and positive earliness by
~42% vs FS/SJF/EDF; Karuna shows minimal earliness but high violation risk."""
from __future__ import annotations

from .common import POLICIES, calibrate_rate, emit, run_sim, spec_for


def _one(rows, tag, spec, wl, n, quick):
    rate = round(calibrate_rate(spec, wl, target=0.6, n=min(n, 64)), 2)
    res = {p: run_sim(p, spec, wl, n=n, rps=rate) for p in POLICIES}
    for p in POLICIES:
        emit(rows, f"{tag}.{p}.cct_slowdown", f"{res[p]['cct_slowdown']:.3f}",
             f"rate={rate} slo={res[p]['slo_attainment']:.3f}")
        emit(rows, f"{tag}.{p}.pos_earliness_s",
             f"{res[p]['pos_earliness']:.4f}")
    cct_cut = 1 - res["mfs"]["cct_slowdown"] / res["fs"]["cct_slowdown"]
    base_e = max(res[p]["pos_earliness"] for p in ("fs", "sjf", "edf"))
    earl_cut = 1 - res["mfs"]["pos_earliness"] / max(base_e, 1e-12)
    emit(rows, f"{tag}.mfs_cct_reduction_vs_fs", f"{cct_cut:.1%}",
         "paper ~52% (fig13) / ~50% (fig14)")
    emit(rows, f"{tag}.mfs_earliness_reduction", f"{earl_cut:.1%}",
         "paper ~42%")


def main(quick: bool = False):
    rows = []
    n = 48 if quick else 128
    _one(rows, "fig13.dbrx_qwenconv",
         spec_for("dbrx", mode="ep", tp=2, ep=16, n_units=2),
         "qwen-conv", n, quick)
    _one(rows, "fig14.llama3_mooncakeconv",
         spec_for("llama3-8b", mode="sp", tp=4, sp=4, n_units=2),
         "mooncake-conv", n, quick)
    return rows


if __name__ == "__main__":
    main()
