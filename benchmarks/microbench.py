"""Fig 6 / Fig 7 / Table 1-2 — the didactic single-link scenarios, measured
(not asserted): layer-unblock times per policy and the inter-request
deadline/earliness outcome — plus the FluidNet water-filling microbenches:
``waterfill.{1key,8key,perflow}`` measure a from-scratch reallocate across
priority-group-size regimes, ``waterfill.incremental.*`` measure the
dirty-group incremental path (full group fills per reallocation and
per-event latency vs. forced full fills) under defer-and-promote churn, and
``waterfill.warmstart.*`` measure the warm-started within-group fill on the
wide single-key group (bit-identical rates, patched incidence structure),
and ``telemetry.overhead`` / ``monitor.overhead`` measure the telemetry
collector's and the online monitor plane's wall-clock cost on an
otherwise-identical cluster run (< 5% budget each). ``--json PATH``
writes the rows as ``BENCH_microbench.json`` (row -> {value, unit})."""
from __future__ import annotations

import time

import numpy as np

from repro.core import MFSScheduler, Stage, make_policy
from repro.core.msflow import Flow, new_flow_id
from repro.netsim.fluid import FluidNet
from repro.netsim.topology import FatTree
from repro.netsim.toy import make_flow, run_toy

from .common import emit


def _fig(rows, tag, coll_size, p2d_size):
    for pol in ("fs", "sjf", "edf"):
        coll = make_flow(Stage.COLLECTIVE, size=coll_size)
        p2d = make_flow(Stage.P2D, size=p2d_size, deadline=10.0)
        finish = run_toy([coll, p2d], make_policy(pol))
        emit(rows, f"{tag}.{pol}.layer_unblock_T", f"{finish[coll.fid]:.2f}")
    coll = make_flow(Stage.COLLECTIVE, size=coll_size)
    p2d = make_flow(Stage.P2D, size=p2d_size, deadline=10.0)
    finish = run_toy([coll, p2d], MFSScheduler())
    emit(rows, f"{tag}.mfs.layer_unblock_T", f"{finish[coll.fid]:.2f}",
         "defer-and-promote")


_TABLE1 = {"A": (2.0, 9.0, 18.0), "B": (4.0, 6.0, 12.0), "C": (3.0, 0.0, 7.0)}


def _bench_waterfill(rows, n_flows: int = 512, reps: int = 20):
    """Reallocate latency vs. priority-group width. ``1key`` is the
    FairShare / shared-RMLQ-band regime (one wide group, served by the
    vectorized route-incidence fill); ``perflow`` is the SJF regime (one
    group per flow, served by the scalar walk)."""
    for label, nkeys in (("1key", 1), ("8key", 8), ("perflow", n_flows)):
        rng = np.random.default_rng(0)
        topo = FatTree(racks=8, hosts_per_rack=8, nic_bw=1.0,
                       gpus_per_server=4, scaleup_bw=4.0)
        net = FluidNet(topo)
        for i in range(n_flows):
            s, d = rng.integers(0, topo.n_nodes, size=2)
            f = Flow(new_flow_id(), i, 0, Stage.P2D,
                     float(rng.uniform(1, 100)), src=int(s), dst=int(d),
                     target_layer=0, n_layers=8)
            f.priority_key = (i % nkeys,)
            if rng.uniform() < 0.2:
                f.rate_cap = float(rng.uniform(0.05, 0.5))
            net.add(f)
        net.reallocate()                      # warm route cache
        t0 = time.perf_counter()
        for _ in range(reps):
            net.reallocate(full=True)         # measure the fill itself, not
            #                                   the dirty-group cache hit
        ms = (time.perf_counter() - t0) / reps * 1e3
        emit(rows, f"waterfill.{label}.reallocate_ms", f"{ms:.3f}",
             f"{n_flows} flows")


def _bench_incremental(rows, n_flows: int = 512, n_bands: int = 8,
                       n_events: int = 400):
    """Dirty-group incremental reallocation vs. from-scratch fills under the
    runtime's real churn pattern: each event completes one flow and admits a
    replacement, with churn concentrated in the *deferred* (low-priority)
    bands — defer-and-promote admits new flows low, so urgent bands stay
    clean and replay their cached allocation. Reports per-event latency
    (reallocate + next_completion, the per-event fluid-net work) and full
    group fills per reallocation for both modes; rates are bit-identical
    (asserted in tests/test_netsim.py)."""
    def drive(incremental: bool) -> tuple:
        rng = np.random.default_rng(0)
        topo = FatTree(racks=8, hosts_per_rack=8, nic_bw=1.0,
                       gpus_per_server=4, scaleup_bw=4.0)
        net = FluidNet(topo, incremental=incremental)
        def mk(i):
            s, d = rng.integers(0, topo.n_nodes, size=2)
            f = Flow(new_flow_id(), i, 0, Stage.P2D,
                     float(rng.uniform(1, 100)), src=int(s), dst=int(d),
                     target_layer=0, n_layers=8)
            # geometric skew toward the lowest band (defer-and-promote
            # admission): band K-1 is hottest, band 0 nearly static
            band = n_bands - 1 - min(rng.geometric(0.5) - 1, n_bands - 1)
            f.priority_key = (band,)
            if rng.uniform() < 0.2:
                f.rate_cap = float(rng.uniform(0.05, 0.5))
            return f
        flows = [mk(i) for i in range(n_flows)]
        for f in flows:
            net.add(f)
        net.reallocate()
        net.stats = {k: 0 for k in net.stats}
        t0 = time.perf_counter()
        for ev in range(n_events):
            victim = flows.pop(int(rng.integers(len(flows))))
            net.remove(victim)
            nf = mk(n_flows + ev)
            flows.append(nf)
            net.add(nf)
            net.reallocate()
            net.next_completion()
        ms = (time.perf_counter() - t0) / n_events * 1e3
        return ms, net.stats["group_fills"] / max(net.stats["reallocs"], 1)

    ms_inc, fills_inc = drive(incremental=True)
    ms_full, fills_full = drive(incremental=False)
    emit(rows, "waterfill.incremental.ms_per_event", f"{ms_inc:.3f}",
         f"{n_flows} flows, {n_bands} bands")
    emit(rows, "waterfill.incremental.full.ms_per_event", f"{ms_full:.3f}",
         f"speedup={ms_full / max(ms_inc, 1e-9):.2f}x")
    emit(rows, "waterfill.incremental.fills_per_realloc", f"{fills_inc:.3f}",
         f"full={fills_full:.3f}")
    emit(rows, "waterfill.incremental.fill_ratio",
         f"{fills_full / max(fills_inc, 1e-9):.2f}",
         "full fills / incremental fills (>=2x target)")


def _bench_warmstart(rows, n_flows: int = 512, n_events: int = 300):
    """Warm-started within-group water-filling under the hot-spot pattern
    the dirty-group cache can't help with: ONE wide single-key group whose
    membership churns every event (completion + arrival), forcing a re-fill
    each epoch. Warm start patches the cached route-incidence structure
    instead of rebuilding it from per-flow route walks; the produced rates
    are proven bit-identical against the cold path on the same churn."""
    def drive(warm: bool):
        rng = np.random.default_rng(0)
        topo = FatTree(racks=8, hosts_per_rack=8, nic_bw=1.0,
                       gpus_per_server=4, scaleup_bw=4.0)
        net = FluidNet(topo)
        net.warmstart = warm
        fid_base = [0]
        def mk():
            fid_base[0] += 1
            s, d = rng.integers(0, topo.n_nodes, size=2)
            f = Flow(1_000_000 + fid_base[0], 0, 0, Stage.P2D,
                     float(rng.uniform(1, 100)), src=int(s), dst=int(d),
                     target_layer=0, n_layers=8)
            f.priority_key = (0,)              # one wide group
            if rng.uniform() < 0.2:
                f.rate_cap = float(rng.uniform(0.05, 0.5))
            return f
        flows = [mk() for _ in range(n_flows)]
        for f in flows:
            net.add(f)
        net.reallocate()
        rates = []
        t0 = time.perf_counter()
        for _ in range(n_events):
            victim = flows.pop(int(rng.integers(len(flows))))
            net.remove(victim)
            nf = mk()
            flows.append(nf)
            net.add(nf)
            net.reallocate()
            rates.append(sorted((f.fid, f.rate) for f in flows))
        ms = (time.perf_counter() - t0) / n_events * 1e3
        return ms, rates, net.stats

    ms_warm, r_warm, st = drive(True)
    ms_cold, r_cold, _ = drive(False)
    emit(rows, "waterfill.warmstart.ms_per_event", f"{ms_warm:.3f}",
         f"{n_flows} flows, 1 key")
    emit(rows, "waterfill.warmstart.off.ms_per_event", f"{ms_cold:.3f}",
         f"speedup={ms_cold / max(ms_warm, 1e-9):.2f}x")
    emit(rows, "waterfill.warmstart.patch_ratio",
         f"{st['vec_patches'] / max(st['vec_patches'] + st['vec_builds'], 1):.3f}",
         f"patches={st['vec_patches']} builds={st['vec_builds']}")
    emit(rows, "waterfill.warmstart.bit_identical", str(r_warm == r_cold),
         "exact float equality vs cold fills, every epoch")


def _bench_kvstore(rows, quick: bool = False):
    """KV-reuse plane microbenches: chain-index resolve+admit throughput on
    a roomy store (``kvstore.index.*``) and admission throughput under
    LRU eviction churn when the tiers are an order of magnitude too small
    for the working set (``kvstore.evict.*``)."""
    from repro.core.kvstore import (KVStore, KVStoreSpec, TierSpec,
                                    chain_keys, kv_route)
    from repro.simcluster.trace import WORKLOADS, generate_trace

    n = 500 if quick else 2000
    trace = generate_trace(WORKLOADS["qwen-agent"], n, rps=100.0, seed=0)
    bt = 256

    class _It:
        pass

    def drive(hbm_cap, remote_cap):
        store = KVStore(
            KVStoreSpec(block_tokens=bt, tiers=(
                TierSpec("hbm", capacity=hbm_cap),
                TierSpec("remote", capacity=remote_cap, fetch_bw=24e9,
                         scope="pooled", writeback=True))),
            bytes_per_token=1e5, unit_eps=[[0], [1], [2], [3]],
            store_eps=[8], nic_bw=25e9)
        backlogs = [0.0, 0.0, 0.0, 0.0]
        t0 = time.perf_counter()
        for r in trace:
            keys = chain_keys(r.prefix_chain, bt)
            u, _ = kv_route(store, keys, r.prompt_len - 1, backlogs, r.rid)
            it = _It()
            it.rid, it.unit, it.n_tokens = r.rid, u, r.prompt_len
            for f in store.admit(it, 0.0):
                store.on_wb_done(f)
        return time.perf_counter() - t0, store

    blk_bytes = bt * 1e5
    dt, store = drive(1e15, 1e15)              # no eviction pressure
    emit(rows, "kvstore.index.ops_per_sec", f"{2 * n / dt:.0f}",
         f"{n} requests resolve+admit, hit_rate="
         f"{store.summary()['hit_rate_tokens']:.3f}")
    dt2, store2 = drive(8 * blk_bytes, 24 * blk_bytes)    # heavy churn
    emit(rows, "kvstore.evict.ops_per_sec", f"{2 * n / dt2:.0f}",
         "tiers far under the chain working set")
    emit(rows, "kvstore.evict.evictions_per_admit",
         f"{store2.stats['evictions'] / max(store2.stats['admitted_blocks'], 1):.3f}",
         f"{store2.stats['evictions']:.0f} evictions")


def _bench_telemetry_overhead(rows, quick: bool = False):
    """Telemetry collector cost: the identical ClusterSim run with the
    collector off vs. fully on (spans + RMLQ audit + link sampling). The
    collector is a pure observer — the two runs produce bit-identical
    schedules and metrics (asserted in tests/test_telemetry.py) — so the
    ratio is pure bookkeeping overhead; the budget is < 5%."""
    from repro.core import TelemetrySpec
    from repro.simcluster.papermodels import PAPER_MODELS
    from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
    from repro.simcluster.trace import WORKLOADS, generate_trace

    n = 60 if quick else 150
    reps = 3 if quick else 7    # paired reps; median rejects jitter
    trace = generate_trace(WORKLOADS["qwen-conv"], n, rps=12.0, seed=0,
                           warmup=12)

    def one(tel) -> float:
        spec = ClusterSpec(model=PAPER_MODELS["mixtral-8x7b"],
                           par=ParallelismSpec(mode="ep", ep=8),
                           n_units=2, telemetry=tel)
        sim = ClusterSim(spec, make_policy("mfs"))
        t0 = time.perf_counter()
        sim.run(trace)
        return time.perf_counter() - t0

    one(None)                    # warm caches before either arm is timed
    # paired off/on runs, median of per-pair ratios: robust to the slow
    # machine drift that biases sequential all-off-then-all-on timing
    ratios = []
    for _ in range(reps):
        t_off = one(None)
        ratios.append(one(TelemetrySpec()) / t_off - 1.0)
    ratios.sort()
    med = ratios[len(ratios) // 2]
    emit(rows, "telemetry.overhead", f"{med:+.3f}",
         f"median of {reps} paired runs, full collector, <0.05 budget")


def _bench_monitor_overhead(rows, quick: bool = False):
    """Online monitor cost: the identical ClusterSim run with the monitor
    plane off vs. on (rolling windows + quantile sketches + live bus
    signals). Like the telemetry collector, the monitor is a pure
    observer — monitor-on and monitor-off runs are bit-identical
    (asserted in tests/test_monitor.py) — so the ratio is pure streaming
    -estimator overhead; same < 5% budget as ``telemetry.overhead``."""
    from repro.core import MonitorSpec
    from repro.simcluster.papermodels import PAPER_MODELS
    from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
    from repro.simcluster.trace import WORKLOADS, generate_trace

    n = 60 if quick else 150
    reps = 3 if quick else 7    # paired reps; median rejects jitter
    trace = generate_trace(WORKLOADS["qwen-conv"], n, rps=12.0, seed=0,
                           warmup=12)

    def one(mon) -> float:
        spec = ClusterSpec(model=PAPER_MODELS["mixtral-8x7b"],
                           par=ParallelismSpec(mode="ep", ep=8),
                           n_units=2, monitor=mon)
        sim = ClusterSim(spec, make_policy("mfs"))
        t0 = time.perf_counter()
        sim.run(trace)
        return time.perf_counter() - t0

    one(None)                    # warm caches before either arm is timed
    # paired off/on runs, median of per-pair ratios: robust to the slow
    # machine drift that biases sequential all-off-then-all-on timing
    ratios = []
    for _ in range(reps):
        t_off = one(None)
        ratios.append(one(MonitorSpec()) / t_off - 1.0)
    ratios.sort()
    med = ratios[len(ratios) // 2]
    emit(rows, "monitor.overhead", f"{med:+.3f}",
         f"median of {reps} paired runs, full signal set, <0.05 budget")


def _bench_decode_roofline(rows):
    """Model error of the analytic ``decode_step_time`` against the
    roofline derived from the decode kernel's actual tiling
    (``kernels.decode_attention.decode_attention_cost``): padding to
    block_k / 128 lanes and the attention flops the smooth model drops."""
    from repro.core.stages import GroupPlan, ParallelismSpec, StageProfile
    from repro.simcluster.hw import A100
    from repro.simcluster.papermodels import PAPER_MODELS

    m = PAPER_MODELS["mixtral-8x7b"]
    prof = StageProfile(m, A100, ParallelismSpec(mode="ep", ep=4),
                        GroupPlan.build(m.n_layers, 8))
    errs = []
    for n in (1, 4, 16, 64):
        for ctx in (200, 1000, 3000, 4096, 20000):
            a = prof.decode_step_time(n, ctx)
            r = prof.decode_step_roofline(n, ctx)
            errs.append(abs(a - r) / r)
    emit(rows, "decode.roofline.model_err_mean",
         f"{sum(errs) / len(errs):.4f}",
         f"max={max(errs):.4f} over {len(errs)} (n_seqs, ctx) points, "
         "mixtral-8x7b/A100")


def main(quick: bool = False):
    rows = []
    _fig(rows, "fig6_ingress", coll_size=2.0, p2d_size=1.0)   # T=3 -> T=2
    _fig(rows, "fig7_egress", coll_size=3.0, p2d_size=1.0)    # T=4 -> T=3

    for pol in ("fs", "sjf", "edf", "karuna", "mfs"):
        flows = {}
        for i, (nm, (size, remain, dr)) in enumerate(_TABLE1.items()):
            dl = (dr - remain) if pol == "mfs" else dr
            flows[nm] = make_flow(Stage.P2D, size=size, deadline=dl, rid=i)
        policy = MFSScheduler() if pol == "mfs" else make_policy(pol)
        finish = run_toy(list(flows.values()), policy)
        done = {nm: finish[f.fid] + _TABLE1[nm][1] for nm, f in flows.items()}
        missed = sorted(nm for nm, t in done.items()
                        if t > _TABLE1[nm][2] + 1e-6)
        earliness = sum(max(0.0, _TABLE1[nm][2] - t)
                        for nm, t in done.items())
        emit(rows, f"table2.{pol}.deadline_misses",
             "+".join(missed) if missed else "none",
             f"pos_earliness={earliness:.1f}")
    _bench_waterfill(rows, reps=5 if quick else 20)
    _bench_incremental(rows, n_events=100 if quick else 400)
    _bench_warmstart(rows, n_events=100 if quick else 300)
    _bench_kvstore(rows, quick=quick)
    _bench_telemetry_overhead(rows, quick=quick)
    _bench_monitor_overhead(rows, quick=quick)
    _bench_decode_roofline(rows)
    return rows


def rows_to_json(rows) -> dict:
    """``emit`` rows ("name,value,annotation") as a committed artifact:
    ``{name: {"value": <float or string>, "unit": <annotation>}}`` —
    the schema bench_compare and the CI drift table consume."""
    out = {}
    for row in rows:
        name, _, rest = row.partition(",")
        value, _, unit = rest.partition(",")
        try:
            val = float(value)
        except ValueError:
            val = value
        out[name] = {"value": val, "unit": unit}
    return out


if __name__ == "__main__":
    import json
    import sys
    argv = sys.argv[1:]
    rows = main(quick="--quick" in argv)
    if "--json" in argv:
        path = argv[argv.index("--json") + 1]
        with open(path, "w") as fh:
            json.dump(rows_to_json(rows), fh, indent=2)
        print(f"microbench.json,{path},{len(rows)} rows")
