"""Fig 6 / Fig 7 / Table 1-2 — the didactic single-link scenarios, measured
(not asserted): layer-unblock times per policy and the inter-request
deadline/earliness outcome — plus the FluidNet water-filling microbench
(per-call reallocate latency across priority-group-size regimes)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import MFSScheduler, Stage, make_policy
from repro.core.msflow import Flow, new_flow_id
from repro.netsim.fluid import FluidNet
from repro.netsim.topology import FatTree
from repro.netsim.toy import make_flow, run_toy

from .common import emit


def _fig(rows, tag, coll_size, p2d_size):
    for pol in ("fs", "sjf", "edf"):
        coll = make_flow(Stage.COLLECTIVE, size=coll_size)
        p2d = make_flow(Stage.P2D, size=p2d_size, deadline=10.0)
        finish = run_toy([coll, p2d], make_policy(pol))
        emit(rows, f"{tag}.{pol}.layer_unblock_T", f"{finish[coll.fid]:.2f}")
    coll = make_flow(Stage.COLLECTIVE, size=coll_size)
    p2d = make_flow(Stage.P2D, size=p2d_size, deadline=10.0)
    finish = run_toy([coll, p2d], MFSScheduler())
    emit(rows, f"{tag}.mfs.layer_unblock_T", f"{finish[coll.fid]:.2f}",
         "defer-and-promote")


_TABLE1 = {"A": (2.0, 9.0, 18.0), "B": (4.0, 6.0, 12.0), "C": (3.0, 0.0, 7.0)}


def _bench_waterfill(rows, n_flows: int = 512, reps: int = 20):
    """Reallocate latency vs. priority-group width. ``1key`` is the
    FairShare / shared-RMLQ-band regime (one wide group, served by the
    vectorized route-incidence fill); ``perflow`` is the SJF regime (one
    group per flow, served by the scalar walk)."""
    for label, nkeys in (("1key", 1), ("8key", 8), ("perflow", n_flows)):
        rng = np.random.default_rng(0)
        topo = FatTree(racks=8, hosts_per_rack=8, nic_bw=1.0,
                       gpus_per_server=4, scaleup_bw=4.0)
        net = FluidNet(topo)
        for i in range(n_flows):
            s, d = rng.integers(0, topo.n_nodes, size=2)
            f = Flow(new_flow_id(), i, 0, Stage.P2D,
                     float(rng.uniform(1, 100)), src=int(s), dst=int(d),
                     target_layer=0, n_layers=8)
            f.priority_key = (i % nkeys,)
            if rng.uniform() < 0.2:
                f.rate_cap = float(rng.uniform(0.05, 0.5))
            net.add(f)
        net.reallocate()                      # warm route cache
        t0 = time.perf_counter()
        for _ in range(reps):
            net.reallocate()
        ms = (time.perf_counter() - t0) / reps * 1e3
        emit(rows, f"waterfill.{label}.reallocate_ms", f"{ms:.3f}",
             f"{n_flows} flows")


def main(quick: bool = False):
    rows = []
    _fig(rows, "fig6_ingress", coll_size=2.0, p2d_size=1.0)   # T=3 -> T=2
    _fig(rows, "fig7_egress", coll_size=3.0, p2d_size=1.0)    # T=4 -> T=3

    for pol in ("fs", "sjf", "edf", "karuna", "mfs"):
        flows = {}
        for i, (nm, (size, remain, dr)) in enumerate(_TABLE1.items()):
            dl = (dr - remain) if pol == "mfs" else dr
            flows[nm] = make_flow(Stage.P2D, size=size, deadline=dl, rid=i)
        policy = MFSScheduler() if pol == "mfs" else make_policy(pol)
        finish = run_toy(list(flows.values()), policy)
        done = {nm: finish[f.fid] + _TABLE1[nm][1] for nm, f in flows.items()}
        missed = sorted(nm for nm, t in done.items()
                        if t > _TABLE1[nm][2] + 1e-6)
        earliness = sum(max(0.0, _TABLE1[nm][2] - t)
                        for nm, t in done.items())
        emit(rows, f"table2.{pol}.deadline_misses",
             "+".join(missed) if missed else "none",
             f"pos_earliness={earliness:.1f}")
    _bench_waterfill(rows, reps=5 if quick else 20)
    return rows


if __name__ == "__main__":
    main()
