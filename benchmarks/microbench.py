"""Fig 6 / Fig 7 / Table 1-2 — the didactic single-link scenarios, measured
(not asserted): layer-unblock times per policy and the inter-request
deadline/earliness outcome."""
from __future__ import annotations

from repro.core import MFSScheduler, Stage, make_policy
from repro.netsim.toy import make_flow, run_toy

from .common import emit


def _fig(rows, tag, coll_size, p2d_size):
    for pol in ("fs", "sjf", "edf"):
        coll = make_flow(Stage.COLLECTIVE, size=coll_size)
        p2d = make_flow(Stage.P2D, size=p2d_size, deadline=10.0)
        finish = run_toy([coll, p2d], make_policy(pol))
        emit(rows, f"{tag}.{pol}.layer_unblock_T", f"{finish[coll.fid]:.2f}")
    coll = make_flow(Stage.COLLECTIVE, size=coll_size)
    p2d = make_flow(Stage.P2D, size=p2d_size, deadline=10.0)
    finish = run_toy([coll, p2d], MFSScheduler())
    emit(rows, f"{tag}.mfs.layer_unblock_T", f"{finish[coll.fid]:.2f}",
         "defer-and-promote")


_TABLE1 = {"A": (2.0, 9.0, 18.0), "B": (4.0, 6.0, 12.0), "C": (3.0, 0.0, 7.0)}


def main(quick: bool = False):
    rows = []
    _fig(rows, "fig6_ingress", coll_size=2.0, p2d_size=1.0)   # T=3 -> T=2
    _fig(rows, "fig7_egress", coll_size=3.0, p2d_size=1.0)    # T=4 -> T=3

    for pol in ("fs", "sjf", "edf", "karuna", "mfs"):
        flows = {}
        for i, (nm, (size, remain, dr)) in enumerate(_TABLE1.items()):
            dl = (dr - remain) if pol == "mfs" else dr
            flows[nm] = make_flow(Stage.P2D, size=size, deadline=dl, rid=i)
        policy = MFSScheduler() if pol == "mfs" else make_policy(pol)
        finish = run_toy(list(flows.values()), policy)
        done = {nm: finish[f.fid] + _TABLE1[nm][1] for nm, f in flows.items()}
        missed = sorted(nm for nm, t in done.items()
                        if t > _TABLE1[nm][2] + 1e-6)
        earliness = sum(max(0.0, _TABLE1[nm][2] - t)
                        for nm, t in done.items())
        emit(rows, f"table2.{pol}.deadline_misses",
             "+".join(missed) if missed else "none",
             f"pos_earliness={earliness:.1f}")
    return rows


if __name__ == "__main__":
    main()
