"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig9 ...]

Each module prints ``name,value,derived`` CSV rows; this driver aggregates
them with wall-clock timings per suite.
"""
from __future__ import annotations

import argparse
import time
import traceback

SUITES = [
    ("fig5_contention", "benchmarks.contention"),
    ("fig6_7_table2_micro", "benchmarks.microbench"),
    ("fig9_testbed", "benchmarks.testbed"),
    ("fig10_11_sim_moe", "benchmarks.sim_moe"),
    ("fig12_sim_sp", "benchmarks.sim_sp"),
    ("fig13_14_breakdown", "benchmarks.breakdown"),
    ("roofline", "benchmarks.roofline"),
    ("largescale", "benchmarks.largescale"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()

    print("suite,name,value,derived")
    failures = []
    for tag, modname in SUITES:
        if args.only and not any(o in tag for o in args.only):
            continue
        t0 = time.time()
        print(f"# === {tag} ({modname}) ===", flush=True)
        try:
            mod = __import__(modname, fromlist=["main"])
            mod.main(quick=args.quick)
            print(f"# {tag} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:
            failures.append(tag)
            print(f"# {tag} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark suites failed: {failures}")
    print("# all benchmark suites completed", flush=True)


if __name__ == "__main__":
    main()
