"""Fig 9 — testbed-scale Mixtral-8x7B (EP=8) on the Qwen conversation and
agent traces: mean-TTFT and CCT reduction of MFS vs Fair Sharing (the
engine's stage-agnostic default), at a calibrated contended load.
Paper: TTFT -20.7% (conv) / -32.3% (agent); CCT -31.9% / -43.1%."""
from __future__ import annotations

from repro.simcluster.hw import RTX3090

from .common import POLICIES, calibrate_rate, emit, run_sim, spec_for


def main(quick: bool = False):
    rows = []
    n = 64 if quick else 256
    spec = spec_for("mixtral-8x7b", ep=8, n_units=2, hw=RTX3090,
                    gpus_per_server=4)
    for wl, tag in (("qwen-conv", "conv"), ("qwen-agent", "agent")):
        rate = round(calibrate_rate(spec, wl, target=0.7, n=min(n, 64)), 2)
        res = {p: run_sim(p, spec, wl, n=n, rps=rate) for p in POLICIES}
        ttft_red = 1 - res["mfs"]["ttft_mean"] / res["fs"]["ttft_mean"]
        cct_red = 1 - res["mfs"]["cct_slowdown"] / res["fs"]["cct_slowdown"]
        for p in POLICIES:
            emit(rows, f"fig9.{tag}.{p}.ttft_mean_ms",
                 f"{res[p]['ttft_mean']*1e3:.2f}",
                 f"rate={rate} slo={res[p]['slo_attainment']:.3f} "
                 f"cct={res[p]['cct_slowdown']:.2f}")
        emit(rows, f"fig9.{tag}.mfs_ttft_reduction_vs_fs",
             f"{ttft_red:.1%}", "paper: 20.7% conv / 32.3% agent")
        emit(rows, f"fig9.{tag}.mfs_cct_reduction_vs_fs",
             f"{cct_red:.1%}", "paper: 31.9% conv / 43.1% agent")
    return rows


if __name__ == "__main__":
    main()
