"""Paper-scale simulation sweep — attainment-vs-rate curves at ≥8 units.

The paper's headline numbers come from "large-scale simulations" beyond the
8-server testbed; this suite reproduces that regime on the incremental
fluid-net core: an 8-unit (32 prefill + 32 decode endpoints) fat-tree,
thousands of requests, all 5 policies, swept across

  * request rate (the attainment curve's falling edge),
  * arrival process — Poisson vs. 2-state MMPP bursts (``ArrivalSpec``),
  * a multi-tenant SLO mix (tight/standard/loose classes), reported as
    per-class attainment.

The **decode-contention sweep** adds the decode plane: 8-GPU EP units
whose collectives cross the fabric, two named decode pools (per-tenant
class pinning), per-token decode progress and the D2D KV-migration
rebalancer, run with rebalancing on vs. off. It reports TTFT attainment
AND per-pool TPOT attainment for all 5 policies, plus MFS's TTFT-advantage
ratios at the highest contended rate. D2D rebalancing traffic carries
tight next-token deadlines, so the deadline-chasing stage-agnostic
baselines (EDF strictly first, Karuna minimal-rate reservations, FairShare
even split) hand it decode-downlink bandwidth that tight-TTFT P2D needed —
MFS defers it by design (own RMLQ band below P2D, MLU promotion only as
the next-token budget runs out) and keeps both SLOs; SJF lands close on
TTFT by accident (migrations are large, so size-ordering also defers
them) but has no mechanism to promote a migration whose destination's
TPOT budget is expiring (``tbt_max`` rows record the stall behavior).

The **KV-reuse sweep** runs the Mooncake long-context tail
(``mooncake-tail``: ~22k-token prompts, heavy upper tail) at 16
sp-parallel units on the testbed NIC share (50 Gbps/GPU), with the tiered
KV store on vs. off. Store-on resolves hits against the live store
(capacity-bounded eviction, so hit rates respond to capacity — the
``capacity_response`` entry shows the same arm at 1/4 pooled capacity),
Stage-1 becomes multi-source across HBM/DRAM/pooled tiers, and prefill
completion emits loose-deadline Stage-WB writebacks that contend with
S2/P2D on the unit uplinks. MFS holds WB in the band below D2D, so its WB
class share on contended links is lower than FairShare's/EDF's while its
TTFT attainment leads the deadline-chasing/fair-sharing baselines; SJF
again lands close by accident (WB flows are the largest class, so
size-ordering also defers them).

The **chunked-prefill arm** reruns the Mooncake tail (store off, top
contended rate) with Sarathi-style chunking on vs. off for all 5 policies:
with ``ChunkSpec(2048)`` every super-layer group computes in token-budgeted
chunks whose P2D leaves while later chunks still compute, so the
long-prompt class (>= 32k tokens) sheds its un-overlapped last-group KV
tail — ``largescale.chunked.long_ttft_gain.*`` records the per-policy
long-prompt mean-TTFT improvement.

The **router arm** sweeps the router plane on the 8-unit paper cluster:
every registered placement policy x {mfs, edf, fs} schedulers x 2 rates
under a hard MMPP overload burst (the regime where placement quality and
admission control decide attainment). The matrix reports all-arrivals SLO
attainment per (router, scheduler, rate) plus MFS-vs-baseline ratios per
router; the admission half reruns the top burst rate with the default
``kv_affinity`` router, shed-nothing vs. a queue-depth admission
controller shedding loose-class traffic — admitted-TTFT attainment must
improve for every scheduler (``largescale.router.admission.*``).

The **telemetry arm** reruns the Mooncake tail (store on, multi-tenant SLO
mix, top contended rate) with the telemetry plane enabled for all 5
policies and turns each policy's misses into a contention-attribution
table: ``slo_miss_report()`` pins every missed request's lost slack to its
dominant (stage, link) pair, so "MFS beats EDF" becomes "EDF loses
tight-class slack queueing P2D on the contended uplinks, MFS doesn't".
The MFS run also writes ``BENCH_trace_sample.json`` — a Chrome/Perfetto
trace-event timeline of one missed tight-SLO request (or a served one when
nothing missed). The collector is a pure observer, so attainment numbers
match the telemetry-off cells exactly.

Emits CSV rows (``largescale.*``) plus ``BENCH_largescale.json`` with the
full curve data for plotting, and the fluid-net incremental-allocation
counters (group fills per reallocation) observed during the sweep. With
the decode plane, KV store, chunking, the router spec and telemetry
disabled the legacy sections are bit-for-bit identical to the
pre-decode-plane / pre-kvstore / pre-chunking / pre-router sweeps.
``--only router`` / ``--only telemetry`` recompute just that arm and merge
it into an existing ``BENCH_largescale.json``, leaving every other section
untouched.
"""
from __future__ import annotations

import itertools
import json
import sys
import time
from typing import Dict, List, Optional

from repro.core import (BatchState, MonitorSpec, Stage, TelemetrySpec,
                        attainment_ceiling, disagg_bound, fixed_route_rate,
                        make_policy)
from repro.core.decode import DecodePoolSpec, DecodeSpec
from repro.core.kvstore import KVStoreSpec, TierSpec
from repro.core.router import AdmissionSpec, RouterSpec
from repro.core.stages import ChunkSpec
from repro.simcluster.hw import A100, Gb, HW
from repro.simcluster.papermodels import PAPER_MODELS
from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
from repro.simcluster.trace import (ArrivalSpec, SLO_CLASSES, WORKLOADS,
                                    generate_trace)

from .common import POLICIES, emit

OUT_JSON = "BENCH_largescale.json"

#: paper-scale cluster: 8 units x 4-GPU EP replicas on a 1:1 fat-tree
SPEC = dict(model="mixtral-8x7b", n_units=8, gpus_per_server=4,
            topology="fattree", hosts_per_rack=8, layer_groups=8)
WORKLOAD = "qwen-conv"
RATES = (24.0, 48.0, 72.0, 96.0)
N_REQUESTS = 2000
WARMUP = 64
SLO_MIX = {"tight": 0.2, "standard": 0.5, "loose": 0.3}

# ---- decode-contention sweep --------------------------------------------
#: 8-GPU EP units (2 servers each => Stage-2 crosses the fabric) sharing a
#: 0.5x decode tier; rates sit on the mmpp falling edge for this spec
DECODE_SPEC = dict(SPEC, layer_groups=8)
DECODE_EP = 8
DECODE_RATIO = 0.5
DECODE_RATES = (36.0, 48.0, 60.0)
N_DECODE = 1000


# ---- KV-reuse sweep: Mooncake long-context tail over the tiered store ----
#: 16 sp-parallel units (sequence-sharded ring S2 crosses the fabric) on the
#: paper's testbed NIC share (50 Gbps/GPU) so long-context KV movement, not
#: compute, is the binding constraint; 2 pooled store nodes
KV_SPEC = dict(model="mixtral-8x7b", n_units=16, gpus_per_server=4,
               topology="fattree", hosts_per_rack=8, layer_groups=8)
KV_WORKLOAD = "mooncake-tail"
KV_SP = 4
KV_RATES = (14.0, 16.0)
KV_DECODE_RATIO = 0.5
N_KV = 300
KV_HW = HW("a100-50g", flops=A100.flops, hbm_bw=A100.hbm_bw,
           nic_bw=50 * Gb, scaleup_bw=A100.scaleup_bw)
#: remote capacity ~55% of the trace's unique-chain working set (~113 GB),
#: so eviction is live and hit rates are capacity-bounded
KV_REMOTE_CAP = 64e9

# ---- router arm: placement policy x scheduler under an overload burst ---
#: the 8-unit paper cluster, multi-tenant SLO mix, and an MMPP process
#: spending 20% of the time in an 8x burst — the regime where placement
#: and admission decide attainment
ROUTER_POLICIES = ("kv_affinity", "least_backlog", "round_robin",
                   "session_affinity")
ROUTER_SCHEDS = ("mfs", "edf", "fs")
ROUTER_RATES = (72.0, 96.0)
N_ROUTER = 800
ROUTER_BURST = ArrivalSpec(process="mmpp", burst_factor=8.0, burst_frac=0.2,
                           dwell=3.0)
#: queue-depth admission: trip once the cluster queues a burst's worth of
#: requests, recover when they drain; sheds loose-class traffic only
ROUTER_ADMISSION = AdmissionSpec(detector="queue_depth",
                                 detector_kw=dict(high=12, low=3))


# ---- telemetry arm: SLO-miss root causes on the Mooncake tail -----------
#: same 16-unit sp cluster / tiered store as the KV-reuse sweep, plus the
#: multi-tenant SLO mix (the tight class is the attribution target) at the
#: top contended rate; telemetry is a pure observer, so the attainment
#: numbers equal the telemetry-off cells
TEL_RATE = KV_RATES[-1]
N_TEL = 300
TRACE_SAMPLE_JSON = "BENCH_trace_sample.json"

# ---- chunked-prefill arm: Sarathi chunks on the Mooncake tail -----------
#: same 16-unit sp cluster / 50 Gbps NIC share as the KV-reuse sweep (the
#: workload whose ~22k-token prompts chunking exists for), store off so the
#: chunking effect is isolated; top contended rate only — chunk-on cells
#: walk ~11x more (group, chunk) events, so the arm stays narrow
CHUNK_TOKENS = 2048
CHUNK_RATE = KV_RATES[-1]
N_CHUNK = 300
#: "long-prompt class" = prompts >= this (the heavy tail whose whole-group
#: KV holds the P2D tail in the unchunked schedule)
CHUNK_LONG_TOKENS = 32768


# ---- yardstick arm: max-flow attainment ceiling on the Mooncake tail ----
#: same 16-unit sp cluster / tiered store / multi-tenant mix as the
#: telemetry arm, pushed past the knee (the regime where the ceiling and
#: the policy gap are both visible); the rate is set where the falling
#: edge separates MFS from every baseline
YARD_RATE = 18.0
N_YARD = 300

#: --progress: stream per-arm status lines (requests done, rolling
#: admitted attainment from the monitor plane, ETA) to stderr
PROGRESS = False


def _sim(spec: ClusterSpec, policy: str, label: str = "",
         total: int = 0) -> ClusterSim:
    """ClusterSim factory for the sweep arms. With ``--progress`` it
    attaches the (passive, bit-identity-tested) monitor plane and streams
    live status lines; without it, construction is exactly the legacy
    ``ClusterSim(spec, make_policy(policy))``."""
    if PROGRESS and spec.monitor is None:
        spec.monitor = MonitorSpec(sample_every=max(1, total // 8))
    sim = ClusterSim(spec, make_policy(policy))
    if PROGRESS and sim.monitor is not None:
        t0 = time.time()

        def _line(mon, label=label, total=total, t0=t0):
            frac = mon.n_done / max(total, 1)
            wall = time.time() - t0
            eta = wall * (1.0 - frac) / max(frac, 1e-9)
            print(f"    [{label}] {mon.n_done}/{total} done  "
                  f"attain={mon.rolling_attainment():.3f}  "
                  f"wall={wall:.0f}s eta={eta:.0f}s",
                  file=sys.stderr, flush=True)

        sim.monitor.on_sample = _line
    return sim


def _kvstore_spec(remote_cap: float = KV_REMOTE_CAP) -> KVStoreSpec:
    # per-unit tiers deliberately smaller than the per-unit working-set
    # share so all three tiers serve hits and LRU eviction is live
    return KVStoreSpec(
        block_tokens=256, pooled_nodes=2, wb_deadline_scale=8.0,
        tiers=(TierSpec("hbm", capacity=2e9),
               TierSpec("dram", capacity=4e9, fetch_bw=12e9,
                        scope="unit", writeback=True),
               TierSpec("remote", capacity=remote_cap, fetch_bw=6.25e9,
                        scope="pooled", writeback=True)))


def _spec_kv(kv: Optional[KVStoreSpec],
             chunk: Optional[ChunkSpec] = None) -> ClusterSpec:
    """The 16-unit sp Mooncake cluster shared by the KV-reuse sweep and
    the chunked arm (one builder so the arms can't silently diverge)."""
    kw = dict(KV_SPEC)
    model = PAPER_MODELS[kw.pop("model")]
    return ClusterSpec(model=model, par=ParallelismSpec(mode="sp", sp=KV_SP),
                       decode_ratio=KV_DECODE_RATIO, hw=KV_HW, kvstore=kv,
                       chunk=chunk, **kw)


def _decode_spec(rebalance: bool) -> DecodeSpec:
    """Two named pools: tenant classes pin tight/standard traffic to the
    bigger ``interactive`` pool (tight TPOT budget), loose traffic to
    ``bulk`` — cross-pool victim contention on the shared fabric."""
    return DecodeSpec(
        pools=(DecodePoolSpec(name="interactive", weight=2.0, slots_per_ep=8,
                              tpot_budget=0.03,
                              classes=("tight", "standard")),
               DecodePoolSpec(name="bulk", weight=1.0, slots_per_ep=8,
                              tpot_budget=0.10, classes=("loose",))),
        mean_out=160, trigger_delta=2, release_delta=1, max_inflight=8,
        min_migrate_remaining=8, rebalance=rebalance)


def _spec() -> ClusterSpec:
    kw = dict(SPEC)
    model = PAPER_MODELS[kw.pop("model")]
    return ClusterSpec(model=model, par=ParallelismSpec(mode="ep", ep=4), **kw)


def _spec_decode(decode: Optional[DecodeSpec]) -> ClusterSpec:
    kw = dict(DECODE_SPEC)
    model = PAPER_MODELS[kw.pop("model")]
    return ClusterSpec(model=model, par=ParallelismSpec(mode="ep", ep=DECODE_EP),
                       decode_ratio=DECODE_RATIO, decode=decode, **kw)


def _run_one(policy: str, trace, collect_stats: bool = False) -> Dict:
    sim = _sim(_spec(), policy, label=f"curves.{policy}", total=len(trace))
    t0 = time.time()
    m = sim.run(trace)
    s = m.summary()
    s["wall_s"] = round(time.time() - t0, 2)
    if collect_stats:
        st = sim.net.stats
        s["fluid_stats"] = {k: st[k] for k in
                            ("reallocs", "group_fills", "groups_seen")}
        s["fills_per_realloc"] = st["group_fills"] / max(st["reallocs"], 1)
        s["groups_per_realloc"] = st["groups_seen"] / max(st["reallocs"], 1)
    # GC invariant: nothing retained after the run (memory is O(active))
    s["flows_retained"] = len(sim.runtime.flows)
    return s


def _per_class_attainment(metrics_by_rid: Dict, trace) -> Dict[str, float]:
    ok: Dict[str, List[int]] = {c: [] for c in SLO_CLASSES}
    for r in trace:
        if r.rid < 0 or r.rid not in metrics_by_rid["ttft"]:
            continue
        met = (metrics_by_rid["ttft"][r.rid]
               <= metrics_by_rid["deadline"][r.rid] + 1e-9)
        ok[r.slo_class].append(1 if met else 0)
    return {c: (sum(v) / len(v) if v else float("nan"))
            for c, v in ok.items()}


def _spec_chunk(chunk_on: bool) -> ClusterSpec:
    return _spec_kv(None, ChunkSpec(CHUNK_TOKENS) if chunk_on else None)


def _run_kvreuse(rows: List[str], quick: bool = False) -> Dict:
    """KV-reuse sweep: Mooncake tail over the tiered store, on vs off.

    store_off is the legacy pre-sampled-reuse model (static owner oracle);
    store_on resolves hits against the live tiered store, S1 is
    multi-source and admission emits Stage-WB writebacks (fixed-mode SLO
    calibration is store-aware: the base comes from expected steady-state
    hits, so the two arms are directly comparable). Reported per policy:
    TTFT attainment, live hit rate, per-tier hit mix, and the WB class
    share on contended links (MFS defers WB below D2D — the
    deadline-chasing/fair-sharing baselines hand it bandwidth)."""
    n_kv = 120 if quick else N_KV
    kv_rates = KV_RATES[-1:] if quick else KV_RATES
    kvd = {"spec": KV_SPEC, "workload": KV_WORKLOAD, "sp": KV_SP,
           "hw": KV_HW.name, "decode_ratio": KV_DECODE_RATIO,
           "rates": list(kv_rates), "n_requests": n_kv,
           "remote_cap": KV_REMOTE_CAP,
           "ttft": {}, "hit_rate": {}, "tier_mix": {}, "wb_share": {},
           "wb_bytes": {}, "evictions": {}}
    for mode, kv in (("store_on", _kvstore_spec()), ("store_off", None)):
        ttft: Dict[str, List[float]] = {p: [] for p in POLICIES}
        hitr: Dict[str, List[float]] = {p: [] for p in POLICIES}
        tmix: Dict[str, List[Dict]] = {p: [] for p in POLICIES}
        wbsh: Dict[str, List[float]] = {p: [] for p in POLICIES}
        wbby: Dict[str, List[float]] = {p: [] for p in POLICIES}
        evc: Dict[str, List[float]] = {p: [] for p in POLICIES}
        for rate in kv_rates:
            trace = generate_trace(WORKLOADS[KV_WORKLOAD], n_kv, rps=rate,
                                   seed=0, warmup=24,
                                   arrival=ArrivalSpec(process="mmpp"))
            for pol in POLICIES:
                sim = _sim(_spec_kv(kv), pol,
                           label=f"kvreuse.{mode}.{pol}", total=len(trace))
                t0 = time.time()
                s = sim.run(trace).summary()
                ttft[pol].append(s["slo_attainment"])
                # store-off arms get null (not NaN — bare NaN is invalid
                # strict JSON and breaks non-Python artifact consumers)
                hitr[pol].append(s.get("kv_hit_rate"))
                tmix[pol].append(s.get("kv_tier_mix", {}))
                wbsh[pol].append(s.get("kv_wb_share_contended"))
                wbby[pol].append(s.get("kv_wb_bytes", 0.0))
                evc[pol].append(s.get("kv_evictions", 0.0))
                assert len(sim.runtime.flows) == 0, "runtime leaked flows"
                mix = s.get("kv_tier_mix") or {}
                emit(rows, f"largescale.kvreuse.{mode}.{pol}.rps{rate:g}",
                     f"{s['slo_attainment']:.4f}",
                     f"hit={s.get('kv_hit_rate', float('nan')):.3f} "
                     f"tiers=" + "/".join(f"{t}:{v:.2f}"
                                          for t, v in mix.items())
                     + f" wb_share={s.get('kv_wb_share_contended', float('nan')):.3f}"
                     f" wall={time.time() - t0:.0f}s")
        kvd["ttft"][mode] = ttft
        kvd["hit_rate"][mode] = hitr
        kvd["tier_mix"][mode] = tmix
        kvd["wb_share"][mode] = wbsh
        kvd["wb_bytes"][mode] = wbby
        kvd["evictions"][mode] = evc
    # hit rate must respond to store capacity: MFS at 1/4 pooled capacity
    trace = generate_trace(WORKLOADS[KV_WORKLOAD], n_kv, rps=kv_rates[-1],
                           seed=0, warmup=24,
                           arrival=ArrivalSpec(process="mmpp"))
    s = ClusterSim(_spec_kv(_kvstore_spec(remote_cap=KV_REMOTE_CAP / 4)),
                   make_policy("mfs")).run(trace).summary()
    kvd["capacity_response"] = {
        "remote_cap": KV_REMOTE_CAP / 4, "hit_rate": s["kv_hit_rate"],
        "full_cap_hit_rate": kvd["hit_rate"]["store_on"]["mfs"][-1]}
    emit(rows, "largescale.kvreuse.capacity_response",
         f"{s['kv_hit_rate']:.3f} -> "
         f"{kvd['capacity_response']['full_cap_hit_rate']:.3f}",
         "hit rate at 1/4 vs full pooled capacity, mfs, top rate")
    # WB deferral: mean WB class share on contended links across rates —
    # lower under MFS (own band below D2D) than under FS/EDF
    kvd["wb_share_mean"] = {
        p: (sum(v for v in kvd["wb_share"]["store_on"][p]
                if v is not None) / max(len(kv_rates), 1))
        for p in POLICIES}
    for p in POLICIES:
        emit(rows, f"largescale.kvreuse.wb_share.{p}",
             f"{kvd['wb_share_mean'][p]:.3f}",
             "mean WB share on contended links, store on")
    # MFS's TTFT advantage with the store on, at the top contended rate
    top = kvd["ttft"]["store_on"]
    kvd["mfs_ttft_ratio_at_top"] = {
        p: top["mfs"][-1] / max(top[p][-1], 1e-9)
        for p in POLICIES if p != "mfs"}
    for p, r in sorted(kvd["mfs_ttft_ratio_at_top"].items()):
        emit(rows, f"largescale.kvreuse.mfs_over_{p}", f"{r:.2f}",
             f"TTFT attainment ratio at rps{kv_rates[-1]:g}, store on")
    return kvd


def _run_chunked(rows: List[str], quick: bool = False) -> Dict:
    """Chunked-prefill arm: chunk on vs off x 5 policies on the Mooncake
    tail at the top contended rate. With chunking on, chunk-*c* P2D
    overlaps chunk-*c+1* compute and the RLI estimate tightens, so the
    long-prompt class (>= CHUNK_LONG_TOKENS) sheds the un-overlapped
    last-group KV tail — the arm records overall attainment plus the
    long-prompt-class mean TTFT / attainment per policy. chunk_off is the
    legacy group-granular schedule (bit-identical to the other sections'
    scheduling model)."""
    n_c = 120 if quick else N_CHUNK
    chd = {"spec": KV_SPEC, "workload": KV_WORKLOAD, "sp": KV_SP,
           "hw": KV_HW.name, "decode_ratio": KV_DECODE_RATIO,
           "rate": CHUNK_RATE, "n_requests": n_c,
           "chunk_tokens": CHUNK_TOKENS, "long_tokens": CHUNK_LONG_TOKENS,
           "ttft": {}, "ttft_mean": {}, "long": {}}
    trace = generate_trace(WORKLOADS[KV_WORKLOAD], n_c, rps=CHUNK_RATE,
                           seed=0, warmup=24,
                           arrival=ArrivalSpec(process="mmpp"))
    for mode, on in (("chunk_off", False), ("chunk_on", True)):
        att: Dict[str, float] = {}
        mean: Dict[str, float] = {}
        lng: Dict[str, Dict[str, float]] = {}
        for pol in POLICIES:
            sim = _sim(_spec_chunk(on), pol,
                       label=f"chunked.{mode}.{pol}", total=len(trace))
            t0 = time.time()
            m = sim.run(trace)
            s = m.summary()
            # empty long class -> null, not NaN (bare NaN is invalid strict
            # JSON and breaks non-Python artifact consumers)
            lp = {k: (None if isinstance(v, float) and v != v else v)
                  for k, v in m.long_prompt_stats(CHUNK_LONG_TOKENS).items()}
            att[pol] = s["slo_attainment"]
            mean[pol] = s["ttft_mean"]
            lng[pol] = lp
            assert len(sim.runtime.flows) == 0, "runtime leaked flows"
            lt = lp["ttft_mean"] if lp["ttft_mean"] is not None else float("nan")
            la = lp["attainment"] if lp["attainment"] is not None else float("nan")
            emit(rows, f"largescale.chunked.{mode}.{pol}.rps{CHUNK_RATE:g}",
                 f"{s['slo_attainment']:.4f}",
                 f"ttft_mean={s['ttft_mean']:.3f} "
                 f"long_ttft={lt:.3f} "
                 f"long_att={la:.3f} (n={lp['n']}) "
                 f"wall={time.time() - t0:.0f}s")
        chd["ttft"][mode] = att
        chd["ttft_mean"][mode] = mean
        chd["long"][mode] = lng
    # the acceptance signal: chunking must cut the long-prompt-class mean
    # TTFT (ratio > 1) — reported per policy (null if the class was empty)
    def _gain(p):
        off = chd["long"]["chunk_off"][p]["ttft_mean"]
        on = chd["long"]["chunk_on"][p]["ttft_mean"]
        if off is None or on is None:
            return None
        return off / max(on, 1e-9)
    chd["long_ttft_gain"] = {p: _gain(p) for p in POLICIES}
    for p in POLICIES:
        g = chd["long_ttft_gain"][p]
        emit(rows, f"largescale.chunked.long_ttft_gain.{p}",
             "null" if g is None else f"{g:.3f}",
             f"long-prompt mean TTFT, chunk_off / chunk_on at "
             f"rps{CHUNK_RATE:g}")
    return chd


def _spec_router(rspec: Optional[RouterSpec]) -> ClusterSpec:
    kw = dict(SPEC)
    model = PAPER_MODELS[kw.pop("model")]
    return ClusterSpec(model=model, par=ParallelismSpec(mode="ep", ep=4),
                       router=rspec, **kw)


def _run_router(rows: List[str], quick: bool = False) -> Dict:
    """Router arm: placement matrix + admission on/off under the burst.

    The matrix runs every placement policy under {mfs, edf, fs} at both
    burst rates (all-arrivals attainment; the ``kv_affinity`` default is
    the extracted historical rule, so its numbers are the legacy router's).
    The admission half reruns the top rate, shed-nothing vs. the
    queue-depth controller: a shed request counts as a miss in
    all-arrivals attainment, so the controller only wins by actually
    protecting the admitted traffic — ``admitted_attainment`` must improve
    for every scheduler."""
    n = 300 if quick else N_ROUTER
    rd = {"spec": SPEC, "workload": WORKLOAD, "n_requests": n,
          "rates": list(ROUTER_RATES), "slo_mix": SLO_MIX,
          "arrival": {"process": ROUTER_BURST.process,
                      "burst_factor": ROUTER_BURST.burst_factor,
                      "burst_frac": ROUTER_BURST.burst_frac,
                      "dwell": ROUTER_BURST.dwell},
          "admission_spec": {"detector": ROUTER_ADMISSION.detector,
                             "detector_kw": dict(ROUTER_ADMISSION.detector_kw),
                             "shed_classes":
                                 list(ROUTER_ADMISSION.shed_classes)},
          "matrix": {r: {p: [] for p in ROUTER_SCHEDS}
                     for r in ROUTER_POLICIES},
          "admission": {}}
    traces = {rate: generate_trace(WORKLOADS[WORKLOAD], n, rps=rate, seed=0,
                                   warmup=WARMUP, arrival=ROUTER_BURST,
                                   slo_mix=SLO_MIX)
              for rate in ROUTER_RATES}
    for rate in ROUTER_RATES:
        for router in ROUTER_POLICIES:
            for pol in ROUTER_SCHEDS:
                sim = _sim(_spec_router(RouterSpec(policy=router)), pol,
                           label=f"router.{router}.{pol}",
                           total=len(traces[rate]))
                t0 = time.time()
                s = sim.run(traces[rate]).summary()
                rd["matrix"][router][pol].append(s["slo_attainment"])
                assert len(sim.runtime.flows) == 0, "runtime leaked flows"
                emit(rows, f"largescale.router.{router}.{pol}.rps{rate:g}",
                     f"{s['slo_attainment']:.4f}",
                     f"p99={s.get('ttft_p99', float('nan')):.3f}s "
                     f"wall={time.time() - t0:.0f}s")
    # MFS vs the stage-agnostic baselines, per router, at the top rate
    rd["mfs_ratio_at_top"] = {
        r: {p: rd["matrix"][r]["mfs"][-1] / max(rd["matrix"][r][p][-1], 1e-9)
            for p in ROUTER_SCHEDS if p != "mfs"}
        for r in ROUTER_POLICIES}
    for r in ROUTER_POLICIES:
        for p, v in sorted(rd["mfs_ratio_at_top"][r].items()):
            emit(rows, f"largescale.router.{r}.mfs_over_{p}", f"{v:.2f}",
                 f"TTFT attainment ratio at rps{ROUTER_RATES[-1]:g}")
    # admission on/off at the top burst rate, default router, per scheduler
    trace = traces[ROUTER_RATES[-1]]
    for pol in ROUTER_SCHEDS:
        base = ClusterSim(_spec_router(RouterSpec()),
                          make_policy(pol)).run(trace)
        ctrl = ClusterSim(_spec_router(
            RouterSpec(admission=ROUTER_ADMISSION)),
            make_policy(pol)).run(trace)
        ent = {"shed_nothing": {"slo_attainment": base.slo_attainment(),
                                "admitted_attainment":
                                    base.admitted_attainment()},
               "admission_on": {"slo_attainment": ctrl.slo_attainment(),
                                "admitted_attainment":
                                    ctrl.admitted_attainment(),
                                "by_class": ctrl.slo_attainment_by_class(),
                                "admitted_by_class":
                                    ctrl.admitted_attainment_by_class(),
                                "n_shed": len(ctrl.shed),
                                "n_deferred": ctrl.n_deferred},
               "admitted_gain": ctrl.admitted_attainment()
                                - base.admitted_attainment()}
        rd["admission"][pol] = ent
        emit(rows, f"largescale.router.admission.{pol}",
             f"{base.admitted_attainment():.4f} -> "
             f"{ctrl.admitted_attainment():.4f}",
             f"admitted-TTFT attainment, shed-nothing -> admission on "
             f"(shed={len(ctrl.shed)}) at rps{ROUTER_RATES[-1]:g}")
    return rd


def _run_telemetry(rows: List[str], quick: bool = False) -> Dict:
    """Telemetry arm: per-policy SLO-miss root causes on the Mooncake tail.

    Reruns the store-on Mooncake tail with the multi-tenant SLO mix at the
    top contended rate, telemetry enabled, and turns each policy's misses
    into a contention-attribution table: ``slo_miss_report()`` pins every
    miss's lost slack to its dominant (stage, link) pair. The acceptance
    signal is ``tight`` coverage — >= 90% of missed tight-class requests
    must attribute to a concrete (stage, link). ``contended_stage_share``
    records which stage class each policy hands the contended
    link-seconds to (the cross-plane generalization of the KV-reuse arm's
    WB share). The MFS run also dumps a Chrome/Perfetto timeline of one
    missed tight request to ``BENCH_trace_sample.json``."""
    n = 120 if quick else N_TEL
    trace = generate_trace(WORKLOADS[KV_WORKLOAD], n, rps=TEL_RATE, seed=0,
                           warmup=24, arrival=ArrivalSpec(process="mmpp"),
                           slo_mix=SLO_MIX)
    td = {"spec": KV_SPEC, "workload": KV_WORKLOAD, "sp": KV_SP,
          "hw": KV_HW.name, "decode_ratio": KV_DECODE_RATIO,
          "rate": TEL_RATE, "n_requests": n, "slo_mix": SLO_MIX,
          "attainment": {}, "attribution": {}, "tight_coverage": {},
          "contended_stage_share": {}, "links": {}, "trace_sample": None}
    for pol in POLICIES:
        spec = _spec_kv(_kvstore_spec())
        spec.telemetry = TelemetrySpec()
        sim = _sim(spec, pol, label=f"telemetry.{pol}", total=len(trace))
        t0 = time.time()
        s = sim.run(trace).summary()
        tel = sim.telemetry
        rep = tel.slo_miss_report(top=5)
        tight = tel.slo_miss_report(slo_class="tight")
        td["attainment"][pol] = s["slo_attainment"]
        # the contention-attribution table: top causes ranked by slack lost
        td["attribution"][pol] = {
            "n_missed": rep["n_missed"], "n_attributed": rep["n_attributed"],
            "coverage": rep["coverage"],
            "causes": [{k: c[k] for k in ("stage", "link", "link_name",
                                          "n", "slack_lost")}
                       for c in rep["causes"]]}
        td["tight_coverage"][pol] = {"n_missed": tight["n_missed"],
                                     "coverage": tight["coverage"]}
        td["contended_stage_share"][pol] = tel.contended_stage_share()
        td["links"][pol] = [{k: lr[k] for k in ("link", "link_name",
                                                "mean_util", "contended_s",
                                                "stage_share")}
                            for lr in tel.link_report(top=3)]
        assert len(sim.runtime.flows) == 0, "runtime leaked flows"
        cause = rep["causes"][0] if rep["causes"] else None
        emit(rows, f"largescale.telemetry.{pol}.rps{TEL_RATE:g}",
             f"{s['slo_attainment']:.4f}",
             f"missed={rep['n_missed']} cov={rep['coverage']}"
             + (f" top={cause['stage']}@{cause['link_name']}"
                f" (n={cause['n']}, slack={cause['slack_lost']:.2f}s)"
                if cause else "")
             + f" wall={time.time() - t0:.0f}s")
        tc = td["tight_coverage"][pol]
        emit(rows, f"largescale.telemetry.{pol}.tight_coverage",
             "null" if tc["coverage"] is None else f"{tc['coverage']:.3f}",
             f"missed tight-class requests pinned to a (stage, link) pair "
             f"(n_missed={tc['n_missed']})")
        if pol == "mfs":
            # one missed tight request's full timeline for Perfetto; fall
            # back to any served request if MFS missed nothing tight
            pick = next((r["rid"] for r in tight["requests"]
                         if r.get("link") is not None),
                        next((r["rid"] for r in rep["requests"]
                              if r.get("link") is not None), None))
            if pick is None:
                pick = next(r for r, tr in sorted(tel.requests.items())
                            if r >= 0 and tr.status == "served")
            tel.save_chrome_trace(TRACE_SAMPLE_JSON, rids={pick})
            td["trace_sample"] = {"path": TRACE_SAMPLE_JSON, "rid": pick}
            emit(rows, "largescale.telemetry.trace_sample",
                 TRACE_SAMPLE_JSON, f"Chrome trace of rid={pick}, mfs arm")
    return td


def _yardstick_demands(sim, items):
    """Replay the stage emitter over single-item batches to measure each
    request's expected byte demand per concrete directed link, its P2D
    byte total, and its prefill compute seconds.

    Group compute time is additive across batch items (per-item flops are
    summed), so the single-item replay is *exact* for compute throughput
    — no batching correction. S1 is excluded (a max-flow-optimal
    placement gets perfect prefix affinity) and WB is excluded
    (deferrable — it never gates TTFT); both exclusions keep the bound
    optimistic. The replay consumes ids from the module-global flow-id
    counter, which perturbs downstream ECMP spine hashes — the reason
    the yardstick arm runs *last* in the sweep."""
    emitter = sim.runtime.emitter
    profile, topo = sim.profile, sim.topo
    G = len(profile.plan)
    t1 = sim.runtime._t_first_decode
    link_bytes: Dict[int, float] = {}
    p2d = comp = 0.0
    n = 0
    for i, it in enumerate(items):
        if it.rid < 0:          # warmup is excluded from attainment
            continue
        n += 1
        bs = BatchState(bid=i, unit=it.owner_unit % sim.spec.n_units,
                        items=[it],
                        group_time=[profile.group_compute_time([it], g)
                                    for g in range(G)])
        bs.p2d_pending[it.rid] = set()
        comp += sum(bs.group_time)
        flows = []
        for g in range(G):
            bs.cur_group = g
            flows += emitter.stage3(bs, g, t1)
            co = emitter.stage2(bs)
            if co is not None:
                flows += co.flows
        for f in flows:
            if f.stage == Stage.P2D:
                p2d += f.size
            for lid in topo.route(f.src, f.dst, f.fid):
                link_bytes[lid] = link_bytes.get(lid, 0.0) + f.size
    n = max(n, 1)
    return ({l: b / n for l, b in link_bytes.items()}, p2d / n, comp / n)


def _run_yardstick(rows: List[str], quick: bool = False) -> Dict:
    """Max-flow optimality yardstick on the Mooncake tail (Helix-style).

    Ports the global max-flow bound to the deployed topology: a demand
    replay of the stage emitter gives per-request link bytes and compute
    seconds, :func:`fixed_route_rate` bounds throughput under the
    deployed routes, and :func:`disagg_bound` gives the routing-free
    S -> units -> NICs -> decode-ingress -> T min-cut (compute and
    network edges in one cut). ``attainment_ceiling`` then converts the
    sustainable rate r* into an upper bound on TTFT attainment at the
    offered rate — ``feasible_frac`` caps it by the fraction of requests
    whose SLO budget even covers their ideal TTFT. Every policy's
    attained value is reported as a fraction of that ceiling: the
    optimality *gap*, not just the policy-vs-policy ordering. The
    acceptance signal is MFS sitting closest to the ceiling with no
    policy above it.

    The flow-id counter is re-seeded at arm entry so the arm's ECMP
    spine picks — and therefore its numbers — are identical whether it
    runs standalone (``--only yardstick``) or last in the full sweep;
    the policy runs come *before* the demand replay so the replay's id
    consumption cannot perturb them either."""
    import repro.core.msflow as msflow
    msflow._flow_counter = itertools.count()
    n = 120 if quick else N_YARD
    trace = generate_trace(WORKLOADS[KV_WORKLOAD], n, rps=YARD_RATE, seed=0,
                           warmup=24, arrival=ArrivalSpec(process="mmpp"))
    yd = {"spec": KV_SPEC, "workload": KV_WORKLOAD, "sp": KV_SP,
          "hw": KV_HW.name, "decode_ratio": KV_DECODE_RATIO,
          "rate": YARD_RATE, "n_requests": n, "slo_mix": None,
          "store": "on", "ceiling": {}, "attainment": {},
          "frac_of_ceiling": {}}
    # ---- attained, per policy (first: the replay must not shift fids) ---
    walls: Dict[str, float] = {}
    for pol in POLICIES:
        sim = _sim(_spec_kv(_kvstore_spec()), pol,
                   label=f"yardstick.{pol}", total=len(trace))
        t0 = time.time()
        att = sim.run(trace).slo_attainment()
        walls[pol] = time.time() - t0
        yd["attainment"][pol] = att
        assert len(sim.runtime.flows) == 0, "runtime leaked flows"
    # ---- ceiling: demand replay on a probe sim (never run) --------------
    probe = ClusterSim(_spec_kv(_kvstore_spec()), make_policy("mfs"))
    items = probe.build_items(trace)
    if probe.kvstore is not None:
        # store-aware expected reuse, exactly as fixed-mode calibration
        entries = [(probe.kv_chain_keys(it), max(0, it.n_tokens - 1))
                   for it in items]
        exp = probe.kvstore.steady_state_reuse(entries)
        for it, e in zip(items, exp):
            it.reuse = min(int(e), max(0, it.n_tokens - 1))
    link_bytes, p2d_bytes, comp_s = _yardstick_demands(probe, items)
    spec = probe.spec
    unit_rate = 1.0 / comp_s
    compute_rate = spec.n_units * unit_rate
    net_fixed, bottleneck = fixed_route_rate(link_bytes,
                                             probe.topo.capacity)
    n_dec = len(probe.runtime.emitter.decode_eps)
    r_star = disagg_bound(
        unit_rates=[unit_rate] * spec.n_units,
        unit_out_caps=[spec.par.gpus * spec.hw.nic_bw] * spec.n_units,
        out_bytes=p2d_bytes,
        decode_in_caps=[spec.hw.nic_bw] * n_dec,
        in_bytes=p2d_bytes)
    # deadlines materialize at arrival; rebuild budgets from the
    # calibrated fixed-mode base exactly as _on_arrival does
    base = probe.runtime._slo_base
    feasible = [1.0 if probe.profile.ideal_ttft(it)
                <= (it.slo_scale if it.slo_scale > 0
                    else spec.slo_scale) * base + 1e-9 else 0.0
                for it in items if it.rid >= 0]
    feas = sum(feasible) / max(len(feasible), 1)
    ceiling = attainment_ceiling(YARD_RATE, r_star, feas)
    yd["ceiling"] = {
        "compute_rate": compute_rate,
        "net_rate_fixed_route": net_fixed,
        "bottleneck_link": bottleneck,
        "rate_maxflow": r_star,
        "feasible_frac": feas,
        "attainment_ceiling": ceiling,
        "per_request": {"compute_s": comp_s, "p2d_bytes": p2d_bytes,
                        "links_touched": len(link_bytes)}}
    emit(rows, "largescale.yardstick.ceiling", f"{ceiling:.4f}",
         f"r*={r_star:.2f}rps (compute={compute_rate:.2f} "
         f"fixed_route={net_fixed:.2f}) feasible={feas:.3f} "
         f"at rps{YARD_RATE:g}")
    for pol in POLICIES:
        att = yd["attainment"][pol]
        yd["frac_of_ceiling"][pol] = att / max(ceiling, 1e-9)
        emit(rows, f"largescale.yardstick.{pol}.rps{YARD_RATE:g}",
             f"{att:.4f}",
             f"frac_of_ceiling={yd['frac_of_ceiling'][pol]:.3f} "
             f"wall={walls[pol]:.0f}s")
    best = max(yd["frac_of_ceiling"], key=lambda p: yd["frac_of_ceiling"][p])
    yd["closest_to_ceiling"] = best
    emit(rows, "largescale.yardstick.closest", best,
         "smallest optimality gap: "
         + " ".join(f"{p}:{ceiling - yd['attainment'][p]:.3f}"
                    for p in POLICIES))
    # the yardstick must actually be a ceiling
    assert all(a <= ceiling + 1e-9 for a in yd["attainment"].values()), \
        "max-flow ceiling violated by an attained value"
    return yd


def main(quick: bool = False, only: Optional[str] = None):
    rows: List[str] = []
    if only in ("router", "telemetry", "yardstick"):
        # recompute just that arm and merge it into the committed
        # artifact — every legacy section stays byte-for-byte untouched
        with open(OUT_JSON) as fh:
            result = json.load(fh)
        arm = {"router": _run_router, "telemetry": _run_telemetry,
               "yardstick": _run_yardstick}[only]
        result[only] = arm(rows, quick)
        with open(OUT_JSON, "w") as fh:
            json.dump(result, fh, indent=2)
        emit(rows, "largescale.json", OUT_JSON, f"{only} arm merged")
        return rows
    n = 300 if quick else N_REQUESTS
    rates = RATES[1:3] if quick else RATES
    result = {"spec": SPEC, "workload": WORKLOAD, "n_requests": n,
              "rates": list(rates), "curves": {}, "slo_mix": {}}

    # ---- attainment-vs-rate curves, Poisson and bursty (MMPP) arrivals ----
    for proc in ("poisson", "mmpp"):
        arrival = ArrivalSpec(process=proc)
        curves: Dict[str, List[float]] = {p: [] for p in POLICIES}
        for rate in rates:
            trace = generate_trace(WORKLOADS[WORKLOAD], n, rps=rate, seed=0,
                                   warmup=WARMUP, arrival=arrival)
            for pol in POLICIES:
                s = _run_one(pol, trace, collect_stats=(pol == "mfs"))
                curves[pol].append(s["slo_attainment"])
                emit(rows, f"largescale.{proc}.{pol}.rps{rate:g}.attainment",
                     f"{s['slo_attainment']:.4f}",
                     f"p99={s.get('ttft_p99', float('nan')):.3f}s "
                     f"wall={s['wall_s']}s")
                assert s["flows_retained"] == 0, "runtime leaked flow state"
                if pol == "mfs":
                    emit(rows,
                         f"largescale.{proc}.rps{rate:g}.fills_per_realloc",
                         f"{s['fills_per_realloc']:.3f}",
                         f"groups_per_realloc={s['groups_per_realloc']:.3f}")
        result["curves"][proc] = curves

    # ---- multi-tenant SLO classes at the middle rate -----------------------
    rate = rates[len(rates) // 2]
    trace = generate_trace(WORKLOADS[WORKLOAD], n, rps=rate, seed=0,
                           warmup=WARMUP, arrival=ArrivalSpec(process="mmpp"),
                           slo_mix=SLO_MIX)
    for pol in POLICIES:
        sim = _sim(_spec(), pol, label=f"slomix.{pol}", total=len(trace))
        m = sim.run(trace)
        by_class = _per_class_attainment(
            {"ttft": m.ttft, "deadline": m.deadline}, trace)
        result["slo_mix"][pol] = by_class
        emit(rows, f"largescale.slomix.{pol}.attainment",
             "/".join(f"{by_class[c]:.3f}" for c in sorted(SLO_CLASSES)),
             "classes=" + "/".join(sorted(SLO_CLASSES)))

    # ---- decode-contention sweep: D2D rebalancing on vs. off --------------
    n_dec = 300 if quick else N_DECODE
    dec_rates = DECODE_RATES[-1:] if quick else DECODE_RATES
    dec = {"spec": DECODE_SPEC, "ep": DECODE_EP, "decode_ratio": DECODE_RATIO,
           "rates": list(dec_rates), "n_requests": n_dec,
           "ttft": {}, "tpot": {}, "tpot_by_pool": {}, "migrations": {},
           "tbt_max": {}}
    for mode, reb in (("d2d_on", True), ("d2d_off", False)):
        ttft: Dict[str, List[float]] = {p: [] for p in POLICIES}
        tpot: Dict[str, List[float]] = {p: [] for p in POLICIES}
        by_pool: Dict[str, List[Dict[str, float]]] = {p: [] for p in POLICIES}
        migr: Dict[str, List[int]] = {p: [] for p in POLICIES}
        tbt: Dict[str, List[float]] = {p: [] for p in POLICIES}
        for rate in dec_rates:
            trace = generate_trace(WORKLOADS[WORKLOAD], n_dec, rps=rate,
                                   seed=0, warmup=WARMUP,
                                   arrival=ArrivalSpec(process="mmpp"),
                                   slo_mix=SLO_MIX, decode_lens=True)
            for pol in POLICIES:
                sim = _sim(_spec_decode(_decode_spec(reb)), pol,
                           label=f"decode.{mode}.{pol}", total=len(trace))
                t0 = time.time()
                s = sim.run(trace).summary()
                ttft[pol].append(s["slo_attainment"])
                tpot[pol].append(s["tpot_attainment"])
                by_pool[pol].append(s["tpot_by_pool"])
                migr[pol].append(int(s["decode_migrations"]))
                # worst token gap (records migration-stall behavior per
                # policy alongside the mean-TBT attainment)
                tbt[pol].append(s.get("tpot_tbt_max", 0.0))
                assert len(sim.runtime.flows) == 0, "runtime leaked flows"
                assert s["decode_live_sessions"] == 0, "plane leaked sessions"
                emit(rows, f"largescale.decode.{mode}.{pol}.rps{rate:g}",
                     f"{s['slo_attainment']:.4f}",
                     f"tpot={s['tpot_attainment']:.3f} "
                     f"migr={int(s['decode_migrations'])} "
                     f"wall={time.time() - t0:.0f}s")
        dec["ttft"][mode] = ttft
        dec["tpot"][mode] = tpot
        dec["tpot_by_pool"][mode] = by_pool
        dec["migrations"][mode] = migr
        dec["tbt_max"][mode] = tbt
    # MFS's TTFT advantage at the highest contended rate, D2D enabled: the
    # deadline-chasing stage-agnostic baselines pay for prioritising D2D
    top = dec["ttft"]["d2d_on"]
    dec["mfs_ttft_ratio_at_top"] = {
        p: top["mfs"][-1] / max(top[p][-1], 1e-9)
        for p in POLICIES if p != "mfs"}
    for p, r in sorted(dec["mfs_ttft_ratio_at_top"].items()):
        emit(rows, f"largescale.decode.mfs_over_{p}", f"{r:.2f}",
             f"TTFT attainment ratio at rps{dec_rates[-1]:g}, d2d on")
    result["decode"] = dec

    # ---- KV-reuse + chunked-prefill + router arms (section functions) ---
    result["kvreuse"] = _run_kvreuse(rows, quick)
    result["chunked"] = _run_chunked(rows, quick)
    result["router"] = _run_router(rows, quick)
    result["telemetry"] = _run_telemetry(rows, quick)
    # last on purpose: the yardstick's demand replay consumes flow ids,
    # which would shift every later arm's ECMP spine picks
    result["yardstick"] = _run_yardstick(rows, quick)

    with open(OUT_JSON, "w") as fh:
        json.dump(result, fh, indent=2)
    emit(rows, "largescale.json", OUT_JSON, f"{n} requests x {len(rates)} rates")
    return rows


if __name__ == "__main__":
    argv = sys.argv[1:]
    only = argv[argv.index("--only") + 1] if "--only" in argv else None
    PROGRESS = "--progress" in argv
    main(quick="--quick" in argv, only=only)
