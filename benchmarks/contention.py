"""Fig 5 — communication contention inflates TTFT (~1.5x) and all-to-all CCT
(~1.8x) on a 16-GPU Mixtral-8x7B prefill cluster (QwenB-agent trace)."""
from __future__ import annotations

from .common import calibrate_rate, emit, run_sim, spec_for


def main(quick: bool = False):
    rows = []
    n = 64 if quick else 192
    spec = spec_for("mixtral-8x7b", ep=8, n_units=2)
    rate = round(calibrate_rate(spec, "qwen-agent", target=0.75,
                                n=min(n, 64)), 2)
    base = run_sim("fs", spec, "qwen-agent", n=n, rps=rate,
                   contention_free=True)
    cont = run_sim("fs", spec, "qwen-agent", n=n, rps=rate)
    ttft_x = cont["ttft_mean"] / base["ttft_mean"]
    cct_x = cont["cct_slowdown"] / max(base["cct_slowdown"], 1e-9)
    emit(rows, "fig5.ttft_no_contention_ms", f"{base['ttft_mean']*1e3:.3f}")
    emit(rows, "fig5.ttft_contention_ms", f"{cont['ttft_mean']*1e3:.3f}",
         f"inflation={ttft_x:.2f}x (paper ~1.5x)")
    emit(rows, "fig5.cct_slowdown_no_contention",
         f"{base['cct_slowdown']:.3f}")
    emit(rows, "fig5.cct_slowdown_contention", f"{cont['cct_slowdown']:.3f}",
         f"inflation={cct_x:.2f}x (paper ~1.8x)")
    return rows


if __name__ == "__main__":
    main()
