"""Fig 10/11 — large-scale simulation: TTFT SLO attainment vs request rate
for the four MoE models (Mixtral-8x22B, DBRX, Grok, Qwen3-Coder) on the
Qwen conversation (Fig 10) and agent (Fig 11) traces.

Request rates are auto-calibrated per (model, workload) onto the falling
edge of the attainment curve — the paper's figures all live there.

Paper: MFS reaches 1.4-1.8x (conv; up to 2.4x DBRX) and 1.4-2.0x (agent)
higher attainment than baselines at high load, and sustains 1.17-1.46x
higher rates at iso-attainment than Karuna."""
from __future__ import annotations

from .common import (POLICIES, calibrate_rate, emit, run_sim, spec_for,
                     sustained_rate)

MODELS = {
    "mixtral-8x22b": dict(mode="ep", tp=4, ep=8),
    "dbrx": dict(mode="ep", tp=2, ep=16),
    "grok": dict(mode="ep", tp=4, ep=8),
    "qwen3-coder": dict(mode="ep", tp=1, ep=32),
}


def main(quick: bool = False):
    rows = []
    n = 48 if quick else 128
    models = list(MODELS)[:2] if quick else list(MODELS)
    for fig, wl in (("fig10", "qwen-conv"), ("fig11", "qwen-agent")):
        for model in models:
            spec = spec_for(model, n_units=2, **MODELS[model])
            r_star = calibrate_rate(spec, wl, n=min(n, 64))
            factors = (0.8, 1.0) if quick else (0.5, 0.75, 1.0, 1.3, 1.7)
            rates = [round(r_star * f, 2) for f in factors]
            results = {}
            for rate in rates:
                res = {p: run_sim(p, spec, wl, n=n, rps=rate)
                       for p in POLICIES}
                results[rate] = res
                best_base = max(res[p]["slo_attainment"]
                                for p in ("fs", "sjf", "edf", "karuna"))
                gain = res["mfs"]["slo_attainment"] / max(best_base, 1e-9)
                vals = " ".join(f"{p}={res[p]['slo_attainment']:.3f}"
                                for p in POLICIES)
                emit(rows, f"{fig}.{model}.rate{rate:g}.slo_attainment",
                     f"{res['mfs']['slo_attainment']:.3f}",
                     f"{vals} mfs_gain={gain:.2f}x")
            mfs_rate = sustained_rate("mfs", spec, wl, rates, results)
            kar_rate = sustained_rate("karuna", spec, wl, rates, results)
            if kar_rate > 0:
                emit(rows, f"{fig}.{model}.iso_attainment_rate_vs_karuna",
                     f"{mfs_rate / kar_rate:.2f}x",
                     "paper: 1.17-1.46x (conv) / 1.2-1.4x (agent)")
    return rows


if __name__ == "__main__":
    main()
