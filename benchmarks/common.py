"""Shared helpers for the benchmark suite.

Every benchmark prints ``name,value,derived`` CSV rows through ``emit`` and
returns a list of those rows so benchmarks.run can aggregate them into
bench_output.txt / EXPERIMENTS.md.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from repro.core import make_policy
from repro.simcluster.papermodels import PAPER_MODELS
from repro.simcluster.sim import ClusterSim, ClusterSpec, ParallelismSpec
from repro.simcluster.trace import WORKLOADS, generate_trace

__all__ = ["emit", "run_sim", "spec_for", "POLICIES", "PAPER_MODELS"]

POLICIES = ("fs", "sjf", "edf", "karuna", "mfs")


def emit(rows: List[str], name: str, value, derived: str = "") -> None:
    line = f"{name},{value},{derived}"
    rows.append(line)
    print(line, flush=True)


def spec_for(model: str, *, mode: str = "ep", tp: int = 1, ep: int = 8,
             sp: int = 1, n_units: int = 2, **kw) -> ClusterSpec:
    par = ParallelismSpec(mode=mode, tp=tp, ep=ep, sp=sp)
    return ClusterSpec(model=PAPER_MODELS[model], par=par, n_units=n_units,
                       **kw)


def run_sim(policy: str, spec: ClusterSpec, workload: str, *, n: int = 96,
            rps: float = 8.0, seed: int = 0, warmup: int = 16,
            contention_free: bool = False) -> Dict:
    trace = generate_trace(WORKLOADS[workload], n_requests=n, rps=rps,
                           seed=seed, warmup=warmup)
    sim = ClusterSim(spec, make_policy(policy), seed=seed,
                     contention_free=contention_free)
    return sim.run(trace).summary()


def calibrate_rate(spec: ClusterSpec, workload: str, *, target: float = 0.6,
                   policy: str = "fs", n: int = 64, lo: float = 0.25,
                   hi: float = 128.0, iters: int = 7) -> float:
    """Request rate where ``policy`` lands near ``target`` attainment —
    the contended-but-not-collapsed regime every paper figure lives in
    (attainment curves are only informative on their falling edge)."""
    for _ in range(iters):
        mid = (lo * hi) ** 0.5
        att = run_sim(policy, spec, workload, n=n, rps=mid)["slo_attainment"]
        if att > target:
            lo = mid
        else:
            hi = mid
        if hi / lo < 1.15:
            break
    return (lo * hi) ** 0.5


def sustained_rate(policy: str, spec: ClusterSpec, workload: str,
                   rates: Sequence[float], results: Dict[float, Dict[str, Dict]],
                   floor: float = 0.9) -> float:
    """Highest evaluated rate whose attainment stays >= floor."""
    best = 0.0
    for r in sorted(rates):
        if results[r][policy]["slo_attainment"] >= floor:
            best = r
    return best
