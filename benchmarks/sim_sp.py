"""Fig 12 — Llama3-8B with sequence parallelism (TP=4, SP=4) on the
Mooncake long-context conversation/agent traces, at calibrated load.

Paper: MFS attains 1.3-1.6x (conv) and 1.4-1.9x (agent) higher SLO
attainment than Karuna under load."""
from __future__ import annotations

from .common import POLICIES, calibrate_rate, emit, run_sim, spec_for


def main(quick: bool = False):
    rows = []
    n = 32 if quick else 96
    spec = spec_for("llama3-8b", mode="sp", tp=4, sp=4, n_units=2)
    for wl, tag in (("mooncake-conv", "conv"), ("mooncake-agent", "agent")):
        # calibrate against Karuna — the strongest baseline in Fig 12
        r_star = calibrate_rate(spec, wl, policy="karuna", target=0.7,
                                n=min(n, 48))
        factors = (1.0,) if quick else (0.7, 1.0, 1.3)
        for f in factors:
            rate = round(r_star * f, 3)
            res = {p: run_sim(p, spec, wl, n=n, rps=rate) for p in POLICIES}
            gain = (res["mfs"]["slo_attainment"]
                    / max(res["karuna"]["slo_attainment"], 1e-9))
            vals = " ".join(f"{p}={res[p]['slo_attainment']:.3f}"
                            for p in POLICIES)
            emit(rows, f"fig12.{tag}.rate{rate:g}.slo_attainment",
                 f"{res['mfs']['slo_attainment']:.3f}",
                 f"{vals} vs_karuna={gain:.2f}x")
    return rows


if __name__ == "__main__":
    main()
