"""§Perf harness — measure one (arch, shape, mesh) cell under a set of
baseline kill-switch env vars vs the optimized defaults.

Runs each configuration in a SUBPROCESS (several switches are read at
import time) and prints the roofline-relevant numbers side by side.

    PYTHONPATH=src python -m benchmarks.perf_compare \
        --arch minitron-8b --shape decode_32k --unroll \
        --baseline-env REPRO_BASELINE_EXPAND_KV=1
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

_CHILD = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import plan_cells, build_cell
from repro.launch.dryrun import collective_bytes

arch, shape, mesh_kind, unroll, out = sys.argv[1:6]
mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
cell = plan_cells([arch], [shape])[0]
cell = build_cell(cell, mesh, unroll=(unroll == "1"))
with mesh:
    compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                       out_shardings=cell.out_shardings).lower(*cell.args).compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):
    ca = ca[0]
ma = compiled.memory_analysis()
rec = {
    "flops": float(ca.get("flops", -1.0)),
    "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
    "collective_bytes": collective_bytes(compiled.as_text())["total_bytes"],
    "temp_gb": ma.temp_size_in_bytes / 1e9,
    "args_gb": ma.argument_size_in_bytes / 1e9,
    "model_flops": cell.model_flops,
}
with open(out, "w") as f:
    json.dump(rec, f)
"""

PEAK, HBM, LINK = 197e12, 819e9, 50e9


def run_once(arch: str, shape: str, mesh: str, unroll: bool,
             extra_env: dict) -> dict:
    env = dict(os.environ)
    env.update(extra_env)
    env.pop("XLA_FLAGS", None)
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        out = f.name
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD, arch, shape, mesh,
         "1" if unroll else "0", out],
        env=env, capture_output=True, text=True, timeout=7200)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    with open(out) as f:
        return json.load(f)


def terms(rec: dict) -> dict:
    return {
        "compute_s": rec["flops"] / PEAK,
        "memory_s": rec["bytes_accessed"] / HBM,
        "collective_s": rec["collective_bytes"] / LINK,
        "temp_gb": rec["temp_gb"],
        "args_gb": rec["args_gb"],
        "useful": rec["model_flops"] / max(rec["flops"] * 256, 1e-9),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--baseline-env", nargs="*", default=[])
    args = ap.parse_args()

    base_env = dict(kv.split("=", 1) for kv in args.baseline_env)
    base = terms(run_once(args.arch, args.shape, args.mesh, args.unroll,
                          base_env))
    opt = terms(run_once(args.arch, args.shape, args.mesh, args.unroll, {}))
    print(f"cell: {args.arch} x {args.shape} ({args.mesh} pod"
          f"{', unrolled' if args.unroll else ''})")
    print(f"{'metric':14s} {'baseline':>12s} {'optimized':>12s} {'delta':>8s}")
    for k in ("compute_s", "memory_s", "collective_s", "temp_gb", "args_gb",
              "useful"):
        b, o = base[k], opt[k]
        delta = (o - b) / b if b else float("inf")
        print(f"{k:14s} {b:12.4f} {o:12.4f} {delta:+8.1%}")


if __name__ == "__main__":
    main()
