"""Network topologies for the flow-level simulator.

Node ids are flat integers, one per NIC-attached endpoint (GPU/NIC pair) —
the paper's testbed exposes 2×100G NICs per 4-GPU server and the simulation
8 NICs per 8-GPU server, so "one endpoint per GPU share of NIC bandwidth" is
the natural granularity.

Topologies provide:
    route(src, dst, fid) -> tuple[int, ...]   link ids traversed
    capacity[lid]                              bytes/sec

Intra-server traffic rides the scale-up fabric (NVSwitch / ICI), modelled as
per-endpoint scale-up up/down links so it can still contend when many
neighbours target one victim endpoint — matching §2.2's victim-unit NIC
contention story at the server boundary.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

__all__ = ["Topology", "SingleToR", "FatTree"]

GB = 1e9
Gb = 1e9 / 8


@dataclass
class Topology:
    n_nodes: int
    capacity: Dict[int, float] = field(default_factory=dict)

    def route(self, src: int, dst: int, fid: int = 0) -> Tuple[int, ...]:
        raise NotImplementedError

    def server_of(self, node: int) -> int:
        raise NotImplementedError


class SingleToR(Topology):
    """All endpoints under one Top-of-Rack switch (the paper's testbed).

    Links (per endpoint i): 2i = uplink (host->ToR), 2i+1 = downlink. The ToR
    backplane is non-blocking. Endpoints on the same server communicate over
    the scale-up fabric: links 2N+2j / 2N+2j+1 are server-local egress/ingress
    with ``scaleup_bw``.
    """

    def __init__(self, n_nodes: int, nic_bw: float = 100 * Gb,
                 gpus_per_server: int = 4, scaleup_bw: float = 900 * GB):
        super().__init__(n_nodes)
        self.gpus_per_server = gpus_per_server
        for i in range(n_nodes):
            self.capacity[2 * i] = nic_bw
            self.capacity[2 * i + 1] = nic_bw
        base = 2 * n_nodes
        self._su = base
        for i in range(n_nodes):
            self.capacity[base + 2 * i] = scaleup_bw
            self.capacity[base + 2 * i + 1] = scaleup_bw

    def server_of(self, node: int) -> int:
        return node // self.gpus_per_server

    def route(self, src: int, dst: int, fid: int = 0) -> Tuple[int, ...]:
        if src == dst:
            return ()
        if self.server_of(src) == self.server_of(dst):
            return (self._su + 2 * src, self._su + 2 * dst + 1)
        return (2 * src, 2 * dst + 1)


class FatTree(Topology):
    """Two-tier leaf-spine with 1:1 oversubscription and per-flow ECMP.

    ``racks`` leaves, ``hosts_per_rack`` endpoints each, ``n_spines`` spines.
    Link naming:
        host up / down:            2i, 2i+1
        leaf(r) -> spine(s) up:    U(r, s)
        spine(s) -> leaf(r) down:  D(r, s)
        scale-up egress/ingress:   per endpoint, as in SingleToR
    ECMP picks the spine by hashing the flow id, a per-flow static choice as
    in real fabrics (hash collisions are part of the contention the paper
    studies).
    """

    def __init__(self, racks: int, hosts_per_rack: int,
                 nic_bw: float = 200 * Gb, n_spines: int | None = None,
                 gpus_per_server: int = 8, scaleup_bw: float = 900 * GB):
        super().__init__(racks * hosts_per_rack)
        self.racks = racks
        self.hosts_per_rack = hosts_per_rack
        self.gpus_per_server = gpus_per_server
        # 1:1 fat tree: aggregate spine bandwidth == aggregate host bandwidth
        self.n_spines = n_spines or hosts_per_rack
        spine_bw = nic_bw * hosts_per_rack / self.n_spines
        n = self.n_nodes
        for i in range(n):
            self.capacity[2 * i] = nic_bw
            self.capacity[2 * i + 1] = nic_bw
        self._up0 = 2 * n
        self._dn0 = 2 * n + racks * self.n_spines
        for r in range(racks):
            for s in range(self.n_spines):
                self.capacity[self._up0 + r * self.n_spines + s] = spine_bw
                self.capacity[self._dn0 + r * self.n_spines + s] = spine_bw
        self._su = self._dn0 + racks * self.n_spines
        for i in range(n):
            self.capacity[self._su + 2 * i] = scaleup_bw
            self.capacity[self._su + 2 * i + 1] = scaleup_bw

    def rack_of(self, node: int) -> int:
        return node // self.hosts_per_rack

    def server_of(self, node: int) -> int:
        return node // self.gpus_per_server

    def route(self, src: int, dst: int, fid: int = 0) -> Tuple[int, ...]:
        if src == dst:
            return ()
        if self.server_of(src) == self.server_of(dst):
            return (self._su + 2 * src, self._su + 2 * dst + 1)
        rs, rd = self.rack_of(src), self.rack_of(dst)
        if rs == rd:
            return (2 * src, 2 * dst + 1)
        s = (fid * 2654435761) % self.n_spines        # deterministic ECMP hash
        return (2 * src,
                self._up0 + rs * self.n_spines + s,
                self._dn0 + rd * self.n_spines + s,
                2 * dst + 1)
