"""Didactic single-link scenario runner — reproduces the paper's Fig 6/7 and
Table 1/2 examples exactly (used by tests/test_paper_examples.py and
benchmarks.microbench).

All flows share one bottleneck link of unit capacity. The runner drives a
policy through the same submit -> assign -> reallocate -> advance loop the
cluster simulator uses, firing periodic "tick" triggers so deadline-driven
promotion (MFS's MLU ladder) can act between completions.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..core import Stage, new_flow_id
from ..core.msflow import Flow
from ..core.policies import Policy
from .fluid import FluidNet
from .topology import Topology

__all__ = ["OneLink", "ToyView", "run_toy"]


class OneLink(Topology):
    """Every src->dst pair traverses the single link 0."""

    def __init__(self, capacity: float = 1.0):
        super().__init__(2)
        self.capacity = {0: capacity}

    def route(self, src: int, dst: int, fid: int = 0) -> Tuple[int, ...]:
        return (0,)

    def server_of(self, node: int) -> int:
        return node


@dataclass
class ToyView:
    net: FluidNet
    lcurr: int = 0

    @property
    def now(self) -> float:
        return self.net.now

    def bottleneck(self, flow):
        return self.net.bottleneck(flow)

    def mlu_inputs(self, flow, level):
        def protected(o):
            if o.stage != Stage.P2D:
                return True
            return o.level < level
        return self.net.bottleneck_protected(flow, protected)

    def l_curr(self, unit: int) -> int:
        return self.lcurr

    def computing(self, rid: int) -> bool:
        return False          # toy flows re-evaluate on ticks

    def red_rank(self, rid: int) -> int:
        return 0

    def downstream_estimate(self, flow) -> float:
        return 0.0


def make_flow(stage: Stage, size: float, deadline: Optional[float] = None,
              rid: int = 0, target_layer: int = 0) -> Flow:
    return Flow(fid=new_flow_id(), rid=rid, unit=0, stage=stage, size=size,
                src=0, dst=1, target_layer=target_layer, n_layers=4,
                deadline=deadline)


def run_toy(flows: List[Flow], policy: Policy, capacity: float = 1.0,
            tick: float = 0.25, t_max: float = 100.0) -> Dict[int, float]:
    """Run all flows (submitted at t=0) to completion; returns fid->finish."""
    policy.reset()
    net = FluidNet(OneLink(capacity))
    view = ToyView(net)
    for f in flows:
        net.add(f)
        policy.on_flow_submitted(f, view)
    finish: Dict[int, float] = {}
    t = 0.0
    while net.flows and t < t_max:
        policy.assign(list(net.flows.values()), view, ("tick",))
        net.reallocate()
        nxt = net.next_completion()
        t_next = min(t + tick, nxt[0] if nxt else t + tick)
        done = net.advance(t_next)
        for f in done:
            finish[f.fid] = f.finished
            policy.on_flow_completed(f, view)
        t = t_next
    return finish
