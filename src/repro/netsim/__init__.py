"""repro.netsim — flow-level fluid network simulator (flowsim analogue)."""
from .topology import Topology, SingleToR, FatTree, GB, Gb
from .fluid import FluidNet, LOCAL_BW
from .events import EventQueue

__all__ = ["Topology", "SingleToR", "FatTree", "GB", "Gb",
           "FluidNet", "LOCAL_BW", "EventQueue"]
