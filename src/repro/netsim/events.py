"""Unified event queue — computation and network events share one timeline.

The paper (§6.1) stresses that Vidur-generated computation events and
flowsim-level network events must be processed "within a single event queue to
ensure correctness"; this is that queue. Events are (time, seq, kind, payload)
with a monotone sequence number for deterministic FIFO tie-breaking, plus an
epoch-based invalidation scheme so stale flow-completion predictions (obsoleted
by a re-allocation) are skipped cheaply instead of being searched and removed.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, Optional, Tuple

__all__ = ["EventQueue"]


class EventQueue:
    def __init__(self):
        self._heap: list = []
        self._seq = itertools.count()
        self.now = 0.0

    def push(self, t: float, kind: str, payload: Any = None,
             epoch: Optional[int] = None) -> None:
        if t < self.now - 1e-9:
            raise ValueError(f"scheduling into the past: {t} < {self.now}")
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload, epoch))

    def pop(self) -> Optional[Tuple[float, str, Any, Optional[int]]]:
        if not self._heap:
            return None
        t, _, kind, payload, epoch = heapq.heappop(self._heap)
        self.now = max(self.now, t)
        return t, kind, payload, epoch

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
