"""Priority-aware fluid (rate-based) flow model — the flowsim analogue.

Bandwidth allocation semantics (matching §5's enforcement model):

  1. Active flows are grouped by ``priority_key`` (lexicographic tuples,
     smaller = more urgent) and groups are served in **strict priority**
     order: a group only sees the capacity left over by more urgent groups.
  2. Within a group, bandwidth is **max-min fair** (progressive filling),
     honouring per-flow ``rate_cap`` ceilings (Karuna-style pacing).
  3. Flows whose route is empty (same-endpoint transfers) complete at the
     memory-copy rate ``LOCAL_BW``.

Between events rates are constant, so completion times are exact; the event
loop re-allocates whenever the active set, keys or caps change. This is the
standard fluid approximation used by flow-level simulators (flowsim, Sincronia,
Karuna) — per-packet effects (reordering etc.) are *designed out* of MFS by
message-atomic promotion, so the fluid model is faithful for this paper.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Tuple

from ..core.msflow import Flow, FlowState
from .topology import Topology

__all__ = ["FluidNet", "LOCAL_BW"]

LOCAL_BW = 2e12      # same-endpoint "transfer" drains at HBM-copy speed
_EPS = 1e-12         # rate/capacity epsilon
_EPS_BYTES = 1e-4    # a flow with less than this many bytes left is done


class FluidNet:
    def __init__(self, topo: Topology):
        self.topo = topo
        self.flows: Dict[int, Flow] = {}
        self.routes: Dict[int, Tuple[int, ...]] = {}
        self.now = 0.0
        self._link_rate: Dict[int, float] = {}      # post-allocation usage
        self._link_members: Dict[int, List[Flow]] = {}

    # ------------------------------------------------------------- lifecycle
    def add(self, flow: Flow) -> None:
        self.flows[flow.fid] = flow
        self.routes[flow.fid] = self.topo.route(flow.src, flow.dst, flow.fid)
        flow.state = FlowState.ACTIVE if flow.state != FlowState.PRUNED else flow.state
        if flow.started is None:
            flow.started = self.now

    def remove(self, flow: Flow) -> None:
        self.flows.pop(flow.fid, None)
        self.routes.pop(flow.fid, None)

    def advance(self, t: float) -> List[Flow]:
        """Progress all flows to time ``t`` at current rates; return the flows
        that completed (remaining hits zero) in this interval."""
        dt = t - self.now
        if dt < -1e-9:
            raise ValueError(f"time went backwards: {self.now} -> {t}")
        done: List[Flow] = []
        for f in self.flows.values():
            if dt > 0 and f.rate > 0.0:
                f.remaining = max(0.0, f.remaining - f.rate * dt)
            # float-safe completion: anything within a sub-byte epsilon (or
            # within one picosecond of draining at the current rate) is done —
            # prevents completion-prediction livelock at time resolution.
            if f.remaining <= max(_EPS_BYTES, f.rate * 1e-12):
                f.remaining = 0.0
                done.append(f)
        self.now = t
        for f in done:
            f.state = FlowState.DONE
            f.finished = t
            f.rate = 0.0
            self.remove(f)
        return done

    # ------------------------------------------------------------ allocation
    def reallocate(self) -> None:
        """Strict-priority, per-group max-min water-filling with rate caps."""
        residual = dict(self.topo.capacity)
        self._link_rate = {lid: 0.0 for lid in residual}
        self._link_members = {}
        groups: Dict[Tuple, List[Flow]] = {}
        for f in self.flows.values():
            groups.setdefault(tuple(f.priority_key), []).append(f)
        for key in sorted(groups):
            self._fill_group(groups[key], residual)

    def _fill_group(self, members: List[Flow], residual: Dict[int, float]) -> None:
        rate = {f.fid: 0.0 for f in members}
        unfrozen = {f.fid: f for f in members}
        # local (routeless) flows drain immediately at LOCAL_BW
        for fid in list(unfrozen):
            f = unfrozen[fid]
            if not self.routes[fid]:
                r = LOCAL_BW if f.rate_cap is None else min(LOCAL_BW, f.rate_cap)
                rate[fid] = r
                del unfrozen[fid]
        while unfrozen:
            # population of unfrozen flows per link
            nflows: Dict[int, int] = {}
            for fid in unfrozen:
                for lid in self.routes[fid]:
                    nflows[lid] = nflows.get(lid, 0) + 1
            # smallest incremental fair share over saturating constraints
            inc = math.inf
            for lid, n in nflows.items():
                inc = min(inc, max(0.0, residual[lid]) / n)
            for fid, f in unfrozen.items():
                if f.rate_cap is not None:
                    inc = min(inc, f.rate_cap - rate[fid])
            if inc < 0:
                inc = 0.0
            if not math.isfinite(inc):
                break
            for fid in unfrozen:
                rate[fid] += inc
                for lid in self.routes[fid]:
                    residual[lid] -= inc
            # freeze: flows at cap, flows crossing a saturated link
            newly_frozen = []
            for fid, f in unfrozen.items():
                at_cap = f.rate_cap is not None and rate[fid] >= f.rate_cap - _EPS
                saturated = any(residual[lid] <= _EPS for lid in self.routes[fid])
                if at_cap or saturated:
                    newly_frozen.append(fid)
            if not newly_frozen:      # numerical guard: freeze everything
                break
            for fid in newly_frozen:
                del unfrozen[fid]
        for f in members:
            f.rate = rate[f.fid]
            for lid in self.routes[f.fid]:
                self._link_rate[lid] = self._link_rate.get(lid, 0.0) + f.rate
                self._link_members.setdefault(lid, []).append(f)

    # --------------------------------------------------------------- queries
    def next_completion(self) -> Optional[Tuple[float, Flow]]:
        best_t, best_f = math.inf, None
        for f in self.flows.values():
            if f.rate > 0.0:
                t = self.now + max(f.remaining / f.rate, 1e-12)
                if t < best_t:
                    best_t, best_f = t, f
        if best_f is None:
            return None
        return best_t, best_f

    def bottleneck(self, flow: Flow) -> Tuple[float, float]:
        """(capacity, rho) of the flow's most-utilised path link, excluding
        the flow's own contribution — feeds the MLU computation (§4.3)."""
        route = self.routes.get(flow.fid)
        if route is None:
            route = self.topo.route(flow.src, flow.dst, flow.fid)
        if not route:
            return LOCAL_BW, 0.0
        best_cap, best_rho = None, -1.0
        for lid in route:
            cap = self.topo.capacity[lid]
            used = self._link_rate.get(lid, 0.0) - (flow.rate if flow.fid in self.flows else 0.0)
            rho = min(1.0, max(0.0, used / cap))
            if rho > best_rho or (rho == best_rho and (best_cap is None or cap < best_cap)):
                best_cap, best_rho = cap, rho
        return float(best_cap), float(best_rho)

    def bottleneck_protected(self, flow: Flow, predicate) -> Tuple[float, float]:
        """Like :meth:`bottleneck`, but rho only counts path traffic for which
        ``predicate(other_flow)`` holds — i.e. traffic the candidate flow is
        *not allowed to preempt*. Feeding this into MLU avoids the positive
        feedback loop where contention from equally-deferred peers inflates
        every peer's urgency simultaneously."""
        route = self.routes.get(flow.fid)
        if route is None:
            route = self.topo.route(flow.src, flow.dst, flow.fid)
        if not route:
            return LOCAL_BW, 0.0
        best_cap, best_rho = None, -1.0
        for lid in route:
            cap = self.topo.capacity[lid]
            used = sum(f.rate for f in self._link_members.get(lid, ())
                       if f.fid != flow.fid and predicate(f))
            rho = min(1.0, max(0.0, used / cap))
            if rho > best_rho or (rho == best_rho and (best_cap is None or cap < best_cap)):
                best_cap, best_rho = cap, rho
        return float(best_cap), float(best_rho)

    def utilization(self) -> Dict[int, float]:
        return {lid: self._link_rate.get(lid, 0.0) / cap
                for lid, cap in self.topo.capacity.items()}
