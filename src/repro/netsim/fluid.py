"""Priority-aware fluid (rate-based) flow model — the flowsim analogue.

Bandwidth allocation semantics (matching §5's enforcement model):

  1. Active flows are grouped by ``priority_key`` (lexicographic tuples,
     smaller = more urgent) and groups are served in **strict priority**
     order: a group only sees the capacity left over by more urgent groups.
  2. Within a group, bandwidth is **max-min fair** (progressive filling),
     honouring per-flow ``rate_cap`` ceilings (Karuna-style pacing).
  3. Flows whose route is empty (same-endpoint transfers) complete at the
     memory-copy rate ``LOCAL_BW``.

Between events rates are constant, so completion times are exact; the event
loop re-allocates whenever the active set, keys or caps change. This is the
standard fluid approximation used by flow-level simulators (flowsim, Sincronia,
Karuna) — per-packet effects (reordering etc.) are *designed out* of MFS by
message-atomic promotion, so the fluid model is faithful for this paper.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from ..core.msflow import Flow, FlowState
from .topology import Topology

__all__ = ["FluidNet", "LOCAL_BW"]

LOCAL_BW = 2e12      # same-endpoint "transfer" drains at HBM-copy speed
_EPS = 1e-12         # rate/capacity epsilon
_EPS_BYTES = 1e-4    # a flow with less than this many bytes left is done


class FluidNet:
    def __init__(self, topo: Topology):
        self.topo = topo
        self.flows: Dict[int, Flow] = {}
        self.routes: Dict[int, Tuple[int, ...]] = {}
        self.now = 0.0
        self._link_rate: Dict[int, float] = {}      # post-allocation usage
        self._link_members: Dict[int, List[Flow]] = {}

    # ------------------------------------------------------------- lifecycle
    def add(self, flow: Flow) -> None:
        self.flows[flow.fid] = flow
        self.routes[flow.fid] = self.topo.route(flow.src, flow.dst, flow.fid)
        flow.state = FlowState.ACTIVE if flow.state != FlowState.PRUNED else flow.state
        if flow.started is None:
            flow.started = self.now

    def remove(self, flow: Flow) -> None:
        self.flows.pop(flow.fid, None)
        self.routes.pop(flow.fid, None)

    def advance(self, t: float) -> List[Flow]:
        """Progress all flows to time ``t`` at current rates; return the flows
        that completed (remaining hits zero) in this interval."""
        dt = t - self.now
        if dt < -1e-9:
            raise ValueError(f"time went backwards: {self.now} -> {t}")
        done: List[Flow] = []
        for f in self.flows.values():
            if dt > 0 and f.rate > 0.0:
                f.remaining = max(0.0, f.remaining - f.rate * dt)
            # float-safe completion: anything within a sub-byte epsilon (or
            # within one picosecond of draining at the current rate) is done —
            # prevents completion-prediction livelock at time resolution.
            if f.remaining <= max(_EPS_BYTES, f.rate * 1e-12):
                f.remaining = 0.0
                done.append(f)
        self.now = t
        for f in done:
            f.state = FlowState.DONE
            f.finished = t
            f.rate = 0.0
            self.remove(f)
        return done

    # ------------------------------------------------------------ allocation
    def reallocate(self) -> None:
        """Strict-priority, per-group max-min water-filling with rate caps."""
        residual = dict(self.topo.capacity)
        self._link_rate = {lid: 0.0 for lid in residual}
        self._link_members = {}
        groups: Dict[Tuple, List[Flow]] = {}
        for f in self.flows.values():
            groups.setdefault(tuple(f.priority_key), []).append(f)
        for key in sorted(groups):
            self._fill_group(groups[key], residual)

    #: group size at which the numpy water-filling overtakes the dict walk
    #: (measured on FatTree(8x8): the matrix path is ~3x faster at 512
    #: flows/group but ~4x slower at <64 because of per-round numpy setup)
    VEC_THRESHOLD = 96

    def _fill_group(self, members: List[Flow], residual: Dict[int, float]) -> None:
        rate = {}
        routed: List[Flow] = []
        # local (routeless) flows drain immediately at LOCAL_BW
        for f in members:
            if not self.routes[f.fid]:
                rate[f.fid] = LOCAL_BW if f.rate_cap is None \
                    else min(LOCAL_BW, f.rate_cap)
            else:
                routed.append(f)
        if len(routed) >= self.VEC_THRESHOLD:
            self._waterfill_vec(routed, residual, rate)
        elif routed:
            self._waterfill_scalar(routed, residual, rate)
        for f in members:
            f.rate = rate[f.fid]
            for lid in self.routes[f.fid]:
                self._link_rate[lid] = self._link_rate.get(lid, 0.0) + f.rate
                self._link_members.setdefault(lid, []).append(f)

    def _waterfill_scalar(self, routed: List[Flow], residual: Dict[int, float],
                          rate: Dict[int, float]) -> None:
        """Progressive filling with per-flow dict walks — wins for the small
        groups produced by per-flow priority keys (SJF, EDF tie-breaks)."""
        unfrozen = {f.fid: f for f in routed}
        for f in routed:
            rate[f.fid] = 0.0
        while unfrozen:
            # population of unfrozen flows per link
            nflows: Dict[int, int] = {}
            for fid in unfrozen:
                for lid in self.routes[fid]:
                    nflows[lid] = nflows.get(lid, 0) + 1
            # smallest incremental fair share over saturating constraints
            inc = math.inf
            for lid, n in nflows.items():
                inc = min(inc, max(0.0, residual[lid]) / n)
            for fid, f in unfrozen.items():
                if f.rate_cap is not None:
                    inc = min(inc, f.rate_cap - rate[fid])
            if inc < 0:
                inc = 0.0
            if not math.isfinite(inc):
                break
            for fid in unfrozen:
                rate[fid] += inc
                for lid in self.routes[fid]:
                    residual[lid] -= inc
            # freeze: flows at cap, flows crossing a saturated link
            newly_frozen = []
            for fid, f in unfrozen.items():
                at_cap = f.rate_cap is not None and rate[fid] >= f.rate_cap - _EPS
                saturated = any(residual[lid] <= _EPS for lid in self.routes[fid])
                if at_cap or saturated:
                    newly_frozen.append(fid)
            if not newly_frozen:      # numerical guard: freeze everything
                break
            for fid in newly_frozen:
                del unfrozen[fid]

    def _waterfill_vec(self, routed: List[Flow], residual: Dict[int, float],
                       rate: Dict[int, float]) -> None:
        """Progressive filling over the group's route-incidence matrix
        A[link, flow]: each round raises every unfrozen flow by the smallest
        constraint (fair share of the tightest link, or the nearest rate
        cap), then freezes flows at cap or on a saturated link — the same
        fixpoint as the scalar walk, in O(rounds) vector ops. Wins for the
        wide single-key groups of FairShare and shared RMLQ bands."""
        lids = sorted({lid for f in routed for lid in self.routes[f.fid]})
        lidx = {lid: i for i, lid in enumerate(lids)}
        A = np.zeros((len(lids), len(routed)))
        for j, f in enumerate(routed):
            for lid in self.routes[f.fid]:
                A[lidx[lid], j] = 1.0
        AT = np.ascontiguousarray(A.T)
        res = np.array([residual[lid] for lid in lids])
        caps = np.array([math.inf if f.rate_cap is None else f.rate_cap
                         for f in routed])
        rates = np.zeros(len(routed))
        active = np.ones(len(routed))
        while True:
            counts = A @ active
            used = counts > 0.0
            # smallest incremental fair share over saturating constraints
            share = np.where(used, np.maximum(res, 0.0)
                             / np.where(used, counts, 1.0), math.inf)
            headroom = np.where(active > 0.0, caps - rates, math.inf)
            inc = min(share.min(initial=math.inf),
                      headroom.min(initial=math.inf))
            if inc < 0:
                inc = 0.0
            if not math.isfinite(inc):
                break
            rates += active * inc
            res -= counts * inc
            # freeze: flows at cap, flows crossing a saturated link
            newly = active * (((rates >= caps - _EPS)
                               | (AT @ (res <= _EPS) > 0.0)))
            if not newly.any():       # numerical guard: freeze everything
                break
            active -= newly
            if not active.any():
                break
        for lid, i in lidx.items():
            residual[lid] = float(res[i])
        for j, f in enumerate(routed):
            rate[f.fid] = float(rates[j])

    # --------------------------------------------------------------- queries
    def next_completion(self) -> Optional[Tuple[float, Flow]]:
        best_t, best_f = math.inf, None
        for f in self.flows.values():
            if f.rate > 0.0:
                t = self.now + max(f.remaining / f.rate, 1e-12)
                if t < best_t:
                    best_t, best_f = t, f
        if best_f is None:
            return None
        return best_t, best_f

    def bottleneck(self, flow: Flow) -> Tuple[float, float]:
        """(capacity, rho) of the flow's most-utilised path link, excluding
        the flow's own contribution — feeds the MLU computation (§4.3)."""
        route = self.routes.get(flow.fid)
        if route is None:
            route = self.topo.route(flow.src, flow.dst, flow.fid)
        if not route:
            return LOCAL_BW, 0.0
        best_cap, best_rho = None, -1.0
        for lid in route:
            cap = self.topo.capacity[lid]
            used = self._link_rate.get(lid, 0.0) - (flow.rate if flow.fid in self.flows else 0.0)
            rho = min(1.0, max(0.0, used / cap))
            if rho > best_rho or (rho == best_rho and (best_cap is None or cap < best_cap)):
                best_cap, best_rho = cap, rho
        return float(best_cap), float(best_rho)

    def bottleneck_protected(self, flow: Flow, predicate) -> Tuple[float, float]:
        """Like :meth:`bottleneck`, but rho only counts path traffic for which
        ``predicate(other_flow)`` holds — i.e. traffic the candidate flow is
        *not allowed to preempt*. Feeding this into MLU avoids the positive
        feedback loop where contention from equally-deferred peers inflates
        every peer's urgency simultaneously."""
        route = self.routes.get(flow.fid)
        if route is None:
            route = self.topo.route(flow.src, flow.dst, flow.fid)
        if not route:
            return LOCAL_BW, 0.0
        best_cap, best_rho = None, -1.0
        for lid in route:
            cap = self.topo.capacity[lid]
            used = sum(f.rate for f in self._link_members.get(lid, ())
                       if f.fid != flow.fid and predicate(f))
            rho = min(1.0, max(0.0, used / cap))
            if rho > best_rho or (rho == best_rho and (best_cap is None or cap < best_cap)):
                best_cap, best_rho = cap, rho
        return float(best_cap), float(best_rho)

    def utilization(self) -> Dict[int, float]:
        return {lid: self._link_rate.get(lid, 0.0) / cap
                for lid, cap in self.topo.capacity.items()}
