"""Priority-aware fluid (rate-based) flow model — the flowsim analogue.

Bandwidth allocation semantics (matching §5's enforcement model):

  1. Active flows are grouped by ``priority_key`` (lexicographic tuples,
     smaller = more urgent) and groups are served in **strict priority**
     order: a group only sees the capacity left over by more urgent groups.
  2. Within a group, bandwidth is **max-min fair** (progressive filling),
     honouring per-flow ``rate_cap`` ceilings (Karuna-style pacing).
  3. Flows whose route is empty (same-endpoint transfers) complete at the
     memory-copy rate ``LOCAL_BW``.

Between events rates are constant, so completion times are exact; the event
loop re-allocates whenever the active set, keys or caps change. This is the
standard fluid approximation used by flow-level simulators (flowsim, Sincronia,
Karuna) — per-packet effects (reordering etc.) are *designed out* of MFS by
message-atomic promotion, so the fluid model is faithful for this paper.

Scaling to paper-sized sweeps (thousands of requests, fat-tree fabrics) rests
on two structural properties of the model, exploited incrementally:

* **Dirty-group reallocation.** A priority group's water-filling fixpoint is a
  pure function of (member set, member rate caps, member routes, residual
  capacity left by more-urgent groups). ``reallocate`` therefore caches, per
  group, the allocation together with the residuals it consumed, and re-runs
  the fill only for groups whose signature or input residuals changed since
  the previous epoch; clean groups replay their cached link usage verbatim,
  which keeps the produced rates bit-identical to a from-scratch allocation
  (asserted by ``tests/test_netsim.py::test_incremental_matches_full``).
* **Lazy-invalidation completion heap.** Between reallocations every flow
  drains linearly, so its *absolute* completion time is invariant; it only
  moves when the flow's rate changes. ``next_completion`` keeps a heap of
  (predicted_t, fid, version) entries pushed on every rate change and skips
  stale entries (version mismatch / flow gone) on pop, replacing the
  per-event O(flows) scan.
* **Warm-started within-group fills.** The wide single-key group (FairShare
  / shared RMLQ bands) churns membership on every completion, so its
  route-incidence matrix was rebuilt from per-flow route walks each fill.
  ``_vec_struct`` seeds the fill from the previous fixpoint's structure and
  patches columns (survivors kept, departures dropped, arrivals appended),
  leaving the fill arithmetic — integer incidence sums, order-independent
  mins — bit-identical to a cold build (``waterfill.warmstart.*``
  microbench rows + tests/test_netsim.py assert this).
"""
from __future__ import annotations

import heapq
import itertools
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.msflow import Flow, FlowState, Stage
from .topology import Topology

__all__ = ["FluidNet", "LOCAL_BW"]

LOCAL_BW = 2e12      # same-endpoint "transfer" drains at HBM-copy speed
_EPS = 1e-12         # rate/capacity epsilon
_EPS_BYTES = 1e-4    # a flow with less than this many bytes left is done


def _eff_cap(f: Flow) -> Optional[float]:
    """Effective per-flow ceiling: the policy-assigned ``rate_cap`` combined
    with the immutable storage-tier fetch ceiling ``tier_cap`` (KV-reuse
    plane). Policies overwrite ``rate_cap`` every assign; the tier ceiling
    survives regardless."""
    if f.tier_cap is None:
        return f.rate_cap
    if f.rate_cap is None:
        return f.tier_cap
    return min(f.rate_cap, f.tier_cap)


class _VecStruct:
    """Warm-started incidence structure for one wide priority group.

    The route-incidence matrix ``A[link, flow]`` (and its transpose) is the
    per-fill setup cost of the vectorized water-fill; membership of the wide
    single-key group churns on every completion, so rebuilding it from
    per-flow route walks dominates. The structure is seeded from the
    previous fill and *patched* — surviving columns are kept (C-speed
    slicing), departed columns dropped, new columns appended — which leaves
    every retained 0/1 entry, and therefore every float the fill computes,
    identical to a from-scratch build: integer-valued incidence sums and
    order-independent mins make the warm-started rates bit-identical
    (asserted in tests/test_netsim.py::test_warmstart_matches_cold).
    Rows of links no longer used by any member stay as all-zero rows (no
    arithmetic effect); the structure is rebuilt once they dominate.
    """

    __slots__ = ("fids", "lids", "lidx", "A", "AT")

    def __init__(self, fids, lids, lidx, A):
        self.fids = fids
        self.lids = lids
        self.lidx = lidx
        self.A = A
        self.AT = np.ascontiguousarray(A.T)


class _GroupAlloc:
    """Cached water-filling result for one priority group.

    ``sig`` is the (fid, rate_cap) tuple of the members in iteration order;
    ``res_in``/``res_out`` map each link the group's routes touch to the
    residual capacity before/after the fill. A cached entry may be replayed
    iff ``sig`` and ``res_in`` are unchanged — then the exact ``res_out``
    floats are restored (NOT a usage sum re-subtracted, which would drift at
    the ulp level) and every member keeps its current rate, so downstream
    groups observe residuals bit-identical to a from-scratch allocation.
    """

    __slots__ = ("sig", "res_in", "res_out")

    def __init__(self, sig, res_in, res_out):
        self.sig = sig
        self.res_in = res_in
        self.res_out = res_out


class FluidNet:
    def __init__(self, topo: Topology, incremental: bool = True):
        self.topo = topo
        self.flows: Dict[int, Flow] = {}
        self.routes: Dict[int, Tuple[int, ...]] = {}
        self.now = 0.0
        #: dirty-group caching toggle (off = every group fills every epoch)
        self.incremental = incremental
        self._link_rate: Dict[int, float] = {}      # post-allocation usage
        self._members: Dict[int, List[Flow]] = {}   # built lazily on demand
        self._members_stale = True
        self._galloc: Dict[Tuple, _GroupAlloc] = {}
        # lazy-invalidation completion heap: (t_pred, seq, fid, version)
        self._pred_heap: List[Tuple[float, int, int, int]] = []
        self._pred_version: Dict[int, int] = {}
        self._pred_seq = itertools.count()
        #: warm-started within-group fills: cache + toggle (rates are
        #: bit-identical either way; off = rebuild incidence every fill)
        self.warmstart = True
        self._vec_cache: Dict[Tuple, _VecStruct] = {}
        #: instrumentation for the incremental-allocation microbenches
        self.stats = {"reallocs": 0, "group_fills": 0, "groups_seen": 0,
                      "vec_builds": 0, "vec_patches": 0}

    # ------------------------------------------------------------- lifecycle
    def add(self, flow: Flow) -> None:
        self.flows[flow.fid] = flow
        self.routes[flow.fid] = self.topo.route(flow.src, flow.dst, flow.fid)
        flow.state = FlowState.ACTIVE if flow.state != FlowState.PRUNED else flow.state
        if flow.started is None:
            flow.started = self.now
        self._members_stale = True

    def remove(self, flow: Flow) -> None:
        """Drop a flow (completion or cancellation) and release its rate from
        the link accounting immediately — a cancelled flow must not keep
        inflating ``bottleneck`` / ``bottleneck_protected`` rho until the
        next reallocation."""
        route = self.routes.pop(flow.fid, ())
        if self.flows.pop(flow.fid, None) is not None and flow.rate > 0.0:
            for lid in route:
                left = self._link_rate.get(lid, 0.0) - flow.rate
                self._link_rate[lid] = left if left > _EPS else 0.0
        flow.rate = 0.0
        self._pred_version.pop(flow.fid, None)
        self._members_stale = True

    def set_rate(self, flow: Flow, rate: float) -> None:
        """Directly assign a rate outside the water-filling path (used by the
        runtime's contention-free mode). Keeps the completion heap coherent
        and drops the group caches, which the assignment bypassed."""
        self._galloc = {}
        self._assign_rate(flow, rate)

    def advance(self, t: float) -> List[Flow]:
        """Progress all flows to time ``t`` at current rates; return the flows
        that completed (remaining hits zero) in this interval."""
        dt = t - self.now
        if dt < -1e-9:
            raise ValueError(f"time went backwards: {self.now} -> {t}")
        done: List[Flow] = []
        for f in self.flows.values():
            if dt > 0 and f.rate > 0.0:
                f.remaining = max(0.0, f.remaining - f.rate * dt)
            # float-safe completion: anything within a sub-byte epsilon (or
            # within one picosecond of draining at the current rate) is done —
            # prevents completion-prediction livelock at time resolution.
            if f.remaining <= max(_EPS_BYTES, f.rate * 1e-12):
                f.remaining = 0.0
                done.append(f)
        self.now = t
        for f in done:
            f.state = FlowState.DONE
            f.finished = t
            self.remove(f)          # zeroes rate + releases link accounting
        return done

    # ------------------------------------------------------------ allocation
    def reallocate(self, full: bool = False) -> None:
        """Strict-priority, per-group max-min water-filling with rate caps.

        Incremental: groups whose member signature and input residuals match
        the cached epoch replay their allocation without re-filling. Pass
        ``full=True`` (or construct with ``incremental=False``) to force a
        from-scratch fill of every group — rates are identical either way.
        """
        self.stats["reallocs"] += 1
        residual = dict(self.topo.capacity)
        groups: Dict[Tuple, List[Flow]] = {}
        for f in self.flows.values():
            groups.setdefault(tuple(f.priority_key), []).append(f)
        self.stats["groups_seen"] += len(groups)
        cache = self._galloc if (self.incremental and not full) else {}
        galloc: Dict[Tuple, _GroupAlloc] = {}
        for key in sorted(groups):
            members = groups[key]
            sig = tuple((f.fid, f.rate_cap) for f in members)
            cached = cache.get(key)
            if (cached is not None and cached.sig == sig
                    and all(residual[lid] == r
                            for lid, r in cached.res_in.items())):
                # clean replay: members already hold these rates; restore the
                # cached post-fill residuals exactly
                residual.update(cached.res_out)
                galloc[key] = cached
                continue
            res_in: Dict[int, float] = {}
            for f in members:
                for lid in self.routes[f.fid]:
                    if lid not in res_in:
                        res_in[lid] = residual[lid]
            rate: Dict[int, float] = {}
            self._fill_group(members, residual, rate, key)
            for f in members:
                self._assign_rate(f, rate[f.fid])
            res_out = {lid: residual[lid] for lid in res_in}
            galloc[key] = _GroupAlloc(sig, res_in, res_out)
            self.stats["group_fills"] += 1
        self._galloc = galloc
        if self._vec_cache:
            # keep warm structures only for groups that still exist
            self._vec_cache = {k: v for k, v in self._vec_cache.items()
                               if k in galloc}
        self._link_rate = {lid: cap - residual[lid]
                           for lid, cap in self.topo.capacity.items()}
        self._members_stale = True

    def _assign_rate(self, f: Flow, r: float) -> None:
        """Set a flow's rate, refreshing its completion prediction iff the
        rate actually changed (linear drain keeps the absolute completion
        time invariant under an unchanged rate)."""
        if r == f.rate:
            return
        f.rate = r
        v = self._pred_version.get(f.fid, 0) + 1
        self._pred_version[f.fid] = v
        if r > 0.0:
            t = self.now + max(f.remaining / r, 1e-12)
            heapq.heappush(self._pred_heap, (t, next(self._pred_seq), f.fid, v))

    #: group size at which the numpy water-filling overtakes the dict walk
    #: (measured on FatTree(8x8): the matrix path is ~3x faster at 512
    #: flows/group but ~4x slower at <64 because of per-round numpy setup)
    VEC_THRESHOLD = 96

    def _fill_group(self, members: List[Flow], residual: Dict[int, float],
                    rate: Dict[int, float], key: Optional[Tuple] = None) -> None:
        """Water-fill one priority group into ``rate`` (fid -> rate), drawing
        down ``residual`` in place. Pure w.r.t. flow state: the caller owns
        rate assignment and link accounting."""
        routed: List[Flow] = []
        # local (routeless) flows drain immediately at LOCAL_BW (or their
        # per-flow ceiling — a host-local tier writeback pays its tier bw)
        for f in members:
            if not self.routes[f.fid]:
                cap = _eff_cap(f)
                rate[f.fid] = LOCAL_BW if cap is None \
                    else min(LOCAL_BW, cap)
            else:
                routed.append(f)
        if len(routed) >= self.VEC_THRESHOLD:
            self._waterfill_vec(routed, residual, rate, key)
        elif routed:
            self._waterfill_scalar(routed, residual, rate)

    def _waterfill_scalar(self, routed: List[Flow], residual: Dict[int, float],
                          rate: Dict[int, float]) -> None:
        """Progressive filling with per-flow dict walks — wins for the small
        groups produced by per-flow priority keys (SJF, EDF tie-breaks)."""
        unfrozen = {f.fid: f for f in routed}
        for f in routed:
            rate[f.fid] = 0.0
        while unfrozen:
            # population of unfrozen flows per link
            nflows: Dict[int, int] = {}
            for fid in unfrozen:
                for lid in self.routes[fid]:
                    nflows[lid] = nflows.get(lid, 0) + 1
            # smallest incremental fair share over saturating constraints
            inc = math.inf
            for lid, n in nflows.items():
                inc = min(inc, max(0.0, residual[lid]) / n)
            for fid, f in unfrozen.items():
                cap = _eff_cap(f)
                if cap is not None:
                    inc = min(inc, cap - rate[fid])
            if inc < 0:
                inc = 0.0
            if not math.isfinite(inc):
                break
            for fid in unfrozen:
                rate[fid] += inc
                for lid in self.routes[fid]:
                    residual[lid] -= inc
            # freeze: flows at cap, flows crossing a saturated link
            newly_frozen = []
            for fid, f in unfrozen.items():
                cap = _eff_cap(f)
                at_cap = cap is not None and rate[fid] >= cap - _EPS
                saturated = any(residual[lid] <= _EPS for lid in self.routes[fid])
                if at_cap or saturated:
                    newly_frozen.append(fid)
            if not newly_frozen:      # numerical guard: freeze everything
                break
            for fid in newly_frozen:
                del unfrozen[fid]

    def _build_struct(self, routed: List[Flow]) -> _VecStruct:
        lids = sorted({lid for f in routed for lid in self.routes[f.fid]})
        lidx = {lid: i for i, lid in enumerate(lids)}
        A = np.zeros((len(lids), len(routed)))
        for j, f in enumerate(routed):
            for lid in self.routes[f.fid]:
                A[lidx[lid], j] = 1.0
        self.stats["vec_builds"] += 1
        return _VecStruct([f.fid for f in routed], lids, lidx, A)

    def _vec_struct(self, routed: List[Flow],
                    key: Optional[Tuple]) -> _VecStruct:
        """Incidence structure for a vectorized fill: seeded from the
        previous fixpoint's structure when only membership churned (see
        :class:`_VecStruct`), rebuilt from the members' routes otherwise."""
        if not self.warmstart or key is None:
            return self._build_struct(routed)
        fids = [f.fid for f in routed]
        cached = self._vec_cache.get(key)
        if cached is not None and cached.fids == fids:
            return cached
        struct = None
        if cached is not None:
            old = set(cached.fids)
            new = set(fids)
            kept = [j for j, fid in enumerate(cached.fids) if fid in new]
            added = [f for f in routed if f.fid not in old]
            # the patch only applies when survivors kept their relative
            # order and newcomers trail (how dict-ordered churn behaves);
            # anything else — e.g. a re-keyed flow landing mid-group —
            # falls back to a full rebuild
            if [cached.fids[j] for j in kept] + [f.fid for f in added] == fids:
                A = cached.A[:, kept] if len(kept) != len(cached.fids) \
                    else cached.A
                lids, lidx = cached.lids, cached.lidx
                newlinks = []
                for f in added:
                    for lid in self.routes[f.fid]:
                        if lid not in lidx and lid not in newlinks:
                            newlinks.append(lid)
                if newlinks:
                    lids = lids + newlinks
                    lidx = dict(lidx)
                    for lid in newlinks:
                        lidx[lid] = len(lidx)
                    A = np.vstack([A, np.zeros((len(newlinks), A.shape[1]))])
                if added:
                    cols = np.zeros((len(lids), len(added)))
                    for j, f in enumerate(added):
                        for lid in self.routes[f.fid]:
                            cols[lidx[lid], j] = 1.0
                    A = np.hstack([A, cols])
                # prune rows of links no member uses anymore: keeps every
                # round's matmul at live-link size (an absent row has no
                # arithmetic effect, so rates stay bit-identical)
                live = A.any(axis=1)
                if not live.all():
                    A = A[live]
                    lids = [lid for lid, keep in zip(lids, live) if keep]
                    lidx = {lid: i for i, lid in enumerate(lids)}
                self.stats["vec_patches"] += 1
                struct = _VecStruct(fids, lids, lidx, A)
        if struct is None:
            struct = self._build_struct(routed)
        self._vec_cache[key] = struct
        return struct

    def _waterfill_vec(self, routed: List[Flow], residual: Dict[int, float],
                       rate: Dict[int, float],
                       key: Optional[Tuple] = None) -> None:
        """Progressive filling over the group's route-incidence matrix
        A[link, flow]: each round raises every unfrozen flow by the smallest
        constraint (fair share of the tightest link, or the nearest rate
        cap), then freezes flows at cap or on a saturated link — the same
        fixpoint as the scalar walk, in O(rounds) vector ops. Wins for the
        wide single-key groups of FairShare and shared RMLQ bands. The
        incidence structure is warm-started across fills (``key`` selects
        the cache slot); rates stay bit-identical to a cold build."""
        struct = self._vec_struct(routed, key)
        lids, lidx, A, AT = struct.lids, struct.lidx, struct.A, struct.AT
        res = np.array([residual[lid] for lid in lids])
        caps = np.array([math.inf if (c := _eff_cap(f)) is None else c
                         for f in routed])
        rates = np.zeros(len(routed))
        active = np.ones(len(routed))
        while True:
            counts = A @ active
            used = counts > 0.0
            # smallest incremental fair share over saturating constraints
            share = np.where(used, np.maximum(res, 0.0)
                             / np.where(used, counts, 1.0), math.inf)
            headroom = np.where(active > 0.0, caps - rates, math.inf)
            inc = min(share.min(initial=math.inf),
                      headroom.min(initial=math.inf))
            if inc < 0:
                inc = 0.0
            if not math.isfinite(inc):
                break
            rates += active * inc
            res -= counts * inc
            # freeze: flows at cap, flows crossing a saturated link
            newly = active * (((rates >= caps - _EPS)
                               | (AT @ (res <= _EPS) > 0.0)))
            if not newly.any():       # numerical guard: freeze everything
                break
            active -= newly
            if not active.any():
                break
        for lid, i in lidx.items():
            residual[lid] = float(res[i])
        for j, f in enumerate(routed):
            rate[f.fid] = float(rates[j])

    # --------------------------------------------------------------- queries
    def next_completion(self) -> Optional[Tuple[float, Flow]]:
        """Earliest predicted flow completion under current rates.

        Heap entries are invalidated lazily: an entry is live only if its
        flow still exists, still transmits, and its rate has not changed
        since the entry was pushed (version match). Stale entries are popped
        on the way to the top; a periodic rebuild bounds heap growth."""
        heap = self._pred_heap
        if len(heap) > 4 * len(self.flows) + 64:
            self._rebuild_predictions()
            heap = self._pred_heap
        while heap:
            t, _, fid, v = heap[0]
            f = self.flows.get(fid)
            if f is None or f.rate <= 0.0 or self._pred_version.get(fid) != v:
                heapq.heappop(heap)
                continue
            return t, f
        return None

    def _rebuild_predictions(self) -> None:
        self._pred_heap = []
        for f in self.flows.values():
            v = self._pred_version.get(f.fid, 0)
            if f.rate > 0.0:
                t = self.now + max(f.remaining / f.rate, 1e-12)
                heapq.heappush(self._pred_heap,
                               (t, next(self._pred_seq), f.fid, v))

    def _link_members(self, lid: int) -> List[Flow]:
        if self._members_stale:
            self._members = {}
            for f in self.flows.values():
                for l in self.routes[f.fid]:
                    self._members.setdefault(l, []).append(f)
            self._members_stale = False
        return self._members.get(lid, [])

    def bottleneck(self, flow: Flow) -> Tuple[float, float]:
        """(capacity, rho) of the flow's most-utilised path link, excluding
        the flow's own contribution — feeds the MLU computation (§4.3)."""
        route = self.routes.get(flow.fid)
        if route is None:
            route = self.topo.route(flow.src, flow.dst, flow.fid)
        if not route:
            return LOCAL_BW, 0.0
        best_cap, best_rho = None, -1.0
        for lid in route:
            cap = self.topo.capacity[lid]
            used = self._link_rate.get(lid, 0.0) - (flow.rate if flow.fid in self.flows else 0.0)
            rho = min(1.0, max(0.0, used / cap))
            if rho > best_rho or (rho == best_rho and (best_cap is None or cap < best_cap)):
                best_cap, best_rho = cap, rho
        return float(best_cap), float(best_rho)

    def bottleneck_protected(self, flow: Flow, predicate) -> Tuple[float, float]:
        """Like :meth:`bottleneck`, but rho only counts path traffic for which
        ``predicate(other_flow)`` holds — i.e. traffic the candidate flow is
        *not allowed to preempt*. Feeding this into MLU avoids the positive
        feedback loop where contention from equally-deferred peers inflates
        every peer's urgency simultaneously."""
        route = self.routes.get(flow.fid)
        if route is None:
            route = self.topo.route(flow.src, flow.dst, flow.fid)
        if not route:
            return LOCAL_BW, 0.0
        best_cap, best_rho = None, -1.0
        for lid in route:
            cap = self.topo.capacity[lid]
            used = sum(f.rate for f in self._link_members(lid)
                       if f.fid != flow.fid and predicate(f))
            rho = min(1.0, max(0.0, used / cap))
            if rho > best_rho or (rho == best_rho and (best_cap is None or cap < best_cap)):
                best_cap, best_rho = cap, rho
        return float(best_cap), float(best_rho)

    def utilization(self) -> Dict[int, float]:
        return {lid: self._link_rate.get(lid, 0.0) / cap
                for lid, cap in self.topo.capacity.items()}

    # ----------------------------------------------------- flow-class tagging
    def class_rates(self, lid: int) -> Dict[Stage, float]:
        """Allocated rate on one link broken down by MsFlow stage — how much
        of a shared decode downlink P2D vs D2D is actually holding."""
        out: Dict[Stage, float] = {}
        for f in self._link_members(lid):
            out[f.stage] = out.get(f.stage, 0.0) + f.rate
        return out

    def class_utilization(self, lids=None) -> Dict[Stage, float]:
        """Aggregate allocated bandwidth per stage over ``lids`` (default:
        every link). Benchmarks sample this to attribute contention on the
        shared downlinks to traffic classes."""
        out: Dict[Stage, float] = {}
        targets = set(lids) if lids is not None else None
        for f in self.flows.values():
            share = sum(1 for l in self.routes[f.fid]
                        if targets is None or l in targets)
            if share:
                out[f.stage] = out.get(f.stage, 0.0) + f.rate * share
        return out
