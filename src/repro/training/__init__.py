"""repro.training — optimizer, train step, checkpointing, fault tolerance."""
from .optim import AdamWConfig, AdamWState, adamw_init, adamw_update, global_norm
from .trainer import TrainState, make_train_step, init_train_state
from .checkpoint import save_checkpoint, restore_checkpoint, latest_step

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "global_norm", "TrainState", "make_train_step", "init_train_state",
           "save_checkpoint", "restore_checkpoint", "latest_step"]
