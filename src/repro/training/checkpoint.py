"""Checkpoint/restore for fault tolerance (no external deps).

Format: one directory per step containing
  * ``manifest.json`` — tree structure, shapes, dtypes, step
  * ``arrays.npz``    — flattened leaves (gathered to host)

Restore is mesh-agnostic: arrays are loaded as host numpy and re-placed with
whatever shardings the caller supplies (elastic relaunch on a different chip
count reshards transparently). Writes are atomic (tmp dir + rename) so a
failure mid-write never corrupts the latest checkpoint; ``latest_step`` scans
for the newest complete manifest.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _to_savable(a: np.ndarray):
    """npz cannot store ml_dtypes (bfloat16 etc.) — view them as uint16/8."""
    if a.dtype.kind not in "fiub":
        width = a.dtype.itemsize
        view = {2: np.uint16, 1: np.uint8, 4: np.uint32}[width]
        return a.view(view), str(a.dtype)
    return a, str(a.dtype)


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    paths, leaves, _ = _flatten_with_paths(tree)
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        savable = [_to_savable(a) for a in host]
        arrays = {f"a{i}": a for i, (a, _) in enumerate(savable)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {"step": step,
                    "paths": paths,
                    "dtypes": [d for _, d in savable]}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        if name.startswith("step_"):
            manifest = os.path.join(directory, name, "manifest.json")
            if os.path.exists(manifest):
                s = int(name.split("_")[1])
                best = s if best is None else max(best, s)
    return best


def restore_checkpoint(directory: str, step: int, like: Any,
                       shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like``; optionally re-place leaves
    with ``shardings`` (same tree structure) for elastic relaunch."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves = [data[f"a{i}"] for i in range(len(manifest["paths"]))]
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    if len(flat_like) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, expected {len(flat_like)}")
    out = []
    flat_shard = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(leaves))
    for arr, saved_dt, ref, shd in zip(leaves, manifest["dtypes"],
                                       flat_like, flat_shard):
        a = np.asarray(arr)
        if str(a.dtype) != saved_dt:             # undo the uint view
            import ml_dtypes
            a = a.view(np.dtype(getattr(ml_dtypes, saved_dt, saved_dt)))
        if hasattr(ref, "dtype") and str(ref.dtype) != str(a.dtype):
            a = a.astype(ref.dtype)
        out.append(jax.device_put(a, shd) if shd is not None
                   else jax.numpy.asarray(a))
    return treedef.unflatten(out)
