"""AdamW with ZeRO-sharded state + global-norm clipping.

Optimizer states inherit the parameters' PartitionSpecs (ZeRO: states live
wherever the param shard lives — with zero3 enabled that is sharded over
(pod, data) x model, so no device ever holds an unsharded state tensor).
``state_dtype`` lets the giant configs (DeepSeek-V3) run bf16 moments: with
2(param)+2+2(bf16 m,v) bytes/param, 671B params fit the 512-chip multi-pod
budget; fp32 moments are the default everywhere else.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32
    warmup: int = 100


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup, 1))
    return cfg.lr * warm


def adamw_update(grads, state: AdamWState, params,
                 cfg: AdamWConfig = AdamWConfig()
                 ) -> Tuple[Any, AdamWState, jnp.ndarray]:
    """Returns (new_params, new_state, pre-clip grad norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = _schedule(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:          # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(cfg.state_dtype), v32.astype(cfg.state_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), gnorm
