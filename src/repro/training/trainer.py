"""Training loop substrate: jitted train_step, checkpointing, fault tolerance.

Fault-tolerance model (large-scale runnability):
  * deterministic checkpoint/restore of the full TrainState (params +
    optimizer moments + step + data cursor) — repro.training.checkpoint;
  * the data pipeline is stateless given (seed, step) so restart resumes
    bit-identically without replaying data;
  * elastic restart: the checkpoint stores logical arrays; on restore they
    are resharded to whatever mesh the relaunch built (chips can come and
    go between runs — pjit resharding handles layout);
  * straggler/overload mitigation at the serving layer reuses MFS's own
    feasibility pruning (Algorithm 1), see repro.serving.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.lm import Model
from .optim import AdamWConfig, AdamWState, adamw_init, adamw_update

__all__ = ["TrainState", "make_train_step", "init_train_state"]


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jnp.ndarray


def init_train_state(model: Model, key, opt_cfg: AdamWConfig = AdamWConfig()
                     ) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw_init(params, opt_cfg),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(model: Model, opt_cfg: AdamWConfig = AdamWConfig()):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        new_params, new_opt, gnorm = adamw_update(
            grads, state.opt, state.params, opt_cfg)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
