"""Router + admission-control plane — pluggable cluster-level placement.

The paper schedules flows *after* a request has been routed, but placement
decides which bottleneck links those flows ever contend on ("Taming Request
Imbalance": SLO attainment in disaggregated serving hinges on
imbalance-aware placement). Until this module existed the router was one
hard-coded rule — ``kv_route``'s 2:1 hit-weighted affinity vs. backlog —
with a near-duplicate fallback copy in each host. This module makes
placement a policy surface, mirroring how ``policies.py`` registers
schedulers and following vLLM production-stack's router layout
(interchangeable affinity policies behind a factory, queue-depth overload
detectors):

  * :class:`RouterPolicy` + a registry (:func:`register_router` /
    :func:`make_router`) with four strategies —

      - ``kv_affinity``   — the historical rule, extracted so both hosts
        share one code path: score every unit by ``2.0 * affinity -
        backlog_tokens`` where affinity is the locally-resident reusable
        prefix (live KV-store residency when a store is attached, the
        trace/prefix-index owner oracle otherwise). Bit-identical to the
        old per-host loops by construction.
      - ``round_robin``   — arrival-order cycling, placement-blind.
      - ``session_affinity`` — rendezvous (highest-random-weight) hashing
        of a stable session key (``rid`` by default, the request's prefix
        identity with ``key="prefix"``), modelled on production-stack's
        ``session_affinity``/``simhash_affinity``: the same session always
        lands on the same unit, with minimal movement as units change.
      - ``least_backlog`` — pure join-the-shortest-queue on backlog tokens.

  * :class:`OverloadDetector` + a registry (:func:`make_detector`) with two
    hysteresis-gated variants — ``queue_depth`` (queued requests or backlog
    tokens vs. high/low watermarks, cluster- or unit-scoped, after
    production-stack's ``num_queueing_request``) and ``laxity_debt`` (the
    summed deadline debt of queued work: how many seconds of already-missed
    slack the queues carry).

  * :class:`AdmissionController` — an admission stage between routing and
    enqueue (Ascendra's pairing of dynamic prioritization with admission
    decisions): while the detector is tripped, requests of the sheddable
    SLO classes (loose, by default) are **shed** (rejected: no pins, no
    slots, counted as an SLO miss against all-arrivals attainment) or
    **deferred** (re-tried after a delay on the original arrival clock, so
    the SLO budget keeps burning) — protecting the TTFT attainment of the
    admitted traffic instead of letting everyone miss.

The plane is host-agnostic like the rest of ``repro.core``: the shared
:class:`repro.core.runtime.MsFlowRuntime` calls the policy through a
:class:`RoutingView` (backlogs, queues, KV-store residency, clock); hosts
only supply state (``prepare_route`` fills the item's legacy reuse/owner
fields, ``kv_chain_keys`` exposes the store keys). The default
configuration — ``kv_affinity`` with admission off — reproduces the
pre-plane placement decisions bit-for-bit on both hosts.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, Mapping, Optional, Tuple, Type

__all__ = [
    "RoutingView",
    "RouterPolicy",
    "KVAffinityRouter",
    "RoundRobinRouter",
    "SessionAffinityRouter",
    "LeastBacklogRouter",
    "register_router",
    "make_router",
    "OverloadDetector",
    "QueueDepthDetector",
    "LaxityDebtDetector",
    "register_detector",
    "make_detector",
    "RouterSpec",
    "AdmissionSpec",
    "AdmissionController",
]


class RoutingView:
    """What a router policy / overload detector may observe.

    A thin read-only window over the shared runtime: placement state
    (per-unit backlogs and queues), the KV-reuse plane when one is
    attached, and the virtual clock. Hosts are reached only through the
    ``kv_chain_keys`` hook — the view never touches host internals.
    """

    def __init__(self, rt: Any):
        self.rt = rt

    @property
    def now(self) -> float:
        return self.rt.net.now

    @property
    def n_units(self) -> int:
        return self.rt.n_units

    @property
    def backlogs(self):
        """Per-unit queued+active prefill tokens (the load signal the
        historical router scored against)."""
        return self.rt.backlog_tokens

    @property
    def kvstore(self):
        """The attached KV-reuse plane, or None (legacy reuse model)."""
        return self.rt.kvstore

    def chain_keys(self, item: Any) -> Tuple:
        """The request's block-key chain (same keys Stage-1 resolves)."""
        return self.rt.host.kv_chain_keys(item)

    def queued(self, unit: int) -> int:
        """Requests waiting in ``unit``'s prefill queue."""
        return len(self.rt.queues[unit])

    def queued_items(self, unit: int) -> Iterable[Any]:
        return iter(self.rt.queues[unit])

    def total_queued(self) -> int:
        return sum(len(q) for q in self.rt.queues)

    def session_key(self, item: Any) -> Tuple:
        """A stable per-session identity for consistent hashing: the
        request's prefix lineage when the host exposes one (trace
        ``prefix_id``), else its rid. Both hosts derive the same key for
        the same rid, so rid-keyed placement is host-parity-exact."""
        pid = getattr(item.payload, "prefix_id", None)
        if pid is not None:
            return ("prefix", int(pid))
        return ("rid", int(item.rid))


# ------------------------------------------------------------ router policies
class RouterPolicy:
    """Cluster-level placement policy: pick the prefill unit for an
    arriving request. Implementations must be deterministic functions of
    (item, view, own state) so both hosts place identically and fixed
    seeds reproduce — no wall clock, no unseeded RNG."""

    name = "base"

    def place(self, item: Any, view: RoutingView) -> int:
        raise NotImplementedError

    def attach_bus(self, bus: Any) -> None:
        """Monitor-plane hook: called once when a ``SignalBus`` is live
        (``ClusterSpec.monitor`` / ``DisaggConfig.monitor`` set). Policies
        that score on streaming signals (rolling link contention, TTFT
        quantiles, laxity debt — ``bus.read(name, key)``) override this;
        the base class ignores it so existing routers are bus-agnostic."""

    def reset(self) -> None:
        """Clear cross-run state (routers are rebuilt per host, but the
        registry contract mirrors ``Policy.reset`` for reuse)."""


def kv_affinity_score(aff: float, backlog: float,
                      affinity_weight: float = 2.0) -> float:
    """The historical routing score both hosts hard-coded: hit-weighted
    affinity (reusable tokens resident on the unit) against its token
    backlog. One definition so the duplicated loops cannot drift."""
    return affinity_weight * aff - backlog


class KVAffinityRouter(RouterPolicy):
    """The extracted historical rule (default router).

    With a KV store attached, affinity is the live per-unit resident-token
    count along the chain's leading hit run (:meth:`KVStore.peek_affinity`
    — read-only; the winner's block plan is resolved by the runtime after
    placement, exactly the old ``kv_route`` order). Without a store,
    affinity falls back to the item's pre-resolved ``(reuse, owner_unit)``
    oracle — the trace's static owner on the simulator, the prefix-index
    entry's owner on the serving path. ``owner_unit < 0`` means "no owner"
    (serving-path miss): no unit gets affinity credit.
    """

    name = "kv_affinity"

    def __init__(self, affinity_weight: float = 2.0):
        self.affinity_weight = affinity_weight

    def place(self, item: Any, view: RoutingView) -> int:
        n = view.n_units
        store = view.kvstore
        if store is not None:
            aff = store.peek_affinity(view.chain_keys(item),
                                      max(0, item.n_tokens - 1), n)
        else:
            owner = item.owner_unit
            aff = [item.reuse if u == owner else 0 for u in range(n)]
        backlogs = view.backlogs
        best, best_score = 0, -float("inf")
        for u in range(n):
            score = kv_affinity_score(aff[u], backlogs[u],
                                      self.affinity_weight)
            if score > best_score:
                best, best_score = u, score
        return best


class RoundRobinRouter(RouterPolicy):
    """Arrival-order cycling over the units. Placement-blind by design —
    the classic load-oblivious baseline (production-stack's
    ``round_robin_affinity``)."""

    name = "round_robin"

    def __init__(self):
        self._next = 0

    def place(self, item: Any, view: RoutingView) -> int:
        u = self._next % view.n_units
        self._next += 1
        return u

    def reset(self) -> None:
        self._next = 0


def _rendezvous_hash(key: Tuple, unit: int, salt: str) -> int:
    """Deterministic 64-bit weight for (session key, unit) — independent of
    PYTHONHASHSEED and identical across hosts/processes."""
    h = hashlib.blake2b(repr((salt, key, unit)).encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


class SessionAffinityRouter(RouterPolicy):
    """Consistent session → unit hashing (rendezvous / highest-random-
    weight): every unit gets a deterministic pseudo-random weight for the
    session key and the max wins, so a session always lands on the same
    unit and re-sizing the cluster moves only ~1/n of sessions. ``key``
    selects the session identity: ``"rid"`` (host-parity-exact) or
    ``"prefix"`` (requests sharing a prefix lineage co-locate — cache
    affinity without a live store)."""

    name = "session_affinity"

    def __init__(self, key: str = "rid", salt: str = "mfs-router"):
        if key not in ("rid", "prefix"):
            raise ValueError(f"session key must be 'rid' or 'prefix', "
                             f"got {key!r}")
        self.key = key
        self.salt = salt

    def _session_key(self, item: Any, view: RoutingView) -> Tuple:
        if self.key == "prefix":
            return view.session_key(item)
        return ("rid", int(item.rid))

    def place(self, item: Any, view: RoutingView) -> int:
        skey = self._session_key(item, view)
        return max(range(view.n_units),
                   key=lambda u: _rendezvous_hash(skey, u, self.salt))


class LeastBacklogRouter(RouterPolicy):
    """Join-the-shortest-queue on backlog tokens (deterministic lowest-id
    tie-break) — pure load balancing, affinity-blind."""

    name = "least_backlog"

    def place(self, item: Any, view: RoutingView) -> int:
        backlogs = view.backlogs
        best, best_b = 0, float("inf")
        for u in range(view.n_units):
            if backlogs[u] < best_b:
                best, best_b = u, backlogs[u]
        return best


_ROUTERS: Dict[str, Type[RouterPolicy]] = {}


def register_router(cls: Type[RouterPolicy]) -> Type[RouterPolicy]:
    """Register a RouterPolicy subclass under its ``name`` (decorator)."""
    _ROUTERS[cls.name] = cls
    return cls


for _cls in (KVAffinityRouter, RoundRobinRouter, SessionAffinityRouter,
             LeastBacklogRouter):
    register_router(_cls)


def make_router(name: str, **kw) -> RouterPolicy:
    if name not in _ROUTERS:
        raise KeyError(f"unknown router policy {name!r}; "
                       f"choose from {sorted(_ROUTERS)}")
    return _ROUTERS[name](**kw)


# -------------------------------------------------------- overload detectors
class OverloadDetector:
    """Hysteresis-gated overload signal driving the admission stage.

    ``update(view, unit)`` is called once per arriving request with the
    routed unit; it refreshes the internal tripped state and returns it.
    Implementations trip when their signal crosses ``high`` and recover
    only once it falls back to ``low`` (two watermarks, so a burst cannot
    flap admission on and off every request).

    With the monitor plane attached the runtime calls :meth:`attach_bus`,
    and detectors read their signal from the ``SignalBus`` instead of
    computing it in-detector. The bus providers are the *same expressions*
    registered as live-view closures (``Monitor.bind_live``), so trip and
    recovery happen at byte-identical times either way (regression-tested
    in ``tests/test_monitor.py``) — the migration buys a shared namespace
    (new detectors subscribe to any signal by name), not new numbers.
    """

    name = "base"
    #: bus signal this detector reads when attached (None = in-detector
    #: computation only; subclasses set or compute it)
    bus_signal: Optional[str] = None

    def __init__(self, high: float, low: float):
        if low > high:
            raise ValueError(f"hysteresis needs low <= high, "
                             f"got low={low} high={high}")
        self.high = high
        self.low = low
        self.tripped = False
        self.n_trips = 0
        self.bus: Any = None

    def attach_bus(self, bus: Any) -> None:
        """Subscribe to the monitor's SignalBus: subsequent ``signal()``
        calls read ``bus_signal`` from the bus when it carries it."""
        if self.bus_signal is not None and bus.has(self.bus_signal):
            self.bus = bus

    def signal(self, view: RoutingView, unit: int) -> float:
        raise NotImplementedError

    def update(self, view: RoutingView, unit: int) -> bool:
        v = self.signal(view, unit)
        if not self.tripped:
            if v >= self.high:
                self.tripped = True
                self.n_trips += 1
        elif v <= self.low:
            self.tripped = False
        return self.tripped

    def reset(self) -> None:
        self.tripped = False
        self.n_trips = 0


class QueueDepthDetector(OverloadDetector):
    """Queue-depth overload (production-stack's ``num_queueing_request``):
    the signal is queued prefill requests (``signal="requests"``) or
    backlog tokens (``signal="tokens"``), summed cluster-wide
    (``scope="cluster"``) or read at the routed unit (``scope="unit"``)."""

    name = "queue_depth"

    def __init__(self, high: float = 64, low: float = 16,
                 signal: str = "requests", scope: str = "cluster"):
        super().__init__(high, low)
        if signal not in ("requests", "tokens"):
            raise ValueError(f"signal must be 'requests' or 'tokens', "
                             f"got {signal!r}")
        if scope not in ("cluster", "unit"):
            raise ValueError(f"scope must be 'cluster' or 'unit', "
                             f"got {scope!r}")
        self._signal = signal
        self.scope = scope
        self.bus_signal = f"queue.{signal}.{scope}"

    def signal(self, view: RoutingView, unit: int) -> float:
        if self.bus is not None:
            # bus-backed: the provider is the same expression as below,
            # registered by Monitor.bind_live — byte-identical trip points
            return self.bus.read(self.bus_signal,
                                 unit if self.scope == "unit" else None)
        if self._signal == "requests":
            if self.scope == "unit":
                return float(view.queued(unit))
            return float(view.total_queued())
        if self.scope == "unit":
            return float(view.backlogs[unit])
        return float(sum(view.backlogs))


class LaxityDebtDetector(OverloadDetector):
    """Deadline-debt overload: for every queued request, debt is the slack
    it has *already* lost — ``max(0, (now + ideal_ttft) - deadline)``
    seconds (even served immediately and contention-free it misses by that
    much). The summed debt is the signal: queue depth measures how much
    work waits, laxity debt measures how late that work already is —
    Ascendra's distinction between load and urgency. Watermarks are in
    seconds of aggregate debt."""

    name = "laxity_debt"
    bus_signal = "laxity.debt"

    def __init__(self, high: float = 2.0, low: float = 0.5):
        super().__init__(high, low)

    def signal(self, view: RoutingView, unit: int) -> float:
        if self.bus is not None:
            # bus-backed: Monitor.bind_live registers this exact summation
            return self.bus.read(self.bus_signal)
        now = view.now
        debt = 0.0
        for u in range(view.n_units):
            for it in view.queued_items(u):
                debt += max(0.0, (now + it.ideal_ttft) - it.deadline)
        return debt


_DETECTORS: Dict[str, Type[OverloadDetector]] = {}


def register_detector(cls: Type[OverloadDetector]) -> Type[OverloadDetector]:
    _DETECTORS[cls.name] = cls
    return cls


for _cls in (QueueDepthDetector, LaxityDebtDetector):
    register_detector(_cls)


def make_detector(name: str, **kw) -> OverloadDetector:
    if name not in _DETECTORS:
        raise KeyError(f"unknown overload detector {name!r}; "
                       f"choose from {sorted(_DETECTORS)}")
    return _DETECTORS[name](**kw)


# ------------------------------------------------------------- configuration
@dataclass(frozen=True)
class AdmissionSpec:
    """Admission-control stage configuration (``RouterSpec.admission``).

    ``mode="shed"`` rejects sheddable requests outright while the detector
    is tripped; ``mode="defer"`` re-tries them after ``defer_delay``
    seconds (on the original arrival clock — the SLO budget keeps burning)
    up to ``max_defers`` times, then sheds if the overload persists.
    ``shed_classes`` names the SLO classes admission may touch — tight and
    standard traffic is never shed by default."""

    detector: str = "queue_depth"
    detector_kw: Mapping[str, Any] = field(default_factory=dict)
    mode: str = "shed"                        # shed | defer
    shed_classes: Tuple[str, ...] = ("loose",)
    defer_delay: float = 0.25
    max_defers: int = 4

    def __post_init__(self):
        if self.mode not in ("shed", "defer"):
            raise ValueError(f"admission mode must be 'shed' or 'defer', "
                             f"got {self.mode!r}")


@dataclass(frozen=True)
class RouterSpec:
    """Routing + admission plane configuration threaded through
    ``ClusterSpec.router`` / ``DisaggConfig.router``. The default —
    ``kv_affinity`` with admission off — reproduces the historical
    placement bit-for-bit."""

    policy: str = "kv_affinity"
    params: Mapping[str, Any] = field(default_factory=dict)
    admission: Optional[AdmissionSpec] = None

    def build(self) -> RouterPolicy:
        return make_router(self.policy, **dict(self.params))

    def build_admission(self) -> Optional["AdmissionController"]:
        return AdmissionController(self.admission) \
            if self.admission is not None else None


class AdmissionController:
    """The admission stage the runtime runs between routing and enqueue."""

    def __init__(self, spec: AdmissionSpec):
        self.spec = spec
        self.detector = make_detector(spec.detector, **dict(spec.detector_kw))
        self.n_shed = 0
        self.n_deferred = 0

    def reset(self) -> None:
        self.detector.reset()
        self.n_shed = 0
        self.n_deferred = 0

    def decide(self, item: Any, view: RoutingView, unit: int) -> str:
        """``"admit"`` | ``"shed"`` | ``"defer"`` for a routed request.

        The detector state refreshes on *every* arrival (so recovery is
        observed even while only non-sheddable traffic flows); only
        requests of the sheddable classes are ever rejected or delayed."""
        tripped = self.detector.update(view, unit)
        if not tripped or item.slo_class not in self.spec.shed_classes:
            return "admit"
        if self.spec.mode == "defer" and item.deferrals < self.spec.max_defers:
            self.n_deferred += 1
            return "defer"
        self.n_shed += 1
        return "shed"
