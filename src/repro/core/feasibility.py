"""Robust inter-request scheduling — Algorithm 1 (Appendix B).

Combines the RED dispatch order (Step 1) with a worst-case feasibility check
(Step 2) and selective pruning with soft enforcement (Step 3) to defeat the
*Black Hole effect*: under overload, a batch may look urgent while carrying a
workload that cannot possibly meet its deadline; serving it starves viable
batches. The algorithm iteratively prunes the requests contributing the most
load to the bottleneck port until the remainder becomes feasible, demoting
pruned requests to a scavenger class rather than dropping them.

Latency estimation follows Appendix B Step 2: computation latency is treated
as deterministic (static transformer graph + offline profile — here the
analytic latency model in repro.simcluster.latency), and communication
latency is the cumulative load on the bottleneck port divided by its
bandwidth, under a worst-case no-overlap-between-batches assumption.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .red import red_score, partition_by_max_gap

__all__ = ["BatchLoad", "InterSchedule", "inter_request_schedule"]


@dataclass
class BatchLoad:
    """Scheduler view of one batch.

    ``request_loads`` maps request id -> load vector over the P network ports
    (bytes each request will push through each port, from the traffic
    matrix; for MoE this uses historical routing statistics — §B notes <=20%
    error is tolerable). ``compute_time`` is the batch's deterministic
    computation latency; ``deadlines`` maps request id -> absolute deadline.
    """

    bid: int
    request_loads: Dict[int, np.ndarray]
    deadlines: Dict[int, float]
    compute_time: float = 0.0

    def load_vector(self, members: Sequence[int]) -> np.ndarray:
        mats = [self.request_loads[r] for r in members]
        if not mats:
            first = next(iter(self.request_loads.values()))
            return np.zeros_like(first)
        return np.sum(mats, axis=0)

    @property
    def red(self) -> float:
        return red_score(list(self.deadlines.values()))

    @property
    def loose_min(self) -> float:
        tight, loose = partition_by_max_gap(list(self.deadlines.values()))
        return loose[0] if loose else tight[0]


@dataclass
class InterSchedule:
    """Output of Algorithm 1."""

    order: List[int]                       # sigma: batch ids by ascending RED
    pruned: List[Tuple[int, int]]          # H: (batch id, request id)
    finish_estimates: Dict[int, float] = field(default_factory=dict)
    red_scores: Dict[int, float] = field(default_factory=dict)


def _est_finish(now: float, S: np.ndarray, L: np.ndarray,
                compute_time: float, port_bw: np.ndarray) -> float:
    """Worst-case finish estimate (Appendix B Step 2).

    The worst-case assumption is *no overlap between batches*: interference S
    from every higher-priority batch is serialised onto the bottleneck port.
    Within a batch, communication normally overlaps computation, so the
    batch's own finish is bounded by the slower of its compute time and its
    bottleneck drain, not their sum.
    """
    with np.errstate(divide="ignore", invalid="ignore"):
        drain = np.where(port_bw > 0, (S + L) / port_bw, 0.0)
    comm = float(drain.max()) if drain.size else 0.0
    return now + max(compute_time, comm)


def inter_request_schedule(
    batches: Sequence[BatchLoad],
    port_bandwidth: np.ndarray,
    now: float = 0.0,
    drop_budget: int = 10**9,
) -> InterSchedule:
    """Algorithm 1: RED ordering + feasibility check + selective pruning.

    Triggered on batch arrival/departure (never per layer — §4.4.2 explicitly
    avoids fine-grained updates to keep the scheduler robust to transient
    load-estimation jitter).
    """
    port_bw = np.asarray(port_bandwidth, dtype=np.float64)
    S = np.zeros_like(port_bw)                    # interference from higher-priority batches
    pool: Dict[Tuple[int, int], np.ndarray] = {}  # candidate pool P: (bid, rid) -> load
    pruned: List[Tuple[int, int]] = []
    # Step 1 — global order by RED (ascending), bid as deterministic tiebreak.
    order = sorted(batches, key=lambda b: (b.red, b.bid))
    sched = InterSchedule(order=[b.bid for b in order], pruned=pruned)
    members: Dict[int, List[int]] = {b.bid: list(b.request_loads) for b in order}

    for b in order:
        sched.red_scores[b.bid] = b.red
        for r in members[b.bid]:
            pool[(b.bid, r)] = b.request_loads[r]
        L = b.load_vector(members[b.bid])
        fhat = _est_finish(now, S, L, b.compute_time, port_bw)
        # Step 2 — worst-case feasibility against the loose-min deadline.
        while fhat > b.loose_min and len(pruned) < drop_budget and pool:
            # Step 3 — prune the heaviest contributor on the bottleneck port.
            u_star = int(np.argmax(S + L))
            key = max(pool, key=lambda k: (pool[k][u_star], k))
            victim_bid, victim_rid = key
            load = pool.pop(key)
            pruned.append(key)
            members[victim_bid].remove(victim_rid)
            if victim_bid == b.bid:
                L = L - load          # drop from the current batch
            else:
                S = S - load          # drop from an already-admitted batch
            fhat = _est_finish(now, S, L, b.compute_time, port_bw)
        S = S + L
        sched.finish_estimates[b.bid] = fhat
    return sched
