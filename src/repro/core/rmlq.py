"""Reverse Multi-Level Queue (RMLQ) — §4.2.

The RMLQ inverts the classic MLFQ discipline: instead of *demoting* flows
over time, every flow is initialised in a **low**-priority queue (deferral)
and is **monotonically promoted** toward higher priority strictly when its
diminishing effective laxity demands immediate service (Defer-and-Promote).

Invariants enforced here (and property-tested in tests/test_core_rmlq.py):

  I1 (monotonicity)   a flow's level never increases — promotion only.
  I2 (atomicity)      promotion is applied at layer boundaries, never within
                      a message, so a message is never fragmented across
                      priority levels (no packet re-ordering — §4.3).
  I3 (reservation)    level 1 admits only explicit-deadline flows whose MLU
                      has crossed the critical threshold U (§4.5).
  I4 (capture)        tau_K = +inf: any flow, however loose, is held by the
                      lowest queue rather than dropped.

The RMLQ itself is a passive priority structure; *when* levels change is
decided by the arbiter (repro.core.arbiter.MFSScheduler), which calls
``promote`` at layer boundaries / periodic ticks per the paper's rules.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

from .msflow import Flow, FlowState, Stage
from .urgency import MLUConfig

__all__ = ["RMLQ"]


class RMLQ:
    """K strict-priority queues + one scavenger class (level K+1)."""

    def __init__(self, cfg: MLUConfig = MLUConfig()):
        self.cfg = cfg
        self.K = cfg.K
        self._queues: List[Dict[int, Flow]] = [dict() for _ in range(cfg.K + 2)]
        self._level: Dict[int, int] = {}
        #: optional decision-audit sink (repro.core.telemetry.Telemetry);
        #: None keeps every record site a single falsy check
        self.audit = None

    # ------------------------------------------------------------------ admin
    def insert(self, flow: Flow, level: int) -> None:
        """Admit a flow at its initial (deferred) level."""
        clamped = self._clamp(level, flow)
        if flow.fid in self._level:
            raise ValueError(f"flow {flow.fid} already queued")
        self._level[flow.fid] = clamped
        flow.level = clamped
        self._queues[clamped][flow.fid] = flow
        if self.audit is not None:
            self.audit.rmlq_event("insert", flow, None, clamped)
            if level < clamped == 2:
                # I3 band clamp: the flow asked for the critical reservation
                # but its band (D2D/WB or no explicit deadline) bars level 1
                self.audit.rmlq_event("clamp", flow, level, clamped)

    def remove(self, flow: Flow) -> None:
        lvl = self._level.pop(flow.fid, None)
        if lvl is not None:
            self._queues[lvl].pop(flow.fid, None)

    def __contains__(self, flow: Flow) -> bool:
        return flow.fid in self._level

    def level_of(self, flow: Flow) -> Optional[int]:
        return self._level.get(flow.fid)

    # -------------------------------------------------------------- promotion
    def promote(self, flow: Flow, new_level: int) -> bool:
        """Move ``flow`` to ``new_level`` iff that is a strict promotion.

        Returns True when the flow actually moved. Demotion requests are
        ignored (I1): the Defer-and-Promote principle deliberately forbids
        priority oscillation, keeping flows in lower tiers until urgency
        strictly necessitates promotion.
        """
        cur = self._level.get(flow.fid)
        if cur is None:
            raise KeyError(f"flow {flow.fid} not queued")
        wanted = new_level
        new_level = self._clamp(new_level, flow)
        if new_level >= cur:
            if self.audit is not None and wanted < new_level == 2 \
                    and wanted < cur:
                # the urgency called for level 1 but the band clamp held the
                # flow back — an invisible non-decision without the audit
                self.audit.rmlq_event("clamp", flow, wanted, new_level)
            return False
        del self._queues[cur][flow.fid]
        self._queues[new_level][flow.fid] = flow
        self._level[flow.fid] = new_level
        flow.level = new_level
        if self.audit is not None:
            self.audit.rmlq_event("promote", flow, cur, new_level)
            if wanted < new_level == 2:
                self.audit.rmlq_event("clamp", flow, wanted, new_level)
        return True

    def demote_to_scavenger(self, flow: Flow) -> None:
        """Overload control (Appendix B): soft-enforce pruning by demoting the
        flow to the scavenger class instead of dropping it. This is the single
        sanctioned exception to I1 and is recorded on the flow state."""
        cur = self._level.get(flow.fid)
        if cur is None:
            return
        del self._queues[cur][flow.fid]
        lvl = self.K + 1
        self._queues[lvl][flow.fid] = flow
        self._level[flow.fid] = lvl
        flow.level = lvl
        flow.state = FlowState.PRUNED
        if self.audit is not None:
            self.audit.rmlq_event("scavenge", flow, cur, lvl)

    def readmit(self, flow: Flow, level: int) -> None:
        """Re-admit a scavenged flow (runtime turned out better than the
        worst-case estimate)."""
        if self._level.get(flow.fid) != self.K + 1:
            return
        del self._queues[self.K + 1][flow.fid]
        level = self._clamp(level, flow)
        self._queues[level][flow.fid] = flow
        self._level[flow.fid] = level
        flow.level = level
        flow.state = FlowState.ACTIVE
        if self.audit is not None:
            self.audit.rmlq_event("readmit", flow, self.K + 1, level)

    # ---------------------------------------------------------------- queries
    def flows(self, level: Optional[int] = None) -> Iterable[Flow]:
        if level is not None:
            return list(self._queues[level].values())
        out: List[Flow] = []
        for q in self._queues[1:]:
            out.extend(q.values())
        return out

    def occupancy(self) -> List[int]:
        return [len(q) for q in self._queues]

    def _clamp(self, level: int, flow: Flow) -> int:
        # I3: level 1 is reserved for explicit-deadline *completion* (Stage 3)
        # flows. D2D rebalancing and KV-store writebacks carry derived
        # deadlines too, but both are deferrable by design (overload control
        # trades them against P2D), so they never enter the critical
        # reservation.
        lo = 1 if (flow.explicit_deadline
                   and flow.stage not in (Stage.D2D, Stage.WB)) else 2
        return max(lo, min(self.K, level))
