"""Urgency metrics — MLU (§4.3) and RLI (§4.4.1).

MLU — Minimal Link Utilization — is the urgency metric for flows carrying an
explicit deadline (Stage 3 / P2D):

    MLU_i(t) = Size_rem(t) / (Time_rem(t) * B * (1 - rho))

i.e. the minimal share of the residual bottleneck capacity the flow must
receive from now on to finish by its deadline. MLU > 1 is infeasible; values
near 1 demand (near-)exclusive service; small values signal ample laxity and
justify deferral.

The continuous MLU is quantised onto K discrete priority levels via a
*geometric* threshold ladder, which minimises the worst-case relative
quantisation error |v - tau_k| / v (the optimal spacing is geometric because
the product of adjacent ratios is fixed at U_max/U_min — §4.3). Since U_max
and U_min are unknown online, the paper parameterises the ladder as

    Q_i = E^(-i) * U      (1 <= i <= K-1),   E = 4, U = 0.5 by default.

Level semantics used throughout this repo: level 1 is the *highest* physical
priority, level K the lowest; level K+1 is the scavenger class used by
overload control (Appendix B). Level assignment for an explicit-deadline flow:

    level(MLU) = 1                      if MLU >= U        (critical)
               = 1 + i  for smallest i  if MLU >= Q_i      (geometric band)
               = K                      otherwise           (ample laxity)

RLI — Relative Layer Index — is the urgency proxy for implicit-deadline flows
(Stages 1 & 2):   RLI = L_target - L_curr.  RLI = 0 means the flow blocks the
computation that is ready to run *now*; larger RLI = wider safe deferral
window. Theorem 1: smallest-RLI-first minimises prefill makespan under the
fluid model. Implicit flows map to levels 2..K (level 1 is reserved for
critical explicit-deadline flows — §4.5) by capping RLI at K-2.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = ["MLUConfig", "mlu", "mlu_level", "geometric_thresholds", "rli_level"]


@dataclass(frozen=True)
class MLUConfig:
    K: int = 8          # number of physical priority levels
    E: float = 4.0      # geometric ratio of the threshold ladder
    U: float = 0.5      # top threshold: MLU >= U ==> critical (level 1)

    def thresholds(self):
        return geometric_thresholds(self.K, self.E, self.U)


def mlu(size_rem: float, time_rem: float, bandwidth: float, rho: float = 0.0) -> float:
    """Minimal Link Utilization of a deadline flow.

    ``bandwidth`` is the bottleneck link capacity along the flow's path and
    ``rho`` the measured background load on it; ``B * (1 - rho)`` is the
    effective residual capacity. A non-positive time budget (deadline passed
    or now) with work remaining is infinite urgency.
    """
    if size_rem <= 0.0:
        return 0.0
    eff = bandwidth * max(0.0, 1.0 - rho)
    if time_rem <= 0.0 or eff <= 0.0:
        return math.inf
    return size_rem / (time_rem * eff)


def geometric_thresholds(K: int, E: float = 4.0, U: float = 0.5):
    """Promotion thresholds Q_i = E^(-i) * U for i = 1..K-1 (descending).

    Q_K is implicitly -inf (``tau_K = +inf`` in deadline terms): arbitrarily
    loose flows are still captured by the lowest-priority queue.
    """
    if K < 2:
        raise ValueError("need at least two priority levels")
    if E <= 1.0:
        raise ValueError("geometric ratio must exceed 1")
    return [U * E ** (-i) for i in range(1, K)]


def mlu_level(value: float, cfg: MLUConfig = MLUConfig()) -> int:
    """Map an MLU value to a discrete RMLQ level (1 = highest priority).

    MLU > 1 "signifies an infeasible overload state" (§4.3): even exclusive
    service cannot meet the deadline, so promoting the flow would burn scarce
    bandwidth on an inevitable miss (the EDF domino / Black-Hole failure the
    paper is explicitly avoiding). Infeasible flows stay in the lowest queue
    and drain opportunistically.
    """
    if not math.isfinite(value) or value > 1.0:
        return cfg.K
    if value >= cfg.U:
        return 1
    # thresholds[i-1] = Q_i;  MLU in [Q_i, Q_{i-1}) -> level i+1
    for i, q in enumerate(cfg.thresholds(), start=1):
        if value >= q:
            return i + 1
    return cfg.K


def rli_level(rli: int, cfg: MLUConfig = MLUConfig()) -> int:
    """Map a Relative Layer Index to an RMLQ level.

    Stage 2 flows have RLI = 0 and "directly enter the high priority queue"
    (§4.5) — i.e. level 2, the top of the implicit-deadline band (level 1 is
    reserved for critical explicit-deadline flows). Stage 1 lookahead flows
    start at 2 + RLI and are promoted as computation advances. The paper caps
    the physical mapping at the lowest queue (§5).
    """
    if rli < 0:
        rli = 0
    return min(cfg.K, 2 + rli)
