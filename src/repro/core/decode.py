"""Decode plane — decode pools, per-token progress, and D2D KV migration.

The prefill side of the runtime ends a request at its first token (TTFT);
this module models everything after it, which is where the paper's overload
control gets its second contender: **decode-instance KV migration and
load-rebalancing transfers fight prefill P2D on the shared decode
downlinks** (§ overload control). Related work motivates the shape of the
model: *Taming Request Imbalance* attributes most disaggregated-serving SLO
violations to decode-side imbalance under variable request patterns, and
*SLOs-Serve* shows TTFT-only scheduling misallocates capacity once decode
(TPOT) SLOs coexist with prefill ones.

Pieces:

  * :class:`DecodePoolSpec` / :class:`DecodeSpec` — named multi-decode
    pools (per-tenant / per-model). Each pool owns a slice of the decode
    endpoints, a per-endpoint slot budget, a TPOT budget (the per-token
    SLO base) and optionally a pool-default TTFT ``slo_scale`` so P2D
    deadlines differ per pool.
  * :class:`DecodeSession` — one request living past its first token:
    sampled output length, per-token times (TBT gaps), migration history.
  * :class:`DecodePlane` — per-endpoint batched decode steps driven by the
    shared event queue (``dstep`` events; step latency from
    ``StageProfile.decode_step_time``), plus the **rebalancer**: when a
    pool's per-endpoint session counts diverge past a hysteresis
    high-water mark, it emits Stage-``D2D`` flows (KV migration from the
    hot endpoint to the cold one) into the same ``FluidNet`` as S1/S2/S3,
    where they share strict-priority water-filling and the decode
    downlinks with P2D traffic.

D2D deadline derivation (``d2d_deadline``): the migrated KV must arrive by
the time the *destination* owes the request its next token under the TPOT
SLO — ``max(t_first_token + tpot_budget * tokens_done, now + tpot_budget)``.
A request ahead of its per-token budget donates its accrued slack, so
loose-SLO rebalancing is exactly the traffic overload control can defer in
favor of tight-TTFT P2D (the MFS arbiter gives D2D its own band below P2D
at equal RMLQ level; baselines treat D2D by their generic rule).

Control-plane only (no JAX) and host-agnostic, like the rest of
``repro.core``: both ``ClusterSim`` and ``DisaggServer`` attach one plane
to the shared runtime, so decode event sequences are host-parity-testable
exactly like prefill stage traces.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .msflow import Flow, Stage, new_flow_id

__all__ = ["DecodePoolSpec", "DecodeSpec", "DecodeSession", "DecodePlane",
           "partition_pools"]


@dataclass(frozen=True)
class DecodePoolSpec:
    """One named decode pool (per-tenant or per-model)."""

    name: str = "default"
    weight: float = 1.0          # share of the decode endpoints
    slots_per_ep: int = 8        # concurrent decode sessions per endpoint
    tpot_budget: float = 0.05    # per-token SLO base (s/token, standard class)
    slo_scale: float = 0.0       # pool-default TTFT scale (0 = cluster-wide);
    #                              this is how P2D deadlines differ per pool
    classes: Tuple[str, ...] = ()  # SLO classes routed here ((), = weighted)


@dataclass(frozen=True)
class DecodeSpec:
    """Decode-plane configuration attached to a cluster/server spec."""

    pools: Tuple[DecodePoolSpec, ...] = (DecodePoolSpec(),)
    mean_out: int = 128          # sampled output length (lognormal mean) when
    out_sigma: float = 0.7       # the request carries no explicit out_tokens
    max_out: int = 0             # 0 = 8x mean
    standard_scale: float = 3.0  # slo_scale of the "standard" tenant class;
    #                              a request's TPOT budget = pool budget x
    #                              (its slo_scale / standard_scale)
    rebalance: bool = True
    trigger_delta: int = 4       # hysteresis high-water (max-min sessions)
    release_delta: int = 1       # hysteresis low-water (stop migrating)
    max_inflight: int = 2        # concurrent D2D migrations per pool
    min_migrate_remaining: int = 4   # don't migrate nearly-finished sessions
    # --- decode-side overload eviction (the Algorithm-1 decode loop) ---
    # When an in-flight migration's derived deadline goes infeasible (the
    # remaining KV cannot arrive in time even at the bottleneck's full
    # capacity), the plane abandons the D2D and releases its slots; loose
    # sessions spill to the bulk pool (``spill_pool``, or the loosest-budget
    # pool when empty), non-loose sessions re-queue on their source
    # endpoint, and loose sessions with nowhere to spill are evicted for
    # good (their KV blocks are released back through the KV store).
    auto_evict: bool = False
    spill_pool: str = ""


def partition_pools(pools: Sequence[DecodePoolSpec],
                    eps: Sequence[int]) -> Dict[str, List[int]]:
    """Split the decode endpoints into contiguous per-pool slices by weight
    (every pool gets at least one endpoint)."""
    eps = list(eps)
    if len(eps) < len(pools):
        raise ValueError(f"{len(pools)} pools need >= {len(pools)} decode "
                         f"endpoints, got {len(eps)}")
    wsum = sum(max(p.weight, 1e-9) for p in pools)
    out: Dict[str, List[int]] = {}
    start, acc = 0, 0.0
    for i, p in enumerate(pools):
        acc += max(p.weight, 1e-9)
        end = len(eps) if i == len(pools) - 1 else int(round(acc / wsum * len(eps)))
        end = min(max(end, start + 1), len(eps) - (len(pools) - 1 - i))
        out[p.name] = eps[start:end]
        start = end
    return out


@dataclass
class DecodeSession:
    """One request on the decode plane (created when its TTFT materialises)."""

    rid: int
    pool: str
    ep: int                      # current decode endpoint
    prompt_tokens: int
    out_tokens: int              # total output tokens incl. the first
    tpot_budget: float           # this request's per-token budget (s/token)
    started: float               # admit time == first-token time
    last_token: float
    tokens_done: int = 1         # the first token came with the prefill handoff
    finished: Optional[float] = None
    state: str = "queued"        # queued | active | migrating | done | evicted
    gap_sum: float = 0.0         # TBT bookkeeping over tokens 2..N
    gap_max: float = 0.0
    n_migrations: int = 0
    migrate_dst: int = -1
    d2d_fid: int = -1
    no_migrate: bool = False     # set after an abandoned migration so the
    #                              rebalancer cannot immediately re-pick it;
    #                              cleared once the session makes token
    #                              progress (conditions have changed)
    payload: Any = None          # the host's request object, if it wants one

    @property
    def ctx_tokens(self) -> int:
        return self.prompt_tokens + self.tokens_done

    @property
    def remaining(self) -> int:
        return self.out_tokens - self.tokens_done

    @property
    def tpot(self) -> float:
        """Mean time per output token after the first (== mean TBT)."""
        if self.tokens_done <= 1:
            return 0.0
        end = self.finished if self.finished is not None else self.last_token
        return (end - self.started) / (self.tokens_done - 1)

    @property
    def tpot_ok(self) -> bool:
        return self.tpot <= self.tpot_budget + 1e-12


class DecodePlane:
    """Decode pools + rebalancer, driven by the shared MsFlow runtime.

    The runtime owns the clock and the fluid net; the plane only reacts to
    events the runtime routes to it (``admit`` at TTFT, ``on_step`` for
    ``dstep`` events, ``on_d2d_done`` for migration completions) and submits
    D2D flows back through ``runtime._submit`` — the same primitive every
    other stage uses, so D2D contends in the exact same water-filling.
    """

    def __init__(self, spec: DecodeSpec, profile: Any,
                 pool_eps: Dict[str, List[int]], *, seed: int = 0,
                 trace: bool = False):
        self.spec = spec
        self.profile = profile
        self.pools: Dict[str, DecodePoolSpec] = {p.name: p for p in spec.pools}
        unknown = set(pool_eps) - set(self.pools)
        if unknown:
            raise ValueError(f"pool_eps names {sorted(unknown)} not in spec")
        self.pool_eps = {name: list(e) for name, e in pool_eps.items()}
        self._pool_of_ep = {ep: name for name, eps in self.pool_eps.items()
                            for ep in eps}
        self.rng = np.random.default_rng(seed)
        self.rt: Any = None                      # bound by MsFlowRuntime

        self.sessions: Dict[int, DecodeSession] = {}    # live only (O(active))
        self.active: Dict[int, Dict[int, DecodeSession]] = {
            ep: {} for eps in self.pool_eps.values() for ep in eps}
        self.queued: Dict[str, Deque[DecodeSession]] = {
            name: deque() for name in self.pools}
        self.queued_on: Dict[int, int] = {ep: 0 for ep in self.active}
        self.incoming: Dict[int, int] = {ep: 0 for ep in self.active}
        self._step_armed: Dict[int, bool] = {ep: False for ep in self.active}
        self._step_members: Dict[int, Tuple[int, ...]] = {}
        self._inflight: Dict[str, int] = {name: 0 for name in self.pools}
        self._rebalancing: Dict[str, bool] = {name: False for name in self.pools}
        self._kv_per_tok = profile.kv_bytes_per_token()
        self._state_b = profile.model.state_bytes(profile.kv_dtype_bytes)
        self._G = len(profile.plan)
        self.stats = {"admitted": 0, "finished": 0, "tokens": 0, "steps": 0,
                      "migrations": 0, "d2d_bytes": 0.0, "evicted": 0,
                      "abandoned": 0, "spilled": 0, "dropped": 0}
        self.trace = trace
        self.event_log: Deque[Tuple] = deque(maxlen=100_000)

    def bind(self, rt: Any) -> None:
        self.rt = rt

    def _log(self, kind: str, rid: int, ep: int, t: float, extra: int = 0) -> None:
        if self.trace:
            self.event_log.append((kind, rid, ep, extra, t))
        # telemetry plane: every decode-plane event funnels through here, so
        # one forward covers admit/finish/d2d/migrated/abandon/spill/evict.
        # Per-token steps are summarized by the finish event (tokens_done in
        # ``extra``) rather than flooding the per-request lifecycle.
        # plane may run unbound (or against a stub runtime) in tests
        tel = getattr(getattr(self, "rt", None), "telemetry", None)
        if tel is not None and kind != "token":
            tel.request_event(rid, "decode_" + kind,
                              {"ep": ep, "extra": extra}, t=t)

    def _release_kv(self, rid: int) -> None:
        """Release the request's KV-store pins (held through decode so the
        live session's prefix blocks cannot be evicted from under it)."""
        kv = getattr(self.rt, "kvstore", None) if self.rt is not None else None
        if kv is not None:
            kv.release(rid)

    # ------------------------------------------------------------ pool routing
    def pick_pool(self, item: Any) -> str:
        """Pool for an arriving request: class-pinned pools first (the
        per-tenant story), else a deterministic weighted hash of the rid so
        both hosts route identically."""
        cls = getattr(item.payload, "slo_class", None)
        if cls is not None:
            for p in self.pools.values():
                if cls in p.classes:
                    return p.name
        open_pools = [p for p in self.pools.values() if not p.classes]
        if not open_pools:
            open_pools = list(self.pools.values())
        wsum = sum(max(p.weight, 1e-9) for p in open_pools)
        u = ((item.rid * 2654435761) % (1 << 32)) / float(1 << 32) * wsum
        acc = 0.0
        for p in open_pools:
            acc += max(p.weight, 1e-9)
            if u < acc:
                return p.name
        return open_pools[-1].name

    def pool_slo_scale(self, pool: str) -> float:
        """Pool-default TTFT scale (0 defers to the cluster-wide default)."""
        p = self.pools.get(pool)
        return p.slo_scale if p is not None else 0.0

    def eps_of(self, pool: str) -> List[int]:
        return self.pool_eps.get(pool) or next(iter(self.pool_eps.values()))

    # -------------------------------------------------------------- admission
    def _sample_out(self) -> int:
        mu = np.log(max(self.spec.mean_out, 1)) - self.spec.out_sigma ** 2 / 2.0
        cap = self.spec.max_out or 8 * self.spec.mean_out
        return int(np.clip(self.rng.lognormal(mu, self.spec.out_sigma), 1, cap))

    def admit(self, item: Any, now: float) -> int:
        """Start the decode phase of a request whose TTFT just materialised.

        Returns the number of D2D flows submitted (rebalancing may trigger
        immediately when admission lands on an already-hot endpoint)."""
        pool = self.pools.get(item.pool) or next(iter(self.pools.values()))
        eps = self.eps_of(pool.name)
        # the session lives where its group-0 P2D KV landed (StageEmitter
        # spreads group g to eps[(rid + g) % len]): admission imbalance is
        # real, which is exactly what the rebalancer exists to fix
        ep = eps[item.rid % len(eps)]
        out = item.out_tokens if item.out_tokens > 0 else self._sample_out()
        rel = (item.slo_scale / self.spec.standard_scale) \
            if item.slo_scale > 0 else 1.0
        sess = DecodeSession(
            rid=item.rid, pool=pool.name, ep=ep, prompt_tokens=item.n_tokens,
            out_tokens=out, tpot_budget=pool.tpot_budget * rel,
            started=now, last_token=now, payload=item.payload)
        self.stats["admitted"] += 1
        self._log("admit", sess.rid, ep, now, out)
        if self.rt is not None:
            self.rt.host.on_decode_admitted(sess)
        if out <= 1:                       # first token was the whole output
            sess.state = "done"
            sess.finished = now
            self.stats["finished"] += 1
            self._release_kv(sess.rid)
            if self.rt is not None:
                self.rt.host.on_decode_done(sess)
            return 0
        self.sessions[sess.rid] = sess
        if len(self.active[ep]) + self.incoming[ep] < pool.slots_per_ep:
            self._activate(sess, ep, now)
        else:
            # placement is sticky: the session's KV lives on ``ep``, so it
            # can only start there — escaping a hot endpoint requires a D2D
            # migration (that asymmetry is what the rebalancer exists for)
            self._enqueue(sess)
        return self._maybe_rebalance(pool.name, now)

    def _enqueue(self, sess: DecodeSession) -> None:
        sess.state = "queued"
        self.queued[sess.pool].append(sess)
        self.queued_on[sess.ep] += 1

    def _activate(self, sess: DecodeSession, ep: int, now: float) -> None:
        sess.ep = ep
        sess.state = "active"
        self.active[ep][sess.rid] = sess
        self._ensure_step(ep, now)

    # --------------------------------------------------------------- stepping
    def _step_time(self, ep: int) -> float:
        members = self.active[ep]
        ctx = float(np.mean([s.ctx_tokens for s in members.values()]))
        return self.profile.decode_step_time(len(members), ctx)

    def _ensure_step(self, ep: int, now: float) -> None:
        if self.active[ep] and not self._step_armed[ep]:
            self._step_armed[ep] = True
            # the batch is fixed when the step launches (continuous batching
            # admits at step boundaries): sessions activated while this step
            # is in flight wait for the next one
            self._step_members[ep] = tuple(self.active[ep])
            self.rt.evq.push(now + self._step_time(ep), "dstep", ep)

    def on_step(self, ep: int, now: float) -> int:
        """One batched decode step finished on ``ep``: every session that
        was in the launched batch (and is still resident) gains a token;
        finished sessions release their slot (queue drains). Returns the
        number of D2D flows submitted by rebalance checks."""
        self._step_armed[ep] = False
        batch = self._step_members.pop(ep, ())
        members = [self.active[ep][r] for r in batch if r in self.active[ep]]
        if members:
            self.stats["steps"] += 1
        for sess in members:
            gap = now - sess.last_token
            sess.gap_sum += gap
            sess.gap_max = max(sess.gap_max, gap)
            sess.last_token = now
            sess.tokens_done += 1
            sess.no_migrate = False    # progress: migration is an option again
            self.stats["tokens"] += 1
            self._log("token", sess.rid, ep, now, sess.tokens_done)
            if sess.tokens_done >= sess.out_tokens:
                self._finish(sess, now)
        self._ensure_step(ep, now)
        return self._maybe_rebalance(self._pool_of_ep[ep], now)

    def _finish(self, sess: DecodeSession, now: float) -> None:
        self.active[sess.ep].pop(sess.rid, None)
        self.sessions.pop(sess.rid, None)        # O(active): evict on finish
        sess.state = "done"
        sess.finished = now
        self.stats["finished"] += 1
        self._release_kv(sess.rid)
        self._log("finish", sess.rid, sess.ep, now, sess.tokens_done)
        if self.rt is not None:
            self.rt.host.on_decode_done(sess)
            mon = getattr(self.rt, "monitor", None)
            if mon is not None:
                mon.on_decode_finished(sess, now)
        self._drain_queue(sess.pool, sess.ep, now)

    def _drain_queue(self, pool: str, ep: int, now: float) -> None:
        """Start queued sessions whose KV lives on ``ep`` (sticky placement:
        a freed slot only helps requests already resident there)."""
        q = self.queued[pool]
        slots = self.pools[pool].slots_per_ep
        while self.queued_on[ep] \
                and len(self.active[ep]) + self.incoming[ep] < slots:
            sess = next(s for s in q if s.ep == ep)
            q.remove(sess)
            self.queued_on[ep] -= 1
            self._activate(sess, ep, now)

    # ------------------------------------------------------------- rebalancer
    def _loads(self, pool: str) -> Dict[int, int]:
        """Per-endpoint load = active + queued-resident sessions + migrations
        already headed there (counting inbound work prevents thrash)."""
        return {ep: len(self.active[ep]) + self.queued_on[ep]
                + self.incoming[ep] for ep in self.pool_eps[pool]}

    def _maybe_rebalance(self, pool: str, now: float) -> int:
        """Hysteresis-gated pool rebalancing: start migrating when the
        max-min session spread reaches ``trigger_delta``, keep going until
        it falls to ``release_delta`` (or the in-flight cap is hit)."""
        spec = self.spec
        if (not spec.rebalance or self.rt is None
                or len(self.pool_eps[pool]) < 2):
            return 0
        loads = self._loads(pool)
        delta = max(loads.values()) - min(loads.values())
        if not self._rebalancing[pool]:
            if delta < spec.trigger_delta:
                return 0
            self._rebalancing[pool] = True
        n_submitted = 0
        while self._inflight[pool] < spec.max_inflight:
            loads = self._loads(pool)
            # deterministic tie-break on endpoint id for host parity
            src = max(loads, key=lambda e: (loads[e], -e))
            dst = min(loads, key=lambda e: (loads[e], e))
            if loads[src] - loads[dst] <= spec.release_delta:
                self._rebalancing[pool] = False
                break
            victim = self._pick_victim(src)
            if victim is None:
                break
            self._start_migration(victim, src, dst, now)
            n_submitted += 1
        return n_submitted

    def _pick_victim(self, ep: int) -> Optional[DecodeSession]:
        """Queued-resident sessions first (they are stalled on the hot
        endpoint and migrating them costs no token gap), then the active
        session with the most remaining tokens (the migration amortises
        best); sessions about to finish are never moved."""
        best: Optional[DecodeSession] = None
        if self.queued_on[ep]:
            for sess in self.queued[self._pool_of_ep[ep]]:
                if sess.ep != ep or sess.no_migrate \
                        or sess.remaining < self.spec.min_migrate_remaining:
                    continue
                if best is None or (sess.remaining, -sess.rid) \
                        > (best.remaining, -best.rid):
                    best = sess
            if best is not None:
                return best
        for sess in self.active[ep].values():
            if sess.no_migrate \
                    or sess.remaining < self.spec.min_migrate_remaining:
                continue
            if best is None or (sess.remaining, -sess.rid) > (best.remaining,
                                                              -best.rid):
                best = sess
        return best

    def d2d_deadline(self, sess: DecodeSession, now: float) -> float:
        """Implicit D2D deadline from the destination's next-token budget:
        the KV must arrive by the time the request's TPOT SLO entitles it to
        its next token; a request ahead of budget donates its accrued slack
        (never less than one token budget from now)."""
        next_due = sess.started + sess.tpot_budget * sess.tokens_done
        return max(next_due, now + sess.tpot_budget)

    def _start_migration(self, sess: DecodeSession, src: int, dst: int,
                         now: float) -> None:
        if sess.state == "queued":
            self.queued[sess.pool].remove(sess)
            self.queued_on[src] -= 1
        else:
            self.active[src].pop(sess.rid, None)
        sess.state = "migrating"
        sess.migrate_dst = dst
        sess.n_migrations += 1
        self.incoming[dst] += 1
        self._inflight[sess.pool] += 1
        size = sess.ctx_tokens * self._kv_per_tok + self._state_b
        f = Flow(new_flow_id(), sess.rid, -1, Stage.D2D, size,
                 src=src, dst=dst, target_layer=0, n_layers=self._G,
                 deadline=self.d2d_deadline(sess, now))
        sess.d2d_fid = f.fid
        self.stats["migrations"] += 1
        self.stats["d2d_bytes"] += size
        self._log("d2d", sess.rid, dst, now, src)
        self.rt._submit(f)
        self._drain_queue(sess.pool, src, now)   # the freed slot is real

    def on_d2d_done(self, flow: Flow, now: float) -> int:
        """Migration landed: the session resumes on the destination (the
        token gap spanning the migration is a real TBT hit)."""
        sess = self.sessions.get(flow.rid)
        if sess is None or sess.state != "migrating" \
                or sess.d2d_fid != flow.fid:
            return 0                     # stale (e.g. session evicted)
        dst = sess.migrate_dst
        sess.migrate_dst = -1
        sess.d2d_fid = -1
        self.incoming[dst] -= 1
        self._inflight[sess.pool] -= 1
        self._log("migrated", sess.rid, dst, now, sess.tokens_done)
        sess.ep = dst
        slots = self.pools[sess.pool].slots_per_ep
        if len(self.active[dst]) + self.incoming[dst] < slots:
            self._activate(sess, dst, now)
        else:                       # dst filled up while the KV was in flight
            self._enqueue(sess)
        return self._maybe_rebalance(sess.pool, now)

    # ----------------------------------------------- decode-side auto-eviction
    def auto_evict_enabled(self) -> bool:
        return self.spec.auto_evict and self.spec.rebalance

    def _spill_target(self, sess: DecodeSession) -> Optional[str]:
        """Bulk pool loose sessions spill into: the configured
        ``spill_pool``, or the loosest-TPOT-budget pool besides the
        session's own."""
        if self.spec.spill_pool and self.spec.spill_pool in self.pools \
                and self.spec.spill_pool != sess.pool:
            return self.spec.spill_pool
        others = [p for p in self.pools.values() if p.name != sess.pool]
        if not others:
            return None
        return max(others, key=lambda p: p.tpot_budget).name

    def _readmit(self, sess: DecodeSession, pool: str, ep: int,
                 now: float) -> None:
        """Put an auto-evicted session back onto the plane (same or spill
        pool); placement is sticky again from ``ep``."""
        sess.ep = ep
        sess.pool = pool
        sess.migrate_dst = -1
        sess.d2d_fid = -1
        sess.no_migrate = True
        self.sessions[sess.rid] = sess
        slots = self.pools[pool].slots_per_ep
        if len(self.active[ep]) + self.incoming[ep] < slots:
            self._activate(sess, ep, now)
        else:
            self._enqueue(sess)

    def auto_evict(self, now: float) -> int:
        """The TPOT-budget eviction rule closing the Algorithm-1 decode
        loop: any in-flight migration whose derived deadline has become
        *infeasible* — the remaining KV cannot arrive by the deadline even
        at the bottleneck link's full capacity — is abandoned via
        :meth:`evict` (cancels the D2D, releases the reserved slots). The
        session is then re-admitted per class: loose sessions spill to the
        bulk pool (looser budget, fresh sticky placement), other sessions
        re-queue on their source endpoint, and loose sessions with nowhere
        to spill are dropped for good — their KV blocks are released back
        through the KV store. Called from the runtime's periodic tick;
        returns the number of sessions acted on (callers resched if > 0).
        """
        if self.rt is None:
            return 0
        acted = 0
        net = self.rt.net
        for sess in [s for s in self.sessions.values()
                     if s.state == "migrating"]:
            fl = self.rt.flows.get(sess.d2d_fid)
            if fl is None or fl.deadline is None:
                continue
            # exclusive-service ceiling = the route's MINIMUM capacity (the
            # most-utilised link can be a fat spine; the NIC still caps
            # actual delivery)
            route = net.routes.get(fl.fid)
            if route is None:
                route = net.topo.route(fl.src, fl.dst, fl.fid)
            cap = min((net.topo.capacity[l] for l in route), default=2e12)
            t_rem = fl.deadline - now
            if t_rem > 0 and fl.remaining <= cap * t_rem:
                continue                       # still feasible: keep going
            src = sess.ep                      # KV never left the source
            cls = getattr(sess.payload, "slo_class", None)
            self.evict(sess.rid, now)          # abandon D2D + release slots
            self.stats["evicted"] -= 1         # re-bucketed below
            self.stats["abandoned"] += 1
            self._log("abandon", sess.rid, src, now, sess.tokens_done)
            spill = self._spill_target(sess)
            if cls == "loose" and spill is not None:
                rel = (sess.tpot_budget
                       / max(self.pools[sess.pool].tpot_budget, 1e-12))
                sess.tpot_budget = self.pools[spill].tpot_budget * rel
                loads = self._loads(spill)
                dst = min(loads, key=lambda e: (loads[e], e))
                self._readmit(sess, spill, dst, now)
                self.stats["spilled"] += 1
                self._log("spill", sess.rid, dst, now, src)
            elif cls != "loose":
                self._readmit(sess, sess.pool, src, now)
            else:                              # loose, nowhere to spill:
                self.stats["evicted"] += 1     # dropped for good (evict()
                self.stats["dropped"] += 1     # already released its KV pins)
            acted += 1
        return acted

    # --------------------------------------------------------------- eviction
    def evict(self, rid: int, now: float) -> bool:
        """Hard-evict a decode session (decode-side overload control / host
        cancellation): releases its pool slot, cancels any in-flight D2D
        flow, and drops all plane state — the O(active) invariant holds."""
        sess = self.sessions.pop(rid, None)
        if sess is None:
            return False
        if sess.state == "active":
            self.active[sess.ep].pop(rid, None)
        elif sess.state == "migrating":
            self.incoming[sess.migrate_dst] -= 1
            self._inflight[sess.pool] -= 1
            rt = self.rt
            fl = rt.flows.get(sess.d2d_fid) if rt is not None else None
            if fl is not None:
                if fl.fid in rt.net.flows:
                    rt.net.remove(fl)
                rt.policy.on_flow_completed(fl, rt.view)
                rt._evict_flow(fl)
        elif sess.state == "queued":
            try:
                self.queued[sess.pool].remove(sess)
                self.queued_on[sess.ep] -= 1
            except ValueError:
                pass
        sess.state = "evicted"
        self.stats["evicted"] += 1
        self._release_kv(rid)   # the session's KV blocks return to the store
        self._log("evict", rid, sess.ep, now, sess.tokens_done)
        self._drain_queue(sess.pool, sess.ep, now)
        return True

    # ---------------------------------------------------------------- queries
    def n_active(self) -> int:
        return sum(len(m) for m in self.active.values())

    def summary(self) -> Dict[str, float]:
        s = dict(self.stats)
        s["live_sessions"] = len(self.sessions)
        return s
