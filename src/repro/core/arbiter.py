"""MFS — the full Defer-and-Promote arbiter over the RMLQ substrate (§4.5).

Per-stage rules
---------------
* Stage 3 (P2D, explicit deadline): initial level and promotions come from the
  MLU geometric ladder (§4.3). Levels are re-evaluated at *layer boundaries*
  while the owning request is still computing, then at *periodic ticks* once
  computation has finished. Promotion is monotone and message-atomic.
* Stage 1 (KV reuse, implicit deadline): initial level = rli_level(RLI); as
  the compute front L_curr advances the RLI shrinks, promoting the flow
  "incrementally at layer boundaries to align with computation progress".
* Stage 2 (collectives, implicit deadline): RLI = 0 by construction — they
  block the next computation step — so they enter the top of the implicit
  band (level 2) directly.
* D2D (decode KV migration, derived deadline): same MLU ladder as Stage 3
  over its next-token (TPOT) deadline, re-evaluated on periodic ticks; at
  equal level it sits in a band *below* P2D and is barred from the level-1
  critical reservation — rebalancing is the first traffic overload control
  defers when tight-TTFT P2D needs the downlink.
* WB (KV-store writeback/replication, loose derived deadline): same MLU
  ladder and tick-driven re-evaluation, one band *below even D2D* and also
  barred from level 1 — background replication is the very last thing that
  may touch a contended link; it only promotes as its own loose deadline
  actually runs out.

Arbitration (§4.5)
------------------
Level 1 is reserved for critical explicit-deadline flows (MLU >= U). Within
each remaining level, early-stage (implicit-deadline) flows take precedence
over last-stage flows so deferred P2D traffic only opportunistically uses
bandwidth; ties among early-stage flows with equal RLI follow the RED rank
sigma from the inter-request scheduler (§4.4.2). Equal keys share bandwidth
max-min fairly, which also spreads a coflow's members evenly.

Priority-key layout (lexicographic, smaller = more urgent):

    (level, band, red_rank)
      level    1..K from the RMLQ, K+1 = scavenger
      band     0 = early-stage (Stages 1-2), 1 = last-stage (Stage 3),
               2 = decode-plane D2D rebalancing, 3 = KV-store writeback
      red_rank rank of the owning batch in sigma (0 when unused)
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

from .msflow import Flow, FlowState, Stage
from .policies import Policy, SchedView
from .rmlq import RMLQ
from .urgency import MLUConfig, mlu, mlu_level, rli_level

__all__ = ["MFSScheduler"]


class MFSScheduler(Policy):
    name = "mfs"
    uses_inter_request = True

    def __init__(self, cfg: MLUConfig = MLUConfig(), tick_interval: float = 2e-3):
        self.cfg = cfg
        #: periodic MLU re-evaluation pitch once a request finished computing
        self.tick_interval = tick_interval
        self.rmlq = RMLQ(cfg)
        #: optional telemetry collector — receives the RMLQ decision audit
        #: plus the MLU/RLI inputs computed right before each decision
        self.telemetry = None

    # ------------------------------------------------------------ admission
    def on_flow_submitted(self, flow: Flow, view: SchedView) -> None:
        self.rmlq.insert(flow, self._target_level(flow, view))

    def on_flow_completed(self, flow: Flow, view: SchedView) -> None:
        self.rmlq.remove(flow)

    def reset(self) -> None:
        self.rmlq = RMLQ(self.cfg)
        self.rmlq.audit = self.telemetry

    def attach_telemetry(self, telemetry) -> None:
        """Route the RMLQ decision audit into ``telemetry`` (survives
        ``reset()``; pass None to detach)."""
        self.telemetry = telemetry
        self.rmlq.audit = telemetry

    # ------------------------------------------------------------ promotion
    def _target_level(self, flow: Flow, view: SchedView) -> int:
        tel = self.telemetry
        if flow.stage in (Stage.P2D, Stage.D2D, Stage.WB):
            # D2D rebalancing and KV-store writebacks enter the RMLQ with
            # their own laxity: the same MLU ladder over their derived
            # deadlines (next-token TPOT budget / loose replication slack),
            # so they promote only as that budget actually runs out
            # (deferred otherwise — P2D wins the tie via the band)
            lvl = min(flow.level, self.cfg.K)
            try:
                cap, rho = view.mlu_inputs(flow, lvl)
            except (AttributeError, NotImplementedError):
                cap, rho = view.bottleneck(flow)
            laxity = flow.deadline - view.now
            u = mlu(flow.remaining, laxity, cap, rho)
            if tel is not None:
                tel.note_urgency(flow.fid, {
                    "mlu": u, "laxity": laxity, "remaining": flow.remaining,
                    "cap": cap, "rho": rho})
            return mlu_level(u, self.cfg)
        if flow.stage == Stage.COLLECTIVE:
            return 2                       # RLI = 0: top of the implicit band
        rli = max(0, flow.target_layer - view.l_curr(flow.unit))
        if tel is not None:
            tel.note_urgency(flow.fid, {
                "rli": rli, "target_layer": flow.target_layer,
                "l_curr": view.l_curr(flow.unit)})
        return rli_level(rli, self.cfg)

    def assign(self, flows: Sequence[Flow], view: SchedView,
               trigger: Tuple = ("event",)) -> None:
        kind = trigger[0]
        unit = trigger[1] if len(trigger) > 1 else None
        for f in flows:
            if f.state == FlowState.PRUNED:
                # Scavenger class: opportunistic leftovers only (Appendix B
                # "soft enforcement"); strict-priority water-filling hands it
                # whatever the admitted classes leave on the table.
                f.priority_key = (self.cfg.K + 1, 1, 0)
                f.rate_cap = None
                continue
            if f not in self.rmlq:          # e.g. re-admitted after pruning
                self.rmlq.insert(f, self._target_level(f, view))
            if self._should_reevaluate(f, view, kind, unit):
                self.rmlq.promote(f, self._target_level(f, view))
            # band: early stages (1-2) > last-stage P2D > D2D rebalancing >
            # KV-store writeback — at equal level, loose-SLO decode
            # migration and background replication are the first things
            # overload control defers in favor of tight-TTFT P2D
            band = {Stage.P2D: 1, Stage.D2D: 2, Stage.WB: 3}.get(f.stage, 0)
            red = view.red_rank(f.rid)
            f.priority_key = (f.level, band, red)
            f.rate_cap = None

    def _should_reevaluate(self, f: Flow, view: SchedView,
                           kind: str, unit: Optional[int]) -> bool:
        if kind == "submit":
            return False                    # level was just computed
        if f.stage == Stage.P2D:
            if view.computing(f.rid):
                # layer-boundary granularity while computing (C-1: priority
                # atomicity at message level, no packet re-ordering)
                return kind == "layer" and unit == f.unit
            return kind == "tick"           # fixed-interval updates afterwards
        if f.stage in (Stage.D2D, Stage.WB):
            return kind == "tick"           # no layer boundaries to ride
        if f.stage == Stage.KV_REUSE:
            return kind == "layer" and unit == f.unit
        return False                        # Stage 2 never moves (already top)

    # ------------------------------------------------- overload-control hooks
    def prune(self, flow: Flow) -> None:
        self.rmlq.demote_to_scavenger(flow)

    def readmit(self, flow: Flow, view: SchedView) -> None:
        self.rmlq.readmit(flow, self._target_level(flow, view))
