"""Online monitor plane — streaming signals over the telemetry probe sites.

PR 8's :mod:`repro.core.telemetry` records everything and answers questions
*after* the run; this module is the **online** half of the observability
stack: a strictly passive :class:`Monitor` that computes event-clock
streaming estimators at the same probe sites and exposes them as **named
signals** on a :class:`SignalBus` that live consumers — ``OverloadDetector``
admission signals, ``RouterPolicy`` placement scores, the benchmark's
``--progress`` reporter — can read *while the run is in flight*.

Pieces:

  * :class:`MonitorSpec` — the knob carried by ``ClusterSpec.monitor`` /
    ``DisaggConfig.monitor``; ``None`` (the default everywhere) keeps the
    runtime byte-identical to the monitor-less code path.
  * :class:`Monitor` — implements the same probe-method subset the runtime
    already calls on :class:`~repro.core.telemetry.Telemetry` (arrival /
    admit / shed / defer / request-done / flow-submitted / flow-closed /
    ``on_advance``), so **no new probe sites exist**: with both planes
    attached a :class:`ProbeFanout` forwards each probe call to both
    collectors behind the runtime's single ``is not None`` guard.
  * :class:`SignalBus` — the name → provider registry. Two provider kinds
    coexist: *streaming estimators* updated by the probes (rolling link
    utilization / contended share, per-stage slack-loss rates, per-SLO-class
    TTFT/TPOT quantile sketches, rolling admitted attainment) and *live
    views* registered by the runtime as closures over its
    :class:`~repro.core.router.RoutingView` (queue depths, laxity debt) —
    the latter are byte-identical to the legacy in-detector computations,
    so migrating ``queue_depth`` / ``laxity_debt`` onto the bus moves their
    trip points by exactly nothing (regression-tested).
  * :class:`FixedBinSketch` — deterministic log-spaced fixed-bin quantile
    sketch: no RNG, no platform-dependent math at observe time (bin edges
    are precomputed once; observation is a ``bisect``), insertion-order
    independent — quantiles are host-parity-exact.
  * :class:`RollingWindow` — event-clock trailing-window accumulator
    (bucket index = ``floor(t / bucket_dt)``; no wall clock anywhere).

Everything here only *reads* runtime state (clock, net rates, item fields);
enabling the monitor never changes scheduling outcomes — monitor-on vs
monitor-off runs are bit-identical (tested, mirroring the telemetry plane's
zero-overhead guard).

Control-plane only (no JAX), host-agnostic like the rest of ``repro.core``.
"""
from __future__ import annotations

from bisect import bisect_left
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

from .msflow import Flow

__all__ = ["MonitorSpec", "Monitor", "SignalBus", "FixedBinSketch",
           "RollingWindow", "ProbeFanout"]


# --------------------------------------------------------------------- spec
@dataclass(frozen=True)
class MonitorSpec:
    """Monitor-plane configuration (attach via ``ClusterSpec.monitor`` or
    ``DisaggConfig.monitor``; ``None`` disables the plane entirely)."""

    enabled: bool = True
    #: trailing-window length (seconds of event time) for rolling signals
    window: float = 2.0
    #: buckets per window — expiry granularity is ``window / buckets``
    buckets: int = 16
    #: link-utilization sampling pitch (same default as the telemetry plane)
    link_dt: float = 2e-3
    #: a link sample counts as contended at >= this utilization
    contended_util: float = 0.9
    #: quantile-sketch bin range [lo, hi) seconds and bin count; values are
    #: clamped into the range (TTFT/TPOT both live comfortably inside it)
    sketch_lo: float = 1e-4
    sketch_hi: float = 1e3
    sketch_bins: int = 256
    #: call ``Monitor.on_sample(monitor)`` every N finished requests
    #: (0 = never) — the benchmark's ``--progress`` hook
    sample_every: int = 0


# ---------------------------------------------------------------- estimators
class FixedBinSketch:
    """Deterministic fixed-bin quantile sketch over log-spaced bins.

    Bin edges are precomputed once from ``(lo, hi, bins)``; observing a
    value is a single ``bisect`` into those edges, so identically
    configured sketches fed the same multiset of values — in any order, on
    any host — report identical quantiles. No RNG, no merging error."""

    __slots__ = ("lo", "hi", "edges", "counts", "n")

    def __init__(self, lo: float = 1e-4, hi: float = 1e3, bins: int = 256):
        if not (lo > 0.0 and hi > lo and bins >= 2):
            raise ValueError(f"need 0 < lo < hi and bins >= 2, "
                             f"got lo={lo} hi={hi} bins={bins}")
        ratio = (hi / lo) ** (1.0 / bins)
        edges: List[float] = []
        e = lo
        for _ in range(bins - 1):
            e *= ratio
            edges.append(e)
        self.lo, self.hi = lo, hi
        self.edges = edges            # bin i covers (edges[i-1], edges[i]]
        self.counts = [0] * bins
        self.n = 0

    def observe(self, x: float) -> None:
        self.counts[bisect_left(self.edges, x)] += 1
        self.n += 1

    def quantile(self, q: float) -> float:
        """The upper edge of the bin holding the ``q``-quantile observation
        (conservative: the true value is <= the reported one), ``nan`` when
        empty."""
        if self.n == 0:
            return float("nan")
        rank = min(self.n - 1, max(0, int(q * self.n)))
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc > rank:
                return self.edges[i] if i < len(self.edges) else self.hi
        return self.hi                                  # pragma: no cover


class RollingWindow:
    """Event-clock trailing-window sum: ``add(t, v)`` accumulates into the
    bucket ``floor(t / bucket_dt)``; ``sum(t)`` drops buckets older than
    ``window`` first. Purely event-time — no wall clock, no RNG."""

    __slots__ = ("window", "bucket_dt", "_buckets", "_total")

    def __init__(self, window: float = 2.0, buckets: int = 16):
        self.window = window
        self.bucket_dt = window / max(1, buckets)
        self._buckets: deque = deque()       # [bucket_index, sum] pairs
        self._total = 0.0

    def _expire(self, t: float) -> None:
        cut = t - self.window
        bd = self.bucket_dt
        while self._buckets and (self._buckets[0][0] + 1) * bd <= cut:
            self._total -= self._buckets.popleft()[1]

    def add(self, t: float, v: float) -> None:
        self._expire(t)
        idx = int(t / self.bucket_dt)
        if self._buckets and self._buckets[-1][0] == idx:
            self._buckets[-1][1] += v
        else:
            self._buckets.append([idx, v])
        self._total += v

    def sum(self, t: float) -> float:
        self._expire(t)
        return self._total

    def rate(self, t: float) -> float:
        """Windowed sum per second of window span."""
        return self.sum(t) / self.window


# ------------------------------------------------------------------ the bus
class SignalBus:
    """Name → provider registry. ``read(name, key=None)`` calls the
    provider with ``key`` (a unit index, link id, SLO class, or stage name —
    signal-specific; ``None`` where the signal is scalar). Providers are
    plain callables, so live-view closures and streaming estimators share
    one namespace."""

    def __init__(self) -> None:
        self._providers: Dict[str, Callable[[Any], float]] = {}
        self._help: Dict[str, str] = {}

    def register(self, name: str, fn: Callable[[Any], float],
                 help: str = "") -> None:
        self._providers[name] = fn
        self._help[name] = help

    def has(self, name: str) -> bool:
        return name in self._providers

    def names(self) -> List[str]:
        return sorted(self._providers)

    def describe(self) -> Dict[str, str]:
        return dict(self._help)

    def read(self, name: str, key: Any = None) -> float:
        fn = self._providers.get(name)
        if fn is None:
            raise KeyError(f"unknown signal {name!r}; "
                           f"registered: {self.names()}")
        return fn(key)


# -------------------------------------------------------------- the monitor
class Monitor:
    """Streaming-estimator collector behind the telemetry probe interface.

    The runtime binds it exactly like the telemetry collector
    (``bind(clock, topo)``), forwards the same probe calls (via
    :class:`ProbeFanout` when both planes are on), and additionally calls
    :meth:`bind_live` with its ``RoutingView`` so the bus carries the live
    queue/laxity signals the migrated detectors read. A pure observer:
    every method only reads its arguments and the bound clock."""

    def __init__(self, spec: Optional[MonitorSpec] = None):
        self.spec = spec if spec is not None else MonitorSpec()
        self.bus = SignalBus()
        self._clock: Callable[[], float] = lambda: 0.0
        self.topo: Any = None
        self.t_first_decode = 0.0
        self._t_link = 0.0                     # last link sample time
        # cumulative counters (whole run)
        self.n_arrivals = 0
        self.n_admitted = 0
        self.n_done = 0
        self.n_met = 0
        self.n_shed = 0
        self.n_deferred = 0
        self.stage_submitted: Dict[str, int] = {}
        # rolling estimators
        w, b = self.spec.window, self.spec.buckets
        self._win_done = RollingWindow(w, b)
        self._win_met = RollingWindow(w, b)
        self._win_shed = RollingWindow(w, b)
        self._win_wall = RollingWindow(w, b)           # sampled link seconds
        self._win_link_util: Dict[int, RollingWindow] = {}   # util * dt
        self._win_link_cont: Dict[int, RollingWindow] = {}   # contended dt
        self._win_slack: Dict[str, RollingWindow] = {}       # slack-loss s
        # per-SLO-class quantile sketches ("all" aggregates every class)
        self.ttft_sketch: Dict[str, FixedBinSketch] = {}
        self.tpot_sketch: Dict[str, FixedBinSketch] = {}
        #: progress hook: called with this monitor every
        #: ``spec.sample_every`` finished requests (0 disables)
        self.on_sample: Optional[Callable[["Monitor"], None]] = None
        self._since_sample = 0
        self._register_signals()

    # -------------------------------------------------------------- binding
    def bind(self, clock: Callable[[], float], topo: Any,
             t_first_decode: float = 0.0) -> None:
        self._clock = clock
        self.topo = topo
        self.t_first_decode = t_first_decode

    def bind_live(self, view: Any) -> None:
        """Register the live-view signals over the runtime's RoutingView.

        These are the *exact* expressions the legacy ``queue_depth`` /
        ``laxity_debt`` detectors computed in-detector, registered as bus
        providers so bus-attached detectors trip/recover at byte-identical
        times (see ``tests/test_monitor.py``)."""
        bus = self.bus
        bus.register("queue.requests.cluster",
                     lambda key: float(view.total_queued()),
                     "queued prefill requests, cluster-wide")
        bus.register("queue.requests.unit",
                     lambda key: float(view.queued(key)),
                     "queued prefill requests at unit ``key``")
        bus.register("queue.tokens.cluster",
                     lambda key: float(sum(view.backlogs)),
                     "backlog tokens, cluster-wide")
        bus.register("queue.tokens.unit",
                     lambda key: float(view.backlogs[key]),
                     "backlog tokens at unit ``key``")

        def _laxity_debt(key: Any) -> float:
            now = view.now
            debt = 0.0
            for u in range(view.n_units):
                for it in view.queued_items(u):
                    debt += max(0.0, (now + it.ideal_ttft) - it.deadline)
            return debt

        bus.register("laxity.debt", _laxity_debt,
                     "summed already-lost slack of queued work (seconds)")

    # ----------------------------------------------------------- registry
    def _register_signals(self) -> None:
        bus = self.bus
        bus.register("slo.attainment", lambda key: self.rolling_attainment(),
                     "rolling admitted-attainment over the trailing window")
        bus.register("slo.attainment.cum",
                     lambda key: (self.n_met / self.n_done
                                  if self.n_done else 1.0),
                     "cumulative admitted-attainment since run start")
        bus.register("throughput.done",
                     lambda key: self._win_done.rate(self._clock()),
                     "finished requests per second, trailing window")
        bus.register("shed.rate",
                     lambda key: self._win_shed.rate(self._clock()),
                     "shed requests per second, trailing window")
        bus.register("link.util", self._sig_link_util,
                     "rolling mean utilization of link ``key``")
        bus.register("link.contended_share", self._sig_link_contended,
                     "share of the window link ``key`` spent contended")
        bus.register("slack_loss.rate", self._sig_slack_loss,
                     "per-stage-class deadline slack lost per second "
                     "(``key`` = stage name, e.g. 'P2D')")
        for q in (0.5, 0.9, 0.99):
            tag = f"p{int(q * 100)}"
            bus.register(f"ttft.{tag}",
                         lambda key, q=q: self._sig_quantile(
                             self.ttft_sketch, key, q),
                         f"TTFT {tag} for SLO class ``key`` ('all' default)")
            bus.register(f"tpot.{tag}",
                         lambda key, q=q: self._sig_quantile(
                             self.tpot_sketch, key, q),
                         f"TPOT {tag} for SLO class ``key`` ('all' default)")

    # ------------------------------------------------------ signal helpers
    def rolling_attainment(self) -> float:
        """Met/done over the trailing window; cumulative ratio before the
        first window fills (1.0 when nothing finished yet)."""
        t = self._clock()
        done = self._win_done.sum(t)
        if done <= 0.0:
            return self.n_met / self.n_done if self.n_done else 1.0
        return self._win_met.sum(t) / done

    def _sig_link_util(self, lid: Any) -> float:
        t = self._clock()
        wall = self._win_wall.sum(t)
        w = self._win_link_util.get(lid)
        if w is None or wall <= 0.0:
            return 0.0
        return w.sum(t) / wall

    def _sig_link_contended(self, lid: Any) -> float:
        t = self._clock()
        wall = self._win_wall.sum(t)
        w = self._win_link_cont.get(lid)
        if w is None or wall <= 0.0:
            return 0.0
        return w.sum(t) / wall

    def _sig_slack_loss(self, stage: Any) -> float:
        name = getattr(stage, "name", stage)
        w = self._win_slack.get(name)
        return w.rate(self._clock()) if w is not None else 0.0

    def _sig_quantile(self, sketches: Dict[str, FixedBinSketch],
                      key: Any, q: float) -> float:
        sk = sketches.get(key if key is not None else "all")
        return sk.quantile(q) if sk is not None else float("nan")

    def _sketch(self, sketches: Dict[str, FixedBinSketch],
                cls: str) -> FixedBinSketch:
        sk = sketches.get(cls)
        if sk is None:
            sk = sketches[cls] = FixedBinSketch(
                self.spec.sketch_lo, self.spec.sketch_hi,
                self.spec.sketch_bins)
        return sk

    def _observe(self, sketches: Dict[str, FixedBinSketch], cls: str,
                 x: float) -> None:
        self._sketch(sketches, cls).observe(x)
        self._sketch(sketches, "all").observe(x)

    # ------------------------------------------------ probe interface (sub)
    # Signatures mirror repro.core.telemetry.Telemetry exactly, so the
    # runtime's probe sites stay single-guard and a ProbeFanout can forward
    # each call verbatim. Methods the monitor has no estimator for are
    # deliberate no-ops (monitor-only runs must accept the full probe set).
    def on_arrival(self, item: Any, unit: int) -> None:
        if item.deferrals == 0:
            self.n_arrivals += 1

    def on_admitted(self, item: Any) -> None:
        self.n_admitted += 1

    def on_deferred(self, item: Any) -> None:
        self.n_deferred += 1

    def on_shed(self, item: Any) -> None:
        self.n_shed += 1
        self._win_shed.add(self._clock(), 1.0)

    def on_batch_started(self, bs: Any) -> None:
        pass

    def on_request_done(self, item: Any, bs: Any) -> None:
        t = self._clock()
        self.n_done += 1
        self._win_done.add(t, 1.0)
        budget = item.deadline - item.arrival
        if item.ttft is not None and item.ttft <= budget + 1e-9:
            self.n_met += 1
            self._win_met.add(t, 1.0)
        cls = getattr(item, "slo_class", "standard") or "standard"
        if item.ttft is not None:
            self._observe(self.ttft_sketch, cls, item.ttft)
        if self.on_sample is not None and self.spec.sample_every > 0:
            self._since_sample += 1
            if self._since_sample >= self.spec.sample_every:
                self._since_sample = 0
                self.on_sample(self)

    def on_decode_finished(self, sess: Any, now: float) -> None:
        """Decode-plane hook (``DecodePlane._finish``): one TPOT sample per
        finished session with >= 2 tokens (TPOT is undefined otherwise)."""
        if sess.tokens_done > 1:
            cls = getattr(sess.payload, "slo_class", "standard") \
                if sess.payload is not None else "standard"
            self._observe(self.tpot_sketch, cls or "standard", sess.tpot)

    def on_pruned(self, rid: int) -> None:
        pass

    def on_readmitted(self, rid: int) -> None:
        pass

    def compute_open(self, bs: Any, g: int, c: int) -> None:
        pass

    def compute_close(self, unit: int) -> None:
        pass

    def coll_wait(self, bid: int, dt: float) -> None:
        pass

    def red_run(self, order: Any, pruned: Any, n_batches: int) -> None:
        pass

    def flow_submitted(self, flow: Flow, stage_log: Any = None) -> None:
        """Per-stage submit counter. In a monitor-only run the runtime hands
        over the legacy stage log exactly as it does to the telemetry
        collector — the appended row is identical, so ``trace_stages``
        output never depends on which collector backs it."""
        if stage_log is not None:
            stage_log.append((flow.rid, flow.stage, flow.target_layer,
                              flow.size, flow.deadline))
        try:
            self.stage_submitted[flow.stage.name] += 1
        except KeyError:
            self.stage_submitted[flow.stage.name] = 1

    def flow_closed(self, flow: Flow, net: Any) -> None:
        if flow.deadline is None or flow.finished is None:
            return
        loss = max(0.0, flow.finished - flow.deadline)
        name = flow.stage.name
        w = self._win_slack.get(name)
        if w is None:
            w = self._win_slack[name] = RollingWindow(
                self.spec.window, self.spec.buckets)
        w.add(flow.finished, loss)

    def on_advance(self, net: Any, t: float) -> None:
        """Link sampling at ``link_dt`` pitch (same cadence discipline as
        the telemetry plane): accumulate utilization-weighted and contended
        link-seconds into the rolling windows."""
        if t - self._t_link < self.spec.link_dt:
            return
        sdt = t - self._t_link
        self._t_link = t
        self._win_wall.add(t, sdt)
        lr = getattr(net, "_link_rate", None)
        if not lr:
            return
        cap = self.topo.capacity
        thr = self.spec.contended_util
        for lid, used in lr.items():
            if used <= 0.0:
                continue
            w = self._win_link_util.get(lid)
            if w is None:
                w = self._win_link_util[lid] = RollingWindow(
                    self.spec.window, self.spec.buckets)
            w.add(t, (used / cap[lid]) * sdt)
            if used >= thr * cap[lid]:
                wc = self._win_link_cont.get(lid)
                if wc is None:
                    wc = self._win_link_cont[lid] = RollingWindow(
                        self.spec.window, self.spec.buckets)
                wc.add(t, sdt)

    # ------------------------------------------------------------- reporting
    def links_seen(self):
        """Link ids that have carried traffic (keys for ``link.util`` /
        ``link.contended_share`` bus reads)."""
        return list(self._win_link_util.keys())

    def snapshot(self) -> Dict[str, float]:
        """Headline signals at the current event time (progress lines,
        examples)."""
        return {
            "t": self._clock(),
            "n_done": self.n_done,
            "n_shed": self.n_shed,
            "n_deferred": self.n_deferred,
            "attainment": self.bus.read("slo.attainment"),
            "attainment_cum": self.bus.read("slo.attainment.cum"),
            "done_rate": self.bus.read("throughput.done"),
            "ttft_p99": self.bus.read("ttft.p99"),
        }


# ------------------------------------------------------------------- fanout
class ProbeFanout:
    """Forward each runtime probe call to both collectors.

    The runtime keeps ONE guard per probe site (``if self._probe is not
    None``); when telemetry and monitor are both attached this object is the
    probe target and replays every call on each. ``flow_submitted`` is
    special-cased so the legacy stage-log row is appended exactly once (by
    the telemetry collector)."""

    def __init__(self, telemetry: Any, monitor: Monitor):
        self.telemetry = telemetry
        self.monitor = monitor

    def flow_submitted(self, flow: Flow, stage_log: Any = None) -> None:
        self.telemetry.flow_submitted(flow, stage_log)
        self.monitor.flow_submitted(flow, None)

    def __getattr__(self, name: str):
        tf = getattr(self.telemetry, name)
        mf = getattr(self.monitor, name, None)
        if mf is None or not callable(tf):
            return tf

        def fan(*a: Any, **kw: Any) -> Any:
            out = tf(*a, **kw)
            mf(*a, **kw)
            return out

        self.__dict__[name] = fan          # cache per-instance
        return fan
