"""Robust Effective Deadline (RED) — §4.4.2 / Appendix B Step 1.

Synchronous batch execution lets a single outlier hijack the urgency of the
whole batch (the *Piggyback effect*): one extremely tight request would pull
every batched peer to the front of the cluster-wide order. RED counteracts
this by splitting the batch into a *tight* and a *loose* sub-batch at the
**maximal deadline gap** and blending their minima, weighted by the tight
fraction f:

    RED(B) = f * D_min^Tight + (1 - f) * D_min^Loose

When tight requests are rare (small f) the score shifts toward the loose
deadline, so isolated outliers cannot dominate; when most of the batch is
tight (f -> 1) RED converges to plain EDF on the batch minimum.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

__all__ = ["red_score", "partition_by_max_gap", "sort_by_red"]


def partition_by_max_gap(deadlines: Sequence[float]) -> Tuple[List[float], List[float]]:
    """Split sorted deadlines into (tight, loose) at the largest gap.

    Returns ``(tight, loose)`` where every tight deadline precedes every loose
    one. A batch of size 1 (or with all-equal deadlines) is all-tight with an
    empty loose set.
    """
    ds = sorted(float(d) for d in deadlines)
    n = len(ds)
    if n == 0:
        raise ValueError("empty batch")
    if n == 1:
        return ds, []
    gaps = [ds[k + 1] - ds[k] for k in range(n - 1)]
    k_star = max(range(n - 1), key=lambda k: gaps[k])
    if gaps[k_star] <= 0.0:
        return ds, []
    return ds[: k_star + 1], ds[k_star + 1:]


def red_score(deadlines: Sequence[float]) -> float:
    """RED of a batch of request deadlines (absolute times)."""
    tight, loose = partition_by_max_gap(deadlines)
    n = len(tight) + len(loose)
    if not loose:
        return tight[0]
    f = len(tight) / n
    return f * tight[0] + (1.0 - f) * loose[0]


@dataclass(frozen=True)
class BatchRef:
    """Minimal view of a batch the inter-request scheduler needs."""

    bid: int
    deadlines: Tuple[float, ...]

    @property
    def red(self) -> float:
        return red_score(self.deadlines)

    @property
    def loose_min(self) -> float:
        """D_min^Lo — the feasibility target of Algorithm 1 (tightest loose
        deadline; falls back to the batch minimum when all-tight)."""
        tight, loose = partition_by_max_gap(self.deadlines)
        return loose[0] if loose else tight[0]


def sort_by_red(batches: Sequence[BatchRef]) -> List[BatchRef]:
    """Global dispatch order: ascending RED, batch id as deterministic tie."""
    return sorted(batches, key=lambda b: (b.red, b.bid))
