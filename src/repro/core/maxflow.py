"""Offline max-flow optimality yardstick (Helix-style attainment ceiling).

Every BENCH number so far is a *ratio over weak baselines*; this module
turns attainment into an **absolute** measurement by computing what the
cluster could do at all — a per-(workload, rate) ceiling no scheduler can
exceed — so each policy's attainment is additionally reported as a fraction
of that ceiling (``benchmarks/largescale.py`` ``yardstick`` arm).

The bound follows Helix's ``global_maxflow_scheduler`` idea: model the
serving pipeline as a single-commodity flow network in **requests/second**
— source → per-unit compute capacity → per-unit NIC egress (link bytes/s ÷
expected bytes/request) → fabric → aggregate decode ingress → sink — and
take the max-flow. A min-cut may mix compute and network edges, which is
exactly what makes the bound tighter than min(compute, network) computed
separately per resource class. Two network readings are reported:

  * **fixed-route** (:func:`fixed_route_rate`): expected per-request bytes
    on each *concrete directed link* under the actual emission + routing
    rules (replayed by the caller), ceiling = min over links of
    capacity/bytes. This is the ceiling *given* the deployed placement.
  * **routing-free** (:func:`disagg_bound` over :class:`FlowGraph`): the
    Dinic bound with placement freedom — an upper bound on any router.

The **attainment ceiling** at arrival rate ``λ`` combines the throughput
bound ``R*`` with per-request feasibility: a request whose contention-free
ideal TTFT already exceeds its deadline budget is unservable by *any*
schedule, so ``ceiling(λ) = feasible_frac × min(1, R*/λ)``.

Deterministic throughout (plain BFS/DFS Dinic, no RNG), control-plane only
(no JAX). The bound is optimistic by construction — deferrable stages (WB)
and perfectly-affine reuse fetches (S1) are excluded from demand — so every
measured attainment must land at or below it.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

__all__ = ["Dinic", "FlowGraph", "fixed_route_rate", "disagg_bound",
           "attainment_ceiling"]

_INF = float("inf")
_EPS = 1e-12


class Dinic:
    """Deterministic Dinic max-flow on float capacities.

    Standard level-graph BFS + blocking-flow DFS; edges are visited in
    insertion order, so the flow value (and the full residual state) is a
    pure function of the construction sequence."""

    def __init__(self, n: int = 0):
        self.n = n
        # edge i: (to, cap); edge i^1 is its reverse
        self._to: List[int] = []
        self._cap: List[float] = []
        self._adj: List[List[int]] = [[] for _ in range(n)]

    def add_node(self) -> int:
        self._adj.append([])
        self.n += 1
        return self.n - 1

    def add_edge(self, u: int, v: int, cap: float) -> int:
        if cap < 0:
            raise ValueError(f"negative capacity {cap} on edge {u}->{v}")
        eid = len(self._to)
        self._to.extend((v, u))
        self._cap.extend((cap, 0.0))
        self._adj[u].append(eid)
        self._adj[v].append(eid + 1)
        return eid

    def _levels(self, s: int, t: int) -> Optional[List[int]]:
        level = [-1] * self.n
        level[s] = 0
        q = deque([s])
        while q:
            u = q.popleft()
            for eid in self._adj[u]:
                v = self._to[eid]
                if level[v] < 0 and self._cap[eid] > _EPS:
                    level[v] = level[u] + 1
                    q.append(v)
        return level if level[t] >= 0 else None

    def _push(self, u: int, t: int, f: float, level: List[int],
              it: List[int]) -> float:
        if u == t:
            return f
        while it[u] < len(self._adj[u]):
            eid = self._adj[u][it[u]]
            v = self._to[eid]
            if self._cap[eid] > _EPS and level[v] == level[u] + 1:
                d = self._push(v, t, min(f, self._cap[eid]), level, it)
                if d > _EPS:
                    self._cap[eid] -= d
                    self._cap[eid ^ 1] += d
                    return d
            it[u] += 1
        return 0.0

    def max_flow(self, s: int, t: int) -> float:
        if s == t:
            return _INF
        total = 0.0
        while True:
            level = self._levels(s, t)
            if level is None:
                return total
            it = [0] * self.n
            while True:
                f = self._push(s, t, _INF, level, it)
                if f <= _EPS:
                    break
                if f == _INF:
                    return _INF        # an unbounded s->t path exists
                total += f


class FlowGraph:
    """Named-node convenience wrapper over :class:`Dinic`.

    Node ids are assigned in first-mention order, so graphs built by the
    same construction sequence are identical — determinism for free."""

    def __init__(self) -> None:
        self._dinic = Dinic()
        self._ids: Dict[str, int] = {}

    def node(self, name: str) -> int:
        nid = self._ids.get(name)
        if nid is None:
            nid = self._ids[name] = self._dinic.add_node()
        return nid

    def edge(self, a: str, b: str, cap: float) -> None:
        self._dinic.add_edge(self.node(a), self.node(b), cap)

    def max_flow(self, s: str = "S", t: str = "T") -> float:
        return self._dinic.max_flow(self.node(s), self.node(t))


def fixed_route_rate(link_bytes: Mapping[int, float],
                     capacity: Sequence[float],
                     ) -> Tuple[float, Optional[int]]:
    """Fixed-route throughput ceiling: ``min over links of capacity[l] /
    bytes-per-request[l]`` (requests/second), plus the arg-min link.

    ``link_bytes`` maps directed link id → *expected bytes one request puts
    on that link* under the deployed emission/routing rules (the caller
    replays the emitter to measure this). Links a request never touches are
    simply absent. Returns ``(inf, None)`` when there is no demand."""
    best, best_lid = _INF, None
    for lid, b in link_bytes.items():
        if b <= 0.0:
            continue
        r = capacity[lid] / b
        if r < best:
            best, best_lid = r, lid
    return best, best_lid


def disagg_bound(unit_rates: Sequence[float],
                 unit_out_caps: Sequence[float],
                 out_bytes: float,
                 decode_in_caps: Sequence[float],
                 in_bytes: float) -> float:
    """Routing-free max-flow bound for the disaggregated prefill→decode
    pipeline, in requests/second.

    ``S → unit_u (compute) → NIC_u (egress) → fabric → decode ingress
    (aggregate) → T``: ``unit_rates[u]`` is unit ``u``'s compute throughput
    (req/s), ``unit_out_caps[u]`` its total NIC egress (bytes/s),
    ``out_bytes``/``in_bytes`` the mean per-request bytes leaving a prefill
    unit / entering the decode tier. Decode ingress is aggregated (one
    edge: Σ caps ÷ bytes) — placement freedom on both sides, so the value
    upper-bounds any concrete router."""
    g = FlowGraph()
    for u, r in enumerate(unit_rates):
        g.edge("S", f"u{u}", r)
        g.edge(f"u{u}", f"n{u}",
               unit_out_caps[u] / out_bytes if out_bytes > 0.0 else _INF)
        g.edge(f"n{u}", "X", _INF)
    agg = sum(decode_in_caps)
    g.edge("X", "D", agg / in_bytes if in_bytes > 0.0 else _INF)
    g.edge("D", "T", _INF)
    return g.max_flow("S", "T")


def attainment_ceiling(rate: float, r_star: float,
                       feasible_frac: float = 1.0) -> float:
    """SLO-attainment ceiling at arrival rate ``rate`` given throughput
    bound ``r_star`` and the fraction of requests whose contention-free
    ideal TTFT fits inside their deadline budget. No scheduler can serve
    more than ``min(1, R*/λ)`` of the offered load, and of the served
    share at most ``feasible_frac`` can make its deadline."""
    if rate <= 0.0:
        return feasible_frac
    return feasible_frac * min(1.0, r_star / rate)
