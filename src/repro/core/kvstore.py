"""KV-reuse plane — a shared tiered prefix store with live hits (§3.1 S1).

Until this module existed the repo *faked* the paper's first stage: traces
pre-sampled a ``reuse_len`` and a static hash picked the owner unit, so a
cache hit never depended on what was actually resident anywhere and Stage-1
traffic never competed with the writebacks that create reusable KV in the
first place. This module is the real thing, following the production-stack
direction of KV-cache-aware routing over a shared LMCache-style store:

  * **Block-granular chain index.** A request's reusable prefix is a chain
    of fixed-size token blocks (``KVStoreSpec.block_tokens``). Chains are
    hierarchical — requests sharing an ancestor share the chain's leading
    blocks — so *partial-prefix* hits exist. Keys are opaque hashables:
    the simulator derives ``(node, j)`` pairs from trace prefix chains
    (:func:`chain_keys`), the serving path content-hashes real tokens
    (:func:`content_chain`); the store never needs to know which.
  * **Multi-tier placement.** Each :class:`TierSpec` is either ``unit``
    scoped (one location per prefill unit: endpoint HBM, host DRAM) or
    ``pooled`` (one shared remote store backed by dedicated fabric
    endpoints). Tiers carry a per-location byte capacity and a fetch
    bandwidth; fetches from a tier ride normal fluid-net flows whose
    ``tier_cap`` bounds their rate at the tier's read path.
  * **LRU + size-aware eviction.** Insertion over capacity evicts the
    least-recently-used *unpinned* blocks until the new block fits. Blocks
    are uniform-size (block granularity), so the size-aware tie-break
    degenerates to count — eviction cost is exact, not approximate.
    Blocks pinned by an in-flight Stage-1 fetch or writeback are never
    evicted from under the transfer.
  * **Live hit resolution at route time.** The router plane's default
    ``kv_affinity`` policy (``repro.core.router``) scores units by
    hit-weighted affinity vs. backlog (the same formula both hosts used
    for the static oracle) and then :meth:`KVStore.resolve` builds a
    per-tier, per-owner **block plan** against the store's state *now* —
    the ``StageEmitter`` turns each plan segment into per-layer-group
    Stage-1 flows from that segment's source endpoints, so S1 becomes
    multi-source (several owners/tiers at different bandwidths).
  * **Writeback flows (Stage ``WB``).** When a request's prefill completes
    the runtime admits its chain: blocks land in the producing unit's HBM
    tier immediately and replication flows toward every ``writeback`` tier
    enter the FluidNet with *loose derived deadlines*
    (``wb_deadline_scale`` x the tier-bandwidth transfer time). The MFS
    arbiter holds WB in an RMLQ band below D2D and bars it from the
    level-1 critical reservation; the stage-agnostic baselines see WB
    through their generic rules (EDF chases the explicit deadline, Karuna
    reserves minimal rate, FairShare splits evenly) and pay for it on the
    contended links — which is exactly the stage-diverse contention the
    paper's scheduler exists to arbitrate.

Control-plane only (numpy + hashlib, no JAX), host-agnostic like the rest
of ``repro.core``: ``ClusterSim`` and ``DisaggServer`` attach one store to
the shared runtime, whose router plane scores and resolves against it.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Hashable, List, Optional, Sequence, Set, Tuple

import numpy as np

from .msflow import Flow, Stage, new_flow_id

__all__ = ["TierSpec", "KVStoreSpec", "HitSegment", "HitPlan", "KVStore",
           "kv_route", "chain_keys", "content_chain"]


@dataclass(frozen=True)
class TierSpec:
    """One storage tier of the KV-reuse plane.

    ``scope="unit"`` places one location per prefill unit (endpoint HBM,
    host DRAM); ``scope="pooled"`` is one shared remote location backed by
    the store's dedicated fabric endpoints. ``capacity`` is bytes *per
    location*; ``fetch_bw`` (0 = uncapped) bounds each fetch/writeback
    flow's rate at the tier's read/write path via ``Flow.tier_cap``.
    ``writeback=True`` tiers receive Stage-``WB`` replication flows on
    prefill completion.
    """

    name: str
    capacity: float
    fetch_bw: float = 0.0
    scope: str = "unit"            # unit | pooled
    writeback: bool = False


#: default 3-tier layout; capacities are deliberately modest so sweeps see
#: capacity-bounded eviction (override per experiment)
_DEFAULT_TIERS = (
    TierSpec("hbm", capacity=4e9, fetch_bw=0.0, scope="unit"),
    TierSpec("dram", capacity=32e9, fetch_bw=30e9, scope="unit",
             writeback=True),
    TierSpec("remote", capacity=256e9, fetch_bw=24e9, scope="pooled",
             writeback=True),
)


@dataclass(frozen=True)
class KVStoreSpec:
    """KV-reuse plane configuration attached to a cluster/server spec."""

    block_tokens: int = 256        # hit/placement granularity (tokens)
    tiers: Tuple[TierSpec, ...] = _DEFAULT_TIERS
    pooled_nodes: int = 1          # fabric endpoints backing the pooled tier
    wb_deadline_scale: float = 8.0  # WB deadline = now + scale x ideal xfer
    # --- popularity-driven hot-block replication (0 = off) ---
    # A block resolved at least ``hot_threshold`` times is "hot": admission
    # and WB completion push copies of it toward additional units' DRAM
    # (the first unit-scoped writeback tier) until ``hot_copies`` units
    # hold one locally — production-stack-style prefetch that spreads the
    # Zipf victim-unit Stage-1 concentration before demand arrives.
    hot_threshold: int = 0
    hot_copies: int = 2
    # Exponential half-life (virtual-clock seconds) of the per-block resolve
    # popularity: a block's count halves every ``hot_halflife`` seconds of
    # not being resolved, so replication chases *current* popularity instead
    # of all-time totals (yesterday's hot prefixes cool off). 0 = no decay,
    # bit-identical to the pre-decay counters.
    hot_halflife: float = 0.0

    def __post_init__(self):
        if not self.tiers or self.tiers[0].scope != "unit":
            raise ValueError("tiers[0] must be the unit-scoped origin tier "
                             "(endpoint HBM) — prefill output lands there")
        if sum(1 for t in self.tiers if t.scope == "pooled") > 1:
            raise ValueError("at most one pooled tier is supported")

    def pooled_tier(self) -> Optional[TierSpec]:
        for t in self.tiers:
            if t.scope == "pooled":
                return t
        return None

    def n_store_nodes(self) -> int:
        return self.pooled_nodes if self.pooled_tier() is not None else 0


@dataclass(frozen=True)
class HitSegment:
    """A contiguous run of hit blocks sharing one (tier, owner) source."""

    tier: str
    tier_idx: int
    loc: int                       # owner unit (-1 = pooled)
    tokens: int
    src_eps: Tuple[int, ...]       # endpoints the fetch flows leave from
    tier_cap: Optional[float]      # per-flow fetch ceiling (None = uncapped)


@dataclass
class HitPlan:
    """Per-tier, per-owner block plan for one request's Stage-1 fetches."""

    tokens: int = 0
    segments: Tuple[HitSegment, ...] = ()

    def tier_tokens(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for s in self.segments:
            out[s.tier] = out.get(s.tier, 0) + s.tokens
        return out


# ---------------------------------------------------------------- chain keys
def chain_keys(prefix_chain: Sequence[Tuple[int, int]],
               block_tokens: int) -> Tuple[Hashable, ...]:
    """Flatten a trace prefix chain — ``((node_id, tokens), ...)`` — into
    block keys. Each node contributes its leading full blocks only, so two
    chains sharing ancestors share exactly the ancestors' block keys.
    Non-leaf node spans (``WorkloadSpec.chain_node_tokens``) should be a
    multiple of ``block_tokens`` so no ancestor tokens fall between
    blocks; only the leaf's trailing partial block is dropped."""
    out: List[Hashable] = []
    for node, tokens in prefix_chain:
        for j in range(int(tokens) // block_tokens):
            out.append((int(node), j))
    return tuple(out)


def content_chain(tokens: np.ndarray,
                  block_tokens: int) -> Tuple[Hashable, ...]:
    """Content-addressed block chain over real tokens (serving path).

    An incremental hash chain at block granularity — block ``i``'s key
    commits to every token before it, so identical leading blocks hash to
    identical keys across requests (hot prefixes dedupe). The chain covers
    at most ``len(tokens) - 1`` tokens: at least one suffix token must
    always be computed, never reused.
    """
    tokens = np.asarray(tokens)
    usable = max(0, len(tokens) - 1)
    out: List[Hashable] = []
    h = hashlib.sha256()
    for i in range(usable // block_tokens):
        h.update(np.ascontiguousarray(
            tokens[i * block_tokens:(i + 1) * block_tokens],
            dtype=np.int32).tobytes())
        out.append(h.digest())
    return tuple(out)


class KVStore:
    """The shared tiered prefix store (see module docstring).

    The store is pure bookkeeping over opaque block keys: residency per
    (tier, location), LRU order, pins, and in-flight writebacks. All byte
    sizing comes from ``bytes_per_token`` (the host's analytic
    ``StageProfile.kv_bytes_per_token()``), so simulation and serving
    account identically.
    """

    def __init__(self, spec: KVStoreSpec, bytes_per_token: float,
                 unit_eps: Sequence[Sequence[int]],
                 store_eps: Sequence[int] = (), *, nic_bw: float = 12.5e9):
        spec.pooled_tier()             # validates via __post_init__ already
        if spec.pooled_tier() is not None and not store_eps:
            raise ValueError("a pooled tier needs dedicated store endpoints")
        self.spec = spec
        self.bytes_per_token = float(bytes_per_token)
        self.block_bytes = spec.block_tokens * self.bytes_per_token
        self.unit_eps = [list(e) for e in unit_eps]
        self.store_eps = list(store_eps)
        self.nic_bw = nic_bw

        #: key -> set of (tier_idx, loc) placements holding a copy
        self.blocks: Dict[Hashable, Set[Tuple[int, int]]] = {}
        #: (tier_idx, loc) -> LRU-ordered resident keys (oldest first)
        self._lru: Dict[Tuple[int, int], OrderedDict] = {}
        self._used: Dict[Tuple[int, int], float] = {}
        self._pins: Dict[Hashable, int] = {}
        self._rid_pins: Dict[int, List[Hashable]] = {}
        self._chain_of: Dict[int, Tuple[Hashable, ...]] = {}
        #: fid -> (keys, tier_idx, loc) for in-flight writebacks
        self._wb: Dict[int, Tuple[Tuple[Hashable, ...], int, int]] = {}
        self._wb_keys: Set[Tuple[Hashable, int, int]] = set()
        #: per-block resolve popularity driving hot replication, stored as
        #: (EWMA count, last-update time) so decay is applied lazily
        self._pop: Dict[Hashable, Tuple[float, float]] = {}
        #: replication target: the first unit-scoped writeback tier (DRAM)
        self._hot_tier: Optional[int] = next(
            (i for i, t in enumerate(spec.tiers)
             if t.scope == "unit" and t.writeback), None)

        self.stats: Dict[str, float] = {
            "lookups": 0, "hits": 0, "hit_tokens": 0, "lookup_tokens": 0,
            "admitted_blocks": 0, "evictions": 0, "failed_inserts": 0,
            "wb_flows": 0, "wb_bytes": 0.0, "wb_done": 0,
            "hot_push_flows": 0, "hot_push_bytes": 0.0,
        }
        for t in spec.tiers:
            self.stats[f"hit_tokens_{t.name}"] = 0
        # contended-link class accounting (sampled by the runtime's tick)
        self._watched: Tuple[int, ...] = tuple(
            l for ep in ([e for eps in self.unit_eps for e in eps]
                         + self.store_eps)
            for l in (2 * ep, 2 * ep + 1))
        self._contended: Dict[str, float] = {}
        self._last_sample: Optional[float] = None

    # ------------------------------------------------------------- placement
    def _tl(self, tier_idx: int, loc: int) -> Tuple[int, int]:
        key = (tier_idx, loc)
        if key not in self._lru:
            self._lru[key] = OrderedDict()
            self._used[key] = 0.0
        return key

    def _rank(self, tl: Tuple[int, int], unit: int) -> Tuple[int, int]:
        """Placement preference for a request served on ``unit``: local
        copies first (any tier beats a network fetch), then tier order."""
        tier_idx, loc = tl
        tier = self.spec.tiers[tier_idx]
        local = 0 if (tier.scope == "unit" and loc == unit) else 1
        return (local, tier_idx)

    def _touch(self, key: Hashable, tl: Tuple[int, int]) -> None:
        lru = self._lru.get(tl)
        if lru is not None and key in lru:
            lru.move_to_end(key)

    def _insert(self, key: Hashable, tier_idx: int, loc: int) -> bool:
        """Place a copy of ``key`` in (tier, loc), evicting LRU unpinned
        blocks until it fits. Returns False if capacity cannot be made
        (every resident block is pinned by an in-flight transfer)."""
        tl = self._tl(tier_idx, loc)
        lru = self._lru[tl]
        if key in lru:
            lru.move_to_end(key)
            return True
        cap = self.spec.tiers[tier_idx].capacity
        if cap > 0:
            if self.block_bytes > cap:
                self.stats["failed_inserts"] += 1
                return False
            while self._used[tl] + self.block_bytes > cap:
                victim = next((k for k in lru if not self._pins.get(k)), None)
                if victim is None:
                    self.stats["failed_inserts"] += 1
                    return False
                del lru[victim]
                self._used[tl] -= self.block_bytes
                pls = self.blocks.get(victim)
                if pls is not None:
                    pls.discard(tl)
                    if not pls:
                        del self.blocks[victim]
                self.stats["evictions"] += 1
        lru[key] = True
        self._used[tl] += self.block_bytes
        self.blocks.setdefault(key, set()).add(tl)
        self.stats["admitted_blocks"] += 1
        return True

    def _pin(self, key: Hashable, rid: Optional[int] = None) -> None:
        self._pins[key] = self._pins.get(key, 0) + 1
        if rid is not None:
            self._rid_pins.setdefault(rid, []).append(key)

    def _unpin(self, key: Hashable) -> None:
        n = self._pins.get(key, 0) - 1
        if n > 0:
            self._pins[key] = n
        else:
            self._pins.pop(key, None)

    # ----------------------------------------------------------- popularity
    def _pop_value(self, key: Hashable, now: float) -> float:
        """Current (decayed) popularity of a block. With ``hot_halflife``
        set, the recorded count halves per elapsed half-life since its last
        update (lazy EWMA — nothing scans the whole table); 0 keeps the
        raw lifetime count."""
        ent = self._pop.get(key)
        if ent is None:
            return 0.0
        val, ts = ent
        hl = self.spec.hot_halflife
        if hl > 0 and now > ts:
            val *= 0.5 ** ((now - ts) / hl)
        return val

    def _bump_pop(self, key: Hashable, now: float) -> None:
        self._pop[key] = (self._pop_value(key, now) + 1.0, now)

    # ------------------------------------------------------------ resolution
    def peek_affinity(self, keys: Sequence[Hashable], max_tokens: int,
                      n_units: int) -> List[int]:
        """Per-unit locally-resident tokens along the chain's leading hit
        run (read-only: no LRU touch, no pins) — the routing affinity."""
        bt = self.spec.block_tokens
        aff = [0] * n_units
        for key in keys[:max(0, max_tokens) // bt]:
            pls = self.blocks.get(key)
            if not pls:
                break
            # one credit per block per unit, however many local tiers hold
            # a copy — affinity measures resident tokens, not copies
            units = {loc for tier_idx, loc in pls
                     if self.spec.tiers[tier_idx].scope == "unit"
                     and 0 <= loc < n_units}
            for u in units:
                aff[u] += bt
        return aff

    def resolve(self, keys: Sequence[Hashable], max_tokens: int, unit: int,
                rid: int, now: float = 0.0) -> HitPlan:
        """Longest resident chain prefix as a per-tier/per-owner block plan.

        Resolution happens against live state *now*: the hit walks leading
        blocks while resident, capped at ``max_tokens`` (callers pass
        ``prompt_len - 1`` so at least one suffix token is always
        computed). Chosen placements are LRU-touched and pinned for ``rid``
        until admission (:meth:`admit`) or :meth:`release`.
        """
        keys = tuple(keys)
        self._chain_of[rid] = keys
        bt = self.spec.block_tokens
        self.stats["lookups"] += 1
        self.stats["lookup_tokens"] += len(keys) * bt
        runs: List[List] = []          # [tier_idx, loc, n_blocks]
        tokens = 0
        for key in keys[:max(0, max_tokens) // bt]:
            pls = self.blocks.get(key)
            if not pls:
                break
            self._bump_pop(key, now)                     # replication signal
            tl = min(pls, key=lambda t: self._rank(t, unit))
            self._touch(key, tl)
            self._pin(key, rid)
            if runs and runs[-1][0] == tl[0] and runs[-1][1] == tl[1]:
                runs[-1][2] += 1
            else:
                runs.append([tl[0], tl[1], 1])
            tokens += bt
        if tokens:
            self.stats["hits"] += 1
            self.stats["hit_tokens"] += tokens
        segs = []
        for tier_idx, loc, n_blocks in runs:
            tier = self.spec.tiers[tier_idx]
            src_eps = tuple(self.store_eps) if tier.scope == "pooled" \
                else tuple(self.unit_eps[loc])
            segs.append(HitSegment(
                tier=tier.name, tier_idx=tier_idx, loc=loc,
                tokens=n_blocks * bt, src_eps=src_eps,
                tier_cap=tier.fetch_bw if tier.fetch_bw > 0 else None))
            self.stats[f"hit_tokens_{tier.name}"] += n_blocks * bt
        return HitPlan(tokens=tokens, segments=tuple(segs))

    def release(self, rid: int) -> None:
        """Drop every pin ``rid`` holds (prefill finished, request pruned
        away, or its decode session was evicted — the blocks themselves
        stay resident and reusable)."""
        for key in self._rid_pins.pop(rid, ()):
            self._unpin(key)
        self._chain_of.pop(rid, None)

    # -------------------------------------------------------------- admission
    def admit(self, item: Any, now: float,
              keep_pins: bool = False) -> List[Flow]:
        """Admission on prefill completion: the request's chain blocks are
        now materialised on the producing unit, so they enter the origin
        (HBM) tier immediately and a Stage-``WB`` replication flow is
        emitted toward every ``writeback`` tier that lacks a copy. WB
        deadlines are loose and *derived*: ``wb_deadline_scale`` times the
        tier-bandwidth transfer time — late enough that MFS can defer them
        below D2D, early enough that EDF-style policies chase them.

        ``keep_pins=True`` (set by the runtime when a decode plane is
        attached) carries the hit pins into the decode phase — the live
        session still references its prefix blocks, so eviction must not
        reclaim them until the decode plane releases the request
        (:meth:`release` on session finish/eviction)."""
        rid = item.rid
        keys = self._chain_of.pop(rid, ())
        if not keep_pins:
            for key in self._rid_pins.pop(rid, ()):
                self._unpin(key)
        if not keys:
            return []
        u = item.unit
        for key in keys:
            self._insert(key, 0, u)
        flows: List[Flow] = []
        for tier_idx, tier in enumerate(self.spec.tiers):
            if not tier.writeback:
                continue
            loc = u if tier.scope == "unit" else -1
            new = tuple(k for k in keys
                        if (tier_idx, loc) not in self.blocks.get(k, ())
                        and (k, tier_idx, loc) not in self._wb_keys)
            if not new:
                continue
            for k in new:
                self._pin(k)
                self._wb_keys.add((k, tier_idx, loc))
            size = len(new) * self.block_bytes
            ueps = self.unit_eps[u]
            src = ueps[rid % len(ueps)]
            dst = src if tier.scope == "unit" \
                else self.store_eps[rid % len(self.store_eps)]
            ref_bw = tier.fetch_bw if tier.fetch_bw > 0 else self.nic_bw
            f = Flow(new_flow_id(), rid, u, Stage.WB, size, src=src, dst=dst,
                     target_layer=0, n_layers=1,
                     deadline=now + self.spec.wb_deadline_scale
                     * size / ref_bw)
            f.tier_cap = tier.fetch_bw if tier.fetch_bw > 0 else None
            self._wb[f.fid] = (new, tier_idx, loc)
            self.stats["wb_flows"] += 1
            self.stats["wb_bytes"] += size
            flows.append(f)
        flows.extend(self._replicate_hot(keys, u, rid, now))
        return flows

    def on_wb_done(self, flow: Flow) -> List[Flow]:
        """A writeback landed: its blocks become resident in the target
        tier (evicting LRU blocks there as needed) and are unpinned.
        Returns follow-on hot-block replication flows (empty unless
        ``hot_threshold`` is set and the landed blocks are hot) for the
        runtime to submit."""
        entry = self._wb.pop(flow.fid, None)
        if entry is None:
            return []
        keys, tier_idx, loc = entry
        for k in keys:
            self._wb_keys.discard((k, tier_idx, loc))
            self._unpin(k)
            self._insert(k, tier_idx, loc)
        self.stats["wb_done"] += 1
        src_unit = loc if (0 <= loc < len(self.unit_eps)
                           and self.spec.tiers[tier_idx].scope == "unit") \
            else flow.unit
        return self._replicate_hot(keys, src_unit, flow.rid,
                                   flow.created if flow.finished is None
                                   else flow.finished)

    # ---------------------------------------------------- hot replication
    def _units_with_copy(self, key: Hashable) -> Set[int]:
        return {loc for tier_idx, loc in self.blocks.get(key, ())
                if self.spec.tiers[tier_idx].scope == "unit"}

    def _replicate_hot(self, keys: Sequence[Hashable], src_unit: int,
                       rid: int, now: float) -> List[Flow]:
        """Popularity-driven push of hot chain blocks toward more units'
        DRAM: every key resolved ≥ ``hot_threshold`` times gets copies
        pushed (one Stage-``WB`` flow per target unit, loose derived
        deadline like any writeback) until ``hot_copies`` units hold one
        locally — the victim unit stops being every sibling request's only
        Stage-1 source."""
        spec = self.spec
        tier_idx = self._hot_tier
        if spec.hot_threshold <= 0 or tier_idx is None \
                or not (0 <= src_unit < len(self.unit_eps)):
            return []
        tier = spec.tiers[tier_idx]
        per_unit: Dict[int, List[Hashable]] = {}
        for k in keys:
            if self._pop_value(k, now) < spec.hot_threshold:
                continue
            holders = self._units_with_copy(k)
            if src_unit not in holders:
                continue                     # push only what we can source
            # in-flight pushes count toward the copy target, or concurrent
            # hot admissions would overshoot hot_copies while one lands
            inflight = {u for u in range(len(self.unit_eps))
                        if (k, tier_idx, u) in self._wb_keys}
            want = spec.hot_copies - len(holders | inflight)
            if want <= 0:
                continue
            # deterministic target order: walk units from src_unit + 1
            for off in range(1, len(self.unit_eps)):
                if want <= 0:
                    break
                u = (src_unit + off) % len(self.unit_eps)
                if u in holders or u in inflight:
                    continue
                per_unit.setdefault(u, []).append(k)
                want -= 1
        flows: List[Flow] = []
        for u, ks in sorted(per_unit.items()):
            for k in ks:
                self._pin(k)
                self._wb_keys.add((k, tier_idx, u))
            size = len(ks) * self.block_bytes
            src = self.unit_eps[src_unit][rid % len(self.unit_eps[src_unit])]
            dst = self.unit_eps[u][rid % len(self.unit_eps[u])]
            ref_bw = tier.fetch_bw if tier.fetch_bw > 0 else self.nic_bw
            f = Flow(new_flow_id(), rid, src_unit, Stage.WB, size,
                     src=src, dst=dst, target_layer=0, n_layers=1,
                     deadline=now + spec.wb_deadline_scale * size / ref_bw)
            f.tier_cap = tier.fetch_bw if tier.fetch_bw > 0 else None
            self._wb[f.fid] = (tuple(ks), tier_idx, u)
            self.stats["wb_flows"] += 1
            self.stats["wb_bytes"] += size
            self.stats["hot_push_flows"] += 1
            self.stats["hot_push_bytes"] += size
            flows.append(f)
        return flows

    # ------------------------------------------------------------ calibration
    def steady_state_reuse(self, entries: Sequence[Tuple[Sequence[Hashable],
                                                         int]]) -> List[int]:
        """Expected per-request hit tokens at steady state, for store-aware
        SLO calibration. Replays the chains in arrival order through a
        *shadow* capacity-bounded LRU over the store's total byte capacity
        (unit tiers × locations + the pooled tier): a request's expected
        hit is its chain's leading run of previously-admitted, still-
        resident blocks. Read-only — live store state, pins and stats are
        untouched, and the replay ignores placement/tier detail (the base
        only needs the expected hit *length*)."""
        total_cap, uncapped = 0.0, False
        for t in self.spec.tiers:
            if t.capacity <= 0:
                uncapped = True
                continue
            total_cap += t.capacity * (len(self.unit_eps)
                                       if t.scope == "unit" else 1)
        max_blocks = float("inf") if uncapped \
            else int(total_cap // max(self.block_bytes, 1e-9))
        bt = self.spec.block_tokens
        seen: OrderedDict = OrderedDict()
        out: List[int] = []
        for keys, max_tokens in entries:
            hit = 0
            for key in keys[:max(0, max_tokens) // bt]:
                if key not in seen:
                    break
                hit += bt
                seen.move_to_end(key)
            out.append(min(hit, max(0, max_tokens)))
            for key in keys:
                if key in seen:
                    seen.move_to_end(key)
                else:
                    seen[key] = True
                    if len(seen) > max_blocks:
                        seen.popitem(last=False)
        return out

    # ----------------------------------------------------------- observation
    def sample_contention(self, net: Any, now: float,
                          max_dt: Optional[float] = None) -> None:
        """Accumulate per-stage allocated rate x time on *contended* watched
        links (NIC up/down of the prefill units and store nodes at >= 90%
        utilisation) — the basis for the WB-share-under-contention metric
        the benchmarks report. Called from the runtime's periodic tick;
        ``max_dt`` caps the credited interval so an idle gap (ticks stop
        when nothing is in flight) is never attributed to the traffic that
        happens to be allocated when sampling resumes."""
        if self._last_sample is None:
            self._last_sample = now
            return
        dt = now - self._last_sample
        self._last_sample = now
        if dt <= 0:
            return
        if max_dt is not None and dt > max_dt:
            dt = max_dt
        for lid in self._watched:
            cap = net.topo.capacity.get(lid)
            if not cap:
                continue
            if net._link_rate.get(lid, 0.0) < 0.9 * cap:
                continue
            for stage, rate in net.class_rates(lid).items():
                name = stage.name
                self._contended[name] = self._contended.get(name, 0.0) \
                    + rate * dt
        return

    def wb_share_contended(self) -> float:
        tot = sum(self._contended.values())
        return self._contended.get("WB", 0.0) / tot if tot > 0 else 0.0

    def resident_bytes(self, tier_name: Optional[str] = None) -> float:
        out = 0.0
        for (tier_idx, _), used in self._used.items():
            if tier_name is None \
                    or self.spec.tiers[tier_idx].name == tier_name:
                out += used
        return out

    def summary(self) -> Dict[str, float]:
        s = dict(self.stats)
        s["hit_rate_tokens"] = self.stats["hit_tokens"] \
            / max(self.stats["lookup_tokens"], 1)
        for t in self.spec.tiers:
            s[f"resident_bytes_{t.name}"] = self.resident_bytes(t.name)
        s["wb_inflight"] = len(self._wb)
        s["wb_share_contended"] = self.wb_share_contended()
        s["pinned_blocks"] = len(self._pins)
        return s


# ------------------------------------------------------------ shared routing
def kv_route(store: KVStore, keys: Sequence[Hashable], max_tokens: int,
             backlogs: Sequence[float], rid: int,
             now: float = 0.0) -> Tuple[int, HitPlan]:
    """Cache-aware routing: score every unit by hit-weighted affinity
    (tokens resident locally along the chain's leading run) against its
    token backlog — the same 2:1 weighting the static-oracle router used —
    then resolve the winner's block plan against live store state.

    Kept as a standalone helper for direct store-level callers and tests;
    the hosts now route through the pluggable router plane
    (``repro.core.router.KVAffinityRouter`` + the runtime's resolve step),
    which reproduces this function's store-op sequence exactly."""
    aff = store.peek_affinity(keys, max_tokens, len(backlogs))
    best, best_score = 0, -float("inf")
    for u in range(len(backlogs)):
        score = 2.0 * aff[u] - backlogs[u]
        if score > best_score:
            best, best_score = u, score
    plan = store.resolve(keys, max_tokens, best, rid, now=now)
    return best, plan
