"""Stage-emission layer of the shared MsFlow runtime (§3.1 + §5).

One implementation of the per-layer-group flow construction that both the
cluster simulator (``repro.simcluster``) and the real-JAX serving path
(``repro.serving``) drive through :class:`repro.core.runtime.MsFlowRuntime`:

  * Stage 1 — per-layer-group KV-reuse fetch flows from the prefix owner
    unit; the group-g slice must arrive before super-layer g computes.
  * Stage 2 — collective coflows per super-layer group: NIC-aggregated
    all-to-all for EP, ring KV exchange striped over TP endpoints for SP,
    scale-up all-reduce for TP. A coflow gates the next group's compute.
  * Stage 3 — P2D transfer of the group's produced KV toward the decode
    unit, carrying the explicit flow-level deadline derived from the
    request's TTFT deadline minus the remaining downstream work (§3.2).

The module is control-plane only (no JAX) and host-agnostic: all model math
comes from :class:`StageProfile`, an analytic latency/volume model over an
``ArchConfig`` + hardware profile, shared verbatim by simulation and
serving so both paths emit byte-identical stage sequences for matched
configurations (the pluggability claim of §5).

With a :class:`ChunkSpec` attached (Sarathi-style chunked prefill) every
stage is emitted per ``(group, chunk)`` instead of per group: Stage-1
fetches split at the chunk token budget, each chunk's collective gates the
next chunk, and chunk-*c* P2D overlaps chunk-*c+1* compute.
``chunk_tokens=0`` reproduces the group-granular emission bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .msflow import Coflow, Flow, Stage, new_flow_id

__all__ = [
    "ParallelismSpec",
    "GroupPlan",
    "ChunkSpec",
    "ChunkPlan",
    "StageProfile",
    "PrefillItem",
    "BatchState",
    "StageEmitter",
]


@dataclass(frozen=True)
class ParallelismSpec:
    """Parallelism of one prefill unit (one model replica).

    ``gpus`` is the number of NIC-attached endpoints the unit spans; the
    three modes reproduce the paper's Stage-2 traffic shapes (§6.1/§7).
    """

    mode: str = "ep"        # ep | sp | tp
    tp: int = 1
    ep: int = 1
    sp: int = 1

    @property
    def gpus(self) -> int:
        return self.tp * max(self.ep, 1) * max(self.sp, 1)


@dataclass(frozen=True)
class GroupPlan:
    """Partition of a model's L layers into G contiguous super-layers."""

    n_layers: int
    groups: Tuple[Tuple[int, ...], ...]

    @classmethod
    def build(cls, n_layers: int, n_groups: int) -> "GroupPlan":
        G = max(1, min(n_groups, n_layers))
        bounds = np.linspace(0, n_layers, G + 1).astype(int)
        return cls(n_layers=n_layers,
                   groups=tuple(tuple(range(bounds[g], bounds[g + 1]))
                                for g in range(G)))

    def __len__(self) -> int:
        return len(self.groups)

    def layers(self, g: int) -> Tuple[int, ...]:
        return self.groups[g]


@dataclass(frozen=True)
class ChunkSpec:
    """Sarathi-style chunked prefill configuration (``ClusterSpec.chunk`` /
    ``DisaggConfig.chunk``).

    ``chunk_tokens`` is the per-batch token budget of one compute chunk:
    each super-layer group's computation is split into sub-group chunks of
    at most that many *new* (non-reused) tokens, and Stage-1/2/3 emission
    happens per chunk — the chunk-*c* P2D overlaps chunk-*c+1* compute and
    the RLI/downstream estimate tightens to remaining-chunk compute.
    ``chunk_tokens=0`` (or a ``None`` spec) reproduces the legacy
    group-granular schedule bit-for-bit.
    """

    chunk_tokens: int = 2048


@dataclass(frozen=True)
class ChunkPlan:
    """Token-budgeted sub-group chunks of one prefill batch.

    The batch's *new* tokens (``max(1, n_tokens - reuse)`` per item, in item
    order) are cut every ``chunk_tokens`` tokens; a chunk may therefore span
    an item boundary and an item may span several chunks. The chunk axis is
    shared by every super-layer group — the runtime walks the
    ``(group, chunk)`` grid group-major, so chunk *c* of group *g* computes
    after chunk *c*'s collective of group *g-1* and after chunk *c-1* of
    group *g*.

    Per chunk the plan records, for every item, how many new tokens it
    contributes (``new_tokens``) and how many of its new tokens were already
    computed by earlier chunks (``prior_new`` — the attention-context
    offset). ``first_chunk``/``last_chunk`` give each item's chunk extent:
    the reused prefix KV ships with the first chunk's P2D (it is available
    as soon as the group's Stage-1 delivered) and the O(1) recurrent state
    with the last (it is final only at end of group).
    """

    chunk_tokens: int
    new_tokens: Tuple[Tuple[int, ...], ...]    # [chunk][item] -> new tokens
    prior_new: Tuple[Tuple[int, ...], ...]     # [chunk][item] -> done before
    first_chunk: Tuple[int, ...]               # [item] -> first chunk index
    last_chunk: Tuple[int, ...]                # [item] -> last chunk index

    @property
    def n_chunks(self) -> int:
        return len(self.new_tokens)

    @classmethod
    def build(cls, items: Sequence["PrefillItem"],
              chunk_tokens: int) -> Optional["ChunkPlan"]:
        if chunk_tokens <= 0:
            return None
        new = [max(1, it.n_tokens - it.reuse) for it in items]
        chunks: List[List[int]] = []
        priors: List[List[int]] = []
        done = [0] * len(items)
        left = list(new)
        while any(left):
            budget = chunk_tokens
            row = [0] * len(items)
            prior = list(done)
            for i, rem in enumerate(left):
                if budget <= 0:
                    break
                take = min(rem, budget)
                row[i] = take
                left[i] -= take
                done[i] += take
                budget -= take
            chunks.append(row)
            priors.append(prior)
        first = tuple(next(c for c, row in enumerate(chunks) if row[i] > 0)
                      for i in range(len(items)))
        last = tuple(max(c for c, row in enumerate(chunks) if row[i] > 0)
                     for i in range(len(items)))
        return cls(chunk_tokens=chunk_tokens,
                   new_tokens=tuple(tuple(r) for r in chunks),
                   prior_new=tuple(tuple(p) for p in priors),
                   first_chunk=first, last_chunk=last)

    def ship_tokens(self, item_idx: int, item: "PrefillItem",
                    c: int) -> int:
        """Prompt tokens whose KV ships with chunk ``c``'s P2D for one item:
        the chunk's new tokens, plus the reused prefix on the item's first
        chunk (totals telescope to ``item.n_tokens`` across chunks)."""
        t = self.new_tokens[c][item_idx]
        if t > 0 and c == self.first_chunk[item_idx]:
            t += item.n_tokens - max(1, item.n_tokens - item.reuse)
        return t


@dataclass
class PrefillItem:
    """One request as seen by the runtime: token counts + reuse + deadline.

    Hosts attach their own request object via ``payload`` (the simulator's
    trace ``Request``, the server's ``ServeRequest`` + prefix-index entry).
    """

    rid: int
    arrival: float
    n_tokens: int                      # prompt length
    reuse: int = 0                     # reused prefix tokens (Stage 1)
    owner_unit: int = 0                # unit owning the reused prefix
    # KV-reuse plane: per-tier/per-owner block plan resolved at route time
    # against live store state (repro.core.kvstore.HitPlan). When set it
    # supersedes the single-owner (reuse, owner_unit) pair and Stage-1
    # emission becomes multi-source.
    hit_plan: Any = None
    slo_scale: float = 0.0             # per-request SLO class scale (0 = use
    #                                    the pool default, then cluster-wide)
    slo_class: str = "standard"        # SLO class label (admission control
    #                                    sheds/defers only the sheddable ones)
    pool: str = ""                     # decode pool ("" = host/plane picks)
    out_tokens: int = 0                # output length (0 = decode plane samples)
    payload: Any = None
    # --- filled by the runtime ---
    unit: int = -1
    deferrals: int = 0                 # admission-control defer retries so far
    deadline: float = 0.0
    ideal_ttft: float = 0.0
    stalls: float = 0.0
    prefill_done: Optional[float] = None
    ttft: Optional[float] = None


@dataclass
class BatchState:
    """Lifecycle of one prefill batch on one unit."""

    bid: int
    unit: int
    items: List[PrefillItem]
    group_time: List[float]            # compute seconds per super-layer group
    started: float = 0.0
    cur_group: int = 0
    # chunked prefill: position on the (group, chunk) grid. With no plan the
    # chunk axis has length 1 and ``cur_chunk`` stays 0 (legacy schedule).
    cur_chunk: int = 0
    chunk_plan: Optional[ChunkPlan] = None
    chunk_time: List[List[float]] = field(default_factory=list)  # [group][chunk]
    phase: str = "wait_s1"             # wait_s1 | compute | wait_coll | drain
    stall_begin: Optional[float] = None
    s1_pending: Dict[int, Set[int]] = field(default_factory=dict)  # group -> fids
    coll: Optional[Coflow] = None
    coll_started: float = 0.0
    p2d_pending: Dict[int, Set[int]] = field(default_factory=dict)  # rid -> outstanding fids
    p2d_last: Dict[int, float] = field(default_factory=dict)        # rid -> latest P2D finish
    recompute_extra: float = 0.0       # legacy aggregate (kept for estimates)
    recomputed: Set[Tuple[int, int]] = field(default_factory=set)   # (rid, group)
    compute_done_at: Optional[float] = None

    @property
    def tokens(self) -> int:
        return sum(i.n_tokens for i in self.items)


class StageProfile:
    """Analytic model math shared by simulation and serving (§6.1).

    Derives compute latencies, per-group KV volumes, Stage-2 collective
    volumes and contention-free (ideal) TTFTs from an ``ArchConfig`` and a
    hardware profile. Instances are duck-typed over ``model`` (needs
    ``n_layers``/``kv_bytes_per_token_layer``/``flops_per_token``/
    ``params_active``/``state_bytes``/``is_moe_layer``/``top_k``/``d_model``)
    and ``hw`` (needs ``flops``/``mfu``/``nic_bw``) so repro.core stays free
    of upward imports.
    """

    def __init__(self, model: Any, hw: Any, par: ParallelismSpec,
                 plan: GroupPlan, kv_dtype_bytes: int = 2,
                 act_dtype_bytes: int = 2, gpus_per_server: int = 4):
        self.model = model
        self.hw = hw
        self.par = par
        self.plan = plan
        self.kv_dtype_bytes = kv_dtype_bytes
        self.act_dtype_bytes = act_dtype_bytes
        self.gpus_per_server = gpus_per_server

    # ------------------------------------------------------------ KV volumes
    def kv_bytes_group(self, g: int) -> float:
        """Per-token KV bytes produced by super-layer group ``g``."""
        m, b = self.model, self.kv_dtype_bytes
        return sum(m.kv_bytes_per_token_layer(b, l) for l in self.plan.layers(g))

    def state_bytes_group(self) -> float:
        """Per-request O(1) recurrent state shipped with each P2D group."""
        return self.model.state_bytes(self.kv_dtype_bytes) / len(self.plan)

    def kv_bytes_per_token(self) -> float:
        """Full-depth per-token KV bytes (D2D migrations move the whole
        context's KV, not one super-layer group's slice)."""
        return sum(self.kv_bytes_group(g) for g in range(len(self.plan)))

    # --------------------------------------------------------------- compute
    def group_compute_time(self, items: Sequence[PrefillItem], g: int) -> float:
        """Analytic compute latency of one super-layer group for a batch."""
        m, hw, par = self.model, self.hw, self.par
        L = m.n_layers
        flops = 0.0
        for it in items:
            new = max(1, it.n_tokens - it.reuse)
            ctx = it.reuse + new / 2.0
            flops += new * m.flops_per_token(ctx) / L * len(self.plan.layers(g))
        return flops / (par.gpus * hw.flops * hw.mfu)

    def chunk_compute_time(self, items: Sequence[PrefillItem],
                           plan: ChunkPlan, g: int, c: int) -> float:
        """Analytic compute latency of chunk ``c`` of super-layer group
        ``g``. Each item's chunk tokens attend over the reused prefix plus
        the new tokens earlier chunks already computed (midpoint context,
        as in :meth:`group_compute_time` — for context-linear FLOP models
        the per-chunk times sum to the group time up to rounding)."""
        m, hw, par = self.model, self.hw, self.par
        L = m.n_layers
        flops = 0.0
        for i, it in enumerate(items):
            n_c = plan.new_tokens[c][i]
            if n_c <= 0:
                continue
            ctx = it.reuse + plan.prior_new[c][i] + n_c / 2.0
            flops += n_c * m.flops_per_token(ctx) / L * len(self.plan.layers(g))
        return flops / (par.gpus * hw.flops * hw.mfu)

    def first_decode_time(self) -> float:
        m, hw, par = self.model, self.hw, self.par
        return 2.0 * m.params_active() / (par.gpus * hw.flops * hw.mfu * 0.3)

    def decode_step_time(self, n_seqs: int, mean_ctx: float) -> float:
        """One batched decode step on ONE decode endpoint: the larger of the
        compute time and the HBM time to stream the active weights plus the
        batch's KV (decode is memory-bound until the batch is deep)."""
        m, hw = self.model, self.hw
        flops_t = 2.0 * m.params_active() * max(n_seqs, 1) \
            / (hw.flops * hw.mfu)
        mem = m.params_active() * self.kv_dtype_bytes \
            + max(n_seqs, 1) * mean_ctx * self.kv_bytes_per_token()
        return max(flops_t, mem / (hw.hbm_bw * hw.hbm_eff))

    def decode_step_roofline(self, n_seqs: int, mean_ctx: float, *,
                             block_k: int = 256) -> float:
        """Kernel-calibrated counterpart of :meth:`decode_step_time`: the
        attention term comes from the *actual* decode kernel's tiling
        (``repro.kernels.decode_attention.decode_attention_cost`` — GQA
        cache layout, 128-lane head padding, ``block_k`` KV padding,
        compute-skipped tail blocks) instead of the smooth
        ``ctx x kv_bytes_per_token`` approximation, and the attention
        flops the analytic model drops are counted. The slow calibration
        test + the ``decode.roofline.*`` microbench row record the model
        error between the two."""
        from ..kernels.decode_attention import decode_attention_cost
        m, hw = self.model, self.hw
        n = max(n_seqs, 1)
        heads = getattr(m, "n_kv", 0) or getattr(m, "n_heads", 1)
        hd = getattr(m, "hd", 128)
        attn_layers = sum(1 for l in range(m.n_layers)
                          if getattr(m, "layer_kind", lambda _l: "attn")(l)
                          == "attn")
        fl, by = decode_attention_cost(n, heads, hd, int(max(mean_ctx, 1)),
                                       block_k=block_k,
                                       dtype_bytes=self.kv_dtype_bytes)
        flops_t = (2.0 * m.params_active() * n + attn_layers * fl) \
            / (hw.flops * hw.mfu)
        mem = m.params_active() * self.kv_dtype_bytes + attn_layers * by
        return max(flops_t, mem / (hw.hbm_bw * hw.hbm_eff))

    def recompute_time(self, reuse_tokens: int, frac: float, g: int) -> float:
        """Compute seconds to re-derive the fraction ``frac`` of a request's
        reused KV for group ``g`` that pruning left undelivered."""
        m, hw, par = self.model, self.hw, self.par
        nlayers = len(self.plan.layers(g))
        flops = frac * reuse_tokens * m.flops_per_token(reuse_tokens / 2) \
            / m.n_layers * nlayers
        return flops / (par.gpus * hw.flops * hw.mfu)

    # ------------------------------------------------------------ collectives
    def stage2_volume_per_ep(self, tokens: float, g: int) -> float:
        """Bytes leaving ONE endpoint for group g's collectives (network)."""
        m, par, d = self.model, self.par, self.act_dtype_bytes
        nlayers = len(self.plan.layers(g))
        if par.mode == "ep":
            moe_layers = sum(1 for l in self.plan.layers(g) if m.is_moe_layer(l))
            per_layer = 2.0 * (tokens / par.ep) * m.top_k * m.d_model * d
            return per_layer * moe_layers    # cross-fabric share applied by caller
        if par.mode == "sp":
            vol = 0.0
            for l in self.plan.layers(g):
                kvb = m.kv_bytes_per_token_layer(self.act_dtype_bytes, l)
                vol += (par.sp - 1) * (tokens / par.sp) * kvb
            return vol / par.tp              # striped across TP endpoints
        # tp: 2 all-reduce per layer, ring cost, scale-up only
        return 2.0 * 2.0 * (par.tp - 1) / par.tp * tokens * m.d_model * d * nlayers / par.tp

    # ----------------------------------------------------------- ideal TTFT
    def ideal_ttft(self, item: PrefillItem) -> float:
        """Low-load (contention-free) TTFT for SLO calibration (§6.1)."""
        par, hw = self.par, self.hw
        total = 0.0
        for g in range(len(self.plan)):
            total += self.group_compute_time([item], g)
            if par.mode == "ep":
                eps_per_server = min(self.gpus_per_server, par.gpus)
                cross = 1.0 - eps_per_server / max(par.gpus, 1)
                v = self.stage2_volume_per_ep(item.n_tokens - item.reuse, g) * cross
                total += v / hw.nic_bw
            elif par.mode == "sp":
                v = self.stage2_volume_per_ep(item.n_tokens, g)
                total += v / hw.nic_bw
        # stage-1 of group 0 cannot be hidden even without contention
        if item.reuse:
            total += item.reuse * self.kv_bytes_group(0) / hw.nic_bw
        # last group's P2D is never overlapped with compute
        total += item.n_tokens * self.kv_bytes_group(len(self.plan) - 1) / hw.nic_bw
        return total + self.first_decode_time()


class StageEmitter:
    """Builds the Stage-1/2/3 flow sets for a batch (§3.1).

    Pure flow construction: registers pending-set bookkeeping on the
    ``BatchState`` but never submits — the runtime owns submission, so the
    same emitter serves both the simulator and the real-JAX data plane.
    """

    def __init__(self, profile: StageProfile, unit_eps: Sequence[Sequence[int]],
                 decode_eps: Sequence[int], topo: Any,
                 pool_eps: Optional[Dict[str, Sequence[int]]] = None,
                 chunk_tokens: int = 0):
        self.profile = profile
        self.par = profile.par
        self.plan = profile.plan
        self.unit_eps = [list(e) for e in unit_eps]
        self.decode_eps = list(decode_eps)
        # named multi-decode pools: P2D targets the owning request's pool
        # slice; None keeps the single flat decode pool (identical emission)
        self.pool_eps = {k: list(v) for k, v in pool_eps.items()} \
            if pool_eps else None
        self.topo = topo
        # chunked prefill: Stage-1 fetches split at the chunk token budget
        # (finer promotion + per-chunk recompute on pruning), Stage-2/3
        # emitted per (group, chunk) via stage2_chunk/stage3_chunk. 0 keeps
        # the legacy group-granular emission bit-for-bit.
        self.chunk_tokens = chunk_tokens

    def _decode_eps_for(self, item: PrefillItem) -> List[int]:
        if self.pool_eps is not None:
            eps = self.pool_eps.get(item.pool)
            if eps:
                return eps
        return self.decode_eps

    # ----------------------------------------------------------- placement
    def rank_endpoint(self, bs: BatchState, item: PrefillItem, g: int) -> int:
        """Endpoint that owns ``item``'s activations for group g."""
        eps = self.unit_eps[bs.unit]
        if self.par.mode == "ep":
            idx = bs.items.index(item) % len(eps)
            return eps[idx]
        # sp / tp: stripe across endpoints by group for multi-NIC egress
        return eps[g % len(eps)]

    # -------------------------------------------------------------- stage 1
    def _s1_flows(self, bs: BatchState, item: PrefillItem, g: int,
                  tokens: int, src_eps: Sequence[int],
                  tier_cap: Optional[float], out: List[Flow]) -> None:
        """Emit group ``g``'s fetch flow(s) for ``tokens`` reused tokens
        sourced from ``src_eps`` (sp mode stripes the slice across the
        destination unit's endpoints, as for single-source fetches).

        With chunked prefill the fetch is cut at the chunk token budget:
        every chunk-of-reuse becomes its own flow, so the scheduler promotes
        pieces independently and pruning recomputes only the chunks that
        never arrived. All pieces still gate chunk 0 of group ``g`` —
        causal attention needs the whole prefix before the group's first
        new token computes."""
        G = len(self.plan)
        if tokens <= 0:
            return
        if self.chunk_tokens > 0:
            pieces = [self.chunk_tokens] * (tokens // self.chunk_tokens)
            if tokens % self.chunk_tokens:
                pieces.append(tokens % self.chunk_tokens)
        else:
            pieces = [tokens]
        for piece in pieces:
            size = piece * self.profile.kv_bytes_group(g)
            if size <= 0:
                return
            if self.par.mode == "sp":
                ueps = self.unit_eps[bs.unit]
                dsts = [ueps[(g + i) % len(ueps)] for i in range(self.par.sp)]
                sizes = [size / self.par.sp] * self.par.sp
            else:
                dsts = [self.rank_endpoint(bs, item, g)]
                sizes = [size]
            for dst, sz in zip(dsts, sizes):
                f = Flow(new_flow_id(), item.rid, bs.unit, Stage.KV_REUSE,
                         sz, src=src_eps[g % len(src_eps)], dst=dst,
                         target_layer=g, n_layers=G)
                f.tier_cap = tier_cap
                bs.s1_pending.setdefault(g, set()).add(f.fid)
                out.append(f)

    def stage1(self, bs: BatchState) -> List[Flow]:
        """Per-layer-group KV-reuse fetch flows.

        With a KV-store hit plan attached the fetch is **multi-source**:
        each plan segment (a run of blocks resident on one tier/owner)
        contributes its own per-group flows from that segment's source
        endpoints, rate-limited at the tier's fetch bandwidth. Without a
        plan, the legacy single-owner path fetches everything from
        ``item.owner_unit``.
        """
        G = len(self.plan)
        out: List[Flow] = []
        for item in bs.items:
            plan = item.hit_plan
            if plan is not None and getattr(plan, "segments", None):
                for seg in plan.segments:
                    if seg.tokens <= 0:
                        continue
                    for g in range(G):
                        self._s1_flows(bs, item, g, seg.tokens, seg.src_eps,
                                       seg.tier_cap, out)
                continue
            if item.reuse <= 0:
                continue
            src_eps = self.unit_eps[item.owner_unit]
            for g in range(G):
                self._s1_flows(bs, item, g, item.reuse, src_eps, None, out)
        return out

    # -------------------------------------------------------------- stage 2
    def stage2(self, bs: BatchState) -> Optional[Coflow]:
        """Collective coflow of the current group (gates the next group)."""
        tokens_new = sum(max(1, it.n_tokens - it.reuse) for it in bs.items)
        tokens_seq = sum(it.n_tokens for it in bs.items)
        return self._stage2(bs, bs.cur_group, tokens_new, tokens_seq)

    def stage2_chunk(self, bs: BatchState, g: int, c: int) -> Optional[Coflow]:
        """Collective coflow of chunk ``c`` of group ``g`` (gates chunk
        ``c+1``'s compute — each chunk's forward pass runs its own
        all-to-all / ring exchange over the chunk's tokens). Chunk volumes
        telescope to the legacy group totals: new tokens go to their chunk,
        the reused prefix share rides the owning item's first chunk."""
        plan = bs.chunk_plan
        tokens_new = sum(plan.new_tokens[c])
        tokens_seq = sum(plan.ship_tokens(i, it, c)
                         for i, it in enumerate(bs.items))
        return self._stage2(bs, g, tokens_new, tokens_seq)

    def _stage2(self, bs: BatchState, g: int, tokens: int,
                tokens_seq: int) -> Optional[Coflow]:
        par, profile = self.par, self.profile
        G = len(self.plan)
        eps = self.unit_eps[bs.unit]
        co = Coflow(cid=new_flow_id(), rid=bs.items[0].rid, unit=bs.unit,
                    stage=Stage.COLLECTIVE, layer=g)
        if par.mode == "ep":
            vol_per_ep = profile.stage2_volume_per_ep(tokens, g)
            if vol_per_ep <= 0:
                return None
            servers: Dict[int, List[int]] = {}
            for e in eps:
                servers.setdefault(self.topo.server_of(e), []).append(e)
            for e in eps:
                my_srv = self.topo.server_of(e)
                for srv, members in servers.items():
                    if srv == my_srv:
                        continue
                    dst = members[eps.index(e) % len(members)]
                    sz = vol_per_ep * len(members) / len(eps)
                    fl = Flow(new_flow_id(), co.rid, bs.unit, Stage.COLLECTIVE,
                              sz, src=e, dst=dst, target_layer=g, n_layers=G)
                    fl.coflow = co.cid
                    co.flows.append(fl)
        elif par.mode == "sp":
            vol = profile.stage2_volume_per_ep(tokens_seq, g)
            if vol <= 0:
                return None
            sp, tp = par.sp, par.tp
            for rank in range(sp):
                nxt_rank = (rank + 1) % sp
                for t in range(tp):
                    src = eps[rank * tp + t]
                    dst = eps[nxt_rank * tp + t]
                    fl = Flow(new_flow_id(), co.rid, bs.unit, Stage.COLLECTIVE,
                              vol, src=src, dst=dst, target_layer=g, n_layers=G)
                    fl.coflow = co.cid
                    co.flows.append(fl)
        else:   # tp: scale-up all-reduce flows between neighbouring endpoints
            vol = profile.stage2_volume_per_ep(tokens, g)
            if vol <= 0:
                return None
            for i, e in enumerate(eps):
                dst = eps[(i + 1) % len(eps)]
                if dst == e:
                    continue
                fl = Flow(new_flow_id(), co.rid, bs.unit, Stage.COLLECTIVE,
                          vol, src=e, dst=dst, target_layer=g, n_layers=G)
                fl.coflow = co.cid
                co.flows.append(fl)
        if not co.flows:
            return None
        return co

    # -------------------------------------------------------------- stage 3
    def stage3(self, bs: BatchState, g: int, t_first_decode: float) -> List[Flow]:
        """P2D flows for group g with the derived flow-level deadline."""
        G = len(self.plan)
        kvb = self.profile.kv_bytes_group(g)
        state_b = self.profile.state_bytes_group()
        out: List[Flow] = []
        for item in bs.items:
            size = item.n_tokens * kvb + state_b
            if size <= 0:
                continue
            deps = self._decode_eps_for(item)
            dst = deps[(item.rid + g) % len(deps)] \
                if deps else self.rank_endpoint(bs, item, g)
            # Flow-level deadline = TTFT deadline minus remaining downstream
            # work (the first decode step) — the paper's "global TTFT
            # materialises into an explicit flow-level bound" (§3.2).
            f = Flow(new_flow_id(), item.rid, bs.unit, Stage.P2D, size,
                     src=self.rank_endpoint(bs, item, g), dst=dst,
                     target_layer=g, n_layers=G,
                     deadline=item.deadline - t_first_decode)
            bs.p2d_pending[item.rid].add(f.fid)
            out.append(f)
        return out

    def stage3_chunk(self, bs: BatchState, g: int, c: int,
                     t_first_decode: float) -> List[Flow]:
        """P2D flows for chunk ``c`` of group ``g`` — the chunk's share of
        the group's produced KV leaves while later chunks still compute.

        Per item the chunk ships its new tokens' KV, plus the reused
        prefix's group-``g`` KV with the item's first chunk (available once
        the group's Stage-1 delivered) and the O(1) recurrent state with
        its last chunk (final only at end of group), so per-request totals
        and deadlines match :meth:`stage3` exactly. All of one item's
        chunks target the same decode endpoint — a request's group KV must
        land on one unit."""
        plan = bs.chunk_plan
        G = len(self.plan)
        kvb = self.profile.kv_bytes_group(g)
        state_b = self.profile.state_bytes_group()
        out: List[Flow] = []
        for i, item in enumerate(bs.items):
            size = plan.ship_tokens(i, item, c) * kvb
            if c == plan.last_chunk[i]:
                size += state_b
            if size <= 0:
                continue
            deps = self._decode_eps_for(item)
            dst = deps[(item.rid + g) % len(deps)] \
                if deps else self.rank_endpoint(bs, item, g)
            f = Flow(new_flow_id(), item.rid, bs.unit, Stage.P2D, size,
                     src=self.rank_endpoint(bs, item, g), dst=dst,
                     target_layer=g, n_layers=G,
                     deadline=item.deadline - t_first_decode)
            bs.p2d_pending[item.rid].add(f.fid)
            out.append(f)
        return out
