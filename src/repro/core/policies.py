"""Scheduling policies: the paper's baselines + shared interface.

A ``Policy`` observes scheduling events and (re)assigns, in place, each active
flow's ``priority_key`` (lexicographic, smaller = more urgent) and optional
``rate_cap``. The fluid network model (repro.netsim) then allocates bandwidth
by strict priority over keys with max-min fair sharing among equal keys,
honouring rate caps — exactly the "software strict-priority queues + limited
hardware classes" enforcement model of §5.

Baselines (§6.3):

  * FairShare — max-min fairness among all concurrent flows, size/deadline
    agnostic (DCTCP-style).
  * SJF — strict Shortest-Remaining-First (pFabric-style); minimises mean FCT
    but starves large urgent transfers and over-prioritises small KV flows.
  * EDF — strict Earliest-Deadline-First among explicit-deadline flows;
    degrades to fair sharing for implicit-deadline flows (application
    deadlines do not translate to flow deadlines), and over-prioritises
    Stage 3.
  * Karuna — mix-flow scheduling [17]: deadline flows are paced at the
    minimal rate that meets their deadline (highest class, rate-capped);
    remaining bandwidth goes to non-deadline flows ordered by SJF.

Decode plane: D2D KV-migration flows (Stage.D2D, derived next-token
deadlines) reach every policy through the same ``assign`` path. The
baselines stay stage-agnostic by construction — EDF and Karuna treat a
tight-deadline migration like any deadline flow (and will happily starve
prefill P2D/collectives for it), SJF sorts the large migrations last,
FairShare splits with them evenly. Only the MFS arbiter
(repro.core.arbiter) is decode-aware: D2D gets its own RMLQ laxity and a
band below P2D, so overload control defers loose rebalancing first.

KV-reuse plane: Stage.WB writeback/replication flows carry *loose* derived
deadlines that are nevertheless often nearer than fresh P2D deadlines —
EDF therefore serves background replication ahead of TTFT-critical
traffic once it shares a contended uplink, Karuna reserves it a minimal
rate, FairShare splits with it evenly. MFS holds WB in the band below
even D2D and promotes it only as its own slack runs out.

The MFS policy itself lives in repro.core.arbiter.
"""
from __future__ import annotations

from typing import Iterable, Optional, Protocol, Sequence, Tuple

from .msflow import Flow, FlowState, Stage

__all__ = [
    "SchedView",
    "Policy",
    "FairShare",
    "SJF",
    "EDF",
    "Karuna",
    "LLFOracle",
    "make_policy",
]


class SchedView(Protocol):
    """What a policy may observe (implemented by the cluster simulator)."""

    now: float

    def bottleneck(self, flow: Flow) -> Tuple[float, float]:
        """(capacity, background load rho) of the flow's bottleneck link."""
        ...

    def l_curr(self, unit: int) -> int:
        """Index of the layer currently executing/ready on ``unit``."""
        ...

    def computing(self, rid: int) -> bool:
        """True while the request's prefill computation is still running."""
        ...

    def red_rank(self, rid: int) -> int:
        """Rank of the request's batch in the RED dispatch order sigma."""
        ...

    def downstream_estimate(self, flow: Flow) -> float:
        """Estimated remaining downstream (compute + comm) time after this
        flow completes — used only by the clairvoyant LLF oracle."""
        ...

    def mlu_inputs(self, flow: Flow, level: int) -> Tuple[float, float]:
        """(capacity, rho) for the MLU computation, where rho counts only
        *protected* traffic — flows the candidate could not preempt even if
        promoted to ``level`` (early-stage flows and explicit-deadline flows
        already above that level). Defaults to :meth:`bottleneck`."""
        ...


class Policy:
    name = "base"
    #: whether repro.simcluster should run Algorithm 1 (RED + pruning)
    uses_inter_request = False

    def assign(self, flows: Sequence[Flow], view: SchedView,
               trigger: Tuple = ("event",)) -> None:
        raise NotImplementedError

    def on_flow_submitted(self, flow: Flow, view: SchedView) -> None:
        """Hook for per-flow admission (MFS uses it for RMLQ insertion)."""

    def on_flow_completed(self, flow: Flow, view: SchedView) -> None:
        """Hook for completion bookkeeping."""

    def reset(self) -> None:
        """Clear cross-run state (schedulers are reused across sim runs)."""


class FairShare(Policy):
    name = "fairshare"

    def assign(self, flows, view, trigger=("event",)):
        for f in flows:
            f.priority_key = (0.0, 0.0)
            f.rate_cap = None


class SJF(Policy):
    name = "sjf"

    def assign(self, flows, view, trigger=("event",)):
        for f in flows:
            f.priority_key = (f.remaining, float(f.fid))
            f.rate_cap = None


class EDF(Policy):
    name = "edf"

    def assign(self, flows, view, trigger=("event",)):
        for f in flows:
            if f.explicit_deadline:
                f.priority_key = (0.0, f.deadline, float(f.fid))
            else:
                f.priority_key = (1.0, 0.0, 0.0)   # fair share band
            f.rate_cap = None


class Karuna(Policy):
    name = "karuna"

    def assign(self, flows, view, trigger=("event",)):
        for f in flows:
            if f.explicit_deadline:
                budget = f.deadline - view.now
                if budget <= 0:
                    # overdue: full throttle at top priority (type-1 behaviour)
                    f.priority_key = (0.0, 0.0, float(f.fid))
                    f.rate_cap = None
                else:
                    f.priority_key = (0.0, 0.0, float(f.fid))
                    f.rate_cap = f.remaining / budget   # minimal required rate
            else:
                f.priority_key = (1.0, f.remaining, float(f.fid))  # SJF band
                f.rate_cap = None


class LLFOracle(Policy):
    """Clairvoyant Least-Laxity-First upper bound.

    MFS *approximates* LLF without knowing laxity (§1); this oracle is given
    the simulator's own downstream estimates, yielding the policy MFS aims
    for. Reported in benchmarks as a ceiling, not a baseline from the paper.
    """

    name = "llf-oracle"

    def assign(self, flows, view, trigger=("event",)):
        for f in flows:
            cap, rho = view.bottleneck(f)
            eff = max(cap * (1.0 - rho), 1e-9)
            xmit = f.remaining / eff
            if f.explicit_deadline:
                laxity = f.deadline - view.now - xmit
            else:
                laxity = max(0.0, view.downstream_estimate(f) - xmit)
            f.priority_key = (laxity, float(f.fid))
            f.rate_cap = None


def make_policy(name: str, **kw) -> Policy:
    from .arbiter import MFSScheduler  # local import: avoid cycle

    table = {
        "fairshare": FairShare,
        "fs": FairShare,
        "sjf": SJF,
        "edf": EDF,
        "karuna": Karuna,
        "llf-oracle": LLFOracle,
        "mfs": MFSScheduler,
    }
    if name not in table:
        raise KeyError(f"unknown policy {name!r}; choose from {sorted(table)}")
    return table[name](**kw) if name == "mfs" else table[name]()
