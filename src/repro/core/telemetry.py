"""Telemetry plane — stage-aware tracing, decision audit, link attribution.

The paper's central claim is that *uncoordinated cross-stage contention on
shared bottleneck links* is the primary cause of TTFT SLO violations. The
rest of the repro can only report end-of-run attainment ratios; this module
makes the mechanism observable: **where a missed request's slack went**
(which stage, which link, queueing vs transfer vs compute) and **what RMLQ
decided and when** (defer level, promotions with the MLU/RLI inputs that
drove them, band clamps, level-1 reservations, Algorithm-1 re-evaluations).

Pieces:

  * :class:`TelemetrySpec` — the knob carried by ``ClusterSpec.telemetry``
    / ``DisaggConfig.telemetry``; ``None`` (the default everywhere) keeps
    the runtime byte-identical to the pre-telemetry code path.
  * :class:`Telemetry` — the collector both hosts attach to the shared
    ``MsFlowRuntime``. Near-zero overhead when absent: every probe site is
    a single ``if tel is not None`` guard, and the collector itself never
    perturbs scheduling (it only reads clock/net state), so TTFTs and
    stage traces with telemetry ON equal the OFF run bit-for-bit.
  * :class:`StageLog` — the bounded stage-trace deque, now counting what
    it drops (the legacy ``deque(maxlen=...)`` lost oldest entries with no
    signal); ``runtime.stage_log`` keeps the historical
    ``(rid, stage, group, size, deadline)`` row format.

What gets recorded (all bounded; drops are counted, never silent):

  * **Request-lifecycle spans** — arrive → route/admit (incl. defer/shed)
    → batch → per-(group, chunk) compute → collective waits → P2D tail →
    first token → decode admit/steps summary → D2D migrations → eviction,
    as per-request event lists plus per-flow spans carrying submit/finish
    times, bytes, a rate-history summary (max rate, #rate changes, time at
    zero rate vs transferring) and the bottleneck link at completion.
  * **Scheduler-decision audit** — every RMLQ insert (the *defer* level),
    promotion, band clamp (D2D/WB barred from the level-1 reservation),
    level-1 reservation entry, scavenge/readmit, and every Algorithm-1
    inter-request re-evaluation (order + pruned set), with the MLU/RLI
    inputs captured at decision time by the arbiter.
  * **Link telemetry** — time-integrated per-link utilization and
    per-stage-class byte shares (generalizing the KV store's one-off
    ``sample_contention``), sampled at ``link_dt`` pitch, plus contended
    time (utilization ≥ ``contended_util``) per link.

Analysis + export:

  * :meth:`Telemetry.ttft_breakdown` — per-request slack attribution
    (queue / S1 stall / compute / collective wait / P2D tail / per-stage
    network queueing-vs-transfer).
  * :meth:`Telemetry.slo_miss_report` — ranks missed requests' dominant
    (stage, link) causes per run; the benchmark's per-policy
    contention-attribution table comes from this.
  * :meth:`Telemetry.to_chrome_trace` — Chrome/Perfetto trace-event JSON,
    so a sweep run renders as an inspectable timeline.

Control-plane only (no JAX), host-agnostic like the rest of ``repro.core``.
"""
from __future__ import annotations

import json
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, List, Optional, Set,
                    Tuple)

from .msflow import Flow, FlowState, Stage

__all__ = ["TelemetrySpec", "Telemetry", "StageLog", "FlowSpan",
           "RequestTrace", "link_name"]


# --------------------------------------------------------------------- spec
@dataclass(frozen=True)
class TelemetrySpec:
    """Telemetry-plane configuration (attach via ``ClusterSpec.telemetry``
    or ``DisaggConfig.telemetry``; ``None`` disables the plane entirely)."""

    enabled: bool = True
    audit: bool = True            # RMLQ / Algorithm-1 decision audit
    link_sampling: bool = True    # per-link per-stage-class accounting
    link_dt: float = 2e-3         # link-sampling pitch (s of sim time); the
    #                               per-flow span rate summary is exact
    #                               regardless — only the per-link byte
    #                               attribution is sampled at this pitch
    contended_util: float = 0.9   # a link counts as contended at ≥ this rho
    max_flow_spans: int = 200_000
    max_audit_events: int = 200_000
    max_request_events: int = 512   # per-request lifecycle event cap
    max_compute_spans: int = 100_000


# ---------------------------------------------------------------- stage log
class StageLog(deque):
    """Bounded stage-trace deque that COUNTS what it drops.

    The legacy ``deque(maxlen=...)`` silently discarded the oldest rows on
    overflow; parity tests comparing truncated logs would then diverge with
    no signal. This subclass keeps the exact row format and iteration
    semantics but increments :attr:`dropped` per lost row and warns once."""

    def __init__(self, maxlen: int = 100_000):
        super().__init__(maxlen=maxlen)
        self.dropped = 0

    def append(self, row) -> None:
        if self.maxlen is not None and len(self) == self.maxlen:
            self.dropped += 1
            if self.dropped == 1:
                warnings.warn(
                    f"stage_log overflowed its {self.maxlen}-row bound; "
                    "oldest entries are being dropped (raise "
                    "stage_log_limit or consume the log incrementally)",
                    RuntimeWarning, stacklevel=3)
        super().append(row)


# ------------------------------------------------------------------ records
@dataclass
class FlowSpan:
    """One submitted flow's life, with a rate-history summary."""

    fid: int
    rid: int
    unit: int
    stage: Stage
    group: int                    # target_layer (S1: consuming group)
    size: float
    deadline: Optional[float]
    created: float
    src: int = -1
    dst: int = -1
    line_cap: float = 0.0         # min capacity over the static route
    finished: Optional[float] = None
    end_state: str = "open"       # open | done | cancelled | pruned
    level0: int = 0               # RMLQ level at submission
    level_final: int = 0
    max_rate: float = 0.0
    rate_changes: int = 0
    idle: float = 0.0             # time active at zero allocated rate
    xfer: float = 0.0             # time active at non-zero rate
    bottleneck: int = -1          # most-utilized route link at completion
    _last_rate: float = -1.0

    @property
    def duration(self) -> float:
        return (self.finished - self.created) \
            if self.finished is not None else 0.0

    @property
    def excess(self) -> float:
        """Slack this flow burned on the network: time queued at zero rate
        plus transfer time beyond the route's line-rate serialization."""
        ideal = self.size / self.line_cap if self.line_cap > 0 else 0.0
        return self.idle + max(0.0, self.xfer - ideal)


@dataclass
class RequestTrace:
    """Per-request lifecycle: ordered events + summary fields."""

    rid: int
    arrival: float = 0.0
    unit: int = -1
    slo_class: str = "standard"
    deadline: Optional[float] = None     # absolute
    ideal_ttft: float = 0.0
    batch: int = -1
    batch_started: Optional[float] = None
    prefill_done: Optional[float] = None
    p2d_last: Optional[float] = None
    stalls: float = 0.0
    ttft: Optional[float] = None         # relative, as reported by metrics
    status: str = "arrived"   # arrived|deferred|shed|admitted|served|pruned
    n_deferrals: int = 0
    events: List[Tuple[float, str, Any]] = field(default_factory=list)
    flows: List[int] = field(default_factory=list)
    events_dropped: int = 0

    def missed(self) -> Optional[bool]:
        if self.status == "shed":
            return True
        if self.ttft is None or self.deadline is None:
            return None
        return self.arrival + self.ttft > self.deadline + 1e-9


def link_name(topo: Any, lid: int) -> str:
    """Best-effort human-readable name for a topology link id."""
    n = getattr(topo, "n_nodes", 0)
    su = getattr(topo, "_su", None)
    up0, dn0 = getattr(topo, "_up0", None), getattr(topo, "_dn0", None)
    if lid < 2 * n:
        return f"nic{lid // 2}.{'up' if lid % 2 == 0 else 'down'}"
    if up0 is not None and dn0 is not None and up0 <= lid < dn0:
        ns = topo.n_spines
        r, s = divmod(lid - up0, ns)
        return f"leaf{r}->spine{s}"
    if up0 is not None and dn0 is not None and su is not None \
            and dn0 <= lid < su:
        ns = topo.n_spines
        r, s = divmod(lid - dn0, ns)
        return f"spine{s}->leaf{r}"
    if su is not None and lid >= su:
        j = lid - su
        return f"su{j // 2}.{'out' if j % 2 == 0 else 'in'}"
    return f"link{lid}"


# ---------------------------------------------------------------- collector
class Telemetry:
    """The telemetry collector one runtime binds (see module docstring).

    Pure observer: reads the runtime clock / fluid-net state, never mutates
    either — enabling it cannot change scheduling outcomes (regression-
    tested: TTFTs and stage traces match the telemetry-off run exactly)."""

    def __init__(self, spec: TelemetrySpec = TelemetrySpec()):
        self.spec = spec
        self._clock: Callable[[], float] = lambda: 0.0
        self.topo: Any = None
        # request lifecycle
        self.requests: Dict[int, RequestTrace] = {}
        # flow spans (kept after close — they ARE the history)
        self.flow_spans: Dict[int, FlowSpan] = {}
        # compute spans: (unit, bid, group, chunk, t0, t1)
        self.compute_spans: List[Tuple[int, int, int, int, float, float]] = []
        self._open_compute: Dict[int, Tuple[int, int, int, float]] = {}
        self.batch_compute: Dict[int, float] = {}    # bid -> compute seconds
        self.batch_coll_wait: Dict[int, float] = {}  # bid -> Stage-2 waits
        # scheduler-decision audit
        self.audit: List[Dict[str, Any]] = []
        self._urgency: Dict[int, Dict[str, Any]] = {}   # fid -> last inputs
        self._levels: Dict[int, Tuple[Stage, int, int]] = {}  # fid ->
        #                                   (stage, insert level, last level)
        # link telemetry (time-integrated)
        self.link_byte_time: Dict[int, float] = {}   # ∫ used_rate dt
        self.link_stage_bytes: Dict[Tuple[int, str], float] = {}
        self.link_contended_time: Dict[int, float] = {}
        self.contended_stage_bytes: Dict[Tuple[int, str], float] = {}
        self._t_link = 0.0          # last link sample time
        self._t0: Optional[float] = None
        self._t_end = 0.0
        self.t_first_decode = 0.0   # set by the runtime at bind
        self.dropped = {"flow_spans": 0, "audit": 0, "request_events": 0,
                        "compute_spans": 0}

    # -------------------------------------------------------------- binding
    def bind(self, clock: Callable[[], float], topo: Any,
             t_first_decode: float = 0.0) -> None:
        self._clock = clock
        self.topo = topo
        self.t_first_decode = t_first_decode

    def _now(self) -> float:
        return self._clock()

    # ---------------------------------------------------- request lifecycle
    def _trace(self, rid: int) -> RequestTrace:
        tr = self.requests.get(rid)
        if tr is None:
            tr = self.requests[rid] = RequestTrace(rid=rid)
        return tr

    def request_event(self, rid: int, kind: str, arg: Any = None,
                      t: Optional[float] = None) -> None:
        tr = self._trace(rid)
        if len(tr.events) >= self.spec.max_request_events:
            tr.events_dropped += 1
            self.dropped["request_events"] += 1
            return
        tr.events.append((self._now() if t is None else t, kind, arg))

    def on_arrival(self, item: Any, unit: int) -> None:
        tr = self._trace(item.rid)
        if item.deferrals == 0 and not tr.events:
            tr.arrival = item.arrival
            self.request_event(item.rid, "arrive", t=item.arrival)
        self.request_event(item.rid, "route",
                           {"unit": unit, "reuse": item.reuse})

    def on_admitted(self, item: Any) -> None:
        tr = self._trace(item.rid)
        tr.status = "admitted"
        tr.unit = item.unit
        tr.slo_class = item.slo_class
        tr.deadline = item.deadline
        tr.ideal_ttft = item.ideal_ttft
        self.request_event(item.rid, "admit", {"unit": item.unit,
                                               "deadline": item.deadline})

    def on_deferred(self, item: Any) -> None:
        tr = self._trace(item.rid)
        tr.status = "deferred"
        tr.n_deferrals = item.deferrals
        tr.slo_class = item.slo_class
        self.request_event(item.rid, "defer", {"n": item.deferrals})

    def on_shed(self, item: Any) -> None:
        tr = self._trace(item.rid)
        tr.status = "shed"
        tr.slo_class = item.slo_class
        tr.deadline = item.deadline
        self.request_event(item.rid, "shed", {"class": item.slo_class})

    def on_batch_started(self, bs: Any) -> None:
        for it in bs.items:
            tr = self._trace(it.rid)
            tr.batch = bs.bid
            tr.batch_started = bs.started
            self.request_event(it.rid, "batch",
                               {"bid": bs.bid, "unit": bs.unit})

    def on_request_done(self, item: Any, bs: Any) -> None:
        tr = self._trace(item.rid)
        tr.status = "served"
        tr.ttft = item.ttft
        tr.prefill_done = item.prefill_done
        tr.p2d_last = bs.p2d_last.get(item.rid)
        tr.stalls = item.stalls
        tr.deadline = item.deadline
        self.request_event(item.rid, "first_token", {"ttft": item.ttft})

    def on_pruned(self, rid: int) -> None:
        tr = self._trace(rid)
        tr.status = "pruned"
        self.request_event(rid, "pruned")

    def on_readmitted(self, rid: int) -> None:
        tr = self._trace(rid)
        if tr.status == "pruned":
            tr.status = "admitted"
        self.request_event(rid, "readmitted")

    # -------------------------------------------------------------- compute
    def compute_open(self, bs: Any, g: int, c: int) -> None:
        self._open_compute[bs.unit] = (bs.bid, g, c, self._now())

    def compute_close(self, unit: int) -> None:
        ent = self._open_compute.pop(unit, None)
        if ent is None:
            return
        bid, g, c, t0 = ent
        t1 = self._now()
        self.batch_compute[bid] = self.batch_compute.get(bid, 0.0) + (t1 - t0)
        if len(self.compute_spans) >= self.spec.max_compute_spans:
            self.dropped["compute_spans"] += 1
            return
        self.compute_spans.append((unit, bid, g, c, t0, t1))

    def coll_wait(self, bid: int, dt: float) -> None:
        self.batch_coll_wait[bid] = self.batch_coll_wait.get(bid, 0.0) + dt

    # ----------------------------------------------------------- flow spans
    def flow_submitted(self, flow: Flow,
                       stage_log: Optional[StageLog] = None) -> None:
        """Open a span for a submitted flow. When ``stage_log`` is given the
        legacy ``(rid, stage, group, size, deadline)`` row is appended too —
        with telemetry on, the stage log is backed by this single probe."""
        if stage_log is not None:
            stage_log.append((flow.rid, flow.stage, flow.target_layer,
                              flow.size, flow.deadline))
        if len(self.flow_spans) >= self.spec.max_flow_spans:
            self.dropped["flow_spans"] += 1
            return
        route = self.topo.route(flow.src, flow.dst, flow.fid) \
            if self.topo is not None else ()
        cap = min((self.topo.capacity[l] for l in route), default=0.0) \
            if route else 0.0
        sp = FlowSpan(fid=flow.fid, rid=flow.rid, unit=flow.unit,
                      stage=flow.stage, group=flow.target_layer,
                      size=flow.size, deadline=flow.deadline,
                      created=flow.created, src=flow.src, dst=flow.dst,
                      line_cap=cap, level0=flow.level,
                      level_final=flow.level)
        self.flow_spans[flow.fid] = sp
        tr = self._trace(flow.rid)
        tr.flows.append(flow.fid)

    def flow_closed(self, flow: Flow, net: Any) -> None:
        """Close the span (completion, pruning cancellation, or eviction).
        Records the end state, the final RMLQ level and the bottleneck link
        (most-utilized link of the flow's route at close time)."""
        sp = self.flow_spans.get(flow.fid)
        self._urgency.pop(flow.fid, None)
        if sp is None or sp.end_state != "open":
            return
        now = self._now()
        sp.finished = flow.finished if flow.finished is not None else now
        sp.level_final = flow.level
        if flow.state == FlowState.DONE and flow.remaining <= 0:
            sp.end_state = "done"
        elif flow.state == FlowState.PRUNED:
            sp.end_state = "pruned"
        else:
            sp.end_state = "cancelled"
        if self.topo is not None:
            route = self.topo.route(flow.src, flow.dst, flow.fid)
            best, best_rho = -1, -1.0
            lr = getattr(net, "_link_rate", {})
            for lid in route:
                rho = lr.get(lid, 0.0) / self.topo.capacity[lid]
                if rho > best_rho:
                    best, best_rho = lid, rho
            sp.bottleneck = best

    # ------------------------------------------------------ time integration
    def on_advance(self, net: Any, t: float) -> None:
        """Called once per event, BEFORE ``net.advance(t)``: rates are
        piecewise-constant over [net.now, t], so integrating rate × dt here
        is exact for the per-flow span summaries. The per-link per-stage
        byte attribution is sampled at ``link_dt`` pitch to bound cost."""
        now = net.now
        dt = t - now
        if self._t0 is None:
            self._t0 = now
        self._t_end = t
        if dt <= 0.0:
            return
        spans = self.flow_spans
        for f in net.flows.values():
            sp = spans.get(f.fid)
            if sp is None:
                continue
            r = f.rate
            if r > 0.0:
                sp.xfer += dt
                if r != sp._last_rate:
                    sp.rate_changes += 1
                    sp._last_rate = r
                    if r > sp.max_rate:
                        sp.max_rate = r
            else:
                sp.idle += dt
        if not self.spec.link_sampling or t - self._t_link < self.spec.link_dt:
            return
        sdt = t - self._t_link
        self._t_link = t
        lr = getattr(net, "_link_rate", None)
        if not lr:
            return
        cap = self.topo.capacity
        contended: Set[int] = set()
        thr = self.spec.contended_util
        for lid, used in lr.items():
            if used <= 0.0:
                continue
            self.link_byte_time[lid] = \
                self.link_byte_time.get(lid, 0.0) + used * sdt
            if used >= thr * cap[lid]:
                contended.add(lid)
                self.link_contended_time[lid] = \
                    self.link_contended_time.get(lid, 0.0) + sdt
        for f in net.flows.values():
            r = f.rate
            if r <= 0.0:
                continue
            st = f.stage.name
            b = r * sdt
            for lid in net.routes[f.fid]:
                self.link_stage_bytes[(lid, st)] = \
                    self.link_stage_bytes.get((lid, st), 0.0) + b
                if lid in contended:
                    self.contended_stage_bytes[(lid, st)] = \
                        self.contended_stage_bytes.get((lid, st), 0.0) + b

    # ------------------------------------------------------- decision audit
    def note_urgency(self, fid: int, inputs: Dict[str, Any]) -> None:
        """Arbiter side-channel: the MLU/RLI inputs computed immediately
        before an insert/promote decision (popped by :meth:`rmlq_event`)."""
        self._urgency[fid] = inputs

    def rmlq_event(self, kind: str, flow: Flow, frm: Optional[int],
                   to: int) -> None:
        """One RMLQ decision: insert (the defer level), promote, clamp
        (barred from the level-1 reservation), scavenge, or readmit. A
        level-1 outcome is additionally flagged as the §4.5 critical
        reservation entry."""
        if not self.spec.audit:
            return
        if kind == "insert":
            self._levels[flow.fid] = (flow.stage, to, to)
        elif kind in ("promote", "scavenge", "readmit"):
            ent = self._levels.get(flow.fid)
            if ent is not None:
                self._levels[flow.fid] = (ent[0], ent[1], to)
        if len(self.audit) >= self.spec.max_audit_events:
            self.dropped["audit"] += 1
            return
        ev = {"t": self._now(), "kind": kind, "fid": flow.fid,
              "rid": flow.rid, "stage": flow.stage.name, "from": frm,
              "to": to}
        if to == 1 and kind in ("insert", "promote", "readmit"):
            ev["reserved"] = True          # I3: level-1 critical reservation
        inputs = self._urgency.pop(flow.fid, None)
        if inputs is not None and kind in ("insert", "promote", "readmit"):
            ev["inputs"] = inputs
        self.audit.append(ev)

    def red_run(self, order: List[int], pruned: Iterable[int],
                n_batches: int) -> None:
        """One Algorithm-1 inter-request re-evaluation (RED ordering +
        feasibility pruning over the live batches)."""
        if not self.spec.audit:
            return
        if len(self.audit) >= self.spec.max_audit_events:
            self.dropped["audit"] += 1
            return
        self.audit.append({"t": self._now(), "kind": "red_run",
                           "order": list(order), "pruned": sorted(pruned),
                           "n_batches": n_batches})

    def rmlq_promoted_count(self, stage: Optional[Stage] = None) -> int:
        """Flows whose audited final level sits below their insert level —
        matches ``MsFlowRuntime.promoted_count`` by construction (every
        level mutation flows through an audited RMLQ entry point)."""
        name = stage.name if stage is not None else None
        return sum(1 for (st, lvl0, lvl) in self._levels.values()
                   if lvl < lvl0 and (name is None or st.name == name))

    def audit_events(self, kind: Optional[str] = None) -> List[Dict]:
        return [e for e in self.audit if kind is None or e["kind"] == kind]

    # ------------------------------------------------------------- analysis
    def ttft_breakdown(self, rid: int) -> Optional[Dict[str, Any]]:
        """Where the request's TTFT went: admission queue, Stage-1 stalls,
        compute, collective waits, P2D tail, first decode step — plus the
        per-stage network split (queued-at-zero-rate vs transferring) from
        its flow spans. Components sum to the TTFT for served requests."""
        tr = self.requests.get(rid)
        if tr is None:
            return None
        out: Dict[str, Any] = {"rid": rid, "status": tr.status,
                               "slo_class": tr.slo_class, "ttft": tr.ttft,
                               "budget": (tr.deadline - tr.arrival)
                               if tr.deadline is not None else None}
        if tr.ttft is not None and out["budget"] is not None:
            out["slack"] = out["budget"] - tr.ttft
        if tr.batch_started is not None:
            out["queue"] = tr.batch_started - tr.arrival
        if tr.prefill_done is not None and tr.batch_started is not None:
            bid = tr.batch
            stall = tr.stalls
            coll = self.batch_coll_wait.get(bid, 0.0)
            comp = self.batch_compute.get(bid, 0.0)
            out["stall_s1"] = stall
            out["coll_wait"] = coll
            out["compute"] = comp
            last = tr.p2d_last if tr.p2d_last is not None else tr.prefill_done
            out["p2d_tail"] = max(0.0, last - tr.prefill_done)
            out["first_decode"] = self.t_first_decode
        stages: Dict[str, Dict[str, float]] = {}
        for fid in tr.flows:
            sp = self.flow_spans.get(fid)
            if sp is None:
                continue
            d = stages.setdefault(sp.stage.name, {"bytes": 0.0, "idle": 0.0,
                                                  "xfer": 0.0, "excess": 0.0,
                                                  "n": 0})
            d["bytes"] += sp.size
            d["idle"] += sp.idle
            d["xfer"] += sp.xfer
            d["excess"] += sp.excess
            d["n"] += 1
        out["stages"] = stages
        return out

    def attribute_miss(self, rid: int) -> Optional[Dict[str, Any]]:
        """Dominant (stage, link) a missed request's slack went to: the
        flow span with the largest network excess (queueing at zero rate +
        transfer beyond line rate), attributed to its bottleneck link."""
        tr = self.requests.get(rid)
        if tr is None or tr.missed() is not True:
            return None
        rec: Dict[str, Any] = {"rid": rid, "slo_class": tr.slo_class,
                               "status": tr.status}
        if tr.ttft is not None and tr.deadline is not None:
            rec["slack_lost"] = tr.ttft - (tr.deadline - tr.arrival)
        if tr.status == "shed":
            rec["stage"], rec["link"] = "admission", None
            return rec
        best: Optional[FlowSpan] = None
        for fid in tr.flows:
            sp = self.flow_spans.get(fid)
            if sp is None or sp.bottleneck < 0:
                continue
            if best is None or sp.excess > best.excess:
                best = sp
        if best is None:
            rec["stage"], rec["link"] = "compute", None
            return rec
        rec["stage"] = best.stage.name
        rec["link"] = best.bottleneck
        rec["link_name"] = link_name(self.topo, best.bottleneck)
        rec["excess"] = best.excess
        rec["flow_idle"] = best.idle
        rec["flow_xfer"] = best.xfer
        return rec

    def slo_miss_report(self, slo_class: Optional[str] = None,
                        top: int = 10) -> Dict[str, Any]:
        """Rank where missed requests' slack went: per-(stage, link) miss
        counts and total slack lost, plus per-request attributions.
        ``coverage`` = fraction of misses pinned to a concrete
        (stage, link) pair (the acceptance signal)."""
        misses: List[Dict[str, Any]] = []
        for rid, tr in self.requests.items():
            if rid < 0 or tr.missed() is not True:
                continue
            if slo_class is not None and tr.slo_class != slo_class:
                continue
            rec = self.attribute_miss(rid)
            if rec is not None:
                misses.append(rec)
        causes: Dict[Tuple[str, Any], Dict[str, Any]] = {}
        n_attr = 0
        for rec in misses:
            key = (rec["stage"], rec.get("link"))
            if rec.get("link") is not None:
                n_attr += 1
            c = causes.setdefault(key, {"stage": key[0], "link": key[1],
                                        "link_name": rec.get("link_name"),
                                        "n": 0, "slack_lost": 0.0})
            c["n"] += 1
            c["slack_lost"] += max(0.0, rec.get("slack_lost", 0.0))
        ranked = sorted(causes.values(),
                        key=lambda c: (-c["slack_lost"], -c["n"]))
        return {"n_missed": len(misses), "n_attributed": n_attr,
                "coverage": (n_attr / len(misses)) if misses else None,
                "causes": ranked[:top], "requests": misses}

    def link_report(self, top: int = 10) -> List[Dict[str, Any]]:
        """Most-contended links over the run: mean utilization, contended
        time, and per-stage-class byte share (the generalized
        ``sample_contention``)."""
        span = max(self._t_end - (self._t0 or 0.0), 1e-12)
        out = []
        for lid, bt in self.link_byte_time.items():
            total = sum(v for (l, _), v in self.link_stage_bytes.items()
                        if l == lid)
            shares = {st: v / total
                      for (l, st), v in sorted(self.link_stage_bytes.items())
                      if l == lid and total > 0}
            out.append({
                "link": lid, "link_name": link_name(self.topo, lid),
                "mean_util": bt / (self.topo.capacity[lid] * span),
                "contended_s": self.link_contended_time.get(lid, 0.0),
                "stage_share": shares})
        out.sort(key=lambda d: -d["contended_s"] or -d["mean_util"])
        return out[:top]

    def contended_stage_share(self) -> Dict[str, float]:
        """Per-stage share of bytes moved over contended link-seconds —
        the cross-plane generalization of ``KVStore.wb_share_contended``."""
        total = sum(self.contended_stage_bytes.values())
        if total <= 0:
            return {}
        agg: Dict[str, float] = {}
        for (_, st), v in self.contended_stage_bytes.items():
            agg[st] = agg.get(st, 0.0) + v
        return {st: v / total for st, v in sorted(agg.items())}

    # --------------------------------------------------------------- export
    def to_chrome_trace(self, rids: Optional[Set[int]] = None) -> Dict:
        """Chrome/Perfetto trace-event JSON (``ph: X`` complete events over
        µs timestamps). Lanes: one pid per serving unit for compute spans,
        pid 10_000 + src node for network flow spans (tid = stage), async
        ``b``/``e`` pairs per request lifetime. ``rids`` filters to a
        request subset (e.g. one missed request's timeline)."""
        ev: List[Dict[str, Any]] = []
        us = 1e6

        def keep(rid: int) -> bool:
            return rids is None or rid in rids

        for (unit, bid, g, c, t0, t1) in self.compute_spans:
            bids = {self.requests[r].batch for r in (rids or ())
                    if r in self.requests} if rids is not None else None
            if bids is not None and bid not in bids:
                continue
            ev.append({"name": f"compute b{bid} g{g}c{c}", "cat": "compute",
                       "ph": "X", "ts": t0 * us, "dur": (t1 - t0) * us,
                       "pid": unit, "tid": 0,
                       "args": {"bid": bid, "group": g, "chunk": c}})
        for sp in self.flow_spans.values():
            if not keep(sp.rid) or sp.finished is None:
                continue
            ev.append({
                "name": f"{sp.stage.name} r{sp.rid} g{sp.group}",
                "cat": f"net.{sp.stage.name}", "ph": "X",
                "ts": sp.created * us, "dur": max(sp.duration, 0.0) * us,
                "pid": 10_000 + max(sp.src, 0), "tid": int(sp.stage),
                "args": {"rid": sp.rid, "bytes": sp.size,
                         "end_state": sp.end_state,
                         "level0": sp.level0, "level": sp.level_final,
                         "idle_s": sp.idle, "xfer_s": sp.xfer,
                         "max_rate": sp.max_rate,
                         "rate_changes": sp.rate_changes,
                         "bottleneck": sp.bottleneck,
                         "bottleneck_name":
                             link_name(self.topo, sp.bottleneck)
                             if sp.bottleneck >= 0 else None,
                         "deadline": sp.deadline}})
        for rid, tr in self.requests.items():
            if not keep(rid):
                continue
            t_end = None
            if tr.ttft is not None:
                t_end = tr.arrival + tr.ttft
            elif tr.events:
                t_end = tr.events[-1][0]
            if t_end is None:
                continue
            common = {"cat": "request", "id": rid, "pid": 20_000,
                      "tid": max(tr.unit, 0)}
            ev.append(dict(common, name=f"request r{rid}", ph="b",
                           ts=tr.arrival * us,
                           args={"slo_class": tr.slo_class,
                                 "status": tr.status}))
            ev.append(dict(common, name=f"request r{rid}", ph="e",
                           ts=t_end * us, args={"ttft": tr.ttft}))
            for (t, kind, arg) in tr.events:
                ev.append({"name": kind, "cat": "lifecycle", "ph": "i",
                           "ts": t * us, "pid": 20_000,
                           "tid": max(tr.unit, 0), "s": "t",
                           "args": {"rid": rid, "detail": arg}})
        for pid, name in ((20_000, "requests"),):
            ev.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "ts": 0,
                       "args": {"name": name}})
        return {"traceEvents": ev, "displayTimeUnit": "ms"}

    def save_chrome_trace(self, path: str,
                          rids: Optional[Set[int]] = None) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_chrome_trace(rids), fh)

    # --------------------------------------------------------------- summary
    def summary(self) -> Dict[str, Any]:
        served = sum(1 for t in self.requests.values()
                     if t.status == "served")
        return {
            "requests": len(self.requests), "served": served,
            "flow_spans": len(self.flow_spans),
            "open_spans": sum(1 for s in self.flow_spans.values()
                              if s.end_state == "open"),
            "compute_spans": len(self.compute_spans),
            "audit_events": len(self.audit),
            "links_sampled": len(self.link_byte_time),
            "dropped": dict(self.dropped),
        }
