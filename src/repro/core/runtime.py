"""MsFlow runtime — the shared orchestration core of §5.

One event-loop driver used by BOTH the cluster simulator
(``repro.simcluster.sim.ClusterSim``) and the real-JAX serving path
(``repro.serving.disagg.DisaggServer``). Every transfer goes through the
standardized primitives

    submit(flow-with-metadata)  ->  fid
    permit(fid, priority)           (the policy's assign() on the RMLQ)
    completion(fid)                 (fires the dependent continuation)

with the pluggable policy deciding priorities and ``repro.netsim.FluidNet``
playing the fabric. Computation events and network events share one
``EventQueue`` (§6.1: "processed within a single event queue").

Per batch and super-layer g a unit: (wait for Stage-1 flows targeting
groups <= g) -> compute C_g -> emit Stage-3 P2D flows for g (+ Stage-2
coflow, which must finish before group g+1 computes). Reused prefix tokens
skip computation but their KV must arrive (Stage 1) before the consuming
layer group runs — late arrivals stall the GPU, which is precisely the
contention -> TTFT coupling the paper measures.

With a :class:`repro.core.decode.DecodePlane` attached, requests live past
their first token: ``dstep`` compute events advance per-endpoint decode
batches on the same queue, and the plane's rebalancer submits Stage-D2D
KV-migration flows through the same ``_submit`` primitive, contending with
S1/S2/S3 in the shared fluid net.

Request placement is a runtime concern, not a host concern: every arrival
runs the pluggable **router plane** (``repro.core.router``) — the
configured :class:`~repro.core.router.RouterPolicy` picks the prefill
unit through a :class:`~repro.core.router.RoutingView`, the KV-reuse hit
resolves against the live store for the chosen unit, and an optional
:class:`~repro.core.router.AdmissionController` may shed or defer
loose-SLO requests while its overload detector is tripped. Hosts
customise the runtime through :class:`RuntimeHost` hooks only — supplying
state the router reads (``prepare_route`` fills the legacy reuse oracle,
``kv_chain_keys`` exposes store keys), admission/completion bookkeeping,
and — on the serving path — launching the *real* JAX prefill when a batch
starts. The full MFS policy surface (RMLQ promotion, Algorithm 1 RED
ordering + feasibility pruning, scavenger readmission) runs identically
on both hosts; there are no degenerate per-host stubs.
"""
from __future__ import annotations

import itertools
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .arbiter import MFSScheduler
from .feasibility import BatchLoad, inter_request_schedule
from .monitor import Monitor, ProbeFanout
from .msflow import Coflow, Flow, FlowState, Stage
from .policies import Policy
from .router import (AdmissionController, KVAffinityRouter, RouterPolicy,
                     RoutingView)
from .stages import (BatchState, ChunkPlan, PrefillItem, StageEmitter,
                     StageProfile)
from .telemetry import StageLog, Telemetry

__all__ = ["RuntimeHost", "MsFlowRuntime", "RuntimeView"]


class RuntimeHost:
    """Hooks a host implements around the shared runtime (all optional).
    The runtime never reaches into host state directly — and since the
    router plane, hosts no longer place requests: the runtime calls the
    configured :class:`~repro.core.router.RouterPolicy`; hosts only supply
    the state it reads."""

    def prepare_route(self, item: PrefillItem) -> None:
        """Called once per arrival BEFORE the router places the request.
        Hosts refresh whatever placement state lives on the item here —
        the serving path matches its prefix index and fills the legacy
        ``(reuse, owner_unit)`` oracle (``owner_unit = -1`` when no owner
        exists); the simulator's trace items arrive pre-filled. With a KV
        store attached the oracle is ignored: the runtime resolves the hit
        against live store state after placement."""

    def on_admitted(self, item: PrefillItem) -> None:
        """Called once per request after routing + deadline derivation."""

    def on_shed(self, item: PrefillItem) -> None:
        """Called when admission control rejects the request (overload +
        sheddable SLO class). The request never enters a queue, holds no
        store pins and no decode slots; hosts record the outcome (an SLO
        miss against all-arrivals attainment)."""

    def on_deferred(self, item: PrefillItem) -> None:
        """Called each time admission control delays the request; it will
        re-arrive after the configured delay on its ORIGINAL arrival clock
        (deadline unchanged — the SLO budget keeps burning)."""

    def on_batch_started(self, bs: BatchState) -> None:
        """Called when a batch forms — the serving host runs the real JAX
        prefill here (results are exact; latency comes from the profile)."""

    def on_request_done(self, item: PrefillItem, bs: BatchState) -> None:
        """Called when a request's TTFT materialises (last P2D arrived)."""

    def on_coflow_done(self, bs: BatchState, co: Coflow, ideal: float) -> None:
        """Called when a Stage-2 coflow completes (CCT bookkeeping)."""

    def on_decode_admitted(self, sess) -> None:
        """Called when a request enters the decode plane (TTFT materialised
        and a ``DecodePlane`` is attached)."""

    def on_decode_done(self, sess) -> None:
        """Called when a decode session produces its last token (TPOT/TBT
        metrics are final on ``sess``)."""

    def kv_chain_keys(self, item: PrefillItem) -> Tuple:
        """Block-key chain of the request's reusable prefix (the same keys
        the host's ``route()`` resolves against the KV store), used by
        fixed-mode SLO calibration to estimate steady-state hit rates. An
        empty tuple means "no reusable prefix"."""
        return ()


class RuntimeView:
    """The one concrete SchedView over FluidNet + runtime state."""

    def __init__(self, rt: "MsFlowRuntime"):
        self.rt = rt

    @property
    def now(self) -> float:
        return self.rt.net.now

    def bottleneck(self, flow: Flow) -> Tuple[float, float]:
        return self.rt.net.bottleneck(flow)

    def mlu_inputs(self, flow: Flow, level: int) -> Tuple[float, float]:
        # Protected = traffic strictly more urgent than this flow would be at
        # ``level``: anything at a higher level, plus early-stage flows at the
        # same level (band precedence, §4.5). Early-stage flows at *lower*
        # levels would be preempted by the promotion, so they don't raise rho.
        def protected(other: Flow) -> bool:
            k = other.priority_key
            return k[0] < level or (k[0] == level and len(k) >= 2 and k[1] == 0)
        return self.rt.net.bottleneck_protected(flow, protected)

    def l_curr(self, unit: int) -> int:
        b = self.rt.active_batch.get(unit)
        return b.cur_group if b else 0

    def computing(self, rid: int) -> bool:
        b = self.rt.batch_of_request.get(rid)
        return bool(b and b.compute_done_at is None)

    def red_rank(self, rid: int) -> int:
        return self.rt.red_ranks.get(rid, 0)

    def downstream_estimate(self, flow: Flow) -> float:
        """Time until the data carried by ``flow`` is actually consumed.

        With chunked prefill the current group's contribution tightens from
        its full compute time to the *remaining chunks* only — policies see
        sharper laxity as the chunk front advances, so MFS promotion fires
        earlier for long prompts (monotonically ≤ the group-granular
        estimate; chunk off reproduces it exactly)."""
        b = self.rt.batch_of_request.get(flow.rid)
        if b is None or b.compute_done_at is not None:
            return 0.0
        if flow.stage == Stage.COLLECTIVE:
            return 0.0                      # blocks the very next step
        if b.chunk_plan is None:
            if flow.stage == Stage.KV_REUSE:   # needed when its group starts
                return sum(b.group_time[b.cur_group:flow.target_layer])
            rem = len(b.group_time) - b.cur_group
            return sum(b.group_time[b.cur_group:]) + b.recompute_extra * rem
        rem_cur = sum(b.chunk_time[b.cur_group][b.cur_chunk:])
        if flow.stage == Stage.KV_REUSE:    # needed when its group starts
            if flow.target_layer <= b.cur_group:
                return 0.0
            return rem_cur + sum(b.group_time[b.cur_group + 1:flow.target_layer])
        rem = len(b.group_time) - b.cur_group
        return rem_cur + sum(b.group_time[b.cur_group + 1:]) \
            + b.recompute_extra * rem


class MsFlowRuntime:
    """Event-loop driver + batch lifecycle + overload control (Algorithm 1)."""

    def __init__(self, topo, net, evq, policy: Policy, profile: StageProfile,
                 emitter: StageEmitter, host: RuntimeHost, n_units: int, *,
                 max_batch_tokens: int = 8192, slo_scale: float = 3.0,
                 slo_mode: str = "per-request", tick_interval: float = 2e-3,
                 drop_budget: int = 32, contention_free: bool = False,
                 trace_stages: bool = False, stage_log_limit: int = 100_000,
                 decode=None, kvstore=None,
                 router: Optional[RouterPolicy] = None,
                 admission: Optional[AdmissionController] = None,
                 telemetry: Optional[Telemetry] = None,
                 monitor: Optional[Monitor] = None):
        self.topo = topo
        self.net = net
        self.evq = evq
        self.policy = policy
        self.profile = profile
        self.emitter = emitter
        self.host = host
        self.n_units = n_units
        self.max_batch_tokens = max_batch_tokens
        self.slo_scale = slo_scale
        self.slo_mode = slo_mode                 # "per-request" | "fixed"
        self.tick_interval = tick_interval
        self.drop_budget = drop_budget
        self.contention_free = contention_free
        #: optional DecodePlane — requests live past their first token,
        #: D2D rebalancing flows share the net with S1/S2/S3
        self.decode = decode
        if decode is not None:
            decode.bind(self)
        #: optional KV-reuse plane (repro.core.kvstore.KVStore) — admission
        #: on prefill completion emits Stage-WB writeback flows through the
        #: same _submit primitive, contending with S1/S2/S3/D2D
        self.kvstore = kvstore
        #: chunked prefill (Sarathi-style): > 0 splits every super-layer
        #: group's compute into token-budgeted chunks with per-chunk
        #: S1/S2/S3 emission; 0 is the legacy group-granular schedule.
        #: The emitter owns the knob — runtime chunk plans and per-chunk
        #: recompute accounting must match the emitted flow granularity,
        #: so there is exactly one source of truth.
        self.chunk_tokens = getattr(emitter, "chunk_tokens", 0)
        self.view = RuntimeView(self)
        #: router plane — the runtime owns placement; the default policy is
        #: the extracted historical rule (hit-weighted affinity vs backlog),
        #: bit-identical to the pre-plane per-host loops
        self.router = router if router is not None else KVAffinityRouter()
        #: optional admission-control stage (None = admit everything, the
        #: legacy behaviour)
        self.admission = admission
        self.routing_view = RoutingView(self)
        self.n_shed = 0
        self.n_deferred = 0

        # --- per-unit serving state ---
        self.queues: List[Deque[PrefillItem]] = [deque() for _ in range(n_units)]
        self.active_batch: Dict[int, BatchState] = {}
        self.batch_of_request: Dict[int, BatchState] = {}
        self.backlog_tokens = [0.0] * n_units
        self._bid = itertools.count()

        # --- scheduler state (O(active), not O(history): completed flows
        # and finished requests are evicted so long traces stay bounded) ---
        self.flows: Dict[int, Flow] = {}
        self.red_ranks: Dict[int, int] = {}
        self.pruned_rids: Set[int] = set()     # currently demoted
        self.ever_pruned: Set[int] = set()     # paid a prune (<= drop budget)
        self.n_pruned = 0
        self.n_red_runs = 0                    # Algorithm 1 invocations
        self._epoch = 0
        self._slo_base: Optional[float] = None  # fixed-mode low-load mean TTFT
        self._tick_armed = False
        self._G = len(profile.plan)
        self._t_first_decode = profile.first_decode_time()
        # optional observability: (rid, stage, group, size, deadline) per
        # submitted flow, consumed by the parity tests and the reports of
        # examples/serve_disagg.py; bounded so tracing cannot grow O(history)
        # — StageLog counts (and warns about) rows the bound drops
        self.trace_stages = trace_stages
        self.stage_log: StageLog = StageLog(maxlen=stage_log_limit)
        self.submit_level: Dict[int, int] = {}   # live flows only
        self._promoted: Dict[Stage, int] = {}    # evicted flows' promotions
        #: telemetry plane (repro.core.telemetry) — None keeps every probe
        #: site a single falsy check; the collector is a pure observer, so
        #: enabling it never changes scheduling outcomes
        self.telemetry = telemetry
        if telemetry is not None:
            telemetry.bind(lambda: self.net.now, topo,
                           t_first_decode=self._t_first_decode)
            if isinstance(policy, MFSScheduler):
                policy.attach_telemetry(telemetry)
        #: online monitor plane (repro.core.monitor) — streaming estimators
        #: over the SAME probe surface; like telemetry, a pure observer, and
        #: its SignalBus feeds detectors/routers the bit-identical values
        #: they used to compute in-line
        self.monitor = monitor
        if monitor is not None:
            monitor.bind(lambda: self.net.now, topo,
                         t_first_decode=self._t_first_decode)
            monitor.bind_live(self.routing_view)
            self.router.attach_bus(monitor.bus)
            if self.admission is not None:
                self.admission.detector.attach_bus(monitor.bus)
        #: single probe target — telemetry, monitor, a fanout over both, or
        #: None; every probe site stays ONE falsy check
        if telemetry is not None and monitor is not None:
            self._probe = ProbeFanout(telemetry, monitor)
        else:
            self._probe = telemetry if telemetry is not None else monitor

    # ---------------------------------------------------------- calibration
    def calibrate_slo(self, items: Sequence[PrefillItem]) -> None:
        """§6.1: one workload-level SLO base = the mean low-load TTFT
        (``slo_mode="fixed"``); each request's budget is its own
        ``slo_scale`` (tight/standard/loose class, falling back to the
        cluster default) times that base. Per-request mode derives each
        deadline from the request's own ideal at admission time instead.

        **Store-aware calibration**: with a KV-reuse plane attached, actual
        reuse comes from live store residency — not the trace's pre-sampled
        ``reuse_len`` — so the base is derived from the *expected
        steady-state hit* of each request's chain (a capacity-bounded LRU
        replay, :meth:`KVStore.steady_state_reuse`). Store-on and store-off
        attainment then measure scheduling against the same notion of
        achievable low-load TTFT instead of penalising store-on cold
        starts. Store-off keeps the legacy pre-sampled-reuse base
        bit-for-bit."""
        if self.slo_mode == "fixed" and items:
            if self.kvstore is not None:
                entries = [(self.host.kv_chain_keys(it),
                            max(0, it.n_tokens - 1)) for it in items]
                expected = self.kvstore.steady_state_reuse(entries)
                self._slo_base = float(np.mean([
                    self.profile.ideal_ttft(PrefillItem(
                        rid=-1, arrival=0.0, n_tokens=it.n_tokens,
                        reuse=min(exp, max(0, it.n_tokens - 1))))
                    for it, exp in zip(items, expected)]))
            else:
                self._slo_base = float(np.mean([self.profile.ideal_ttft(i)
                                                for i in items]))
        else:
            self._slo_base = None

    # ------------------------------------------------------------- plumbing
    def push_arrival(self, item: PrefillItem) -> None:
        self.evq.push(item.arrival, "arr", item)

    def _submit(self, flow: Flow) -> None:
        flow.created = self.net.now
        self.flows[flow.fid] = flow
        self.net.add(flow)
        if flow.rid in self.pruned_rids and flow.stage != Stage.COLLECTIVE:
            flow.state = FlowState.PRUNED
        self.policy.on_flow_submitted(flow, self.view)
        self.submit_level[flow.fid] = flow.level
        if self._probe is not None:
            # with telemetry/monitor on, the legacy stage log is backed by
            # the same probe (one append site, identical rows)
            self._probe.flow_submitted(
                flow, self.stage_log if self.trace_stages else None)
        elif self.trace_stages:
            self.stage_log.append((flow.rid, flow.stage, flow.target_layer,
                                   flow.size, flow.deadline))

    def _resched(self, trigger: Tuple = ("event",)) -> None:
        active = list(self.net.flows.values())
        self.policy.assign(active, self.view, trigger)
        if self.contention_free:
            for f in active:
                route = self.net.routes[f.fid]
                self.net.set_rate(f, min((self.topo.capacity[l] for l in route),
                                         default=2e12))
            self.net._link_rate = {}
        else:
            self.net.reallocate()
        self._epoch += 1
        nxt = self.net.next_completion()
        if nxt is not None:
            self.evq.push(nxt[0], "net", None, epoch=self._epoch)

    # ---------------------------------------------------------- unit driver
    def _maybe_start_batch(self, u: int) -> None:
        if u in self.active_batch or not self.queues[u]:
            return
        batch: List[PrefillItem] = []
        tokens = 0
        while self.queues[u]:
            it = self.queues[u][0]
            if batch and tokens + it.n_tokens > self.max_batch_tokens:
                break
            batch.append(self.queues[u].popleft())
            tokens += it.n_tokens
        bs = BatchState(
            bid=next(self._bid), unit=u, items=batch,
            group_time=[self.profile.group_compute_time(batch, g)
                        for g in range(self._G)],
            started=self.net.now)
        if self.chunk_tokens > 0:
            bs.chunk_plan = ChunkPlan.build(batch, self.chunk_tokens)
            bs.chunk_time = [
                [self.profile.chunk_compute_time(batch, bs.chunk_plan, g, c)
                 for c in range(bs.chunk_plan.n_chunks)]
                for g in range(self._G)]
        self.active_batch[u] = bs
        for it in batch:
            self.batch_of_request[it.rid] = bs
            bs.p2d_pending[it.rid] = set()
        self.host.on_batch_started(bs)
        if self._probe is not None:
            self._probe.on_batch_started(bs)
        for f in self.emitter.stage1(bs):
            self._submit(f)
        if self.policy.uses_inter_request:
            self._run_inter_request()
        self._try_start_group(bs)
        self._resched(("submit",))

    def _try_start_group(self, bs: BatchState) -> None:
        """Start the next cell of the (group, chunk) grid. Stage-1 gates
        only a group's FIRST chunk (causal attention needs the whole reused
        prefix before the group's first new token; later chunks depend on
        the previous chunk's collective instead); without a chunk plan the
        grid's chunk axis has length 1 and this is the legacy group walk."""
        g, c = bs.cur_group, bs.cur_chunk
        blocking = set()
        if c == 0:
            for gg in range(g + 1):
                for fid in bs.s1_pending.get(gg, ()):  # still outstanding
                    fl = self.flows[fid]
                    # scavenged (pruned) Stage-1 flows do NOT block the batch:
                    # their reuse is abandoned and recomputed instead (§5:
                    # "requests can be pruned ... to suppress communication")
                    if fl.state not in (FlowState.DONE, FlowState.PRUNED):
                        blocking.add(fid)
        if blocking:
            bs.phase = "wait_s1"
            if bs.stall_begin is None:
                bs.stall_begin = self.net.now
            return
        if bs.stall_begin is not None:
            dt = self.net.now - bs.stall_begin
            for it in bs.items:
                it.stalls += dt
            bs.stall_begin = None
        bs.phase = "compute"
        if bs.chunk_plan is None:
            dur = bs.group_time[g] + self._recompute_penalty(bs, g)
        else:
            dur = bs.chunk_time[g][c] \
                + (self._recompute_penalty(bs, g) if c == 0 else 0.0)
        if self._probe is not None:
            self._probe.compute_open(bs, g, c)
        self.evq.push(self.net.now + dur, "compute", (bs.bid, bs.unit, g, c))

    def _recompute_penalty(self, bs: BatchState, g: int) -> float:
        """Compute time to re-derive reused KV that pruning left undelivered.

        Charged once per (request, group), proportional to the undelivered
        fraction; the stale flow is cancelled to free its bandwidth."""
        extra = 0.0
        for gg in range(g + 1):
            for fid in list(bs.s1_pending.get(gg, ())):
                fl = self.flows[fid]
                if fl.state != FlowState.PRUNED or fl.remaining <= 0:
                    continue
                if (fl.rid, gg) in bs.recomputed:
                    continue
                it = next(i for i in bs.items if i.rid == fl.rid)
                if self.chunk_tokens > 0:
                    # chunked S1: the group's fetch is many chunk flows, so
                    # the (rid, group) is NOT marked done — each pruned
                    # chunk pays for ITS undelivered bytes relative to the
                    # request's whole group fetch (fractions over the
                    # group's chunk flows sum to the undelivered share;
                    # delivered chunks are never recomputed)
                    total = it.reuse * self.profile.kv_bytes_group(gg)
                    frac = fl.remaining / max(total, 1e-9)
                else:
                    bs.recomputed.add((fl.rid, gg))
                    frac = fl.remaining / max(fl.size, 1e-9)
                extra += self.profile.recompute_time(it.reuse, frac, gg)
                bs.s1_pending[gg].discard(fid)
                if fid in self.net.flows:
                    self.net.remove(fl)
                self.policy.on_flow_completed(fl, self.view)
                self._evict_flow(fl)
        return extra

    # ----------------------------------------------------------- state GC
    def _evict_flow(self, f: Flow) -> None:
        """Drop a finished/cancelled flow from runtime state, folding its
        promotion outcome into the compact per-stage counters first."""
        if self._probe is not None:
            self._probe.flow_closed(f, self.net)
        self.flows.pop(f.fid, None)
        lvl0 = self.submit_level.pop(f.fid, None)
        if lvl0 is not None and f.level < lvl0:
            self._promoted[f.stage] = self._promoted.get(f.stage, 0) + 1

    def promoted_count(self, stage: Optional[Stage] = None) -> int:
        """Flows promoted below their submission level (evicted + live)."""
        n = sum(v for s, v in self._promoted.items()
                if stage is None or s == stage)
        for fid, lvl0 in self.submit_level.items():
            f = self.flows.get(fid)
            if f is not None and (stage is None or f.stage == stage) \
                    and f.level < lvl0:
                n += 1
        return n

    # --------------------------------------------------------- event handlers
    def _on_arrival(self, item: PrefillItem) -> None:
        # Router plane: the host refreshes placement state (prefix-index
        # match / legacy reuse oracle), the configured policy places, and —
        # with a KV store attached — the winner's hit resolves against live
        # store state (pins + LRU touches happen for the chosen unit ONLY,
        # exactly the old kv_route order: read-only peek, then one resolve).
        self.host.prepare_route(item)
        u = self.router.place(item, self.routing_view)
        if self.kvstore is not None:
            keys = self.host.kv_chain_keys(item)
            plan = self.kvstore.resolve(keys, max(0, item.n_tokens - 1), u,
                                        item.rid, now=self.net.now)
            item.reuse = plan.tokens
            item.hit_plan = plan
            item.owner_unit = u
        if item.owner_unit < 0:
            item.owner_unit = u             # no-owner sentinel: self-owned
        item.unit = u
        if self._probe is not None:
            self._probe.on_arrival(item, u)
        if self.decode is not None and not item.pool:
            item.pool = self.decode.pick_pool(item)
        item.ideal_ttft = self.profile.ideal_ttft(item)
        # per-request SLO class (tight/standard/loose) scales either the
        # workload-level base (fixed mode) or the request's own ideal;
        # classless requests fall back to the pool default (P2D deadlines
        # differ per pool), then the cluster-wide default
        scale = item.slo_scale
        if scale <= 0 and self.decode is not None:
            scale = self.decode.pool_slo_scale(item.pool)
        if scale <= 0:
            scale = self.slo_scale
        if self.slo_mode == "fixed" and self._slo_base is not None:
            item.deadline = item.arrival + scale * self._slo_base
        else:
            item.deadline = item.arrival + scale * item.ideal_ttft
        # Admission stage: while the overload detector is tripped, sheddable
        # requests are rejected or delayed BEFORE they hold any resources —
        # the resolve above pinned store blocks for the hit, so both paths
        # must release them (re-resolved on a deferred retry).
        if self.admission is not None:
            verdict = self.admission.decide(item, self.routing_view, u)
            if verdict != "admit":
                if self.kvstore is not None:
                    self.kvstore.release(item.rid)
                    item.reuse, item.hit_plan = 0, None
                if verdict == "defer":
                    item.deferrals += 1
                    self.n_deferred += 1
                    self.host.on_deferred(item)
                    if self._probe is not None:
                        self._probe.on_deferred(item)
                    self.evq.push(self.net.now + self.admission.spec.defer_delay,
                                  "arr", item)
                else:
                    self.n_shed += 1
                    self.host.on_shed(item)
                    if self._probe is not None:
                        self._probe.on_shed(item)
                return
        self.queues[u].append(item)
        self.backlog_tokens[u] += item.n_tokens
        self.host.on_admitted(item)
        if self._probe is not None:
            self._probe.on_admitted(item)
        self._maybe_start_batch(u)

    def _on_compute_done(self, bid: int, unit: int, g: int, c: int = 0) -> None:
        bs = self.active_batch.get(unit)
        if bs is None or bs.bid != bid or bs.cur_group != g \
                or bs.cur_chunk != c or bs.phase != "compute":
            return   # stale
        if self._probe is not None:
            self._probe.compute_close(unit)
        if bs.chunk_plan is None:
            for f in self.emitter.stage3(bs, g, self._t_first_decode):
                self._submit(f)
            co = self.emitter.stage2(bs)
        else:
            # chunked prefill: the chunk's P2D leaves NOW, overlapping the
            # next chunk's compute; the chunk's collective gates that compute
            for f in self.emitter.stage3_chunk(bs, g, c, self._t_first_decode):
                self._submit(f)
            co = self.emitter.stage2_chunk(bs, g, c)
        if co is not None:
            co.started = self.net.now
            for fl in co.flows:
                self._submit(fl)
            bs.coll = co
            bs.coll_started = self.net.now
            bs.phase = "wait_coll"
            self._resched(("layer", unit))
            return
        self._advance_group(bs)
        self._resched(("layer", unit))

    def _advance_group(self, bs: BatchState) -> None:
        if bs.chunk_plan is not None \
                and bs.cur_chunk + 1 < bs.chunk_plan.n_chunks:
            bs.cur_chunk += 1            # next cell of the chunk grid
            bs.coll = None
            self._try_start_group(bs)
            return
        bs.cur_chunk = 0
        bs.cur_group += 1
        bs.coll = None
        if bs.cur_group >= self._G:
            bs.compute_done_at = self.net.now
            for it in bs.items:
                it.prefill_done = self.net.now
                self._maybe_finish_request(it, bs)
            bs.phase = "drain"
            del self.active_batch[bs.unit]
            self.backlog_tokens[bs.unit] = max(
                0.0, self.backlog_tokens[bs.unit] - bs.tokens)
            self._arm_tick()
            if self.policy.uses_inter_request:
                self._run_inter_request()
            self._maybe_start_batch(bs.unit)
        else:
            self._try_start_group(bs)

    def _maybe_finish_request(self, item: PrefillItem, bs: BatchState) -> None:
        if item.ttft is not None or item.prefill_done is None:
            return
        # Completion requires every *actually emitted* P2D flow to be done.
        # (Counting groups instead would deadlock requests whose KV-light
        # groups emitted no flow at all.) prefill_done is only set after the
        # last group ran, so the emitted set is final here. ``p2d_pending``
        # holds the still-outstanding fids (done flows are discarded as they
        # complete, with the latest finish time folded into ``p2d_last``) so
        # this check never needs the evicted flow records.
        if bs.p2d_pending.get(item.rid):
            return
        last = bs.p2d_last.get(item.rid, item.prefill_done)
        item.ttft = max(item.prefill_done, last) - item.arrival \
            + self._t_first_decode
        self.batch_of_request.pop(item.rid, None)
        self.red_ranks.pop(item.rid, None)
        self.pruned_rids.discard(item.rid)
        self.host.on_request_done(item, bs)
        if self._probe is not None:
            self._probe.on_request_done(item, bs)
        if self.kvstore is not None:
            # KV-reuse plane admission: the chain's blocks are registered in
            # the origin tier and loose-deadline Stage-WB replication flows
            # enter the shared net. Hit pins are released here unless a
            # decode plane holds the session live past its first token —
            # then the plane releases them on session finish/eviction.
            wbs = self.kvstore.admit(item, self.net.now,
                                     keep_pins=self.decode is not None)
            for f in wbs:
                self._submit(f)
            if wbs:
                self._resched(("submit",))
                self._arm_tick()
        if self.decode is not None:
            if self.decode.admit(item, self.net.now):
                self._resched(("submit",))   # admission triggered D2D flows
                self._arm_tick()

    def _on_flow_done(self, f: Flow) -> None:
        self.policy.on_flow_completed(f, self.view)
        if f.stage == Stage.WB:
            if self.kvstore is not None:
                # blocks land in the target tier; popularity-driven hot-block
                # replication may push follow-on WB flows toward more units
                wbs = self.kvstore.on_wb_done(f)
                for w in wbs or ():
                    self._submit(w)
                if wbs:
                    self._resched(("submit",))
                    self._arm_tick()
            self._evict_flow(f)
            return
        if f.stage == Stage.D2D:
            if self.decode is not None \
                    and self.decode.on_d2d_done(f, self.net.now):
                self._resched(("submit",))   # follow-up migrations submitted
            self._evict_flow(f)
            return
        bs = self.batch_of_request.get(f.rid)
        if f.stage == Stage.KV_REUSE:
            if bs is not None:
                bs.s1_pending.get(f.target_layer, set()).discard(f.fid)
                if bs.phase == "wait_s1":
                    self._try_start_group(bs)
        elif f.stage == Stage.COLLECTIVE:
            if bs is not None and bs.coll is not None and f.coflow == bs.coll.cid:
                if bs.coll.done():
                    bs.coll.finished = self.net.now
                    if self._probe is not None:
                        self._probe.coll_wait(
                            bs.bid, self.net.now - bs.coll_started)
                    co = bs.coll
                    self.host.on_coflow_done(bs, co, self._coflow_ideal(co))
                    if bs.phase == "wait_coll":
                        self._advance_group(bs)
        else:  # P2D
            if bs is not None:
                pend = bs.p2d_pending.get(f.rid)
                if pend is not None:
                    pend.discard(f.fid)
                    if f.finished is not None:
                        bs.p2d_last[f.rid] = max(
                            bs.p2d_last.get(f.rid, 0.0), f.finished)
                self._maybe_finish_request(
                    next(i for i in bs.items if i.rid == f.rid), bs)
        self._evict_flow(f)

    def _coflow_ideal(self, co: Coflow) -> float:
        worst = 0.0
        for f in co.flows:
            route = self.topo.route(f.src, f.dst, f.fid)
            cap = min((self.topo.capacity[l] for l in route), default=2e12)
            worst = max(worst, f.size / cap)
        return worst

    def _arm_tick(self) -> None:
        if not self._tick_armed:
            self._tick_armed = True
            self.evq.push(self.net.now + self.tick_interval, "tick", None)

    def _on_tick(self) -> None:
        self._tick_armed = False
        if self.kvstore is not None:
            # contended-link class accounting (WB share vs P2D/D2D/S1);
            # credit at most two tick pitches so idle gaps between bursts
            # are never attributed to the resuming traffic
            self.kvstore.sample_contention(self.net, self.net.now,
                                           max_dt=2 * self.tick_interval)
        if self.decode is not None and self.decode.auto_evict_enabled():
            # decode-side Algorithm-1 loop: abandon migrations whose derived
            # deadline went infeasible (spill/evict per class) — may cancel
            # and submit flows, so the allocation must refresh
            if self.decode.auto_evict(self.net.now):
                self._resched(("tick",))
        # post-compute P2D flows, in-flight D2D migrations and KV-store
        # writebacks all re-evaluate their MLU level on the periodic tick
        # (no layer boundaries to ride)
        post = [f for f in self.net.flows.values()
                if (f.stage == Stage.P2D and not self.view.computing(f.rid))
                or f.stage in (Stage.D2D, Stage.WB)]
        if post:
            self._resched(("tick",))
            self._arm_tick()

    # ------------------------------------------------- Algorithm 1 coupling
    def _run_inter_request(self) -> None:
        batches: List[BatchLoad] = []
        n_ports = 2 * self.topo.n_nodes       # NIC up/down links
        for bs in self.active_batch.values():
            loads: Dict[int, np.ndarray] = {}
            deadlines: Dict[int, float] = {}
            for it in bs.items:
                v = np.zeros(n_ports)
                for fid_set in list(bs.s1_pending.values()):
                    for fid in fid_set:
                        # pending sets hold live (outstanding/pruned) fids only
                        fl = self.flows.get(fid)
                        if fl is None or fl.rid != it.rid:
                            continue
                        for lid in self.topo.route(fl.src, fl.dst, fl.fid):
                            if lid < n_ports:
                                v[lid] += fl.remaining
                rem_kv = it.n_tokens * sum(
                    self.profile.kv_bytes_group(g)
                    for g in range(bs.cur_group, self._G))
                ep = self.emitter.rank_endpoint(bs, it, bs.cur_group)
                v[2 * ep] += rem_kv           # future P2D leaves via this NIC
                loads[it.rid] = v
                deadlines[it.rid] = it.deadline
            rem_groups = len(bs.group_time) - bs.cur_group
            if bs.chunk_plan is None:
                comp = sum(bs.group_time[bs.cur_group:]) \
                    + bs.recompute_extra * rem_groups
            else:       # chunk-aware: only the current group's REMAINING
                comp = sum(bs.chunk_time[bs.cur_group][bs.cur_chunk:]) \
                    + sum(bs.group_time[bs.cur_group + 1:]) \
                    + bs.recompute_extra * rem_groups
            batches.append(BatchLoad(bs.bid, loads, deadlines, comp))
        if not batches:
            return
        self.n_red_runs += 1
        port_bw = np.array([self.topo.capacity[l] for l in range(n_ports)])
        # Algorithm 1 takes a GLOBAL total drop budget; spend it across the
        # whole run so overload control cannot death-spiral the cluster.
        budget_left = max(0, self.drop_budget - self.n_pruned)
        sched = inter_request_schedule(batches, port_bw, now=self.net.now,
                                       drop_budget=budget_left)
        rank_of_batch = {bid: i for i, bid in enumerate(sched.order)}
        newly_pruned = {rid for (_, rid) in sched.pruned}
        if self._probe is not None:
            self._probe.red_run(sched.order, newly_pruned, len(batches))
        for bs in self.active_batch.values():
            for it in bs.items:
                self.red_ranks[it.rid] = rank_of_batch.get(bs.bid, 0)
        # soft enforcement: demote pruned requests' flows, abandon their reuse
        for bs in self.active_batch.values():
            for it in bs.items:
                if it.rid in newly_pruned and it.rid not in self.pruned_rids:
                    self.pruned_rids.add(it.rid)
                    self.ever_pruned.add(it.rid)
                    self.n_pruned += 1
                    if self._probe is not None:
                        self._probe.on_pruned(it.rid)
                    self._apply_prune(bs, it)
        # re-admission: requests no longer in the pruned set
        for rid in list(self.pruned_rids):
            if rid not in newly_pruned and rid in self.batch_of_request:
                self.pruned_rids.discard(rid)
                if self._probe is not None:
                    self._probe.on_readmitted(rid)
                for f in self.net.flows.values():
                    if f.rid == rid and f.state == FlowState.PRUNED:
                        f.state = FlowState.ACTIVE
                        if isinstance(self.policy, MFSScheduler):
                            self.policy.readmit(f, self.view)

    def _apply_prune(self, bs: BatchState, item: PrefillItem) -> None:
        """Soft enforcement (Appendix B Step 3): demote the request's
        KV-reuse and P2D flows to the scavenger class. Scavenged Stage-1
        flows no longer block the batch; whatever has not arrived by the time
        its layer group runs is recomputed (paid in _recompute_penalty)."""
        for f in list(self.net.flows.values()):
            if f.rid != item.rid or f.stage == Stage.COLLECTIVE:
                continue
            f.state = FlowState.PRUNED
            if isinstance(self.policy, MFSScheduler):
                self.policy.prune(f)
        if bs.phase == "wait_s1":
            self._try_start_group(bs)

    # ------------------------------------------------------------------ run
    def run(self, max_events: int = 5_000_000) -> None:
        """Drain the event queue (arrivals must already be pushed)."""
        n_ev = 0
        while self.evq and n_ev < max_events:
            popped = self.evq.pop()
            if popped is None:
                break
            t, kind, payload, epoch = popped
            n_ev += 1
            if self._probe is not None:
                # BEFORE advance: current rates are exactly the rates active
                # over [net.now, t], so span/link integration here is exact
                self._probe.on_advance(self.net, t)
            done = self.net.advance(t)
            for f in done:
                self._on_flow_done(f)
            if kind == "arr":
                self._on_arrival(payload)
                self._resched(("submit",))
            elif kind == "compute":
                self._on_compute_done(*payload)
            elif kind == "tick":
                self._on_tick()
            elif kind == "dstep":
                if self.decode is not None \
                        and self.decode.on_step(payload, t):
                    self._resched(("submit",))   # rebalancer emitted D2D
                    self._arm_tick()
            elif kind == "net":
                if done:
                    self._resched(("event",))
                elif epoch == self._epoch:
                    # numerically-stalled prediction; force refresh
                    self._resched(("event",))
