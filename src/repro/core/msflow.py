"""Multi-stage Flow (MsFlow) abstraction — §3.1 of the paper.

An MsFlow is the per-layer communication workload of a prefill request. It
consists of three temporally dependent stages:

  * Stage 1 (Initialization)  — KV-cache reuse fetch; implicit deadline;
    blocks the *target* layer's computation (lookahead transfer).
  * Stage 2 (Execution)       — collective communication (all-to-all for EP,
    all-gather/reduce-scatter for SP/TP); implicit deadline; strictly blocks
    the next computation step (RLI = 0).
  * Stage 3 (Completion)      — P2D transfer of the produced KV to the decode
    unit; explicit deadline = the request's TTFT deadline; never blocks
    prefill computation.

This module defines the plain-data flow records shared by the scheduler
(`repro.core`), the network simulator (`repro.netsim`) and the cluster
simulator (`repro.simcluster`). It is control-plane only: no JAX here.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Optional, Tuple

__all__ = [
    "Stage",
    "Flow",
    "Coflow",
    "FlowState",
    "new_flow_id",
]

_flow_counter = itertools.count()


def new_flow_id() -> int:
    return next(_flow_counter)


class Stage(IntEnum):
    """MsFlow stage identifiers (paper §3.1 + the decode plane)."""

    KV_REUSE = 1    # Stage 1: initialization — remote reusable KV fetch
    COLLECTIVE = 2  # Stage 2: execution — blocking collective
    P2D = 3         # Stage 3: completion — prefill→decode KV transfer
    D2D = 4         # decode plane: KV migration between decode endpoints
    #                 (load rebalancing); implicit deadline derived from the
    #                 destination's next-token (TPOT) budget
    WB = 5          # KV-reuse plane: writeback/replication of newly produced
    #                 prefix blocks into slower store tiers; loose derived
    #                 deadline — the most deferrable traffic class


class FlowState(IntEnum):
    PENDING = 0     # submitted, not yet permitted to transmit
    ACTIVE = 1      # transmitting (rate assigned by the fluid model)
    DONE = 2
    PRUNED = 3      # demoted to the scavenger class by overload control


@dataclass
class Flow:
    """A single point-to-point transfer.

    ``src``/``dst`` are node ids understood by the topology (host or NIC
    level). ``target_layer`` is the layer whose computation consumes this
    flow's data (L_target in the paper); for Stage 3 flows it is the layer
    that *produced* the data and is used only for promotion granularity.
    """

    fid: int
    rid: int                      # request id
    unit: int                     # serving-unit id that owns the request
    stage: Stage
    size: float                   # bytes
    src: int
    dst: int
    target_layer: int
    n_layers: int                 # depth L of the owning model
    deadline: Optional[float] = None   # absolute; Stage 3 only
    created: float = 0.0

    # --- runtime state (owned by netsim / scheduler) ---
    remaining: float = field(default=-1.0)
    state: FlowState = FlowState.PENDING
    rate: float = 0.0
    started: Optional[float] = None
    finished: Optional[float] = None
    # Scheduler-assigned knobs consumed by the fluid model:
    #   priority_key — lexicographically smaller = more urgent
    #   rate_cap     — optional ceiling (Karuna-style minimal-rate pacing)
    priority_key: Tuple = (0,)
    rate_cap: Optional[float] = None
    # Immutable per-tier fetch ceiling set at submission by the KV store
    # (host-DRAM / pooled-store read path): the fluid model caps the flow at
    # min(rate_cap, tier_cap), so policies may overwrite rate_cap freely.
    tier_cap: Optional[float] = None
    # RMLQ bookkeeping: current discrete level (1 = highest priority, K =
    # lowest, K+1 = scavenger). Promotion is monotone: level only decreases.
    level: int = 10**9
    coflow: Optional[int] = None  # owning coflow id, if any

    def __post_init__(self) -> None:
        if self.remaining < 0:
            self.remaining = float(self.size)

    @property
    def explicit_deadline(self) -> bool:
        return self.deadline is not None

    def __hash__(self) -> int:  # allow set membership
        return self.fid

    def __eq__(self, other) -> bool:
        return isinstance(other, Flow) and other.fid == self.fid


@dataclass
class Coflow:
    """A group of flows that complete together (e.g. one all-to-all phase).

    Completion time of the coflow = max over member completion times. Used
    for Stage 2 collectives and for the per-layer Stage 1/3 flow groups.
    """

    cid: int
    rid: int
    unit: int
    stage: Stage
    layer: int
    flows: list = field(default_factory=list)
    started: Optional[float] = None
    finished: Optional[float] = None

    @property
    def size(self) -> float:
        return sum(f.size for f in self.flows)

    @property
    def remaining(self) -> float:
        return sum(f.remaining for f in self.flows)

    def done(self) -> bool:
        return all(f.state == FlowState.DONE for f in self.flows)
