"""repro.core — the paper's contribution: MFS multi-stage flow scheduling.

Public surface:
    Stage, Flow, Coflow            — MsFlow abstraction (§3.1)
    MLUConfig, mlu, mlu_level      — explicit-deadline urgency (§4.3)
    rli_level                      — implicit-deadline urgency (§4.4.1)
    RMLQ                           — Reverse Multi-Level Queue (§4.2)
    red_score, sort_by_red         — Robust Effective Deadline (§4.4.2)
    inter_request_schedule         — Algorithm 1 (Appendix B)
    MFSScheduler                   — the full arbiter (§4.5)
    FairShare, SJF, EDF, Karuna    — baselines (§6.3), LLFOracle ceiling
    GroupPlan, StageProfile, StageEmitter — shared stage-emission layer (§3.1)
    DecodePlane, DecodeSpec        — decode plane: pools, TPOT tracking,
                                     D2D KV-migration rebalancing
    KVStore, KVStoreSpec, TierSpec — KV-reuse plane: shared tiered prefix
                                     store, live hits, Stage-WB writebacks
    RouterPolicy, RouterSpec, make_router — router plane: pluggable
                                     cluster-level placement policies
    OverloadDetector, AdmissionSpec — overload-triggered admission control
                                     (shed/defer loose-SLO requests)
    Telemetry, TelemetrySpec       — telemetry plane: lifecycle spans, RMLQ
                                     decision audit, link-contention
                                     attribution, SLO-miss root causes
    Monitor, MonitorSpec, SignalBus — online monitor plane: streaming
                                     estimators over the same probe sites,
                                     live signals for detectors/routers
    Dinic, FlowGraph, disagg_bound — max-flow optimality yardstick
                                     (Helix-style attainment ceiling)
    MsFlowRuntime, RuntimeHost     — shared orchestration runtime (§5)
"""
from .msflow import Stage, Flow, Coflow, FlowState, new_flow_id
from .urgency import MLUConfig, mlu, mlu_level, geometric_thresholds, rli_level
from .rmlq import RMLQ
from .red import red_score, partition_by_max_gap, sort_by_red, BatchRef
from .feasibility import BatchLoad, InterSchedule, inter_request_schedule
from .policies import (
    Policy,
    SchedView,
    FairShare,
    SJF,
    EDF,
    Karuna,
    LLFOracle,
    make_policy,
)
from .arbiter import MFSScheduler
from .stages import (ParallelismSpec, GroupPlan, ChunkSpec, ChunkPlan,
                     StageProfile, PrefillItem, BatchState, StageEmitter)
from .decode import (DecodePoolSpec, DecodeSpec, DecodeSession, DecodePlane,
                     partition_pools)
from .kvstore import (TierSpec, KVStoreSpec, HitSegment, HitPlan, KVStore,
                      kv_route, chain_keys, content_chain)
from .router import (RoutingView, RouterPolicy, KVAffinityRouter,
                     RoundRobinRouter, SessionAffinityRouter,
                     LeastBacklogRouter, register_router, make_router,
                     OverloadDetector, QueueDepthDetector, LaxityDebtDetector,
                     register_detector, make_detector,
                     RouterSpec, AdmissionSpec, AdmissionController)
from .telemetry import (Telemetry, TelemetrySpec, StageLog, FlowSpan,
                        RequestTrace, link_name)
from .monitor import (Monitor, MonitorSpec, SignalBus, FixedBinSketch,
                      RollingWindow, ProbeFanout)
from .maxflow import (Dinic, FlowGraph, fixed_route_rate, disagg_bound,
                      attainment_ceiling)
from .runtime import MsFlowRuntime, RuntimeHost, RuntimeView

__all__ = [
    "Stage", "Flow", "Coflow", "FlowState", "new_flow_id",
    "MLUConfig", "mlu", "mlu_level", "geometric_thresholds", "rli_level",
    "RMLQ",
    "red_score", "partition_by_max_gap", "sort_by_red", "BatchRef",
    "BatchLoad", "InterSchedule", "inter_request_schedule",
    "Policy", "SchedView",
    "FairShare", "SJF", "EDF", "Karuna", "LLFOracle", "make_policy",
    "MFSScheduler",
    "ParallelismSpec", "GroupPlan", "ChunkSpec", "ChunkPlan", "StageProfile",
    "PrefillItem", "BatchState", "StageEmitter",
    "DecodePoolSpec", "DecodeSpec", "DecodeSession", "DecodePlane",
    "partition_pools",
    "TierSpec", "KVStoreSpec", "HitSegment", "HitPlan", "KVStore",
    "kv_route", "chain_keys", "content_chain",
    "RoutingView", "RouterPolicy", "KVAffinityRouter", "RoundRobinRouter",
    "SessionAffinityRouter", "LeastBacklogRouter", "register_router",
    "make_router", "OverloadDetector", "QueueDepthDetector",
    "LaxityDebtDetector", "register_detector", "make_detector",
    "RouterSpec", "AdmissionSpec", "AdmissionController",
    "Telemetry", "TelemetrySpec", "StageLog", "FlowSpan", "RequestTrace",
    "link_name",
    "Monitor", "MonitorSpec", "SignalBus", "FixedBinSketch", "RollingWindow",
    "ProbeFanout",
    "Dinic", "FlowGraph", "fixed_route_rate", "disagg_bound",
    "attainment_ceiling",
    "MsFlowRuntime", "RuntimeHost", "RuntimeView",
]
