"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be imported/run before anything else initialises jax: the first two
lines pin 512 placeholder host devices so ``jax.make_mesh`` can build the
production meshes. Do NOT set this env var anywhere global — smoke tests
and benches see 1 device.

Per cell this entrypoint records:
  * compile success,
  * ``compiled.memory_analysis()``  (per-device bytes — proves it fits),
  * ``compiled.cost_analysis()``    (HLO FLOPs / bytes for the roofline),
  * collective bytes parsed from the partitioned HLO text, per collective
    kind (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) — cost_analysis does not expose these,
  * analytic per-device input residency (params + caches + batch).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --mesh both \
        [--arch qwen1.5-32b ...] [--shape train_4k ...] [--out experiments]
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .mesh import make_production_mesh
from .specs import Cell, build_cell, plan_cells

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*(?P<lhs>[^=]*?)\s+(?P<op>all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-kind result bytes of every collective in the partitioned HLO.

    ``-done`` variants are skipped (their ``-start`` twin already counted).
    Returns {kind: {count, bytes}} plus a total.
    """
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if m is None or "-done(" in line:
            continue
        kind = m.group("op")
        lhs = m.group("lhs")
        b = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(lhs))
        out[kind]["count"] += 1
        out[kind]["bytes"] += float(b)
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out


def _spec_shards(sharding, shape) -> int:
    """Number of devices one leaf is split over (for residency math)."""
    try:
        spec = sharding.spec
        mesh_shape = dict(zip(sharding.mesh.axis_names, sharding.mesh.shape.values())) \
            if hasattr(sharding.mesh.shape, "values") else None
    except AttributeError:
        return 1
    n = 1
    mesh = sharding.mesh
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            n *= mesh.shape[a]
    return n


def analytic_input_bytes(args, shardings) -> float:
    """Exact per-device residency of the cell's inputs."""
    leaves_a = jax.tree.leaves(args)
    leaves_s = jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
    total = 0.0
    for a, s in zip(leaves_a, leaves_s):
        size = np.prod(a.shape) * a.dtype.itemsize if a.shape else a.dtype.itemsize
        total += size / _spec_shards(s, a.shape)
    return total


def run_cell(cell: Cell, mesh, save_hlo: Optional[str] = None,
             unroll: bool = False) -> Dict[str, Any]:
    rec: Dict[str, Any] = {"arch": cell.arch, "shape": cell.shape.name,
                           "kind": cell.kind, "mesh": "x".join(
                               f"{mesh.shape[a]}{a}" for a in mesh.axis_names)}
    if cell.skip:
        rec["status"] = "skip"
        rec["reason"] = cell.skip
        return rec
    t0 = time.time()
    try:
        cell = build_cell(cell, mesh, unroll=unroll)
        with mesh:
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             out_shardings=cell.out_shardings)
            lowered = jitted.lower(*cell.args)
            compiled = lowered.compile()
        rec["status"] = "ok"
        rec["compile_s"] = round(time.time() - t0, 1)
        rec["model_flops"] = cell.model_flops
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            rec["cost_analysis"] = {
                "flops": float(ca.get("flops", -1.0)),
                "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
            }
        except Exception as e:              # pragma: no cover
            rec["cost_analysis"] = {"error": str(e)}
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(ma, k)) for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)}
        except Exception as e:              # pragma: no cover
            rec["memory_analysis"] = {"error": str(e)}
        rec["input_bytes_per_device"] = analytic_input_bytes(
            cell.args, cell.in_shardings)
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo)
        rec["hlo_instructions"] = hlo.count("\n")
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--arch", nargs="*", default=None)
    ap.add_argument("--shape", nargs="*", default=None)
    ap.add_argument("--out", default="experiments")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer scan so cost_analysis / collective"
                         " counts are exact (roofline pass; slower compiles)")
    ap.add_argument("--tag", default="",
                    help="suffix for the output json filename")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    for mesh_name, mesh in meshes:
        results: List[Dict[str, Any]] = []
        for cell in plan_cells(args.arch, args.shape):
            hlo_path = (os.path.join(
                args.out, f"hlo_{mesh_name}_{cell.arch}_{cell.shape.name}.txt")
                if args.save_hlo else None)
            rec = run_cell(cell, mesh, save_hlo=hlo_path, unroll=args.unroll)
            results.append(rec)
            status = rec["status"]
            extra = ""
            if status == "ok":
                ma = rec.get("memory_analysis", {})
                arg_gb = ma.get("argument_size_in_bytes", 0) / 1e9
                col_gb = rec["collectives"]["total_bytes"] / 1e9
                extra = (f"args={arg_gb:.2f}GB/dev "
                         f"coll={col_gb:.3f}GB "
                         f"compile={rec['compile_s']}s")
            elif status == "fail":
                extra = rec["error"][:120]
            else:
                extra = rec["reason"][:60]
            print(f"[{mesh_name}] {cell.arch:22s} {cell.shape.name:12s} "
                  f"{status:4s} {extra}", flush=True)
        path = os.path.join(args.out, f"dryrun_{mesh_name}{args.tag}.json")
        with open(path, "w") as f:
            json.dump(results, f, indent=1)
        ok = sum(r["status"] == "ok" for r in results)
        skip = sum(r["status"] == "skip" for r in results)
        fail = sum(r["status"] == "fail" for r in results)
        print(f"[{mesh_name}] done: {ok} ok / {skip} skip / {fail} fail "
              f"-> {path}", flush=True)


if __name__ == "__main__":
    main()
