"""Training launcher: builds (mesh, model, data, optimizer), runs the jitted
train_step loop with checkpoint/restart fault tolerance.

Scales from single-host CPU smoke runs (``--arch smollm-360m --smoke``) to
the production mesh (same code path — the mesh and ShardCtx change, nothing
else). Restart-safe: the data pipeline is stateless given (seed, step), so
``--resume`` continues bit-identically from the last checkpoint, including
after an elastic mesh change (checkpoints store logical arrays that get
resharded on load).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
        --steps 200 --batch 8 --seq 128 [--ckpt /tmp/ck --resume]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, SMOKES
from ..models.lm import build_model
from ..models.sharding import ShardCtx
from ..training.checkpoint import (latest_step, restore_checkpoint,
                                   save_checkpoint)
from ..training.optim import AdamWConfig
from ..training.trainer import init_train_state, make_train_step
from .mesh import make_mesh_for

__all__ = ["synthetic_batch", "run"]


def synthetic_batch(cfg, batch: int, seq: int, seed: int, step: int):
    """Deterministic synthetic LM data: (seed, step) -> batch. Stateless, so
    restart resumes the exact stream (fault-tolerance contract)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    toks = rng.integers(0, cfg.vocab, size=(batch, seq + 1), dtype=np.int32)
    out = {"tokens": jnp.asarray(toks[:, :-1]),
           "labels": jnp.asarray(toks[:, 1:])}
    if cfg.family == "vlm":
        emb = rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32)
        out = {"inputs_embeds": jnp.asarray(emb, jnp.bfloat16),
               "labels": out["labels"]}
    if cfg.enc_layers:
        src = rng.normal(size=(batch, max(16, seq // 4), cfg.d_model))
        out["src_embeds"] = jnp.asarray(src, jnp.bfloat16)
    if cfg.mtp:
        out["labels2"] = jnp.asarray(
            np.concatenate([toks[:, 2:], toks[:, -1:]], 1))
    return out


def run(arch: str, *, smoke: bool = True, steps: int = 100, batch: int = 8,
        seq: int = 128, lr: float = 3e-4, seed: int = 0,
        ckpt_dir: str = "", ckpt_every: int = 50, resume: bool = False,
        model_par: int = 1, log_every: int = 10, remat: bool = False):
    cfg = (SMOKES if smoke else ARCHS)[arch]
    n_dev = jax.device_count()
    ctx = (ShardCtx(mesh=make_mesh_for(n_dev, model_par))
           if n_dev > 1 else ShardCtx())
    model = build_model(cfg, ctx, remat=remat)
    opt_cfg = AdamWConfig(lr=lr)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0,))

    start = 0
    if resume and ckpt_dir and latest_step(ckpt_dir) is not None:
        start = latest_step(ckpt_dir)
        abstract = jax.eval_shape(
            lambda k: init_train_state(model, k, opt_cfg),
            jax.random.PRNGKey(seed))
        state = restore_checkpoint(ckpt_dir, start, abstract)
        print(f"resumed from step {start}")
    else:
        state = init_train_state(model, jax.random.PRNGKey(seed), opt_cfg)

    losses = []
    t0 = time.time()
    for step in range(start, steps):
        batch_data = synthetic_batch(cfg, batch, seq, seed, step)
        state, metrics = step_fn(state, batch_data)
        losses.append(float(metrics["loss"]))
        if log_every and (step + 1) % log_every == 0:
            dt = (time.time() - t0) / max(1, len(losses))
            print(f"step {step + 1:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"{dt * 1e3:.0f} ms/step", flush=True)
        if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step + 1, state)
    return state, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    a = ap.parse_args()
    _, losses = run(a.arch, smoke=a.smoke, steps=a.steps, batch=a.batch,
                    seq=a.seq, lr=a.lr, seed=a.seed, ckpt_dir=a.ckpt,
                    ckpt_every=a.ckpt_every, resume=a.resume,
                    model_par=a.model_par, remat=a.remat)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
