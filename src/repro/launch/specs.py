"""Per-(architecture x input-shape) dry-run cell construction.

For every cell this module builds:
  * the step function actually deployed for that shape kind
      - train_*   -> ``train_step``  (loss + AdamW update, remat)
      - prefill_* -> ``prefill_step`` (logits + KV cache)
      - decode_* / long_* -> ``serve_step`` (one token against a full cache)
  * ShapeDtypeStruct stand-ins for every input (no allocation),
  * in/out NamedShardings derived from launch.shardings,
  * roofline metadata (MODEL_FLOPS, bytes) consumed by benchmarks.roofline.

Cell-level policy decisions (recorded in DESIGN.md / EXPERIMENTS.md):
  * decode KV caches are sequence-sharded over "model" (flash-decoding) and
    store real (unpadded) KV heads;
  * qwen1.5-32b decode_32k stores int8 KV — the only cell whose bf16 cache
    exceeds pod HBM;
  * DeepSeek-V3 runs 2D expert parallelism over ("data","model") — a 16-way
    shard of its 645B expert bank cannot fit one chip;
  * training runs ZeRO-3 over the DP axes with remat; DeepSeek-V3 training
    additionally uses bf16 optimizer moments;
  * ``long_500k`` lowers only for the bounded-state archs (mamba2,
    recurrentgemma); the 8 full-attention archs are documented skips.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES
from ..configs.base import ArchConfig, ShapeCell
from ..models.lm import Model, build_model
from ..models.sharding import ShardCtx
from ..training.optim import AdamWConfig
from ..training.trainer import TrainState, init_train_state, make_train_step
from .shardings import batch_specs, cache_specs, param_specs, to_shardings

__all__ = ["Cell", "plan_cells", "build_cell", "input_specs", "make_ctx",
           "SKIP_REASONS", "KV_DTYPE_OVERRIDES"]

# archs with an O(1)-state long-context path; everyone else skips long_500k
_SUBQUADRATIC = {"mamba2-1.3b", "recurrentgemma-9b"}

SKIP_REASONS: Dict[Tuple[str, str], str] = {
    (a, "long_500k"): ("pure full attention: a 524288-token dense KV cache "
                       "has no sub-quadratic path (documented skip)")
    for a in ARCHS if a not in _SUBQUADRATIC
}

#: cells whose bf16 KV cache exceeds pod HBM -> int8 storage
KV_DTYPE_OVERRIDES: Dict[Tuple[str, str], Any] = {
    ("qwen1.5-32b", "decode_32k"): jnp.int8,
}

#: MoE archs whose expert bank needs pod-wide (2D) expert parallelism
_EP_2D = {"deepseek-v3-671b"}


@dataclass
class Cell:
    arch: str
    shape: ShapeCell
    kind: str                                  # train | prefill | decode
    fn: Callable = None
    args: Tuple = ()                           # ShapeDtypeStructs
    in_shardings: Tuple = ()
    out_shardings: Any = None
    model_flops: float = 0.0                   # 6ND / 2ND per step
    skip: Optional[str] = None
    kv_dtype: Any = jnp.bfloat16
    notes: str = ""


def plan_cells(archs: Optional[List[str]] = None,
               shapes: Optional[List[str]] = None) -> List[Cell]:
    out = []
    for a in (archs or list(ARCHS)):
        for s in SHAPES:
            if shapes and s.name not in shapes:
                continue
            out.append(Cell(arch=a, shape=s, kind=s.kind,
                            skip=SKIP_REASONS.get((a, s.name)),
                            kv_dtype=KV_DTYPE_OVERRIDES.get(
                                (a, s.name), jnp.bfloat16)))
    return out


# =====================================================================
# context / policy selection
# =====================================================================
def make_ctx(cfg: ArchConfig, mesh: Mesh, shape: ShapeCell) -> ShardCtx:
    multi_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    ep_axes = (("data", "model") if cfg.name in _EP_2D else ("model",))
    return ShardCtx(
        mesh=mesh,
        batch_axes=batch_axes,
        zero3=(shape.kind == "train"),
        zero3_axes=batch_axes,
        ep_axes=ep_axes,
        kv_seq_shard=(shape.kind == "decode"),
    )


def _src_len(cfg: ArchConfig, seq_len: int) -> int:
    """Encoder frame count for the stubbed audio frontend."""
    return max(16, min(4096, seq_len // 4))


# =====================================================================
# input specs (ShapeDtypeStruct stand-ins, per the brief)
# =====================================================================
def input_specs(arch: str, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract model inputs for one cell (weak-type-correct, shardable)."""
    cfg = ARCHS[arch]
    shape = next(s for s in SHAPES if s.name == shape_name)
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    batch: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "decode":
        batch["tok"] = sds((B, 1), i32)
        batch["pos"] = sds((), i32)
        return batch
    if cfg.family == "vlm":
        # modality frontend stub: precomputed patch embeddings
        batch["inputs_embeds"] = sds((B, T, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = sds((B, T), i32)
    if cfg.enc_layers:
        batch["src_embeds"] = sds((B, _src_len(cfg, T), cfg.d_model),
                                  jnp.bfloat16)
    if shape.kind == "train":
        batch["labels"] = sds((B, T), i32)
        if cfg.mtp:
            batch["labels2"] = sds((B, T), i32)
    return batch


# =====================================================================
# cell building
# =====================================================================
def build_cell(cell: Cell, mesh: Mesh, unroll: bool = False) -> Cell:
    """Populate ``cell`` with fn/args/shardings for ``mesh``."""
    cfg = ARCHS[cell.arch]
    shape = cell.shape
    ctx = make_ctx(cfg, mesh, shape)
    model = build_model(cfg, ctx, remat=(shape.kind == "train"))
    model.unroll = unroll
    key = jax.random.PRNGKey(0)

    pspecs = param_specs(model, key)
    psh = to_shardings(pspecs, mesh)
    abstract_params = jax.eval_shape(model.init, key)

    batch_abs = _model_batch(cfg, shape)
    bsh = to_shardings(batch_specs(batch_abs, ctx), mesh)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(
            state_dtype=(jnp.bfloat16 if cfg.name == "deepseek-v3-671b"
                         else jnp.float32))
        step = make_train_step(model, opt_cfg)
        state_abs = jax.eval_shape(
            lambda k: init_train_state(model, k, opt_cfg), key)
        state_spec = TrainState(
            params=pspecs,
            opt=type(state_abs.opt)(step=P(), m=pspecs, v=pspecs),
            step=P())
        ssh = to_shardings(state_spec, mesh)
        metrics_sh = {"loss": NamedSharding(mesh, P()),
                      "grad_norm": NamedSharding(mesh, P())}
        cell.fn = step
        cell.args = (state_abs, batch_abs)
        cell.in_shardings = (ssh, bsh)
        cell.out_shardings = (ssh, metrics_sh)
        cell.model_flops = 6.0 * cfg.params_active() * shape.global_batch \
            * shape.seq_len
    elif shape.kind == "prefill":
        def prefill_step(p, b):
            return model.prefill(p, b)
        cell.fn = prefill_step
        cell.args = (abstract_params, batch_abs)
        cell.in_shardings = (psh, bsh)
        cell.out_shardings = None                       # compiler chooses
        cell.model_flops = 2.0 * cfg.params_active() * shape.global_batch \
            * shape.seq_len
    else:                                               # decode / long
        B, S = shape.global_batch, shape.seq_len
        src = _src_len(cfg, S) if cfg.enc_layers else 0
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(B, S, cell.kv_dtype, src_len=src))
        csh = to_shardings(cache_specs(cache_abs, ctx), mesh)
        tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
        tok_sh = NamedSharding(mesh, batch_specs(tok_abs, ctx))
        pos_sh = NamedSharding(mesh, P())

        def serve_step(p, caches, tok, pos):
            return model.decode_step(p, caches, tok, pos)
        logits_sh = NamedSharding(mesh, P(None, None, None))
        cell.fn = serve_step
        cell.args = (abstract_params, cache_abs, tok_abs, pos_abs)
        cell.in_shardings = (psh, csh, tok_sh, pos_sh)
        cell.out_shardings = (logits_sh, csh)           # stable decode loop
        cell.model_flops = 2.0 * cfg.params_active() * B
    return cell


def _model_batch(cfg: ArchConfig, shape: ShapeCell):
    """ShapeDtypeStruct batch in the model's own key naming."""
    B, T = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "vlm":
        batch["inputs_embeds"] = sds((B, T, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = sds((B, T), jnp.int32)
    if cfg.enc_layers:
        batch["src_embeds"] = sds((B, _src_len(cfg, T), cfg.d_model),
                                  jnp.bfloat16)
    if shape.kind == "train":
        batch["labels"] = sds((B, T), jnp.int32)
        if cfg.mtp:
            batch["labels2"] = sds((B, T), jnp.int32)
    return batch
