"""Serving launcher: disaggregated P/D cluster with MFS-scheduled transfers.

Runs the real JAX engine (reduced config on CPU; full config on a pod) under
the DisaggServer orchestrator and reports per-request TTFT / SLO attainment
per scheduling policy.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --requests 16 --rps 200 --policy mfs [--policy fs ...]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import ARCHS, SMOKES
from ..core import make_policy
from ..models.lm import build_model
from ..serving import DisaggConfig, DisaggServer, ServeRequest

__all__ = ["make_requests", "run"]


def make_requests(cfg, n: int, rps: float, seed: int = 0,
                  reuse_rate: float = 0.5, mean_prompt: int = 48,
                  max_new: int = 4):
    """Synthetic request stream with Zipf-hot shared prefixes (the paper's
    agent-workload shape at toy scale)."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, cfg.vocab, size=(32,)) for _ in range(4)]
    pmf = np.array([1.0 / (i + 1) ** 1.6 for i in range(4)])
    pmf /= pmf.sum()
    gaps = rng.exponential(1.0 / rps, size=n)
    arrivals = np.cumsum(gaps)
    out = []
    for i in range(n):
        ln = int(np.clip(rng.lognormal(np.log(mean_prompt), 0.4), 16, 512))
        if rng.uniform() < reuse_rate:
            pfx = prefixes[rng.choice(4, p=pmf)]
            toks = np.concatenate([pfx, rng.integers(0, cfg.vocab,
                                                     size=(max(1, ln - 32),))])
        else:
            toks = rng.integers(0, cfg.vocab, size=(ln,))
        out.append(ServeRequest(rid=i, arrival=float(arrivals[i]),
                                tokens=toks, max_new=max_new))
    return out


def run(arch: str, *, smoke: bool = True, n_requests: int = 16,
        rps: float = 200.0, policies=("mfs",), seed: int = 0,
        n_units: int = 2, verbose: bool = True):
    cfg = (SMOKES if smoke else ARCHS)[arch]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    reqs = make_requests(cfg, n_requests, rps, seed)
    summary = {}
    for pol in policies:
        srv = DisaggServer(model, params, policy=make_policy(pol),
                           cfg=DisaggConfig(n_prefill_units=n_units))
        res = srv.serve(reqs)
        slo = sum(r.met_slo for r in res) / len(res)
        mean_ttft = float(np.mean([r.ttft for r in res]))
        reuse = sum(r.reused_tokens for r in res) / max(
            1, sum(len(r0.tokens) for r0 in reqs))
        summary[pol] = {"slo_attainment": slo, "mean_ttft_ms": mean_ttft * 1e3,
                        "reuse_fraction": reuse}
        if verbose:
            print(f"{pol:10s} slo={slo:6.3f} mean_ttft={mean_ttft * 1e3:8.3f}ms"
                  f" reuse={reuse:.2%}", flush=True)
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rps", type=float, default=200.0)
    ap.add_argument("--policy", action="append", default=None)
    ap.add_argument("--units", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    run(a.arch, smoke=a.smoke, n_requests=a.requests, rps=a.rps,
        policies=tuple(a.policy or ["mfs", "fs", "sjf", "edf", "karuna"]),
        seed=a.seed, n_units=a.units)


if __name__ == "__main__":
    main()
