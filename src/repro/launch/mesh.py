"""Production meshes for the multi-pod dry-run.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — the dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialisation, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh_for"]


def make_production_mesh(*, multi_pod: bool = False):
    """(data=16, model=16) single pod, (pod=2, data=16, model=16) 512-chip."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(n_devices: int, model_par: int = 1):
    """Small mesh helper for examples/tests on however many devices exist."""
    assert n_devices % model_par == 0
    return jax.make_mesh((n_devices // model_par, model_par),
                         ("data", "model"))
