"""repro.launch — meshes, dry-run, and cluster entrypoints.

NOTE: ``repro.launch.dryrun`` must be the FIRST jax-touching import of its
process (it pins 512 placeholder devices). Import it only as an entrypoint
(``python -m repro.launch.dryrun``), never from library code.
"""
from .mesh import make_production_mesh, make_mesh_for

__all__ = ["make_production_mesh", "make_mesh_for"]
