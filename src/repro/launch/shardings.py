"""Parameter / cache / batch PartitionSpec derivation for the dry-run.

Specs are derived *structurally* from an abstract ``jax.eval_shape`` of the
model init: every leaf is classified by the names on its tree path and its
rank, so new blocks inherit sensible shardings without a registry edit.

Layout policy (see DESIGN.md §5):
  * TP: projection output dims (heads, d_ff, vocab) over "model"; the
    mirrored input dims of the out-projections over "model" as well.
  * EP: expert bank dim over ``ep_axes`` (("model",) or ("data","model")
    for DeepSeek-V3-scale banks).
  * ZeRO-3 (training): the non-TP dim of every matmul weight over
    ``zero3_axes``; optimizer moments inherit the same specs.
  * SSM / RG-LRU mixers: replicated over "model" (their recurrences are
    latency-bound and small), ZeRO-3 over data for training.
  * Decode KV caches: sequence-sharded over "model" (flash-decoding);
    batch over the DP axes.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.sharding import ShardCtx

__all__ = ["param_specs", "cache_specs", "batch_specs", "to_shardings"]


def _name(entry) -> str:
    return str(getattr(entry, "key", getattr(entry, "idx", entry)))


def _path_names(path) -> Tuple[str, ...]:
    return tuple(_name(e) for e in path)


# weight-dict parents whose 'w' has its OUTPUT dim TP-sharded
_OUT_TP = {"wq", "wk", "wv", "wq_b", "wk_b", "wv_b", "wi", "wg", "unembed",
           "w_x", "w_gate_branch"}      # rglru width is TP-sharded too
# parents whose 'w' has its INPUT dim TP-sharded (out-projections)
_IN_TP = {"wo", "w_out_rg"}
# parents kept replicated on "model" (latent/small projections)
_REPL = {"wq_a", "wkv_a", "mtp_proj"}
# moe expert bank leaves (3D arrays, dim0 = expert)
_EXPERT = {"w_in", "w_gate", "w_out"}


def _zero3(ctx: ShardCtx):
    if not ctx.zero3:
        return None
    return ctx.zero3_axes if len(ctx.zero3_axes) > 1 else ctx.zero3_axes[0]


def _axes_size(ctx: ShardCtx, ax) -> int:
    if ax is None or ctx.mesh is None:
        return 1
    if isinstance(ax, str):
        return ctx.mesh.shape[ax]
    n = 1
    for a in ax:
        n *= ctx.mesh.shape[a]
    return n


def _guarded(ctx: ShardCtx, leaf, *axes) -> P:
    """Drop any proposed axis whose mesh size does not divide the dim —
    e.g. mamba2's vocab (50280) is not 16-divisible, so its embedding
    falls back to replicated-over-model."""
    parts = []
    # leading dims beyond the spec (scan-stacked) default to None
    axes = list(axes) + [None] * (leaf.ndim - len(axes))
    for size, ax in zip(leaf.shape, axes):
        n = _axes_size(ctx, ax)
        parts.append(ax if (n > 1 and size % n == 0) else None)
    return P(*parts)


def param_pspec(path, leaf, ctx: ShardCtx) -> P:
    names = _path_names(path)
    leafname = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    z3 = _zero3(ctx)
    mdl = ctx.model_axis
    ep = ctx.ep_axes if len(ctx.ep_axes) > 1 else ctx.ep_axes[0]
    # scan-stacked layer params carry a leading (count,) dim; spec dims are
    # matched from the RIGHT
    extra = 0
    core_ndim = leaf.ndim

    def with_lead(*axes):
        lead = leaf.ndim - len(axes)
        return _guarded(ctx, leaf, *([None] * lead), *axes)

    # ---- embeddings -----------------------------------------------------
    if leafname == "embed":
        return _guarded(ctx, leaf, mdl, z3)          # vocab sharded
    # ---- MoE expert banks ([count?, E, d, F]) ---------------------------
    if leafname in _EXPERT and leaf.ndim >= 3:
        # ZeRO-3 the d_model dim over every DP axis NOT already carrying
        # experts (§Perf iteration: optimizer moments of a 645B expert bank
        # must not replicate over the pod). The EP shard_map gathers the
        # spare axes back at use — standard ZeRO-3 cost.
        extra = None
        if ctx.zero3:
            cand = [a for a in ctx.zero3_axes if a not in ctx.ep_axes]
            if cand:
                extra = tuple(cand) if len(cand) > 1 else cand[0]
        return with_lead(ep, extra, None)
    if leafname == "router":
        return with_lead(None, None)
    # rglru block-diagonal gates + per-channel decay: width over "model"
    if leafname in ("gate_in", "gate_rec"):
        return with_lead(mdl, None, None)
    if leafname == "a_param":
        return with_lead(mdl)
    # ---- dense dicts {'w': [in, out], 'b': [out]} -----------------------
    if leafname == "w":
        owner = parent
        if owner in _OUT_TP or (len(names) >= 3 and names[-3] == "unembed"):
            return with_lead(z3, mdl)
        if owner in _IN_TP:
            return with_lead(mdl, z3)
        if owner in _REPL:
            return with_lead(z3, None)
        if owner == "w_out":                        # mixer out-proj (ssd/rglru)
            return with_lead(None, z3)
        if owner == "w_in":                         # ssd fused in-proj (dense)
            return with_lead(z3, None)
        return with_lead(z3, None)
    if leafname == "b":
        return with_lead(mdl if parent in _OUT_TP else None)
    # ---- everything else (norm gains, conv kernels, A_log, gates...) ----
    return P(*([None] * leaf.ndim))


def param_specs(model, key=None) -> Any:
    """PartitionSpec pytree matching ``model.init``."""
    import jax.random as jr
    key = key if key is not None else jr.PRNGKey(0)
    abstract = jax.eval_shape(model.init, key)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(path, leaf, model.ctx), abstract)


# =====================================================================
# decode caches
# =====================================================================
def cache_pspec(path, leaf, ctx: ShardCtx) -> P:
    """Decode-cache leaf spec: [count, B, S, ...] token leaves get
    (None, batch, "model", ...); state leaves (None, batch, ...)."""
    names = _path_names(path)
    leafname = names[-1]
    batch = (ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0])
    if leaf.shape[1] == 1 or _all_one(ctx, leaf.shape[1]):
        batch = None                                  # B not divisible: replicate
    token = leafname in ("k", "v", "c", "kr", "xk", "xv")
    if token:
        seq = ctx.model_axis if ctx.kv_seq_shard else None
        rest = [None] * (leaf.ndim - 3)
        return P(None, batch, seq, *rest)
    return P(None, batch, *([None] * (leaf.ndim - 2)))


def _all_one(ctx: ShardCtx, b: int) -> bool:
    if ctx.mesh is None:
        return True
    n = 1
    for a in ctx.batch_axes:
        n *= ctx.mesh.shape[a]
    return b % n != 0


def cache_specs(abstract_cache, ctx: ShardCtx) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_pspec(path, leaf, ctx), abstract_cache)


# =====================================================================
# batches
# =====================================================================
def batch_specs(abstract_batch, ctx: ShardCtx) -> Any:
    """tokens/labels [B, T] -> P(batch, None); embeds [B, T, D] likewise."""
    batch = (ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0])

    def spec(path, leaf):
        b = batch if not _all_one(ctx, leaf.shape[0]) else None
        return P(b, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(spec, abstract_batch)


def to_shardings(spec_tree, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
