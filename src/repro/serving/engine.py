"""JAX serving engine: jitted prefill / suffix-prefill and a slotted
continuous-batching decode loop.

The decode loop keeps one stacked cache pytree of fixed capacity
(``max_slots`` sequences x ``capacity`` tokens) and vmaps
``Model.decode_step`` over slots with **per-slot positions** — the vmapped
``dynamic_update_slice`` writes each sequence at its own offset, which is
what lets sequences of different lengths share a batch (continuous
batching). Slots are recycled as sequences retire; inactive slots still
compute (dead lanes) and are masked out of the results, exactly as a
fixed-shape TPU serving binary would.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.lm import Model
from .paged_kv import is_token_leaf_path

__all__ = ["ServingEngine", "DecodeBatch"]


class ServingEngine:
    """Prefill-side engine for one serving unit."""

    def __init__(self, model: Model, params: Any):
        self.model = model
        self.params = params
        self._full = jax.jit(lambda p, b: model.prefill(p, b))
        self._suffix = jax.jit(
            lambda p, b, caches, pos: model.prefill(p, b, caches=caches,
                                                    pos=pos))

    def prefill(self, tokens: np.ndarray,
                prefix_cache: Optional[Any] = None,
                prefix_len: int = 0,
                extra: Optional[Dict[str, Any]] = None
                ) -> Tuple[int, Any, jnp.ndarray]:
        """Prefill one request (B=1). Returns (first_token, cache, logits).

        With ``prefix_cache`` the engine computes only the suffix
        ``tokens[prefix_len:]`` — the compute saving of Stage-1 KV reuse.
        """
        tokens = np.asarray(tokens)
        if prefix_cache is not None and prefix_len > 0:
            batch = {"tokens": jnp.asarray(tokens[None, prefix_len:],
                                           jnp.int32)}
            if extra:
                batch.update(extra)
            logits, cache = self._suffix(self.params, batch, prefix_cache,
                                         jnp.asarray(prefix_len, jnp.int32))
        else:
            batch = {"tokens": jnp.asarray(tokens[None], jnp.int32)}
            if extra:
                batch.update(extra)
            logits, cache = self._full(self.params, batch)
        first = int(jnp.argmax(logits[0, -1]))
        return first, cache, logits


@dataclass
class _Slot:
    rid: int
    pos: int                 # next write position == current length
    tokens: List[int] = field(default_factory=list)
    max_new: int = 16


class DecodeBatch:
    """Slotted continuous-batching decode engine (one decode unit)."""

    def __init__(self, model: Model, params: Any, capacity: int = 256,
                 max_slots: int = 8):
        self.model = model
        self.params = params
        self.capacity = capacity
        self.max_slots = max_slots
        self.slots: Dict[int, _Slot] = {}
        self._free = list(range(max_slots - 1, -1, -1))
        self._stacked: Optional[Any] = None
        self._tok = jnp.zeros((max_slots, 1, 1), jnp.int32)
        self._pos = jnp.zeros((max_slots,), jnp.int32)
        self._step_fn = None

    # ------------------------------------------------------------- plumbing
    def _leaf_window(self, path) -> int:
        """Sliding window of the layer owning this cache leaf (0 = full)."""
        try:
            seg = self.model.segments[path[0].idx]
            return seg.kinds[path[1].idx][2]
        except (AttributeError, IndexError):
            return 0

    def _leaf_capacity(self, path) -> int:
        w = self._leaf_window(path)
        return min(self.capacity, w) if w else self.capacity

    def _build(self, example_cache: Any) -> None:
        n = self.max_slots

        def expand(path, leaf):
            # [count, 1, S, ...] token leaf -> [count, n, cap, ...]
            # [count, 1, ...]    state leaf -> [count, n, ...]
            shp = list(leaf.shape)
            shp[1] = n
            if is_token_leaf_path(path):
                shp[2] = self._leaf_capacity(path)
            return jnp.zeros(tuple(shp), leaf.dtype)

        self._stacked = jax.tree_util.tree_map_with_path(expand, example_cache)
        model = self.model

        def one(p, cache, tok, pos):
            # vmap strips the B axis (axis 1); run the model at B=1 inside
            cache = jax.tree.map(lambda x: x[:, None], cache)
            logits, new_cache = model.decode_step(p, cache, tok, pos)
            return logits, jax.tree.map(lambda x: x[:, 0], new_cache)

        self._step_fn = jax.jit(jax.vmap(
            one, in_axes=(None, 1, 0, 0), out_axes=(0, 1)))

    # ------------------------------------------------------------ lifecycle
    def add(self, rid: int, cache: Any, n_tokens: int, first_token: int,
            max_new: int = 16) -> int:
        """Admit a prefilled sequence; returns its slot id."""
        if not self._free:
            raise RuntimeError("decode batch full")
        if self._stacked is None:
            self._build(cache)
        slot = self._free.pop()

        def write(path, big, small):
            x = small[:, 0]                           # [count, S, ...] / [count, ...]
            if is_token_leaf_path(path):
                cap = big.shape[2]
                w = self._leaf_window(path)
                if w and x.shape[1] == cap and n_tokens > cap:
                    # window-cropped leaf holds positions [n-cap, n) at
                    # [0, cap); restore the rolling-buffer invariant
                    # (position p lives at index p % cap) for decode
                    x = jnp.roll(x, (n_tokens - cap) % cap, axis=1)
                pad = cap - x.shape[1]
                if pad < 0:
                    raise ValueError("sequence longer than decode capacity")
                if pad:
                    x = jnp.pad(x, [(0, 0), (0, pad)]
                                + [(0, 0)] * (x.ndim - 2))
            return big.at[:, slot].set(x)

        self._stacked = jax.tree_util.tree_map_with_path(
            write, self._stacked, cache)
        self._tok = self._tok.at[slot, 0, 0].set(first_token)
        self._pos = self._pos.at[slot].set(n_tokens)
        self.slots[slot] = _Slot(rid=rid, pos=n_tokens, tokens=[first_token],
                                 max_new=max_new)
        return slot

    def remove(self, slot: int) -> _Slot:
        s = self.slots.pop(slot)
        self._free.append(slot)
        return s

    # ----------------------------------------------------------------- step
    def step(self) -> Dict[int, int]:
        """One decode step for every active slot. Returns {rid: new_token}
        and retires slots that reached ``max_new`` or capacity."""
        if not self.slots:
            return {}
        logits, self._stacked = self._step_fn(
            self.params, self._stacked, self._tok, self._pos)
        nxt = jnp.argmax(logits[:, 0, -1], axis=-1).astype(jnp.int32)
        out: Dict[int, int] = {}
        for slot, meta in list(self.slots.items()):
            t = int(nxt[slot])
            meta.tokens.append(t)
            meta.pos += 1
            out[meta.rid] = t
            self._tok = self._tok.at[slot, 0, 0].set(t)
            self._pos = self._pos.at[slot].set(meta.pos)
            if len(meta.tokens) >= meta.max_new or meta.pos >= self.capacity - 1:
                self.remove(slot)
        return out

    @property
    def n_active(self) -> int:
        return len(self.slots)
