"""Disaggregated serving orchestrator — real JAX data plane, scheduled
transfers on a virtual network.

This is the paper's §5 integration re-based onto the JAX engine: every
transfer the serving system performs is driven through the three
standardized primitives

    submit(task-with-metadata)  ->  fid
    permit(fid, priority)           (the policy's assign() on the RMLQ)
    completion(fid)                 (fires the dependent continuation)

with the policy (MFS or any baseline) deciding priorities and a fluid
network model (repro.netsim) playing the role of the fabric. Computation is
*real* — prefill and decode run the actual model on this host — while its
latency on the target cluster comes from the analytic operator model, so
the virtual clock reflects target-hardware timing. Computation events and
network events share one EventQueue (§6.1).

Request lifecycle (one MsFlow chain per request, §3.1):
  arrival -> route to a prefill unit (KV-aware)
    Stage 1: prefix-index hit on a remote owner => KV-reuse fetch flow
    compute: per-layer-group; at each boundary a "layer" trigger promotes
             (RMLQ), Stage-2 collective coflows gate the next group, and the
             group's P2D KV (Stage 3) is submitted with the TTFT deadline
    TTFT   = completion of the last P2D flow + first decode step
  decode  -> slotted continuous batching on the decode unit (real tokens).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import MFSScheduler, Policy, Stage
from ..core.msflow import Coflow, Flow, FlowState, new_flow_id
from ..models.lm import Model
from ..netsim.events import EventQueue
from ..netsim.fluid import FluidNet
from ..netsim.topology import SingleToR
from ..simcluster.hw import HW, TPU_V5E
from .engine import DecodeBatch, ServingEngine
from .paged_kv import PagedStore, PrefixIndex, cache_bytes, cache_has_state

__all__ = ["DisaggServer", "ServeRequest", "ServeResult", "DisaggConfig"]


@dataclass
class ServeRequest:
    rid: int
    arrival: float
    tokens: np.ndarray
    max_new: int = 8
    extra: Optional[Dict[str, Any]] = None     # e.g. src_embeds for enc-dec


@dataclass
class ServeResult:
    rid: int
    ttft: float
    deadline: float
    met_slo: bool
    first_token: int
    tokens: List[int] = field(default_factory=list)
    reused_tokens: int = 0
    unit: int = -1


@dataclass(frozen=True)
class DisaggConfig:
    n_prefill_units: int = 2
    hw: HW = TPU_V5E
    layer_groups: int = 4           # P2D / promotion granularity
    slo_scale: float = 3.0          # SLO = scale x contention-free TTFT (§6.1)
    page_size: int = 16
    n_pages: int = 1024
    decode_capacity: int = 256
    decode_slots: int = 8
    kv_dtype_bytes: int = 2
    ep: int = 1                     # modeled expert-parallel width per unit
    gpus_per_unit: int = 1


class DisaggServer:
    """One decode unit + N prefill units sharing a ToR, MFS-scheduled."""

    def __init__(self, model: Model, params: Any, policy: Policy = None,
                 cfg: DisaggConfig = DisaggConfig()):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.policy = policy if policy is not None else MFSScheduler()
        self.policy.reset()

        n_ep = cfg.n_prefill_units * cfg.gpus_per_unit + 1   # +1 decode unit
        self.topo = SingleToR(n_ep, nic_bw=cfg.hw.nic_bw,
                              gpus_per_server=cfg.gpus_per_unit,
                              scaleup_bw=cfg.hw.scaleup_bw)
        self.net = FluidNet(self.topo)
        self.evq = EventQueue()
        self.engines = [ServingEngine(model, params)
                        for _ in range(cfg.n_prefill_units)]
        self.decoder = DecodeBatch(model, params, capacity=cfg.decode_capacity,
                                   max_slots=cfg.decode_slots)
        self.store = PagedStore(cfg.page_size, cfg.n_pages)
        self.index = PrefixIndex(self.store)
        self.view = _View(self)

        mcfg = model.cfg
        G = max(1, min(cfg.layer_groups, mcfg.n_layers))
        bounds = np.linspace(0, mcfg.n_layers, G + 1).astype(int)
        self._groups = [list(range(bounds[g], bounds[g + 1]))
                        for g in range(G)]
        self._epoch = 0
        # per-request runtime state
        self._req: Dict[int, _ReqState] = {}
        self._unit_lcurr = [0] * cfg.n_prefill_units
        self._unit_busy = [False] * cfg.n_prefill_units
        self._unit_queue: List[List[ServeRequest]] = [
            [] for _ in range(cfg.n_prefill_units)]
        self.results: Dict[int, ServeResult] = {}

    # --------------------------------------------------------- flow plumbing
    def _endpoint(self, unit: int, gpu: int = 0) -> int:
        return unit * self.cfg.gpus_per_unit + gpu

    @property
    def _decode_ep(self) -> int:
        return self.cfg.n_prefill_units * self.cfg.gpus_per_unit

    def _submit(self, flow: Flow) -> None:
        flow.created = self.evq.now
        self.net.add(flow)
        self.policy.on_flow_submitted(flow, self.view)

    def _resched(self, trigger=("event",)) -> None:
        self.policy.assign(list(self.net.flows.values()), self.view, trigger)
        self.net.reallocate()
        self._epoch += 1
        nxt = self.net.next_completion()
        if nxt is not None:
            self.evq.push(nxt[0], "net", None, epoch=self._epoch)

    # ----------------------------------------------------------- model math
    def _kv_bytes_per_token(self, layers: Sequence[int]) -> float:
        m, b = self.model.cfg, self.cfg.kv_dtype_bytes
        return sum(m.kv_bytes_per_token_layer(b, l) for l in layers)

    def _group_time(self, n_new: int, ctx: float, g: int) -> float:
        m, hw = self.model.cfg, self.cfg.hw
        fl = n_new * m.flops_per_token(ctx) / m.n_layers * len(self._groups[g])
        return fl / (self.cfg.gpus_per_unit * hw.flops * hw.mfu)

    def _stage2_bytes(self, n_new: int, g: int) -> float:
        m = self.model.cfg
        if self.cfg.ep <= 1 or m.n_experts == 0:
            return 0.0
        moe = sum(1 for l in self._groups[g] if m.is_moe_layer(l))
        return 2.0 * (n_new / self.cfg.ep) * m.top_k * m.d_model * 2 * moe

    def _ideal_ttft(self, r: ServeRequest, reuse: int) -> float:
        hw = self.cfg.hw
        n_new = max(1, len(r.tokens) - reuse)
        ctx = reuse + n_new / 2.0
        total = sum(self._group_time(n_new, ctx, g)
                    for g in range(len(self._groups)))
        if reuse:
            total += reuse * self._kv_bytes_per_token(range(self.model.cfg.n_layers)) / hw.nic_bw
        total += len(r.tokens) * self._kv_bytes_per_token(self._groups[-1]) / hw.nic_bw
        return total

    # --------------------------------------------------------------- serving
    def serve(self, requests: Sequence[ServeRequest],
              decode_steps: int = 4) -> List[ServeResult]:
        for r in sorted(requests, key=lambda x: x.arrival):
            self.evq.push(r.arrival, "arrival", r)
        while self.evq:
            t, kind, payload, epoch = self.evq.pop()
            done = self.net.advance(t)
            for f in done:
                self._on_flow_done(f)
            if kind == "net":
                if epoch != self._epoch:
                    continue            # stale completion prediction
                self._resched()
            elif kind == "arrival":
                self._on_arrival(payload)
            elif kind == "group_done":
                self._on_group_done(*payload)
        # all prefills finished: run the decode continuation (real tokens)
        for _ in range(decode_steps):
            if not self.decoder.n_active:
                break
            for rid, tok in self.decoder.step().items():
                self.results[rid].tokens.append(tok)
        return [self.results[r.rid] for r in requests]

    # ---------------------------------------------------------------- events
    def _on_arrival(self, r: ServeRequest) -> None:
        entry = self.index.match(r.tokens)
        # KV-aware routing: prefer the prefix owner, penalise busy units
        owner = entry.owner_unit if entry else None
        scores = []
        for u in range(self.cfg.n_prefill_units):
            aff = entry.n_tokens if (entry and u == owner) else 0
            scores.append(2.0 * aff - 1e6 * (self._unit_busy[u]
                                             or bool(self._unit_queue[u])))
        unit = int(np.argmax(scores))
        reuse = entry.n_tokens if entry else 0
        if reuse >= len(r.tokens):          # guarantee >=1 suffix token
            reuse = 0
            entry = None
        deadline = r.arrival + self.cfg.slo_scale * self._ideal_ttft(r, reuse)
        st = _ReqState(req=r, unit=unit, entry=entry, reuse=reuse,
                       deadline=deadline)
        self._req[r.rid] = st
        if self._unit_busy[unit]:
            self._unit_queue[unit].append(r)
            st.queued = True
            return
        self._start_prefill(st)

    def _start_prefill(self, st: "_ReqState") -> None:
        r, unit = st.req, st.unit
        self._unit_busy[unit] = True
        st.queued = False
        if st.entry is not None and st.entry.owner_unit != unit:
            # Stage 1: fetch the reused prefix from its owner unit
            f = Flow(fid=new_flow_id(), rid=r.rid, unit=unit,
                     stage=Stage.KV_REUSE, size=float(st.entry.bytes),
                     src=self._endpoint(st.entry.owner_unit),
                     dst=self._endpoint(unit),
                     target_layer=0, n_layers=self.model.cfg.n_layers)
            st.stage1 = f
            self._submit(f)
            self._resched(("submit",))
            return                          # compute starts on completion
        self._begin_compute(st)

    def _begin_compute(self, st: "_ReqState") -> None:
        r = st.req
        prefix_cache = None
        if st.entry is not None:
            prefix_cache = self.index.fetch(st.entry)
        # REAL compute (the result is exact; the latency is the target HW's)
        first, cache, _ = self.engines[st.unit].prefill(
            r.tokens, prefix_cache=prefix_cache, prefix_len=st.reuse,
            extra=r.extra)
        st.first_token = first
        st.cache = cache
        st.compute_started = self.evq.now
        self._unit_lcurr[st.unit] = 0
        self._schedule_group(st, 0)

    def _schedule_group(self, st: "_ReqState", g: int) -> None:
        n_new = max(1, len(st.req.tokens) - st.reuse)
        ctx = st.reuse + n_new / 2.0
        dt = self._group_time(n_new, ctx, g)
        self.evq.push(self.evq.now + dt, "group_done", (st.req.rid, g))

    def _on_group_done(self, rid: int, g: int) -> None:
        st = self._req[rid]
        G = len(self._groups)
        self._unit_lcurr[st.unit] = self._groups[g][-1] + 1
        # Stage 2: EP collective of this group (gates the next group)
        s2 = self._stage2_bytes(max(1, len(st.req.tokens) - st.reuse), g)
        if s2 > 0 and self.cfg.gpus_per_unit > 1:
            co = Coflow(cid=new_flow_id(), rid=rid, unit=st.unit,
                        stage=Stage.COLLECTIVE, layer=self._groups[g][-1])
            geps = [self._endpoint(st.unit, i)
                    for i in range(self.cfg.gpus_per_unit)]
            for i in geps:
                for j in geps:
                    if i == j:
                        continue
                    f = Flow(fid=new_flow_id(), rid=rid, unit=st.unit,
                             stage=Stage.COLLECTIVE,
                             size=s2 / max(1, len(geps) - 1),
                             src=i, dst=j, target_layer=self._groups[g][-1],
                             n_layers=self.model.cfg.n_layers)
                    f.coflow = co.cid
                    co.flows.append(f)
                    self._submit(f)
            st.pending_s2[g] = co
        # Stage 3: this group's P2D KV, explicit TTFT deadline
        kvb = len(st.req.tokens) * self._kv_bytes_per_token(self._groups[g])
        if kvb > 0:
            f = Flow(fid=new_flow_id(), rid=rid, unit=st.unit,
                     stage=Stage.P2D, size=kvb,
                     src=self._endpoint(st.unit), dst=self._decode_ep,
                     target_layer=self._groups[g][-1],
                     n_layers=self.model.cfg.n_layers,
                     deadline=st.deadline)
            st.p2d_pending.add(f.fid)
            self._submit(f)
        st.groups_done = g + 1
        self._resched(("layer", st.unit))
        if g + 1 < G:
            if st.pending_s2.get(g) is not None:
                st.waiting_group = g + 1      # gated on Stage-2 completion
            else:
                self._schedule_group(st, g + 1)
        else:
            st.compute_finished = True
            self._maybe_finish(st)

    def _on_flow_done(self, f: Flow) -> None:
        st = self._req.get(f.rid)
        if st is None:
            return
        if st.stage1 is not None and f.fid == st.stage1.fid:
            st.stage1 = None
            self._begin_compute(st)
        elif f.stage == Stage.COLLECTIVE:
            for g, co in list(st.pending_s2.items()):
                if co is not None and co.done():
                    co.finished = self.evq.now
                    st.pending_s2[g] = None
                    if st.waiting_group == g + 1:
                        w = st.waiting_group
                        st.waiting_group = None
                        self._schedule_group(st, w)
        elif f.stage == Stage.P2D:
            st.p2d_pending.discard(f.fid)
            self._maybe_finish(st)

    def _maybe_finish(self, st: "_ReqState") -> None:
        if not st.compute_finished or st.p2d_pending or st.finished:
            return
        st.finished = True
        r = st.req
        ttft = self.evq.now - r.arrival
        res = ServeResult(rid=r.rid, ttft=ttft, deadline=st.deadline,
                          met_slo=(r.arrival + ttft) <= st.deadline,
                          first_token=st.first_token,
                          tokens=[st.first_token], reused_tokens=st.reuse,
                          unit=st.unit)
        self.results[r.rid] = res
        # register the prefix for future reuse + hand off to the decode unit
        if cache_has_state(st.cache):
            self.index.insert_snapshot(r.tokens, st.cache, st.unit)
        else:
            try:
                pages = self.store.put(st.cache, len(r.tokens))
                self.index.insert_paged(
                    r.tokens, pages, st.unit,
                    self._kv_bytes_per_token(range(self.model.cfg.n_layers)))
                self.store.release(pages)   # index holds its own references
            except MemoryError:
                pass                         # pool full: skip registration
        if self.decoder.n_active < self.cfg.decode_slots:
            self.decoder.add(r.rid, st.cache, len(r.tokens), st.first_token,
                             max_new=r.max_new)
        st.cache = None
        # free the unit, start the next queued request
        self._unit_busy[st.unit] = False
        if self._unit_queue[st.unit]:
            nxt = self._unit_queue[st.unit].pop(0)
            self._start_prefill(self._req[nxt.rid])


@dataclass
class _ReqState:
    req: ServeRequest
    unit: int
    entry: Any
    reuse: int
    deadline: float
    queued: bool = False
    stage1: Optional[Flow] = None
    cache: Any = None
    first_token: int = -1
    compute_started: float = -1.0
    compute_finished: bool = False
    finished: bool = False
    groups_done: int = 0
    waiting_group: Optional[int] = None
    pending_s2: Dict[int, Optional[Coflow]] = field(default_factory=dict)
    p2d_pending: set = field(default_factory=set)


class _View:
    """SchedView implementation over the orchestrator state."""

    def __init__(self, srv: DisaggServer):
        self._s = srv

    @property
    def now(self) -> float:
        return self._s.evq.now

    def bottleneck(self, flow: Flow) -> Tuple[float, float]:
        return self._s.net.bottleneck(flow)

    def mlu_inputs(self, flow: Flow, level: int) -> Tuple[float, float]:
        def protected(o: Flow) -> bool:
            if o.stage != Stage.P2D:
                return True
            return o.level < level
        return self._s.net.bottleneck_protected(flow, protected)

    def l_curr(self, unit: int) -> int:
        return self._s._unit_lcurr[unit]

    def computing(self, rid: int) -> bool:
        st = self._s._req.get(rid)
        return st is not None and not st.compute_finished

    def red_rank(self, rid: int) -> int:
        return 0     # single-batch units: RED ordering degenerates

    def downstream_estimate(self, flow: Flow) -> float:
        return 0.0
