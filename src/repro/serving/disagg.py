"""Disaggregated serving orchestrator — real JAX data plane, scheduled
transfers on a virtual network.

This is the paper's §5 integration re-based onto the shared MsFlow runtime
(``repro.core.runtime``): the event loop, per-layer-group stage emission
(Stage-1 KV-reuse fetches, Stage-2 collectives, Stage-3 P2D with deadline
derivation), SLO calibration and the policy-facing SchedView are the same
objects the cluster simulator drives — MFS is exercised at full fidelity
(RMLQ promotion, Algorithm 1 RED ordering + feasibility pruning, scavenger
readmission) on the real-JAX path, with no degenerate stubs.

What this module contributes is the *data plane*:

  * prefill units run the actual model (``ServingEngine``) — results are
    exact; latency on the target cluster comes from the shared analytic
    ``StageProfile``, so the virtual clock reflects target-hardware timing;
  * KV-aware routing over a content-addressed ``PrefixIndex`` (real pages);
  * queued multi-request prefill batching per unit (token-capped, like the
    simulator) instead of one-request-at-a-time service;
  * decode via slotted continuous batching on the decode unit (real tokens).

Request lifecycle (one MsFlow chain per request, §3.1):
  arrival -> route to a prefill unit (prefix-affinity vs. backlog)
    Stage 1: prefix-index hit => per-layer-group KV-reuse flows from the
             owner unit; group g's slice gates super-layer g's compute
    compute: per super-layer group; at each boundary a "layer" trigger
             promotes (RMLQ), Stage-2 coflows gate the next group, and the
             group's P2D KV (Stage 3) carries the derived TTFT deadline
    TTFT   = completion of the last P2D flow + first decode step
  decode  -> slotted continuous batching on the decode unit (real tokens).
             With ``DisaggConfig.decode`` set, the modeled decode plane
             (named pools over ``n_decode_units`` endpoints, per-token
             ``dstep`` events, D2D rebalancing flows) also runs on the
             virtual clock — the same ``DecodePlane`` the simulator
             drives, so decode event traces are host-parity-testable.

Pruned requests (Algorithm 1) keep their *results* exact: the prefix pages
are local, so the real prefill still reuses them — only the modeled clock
pays the recompute penalty for KV the scavenged Stage-1 flow never
delivered, exactly as the simulator charges it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import MFSScheduler, Policy
from ..core.decode import (DecodePlane, DecodeSession, DecodeSpec,
                           partition_pools)
from ..core.kvstore import KVStore, KVStoreSpec, content_chain
from ..core.router import AdmissionSpec, RouterSpec
from ..core.runtime import MsFlowRuntime, RuntimeHost
from ..core.stages import (BatchState, ChunkSpec, GroupPlan, ParallelismSpec,
                           PrefillItem, StageEmitter, StageProfile)
from ..core.monitor import Monitor, MonitorSpec
from ..core.telemetry import Telemetry, TelemetrySpec
from ..netsim.events import EventQueue
from ..netsim.fluid import FluidNet
from ..netsim.topology import SingleToR
from ..simcluster.hw import HW, TPU_V5E
from .engine import DecodeBatch, ServingEngine
from .paged_kv import PagedStore, PrefixIndex, cache_has_state

__all__ = ["DisaggServer", "ServeRequest", "ServeResult", "DisaggConfig"]


@dataclass
class ServeRequest:
    rid: int
    arrival: float
    tokens: np.ndarray
    max_new: int = 8
    slo_class: str = "standard"     # tight | standard | loose (admission
    #                                 control sheds only the sheddable ones)
    extra: Optional[Dict[str, Any]] = None     # e.g. src_embeds for enc-dec


@dataclass
class ServeResult:
    rid: int
    ttft: float
    deadline: float
    met_slo: bool
    first_token: int
    tokens: List[int] = field(default_factory=list)
    reused_tokens: int = 0
    unit: int = -1
    pruned: bool = False
    shed: bool = False              # rejected by admission control: never
    #                                 prefilled, no first token, SLO missed
    # --- decode plane (modeled clock; real tokens come from DecodeBatch) ---
    pool: str = ""
    tpot: float = 0.0               # mean modeled time per output token
    tpot_ok: bool = True
    migrations: int = 0


@dataclass(frozen=True)
class DisaggConfig:
    n_prefill_units: int = 2
    hw: HW = TPU_V5E
    layer_groups: int = 4           # P2D / promotion granularity
    slo_scale: float = 3.0          # SLO = scale x contention-free TTFT (§6.1)
    page_size: int = 16
    n_pages: int = 1024
    decode_capacity: int = 256
    decode_slots: int = 8
    kv_dtype_bytes: int = 2
    gpus_per_unit: int = 1          # endpoints (= modeled EP ranks) per unit
    max_batch_tokens: int = 8192    # prefill batch cap per unit
    tick_interval: float = 2e-3     # post-compute MLU re-evaluation pitch
    drop_budget: int = 32           # Algorithm 1 global drop budget B
    n_decode_units: int = 1         # modeled decode endpoints (pools split these)
    decode: Optional[DecodeSpec] = None   # attach the modeled decode plane
    # KV-reuse plane: with a spec attached, scheduling truth (reuse length,
    # sources, tiers) comes from the shared tiered KVStore — the
    # content-addressed PrefixIndex stays the *data-plane* page map that
    # materialises real prefix caches when it can cover the modeled hit.
    kvstore: Optional[KVStoreSpec] = None
    # chunked prefill: the modeled clock walks the (group, chunk) grid with
    # per-chunk S1/S2/S3 emission, and the data plane materialises paged
    # prefix caches in chunk slices (PagedStore.gather_slice) instead of
    # one monolithic gather. None (or chunk_tokens=0) = legacy schedule.
    chunk: Optional[ChunkSpec] = None
    # router + admission plane (None = the default ``kv_affinity`` policy
    # with admission off — the historical placement, bit-identical).
    router: Optional[RouterSpec] = None
    # telemetry plane (None = off, zero overhead); read the collector via
    # ``DisaggServer.telemetry`` after a run for ttft_breakdown /
    # slo_miss_report / the RMLQ audit / Chrome trace export
    telemetry: Optional[TelemetrySpec] = None
    # online monitor plane (None = off): streaming estimators + SignalBus
    # for live detectors/routers; read via ``DisaggServer.monitor``
    monitor: Optional[MonitorSpec] = None

    def chunk_tokens(self) -> int:
        return self.chunk.chunk_tokens if self.chunk is not None else 0


@dataclass
class _ServeJob:
    """Data-plane state riding on a PrefillItem as its payload."""

    req: ServeRequest
    entry: Any = None               # PrefixIndex hit backing the reuse
    cache: Any = None
    first_token: int = -1


class DisaggServer(RuntimeHost):
    """One decode unit + N prefill units sharing a ToR, MFS-scheduled."""

    def __init__(self, model: Any, params: Any, policy: Policy = None,
                 cfg: DisaggConfig = DisaggConfig()):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.policy = policy if policy is not None else MFSScheduler()
        self.policy.reset()

        n_prefill = cfg.n_prefill_units * cfg.gpus_per_unit
        n_decode = max(1, cfg.n_decode_units)
        n_store = cfg.kvstore.n_store_nodes() if cfg.kvstore else 0
        self.topo = SingleToR(n_prefill + n_decode + n_store,
                              nic_bw=cfg.hw.nic_bw,
                              gpus_per_server=cfg.gpus_per_unit,
                              scaleup_bw=cfg.hw.scaleup_bw)
        mcfg = model.cfg
        par = ParallelismSpec(mode="ep", ep=cfg.gpus_per_unit)
        plan = GroupPlan.build(mcfg.n_layers,
                               min(cfg.layer_groups, mcfg.n_layers))
        self.profile = StageProfile(
            model=mcfg, hw=cfg.hw, par=par, plan=plan,
            kv_dtype_bytes=cfg.kv_dtype_bytes, act_dtype_bytes=2,
            gpus_per_server=cfg.gpus_per_unit)
        unit_eps = [list(range(u * cfg.gpus_per_unit,
                               (u + 1) * cfg.gpus_per_unit))
                    for u in range(cfg.n_prefill_units)]
        decode_eps = list(range(n_prefill, n_prefill + n_decode))
        store_eps = list(range(n_prefill + n_decode,
                               n_prefill + n_decode + n_store))
        self.kvstore: Optional[KVStore] = None
        if cfg.kvstore is not None:
            if cfg.kvstore.block_tokens % cfg.page_size:
                raise ValueError("kvstore.block_tokens must be a multiple of"
                                 " page_size so block-aligned hits are valid"
                                 " paged-cache resume points")
            pooled = cfg.kvstore.pooled_tier()
            if pooled is not None and pooled.fetch_bw > 0:
                for e in store_eps:
                    self.topo.capacity[2 * e] = pooled.fetch_bw
                    self.topo.capacity[2 * e + 1] = pooled.fetch_bw
            self.kvstore = KVStore(
                cfg.kvstore, self.profile.kv_bytes_per_token(),
                unit_eps, store_eps, nic_bw=cfg.hw.nic_bw)
        self.decode_plane: Optional[DecodePlane] = None
        pool_eps = None
        if cfg.decode is not None:
            pool_eps = partition_pools(cfg.decode.pools, decode_eps)
            self.decode_plane = DecodePlane(cfg.decode, self.profile,
                                            pool_eps, seed=0)
        emitter = StageEmitter(self.profile, unit_eps,
                               decode_eps=decode_eps, topo=self.topo,
                               pool_eps=pool_eps,
                               chunk_tokens=cfg.chunk_tokens())
        rspec = cfg.router
        self.telemetry: Optional[Telemetry] = \
            Telemetry(cfg.telemetry) if cfg.telemetry is not None \
            and cfg.telemetry.enabled else None
        self.monitor: Optional[Monitor] = \
            Monitor(cfg.monitor) if cfg.monitor is not None \
            and cfg.monitor.enabled else None
        self.runtime = MsFlowRuntime(
            self.topo, FluidNet(self.topo), EventQueue(), self.policy,
            self.profile, emitter, host=self, n_units=cfg.n_prefill_units,
            max_batch_tokens=cfg.max_batch_tokens, slo_scale=cfg.slo_scale,
            slo_mode="per-request", tick_interval=cfg.tick_interval,
            drop_budget=cfg.drop_budget, decode=self.decode_plane,
            kvstore=self.kvstore,
            router=rspec.build() if rspec is not None else None,
            admission=rspec.build_admission() if rspec is not None else None,
            telemetry=self.telemetry, monitor=self.monitor)

        self.engines = [ServingEngine(model, params)
                        for _ in range(cfg.n_prefill_units)]
        self.decoder = DecodeBatch(model, params, capacity=cfg.decode_capacity,
                                   max_slots=cfg.decode_slots)
        self.store = PagedStore(cfg.page_size, cfg.n_pages)
        self.index = PrefixIndex(self.store)
        self.results: Dict[int, ServeResult] = {}

    @property
    def net(self) -> FluidNet:
        return self.runtime.net

    # ----------------------------------------------------------- model math
    def _kv_bytes_per_token(self) -> float:
        m, b = self.model.cfg, self.cfg.kv_dtype_bytes
        return sum(m.kv_bytes_per_token_layer(b, l)
                   for l in range(m.n_layers))

    # ------------------------------------------------------------ host hooks
    def prepare_route(self, item: PrefillItem) -> None:
        """Refresh placement state before the runtime's router places.

        Matches the content-addressed PrefixIndex and fills the legacy
        ``(reuse, owner_unit)`` oracle the ``kv_affinity`` policy scores
        (``owner_unit = -1`` when no entry owns the prefix — the runtime
        self-assigns after placement). With the KV-reuse plane attached the
        oracle is ignored — the hit (length, sources, tiers) resolves
        against the live shared store after placement — and the PrefixIndex
        entry is kept only as the data-plane capability that materialises
        real pages for the modeled hit.
        """
        job: _ServeJob = item.payload
        entry = self.index.match(job.req.tokens)
        if self.kvstore is not None:
            job.entry = entry
            return
        reuse = entry.n_tokens if entry else 0
        if reuse >= len(job.req.tokens):    # guarantee >=1 suffix token
            reuse, entry = 0, None
        job.entry = entry
        item.reuse = reuse
        # decode pool: left empty here, so the runtime fills it via
        # DecodePlane.pick_pool after routing (set item.pool to override)
        item.owner_unit = entry.owner_unit if entry else -1

    def kv_chain_keys(self, item: PrefillItem):
        # the keys the router plane scores and the runtime resolves, also
        # used by store-aware SLO calibration
        if self.kvstore is None:
            return ()
        job: _ServeJob = item.payload
        return content_chain(job.req.tokens, self.kvstore.spec.block_tokens)

    def on_shed(self, item: PrefillItem) -> None:
        # rejected before any prefill ran: record a result so callers see
        # the outcome (no first token, SLO counted as missed)
        job: _ServeJob = item.payload
        r = job.req
        self.results[r.rid] = ServeResult(
            rid=r.rid, ttft=float("inf"), deadline=item.deadline,
            met_slo=False, first_token=-1, tokens=[], shed=True)

    def on_batch_started(self, bs: BatchState) -> None:
        # REAL compute (results are exact; the virtual clock runs on the
        # shared analytic profile). The prefix pages are host-local, so the
        # data plane can reuse them even when the modeled Stage-1 flow is
        # later pruned — only the clock pays the recompute penalty then.
        for it in bs.items:
            job: _ServeJob = it.payload
            prefix_cache = self._prefix_cache_for(job.entry, it.reuse)
            first, cache, _ = self.engines[bs.unit].prefill(
                job.req.tokens, prefix_cache=prefix_cache,
                prefix_len=it.reuse if prefix_cache is not None else 0,
                extra=job.req.extra)
            job.first_token = first
            job.cache = cache

    def _prefix_cache_for(self, entry: Any, reuse: int) -> Optional[Any]:
        """Materialise a prefix cache covering exactly ``reuse`` tokens.

        The modeled hit (KV store) and the data-plane capability
        (PrefixIndex) can disagree — the store evicts, the index does not —
        so paged entries are sliced down to the modeled hit and anything
        the index cannot cover is recomputed by the real prefill (results
        stay exact; the virtual clock already charged the modeled hit).

        With chunked prefill the paged prefix is materialised in
        ``chunk_tokens`` slices (``PagedStore.gather_slice``) and stitched
        along the token axis — the data-plane mirror of the per-chunk
        Stage-1 arrival granularity the modeled clock schedules.
        """
        if entry is None or reuse <= 0:
            return None
        ct = self.cfg.chunk_tokens()
        if entry.pages and ct > 0:
            bounds = list(range(0, reuse, ct)) + [reuse]
            slices = [self.store.gather_slice(entry.pages, a, b)
                      for a, b in zip(bounds, bounds[1:])]
            if len(slices) == 1:
                return slices[0]
            return jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, axis=2), *slices)
        if entry.n_tokens == reuse:
            return self.index.fetch(entry)
        if entry.pages and entry.n_tokens > reuse:
            return self.store.gather(entry.pages, reuse)
        return None                     # snapshot mismatch: recompute fully

    def on_request_done(self, item: PrefillItem, bs: BatchState) -> None:
        job: _ServeJob = item.payload
        r = job.req
        res = ServeResult(
            rid=r.rid, ttft=item.ttft, deadline=item.deadline,
            met_slo=(item.arrival + item.ttft) <= item.deadline,
            first_token=job.first_token, tokens=[job.first_token],
            reused_tokens=item.reuse, unit=item.unit,
            pruned=r.rid in self.runtime.ever_pruned)
        self.results[r.rid] = res
        # register the prefix for future reuse + hand off to the decode unit
        if cache_has_state(job.cache):
            self.index.insert_snapshot(r.tokens, job.cache, item.unit)
        else:
            try:
                pages = self.store.put(job.cache, len(r.tokens))
                self.index.insert_paged(r.tokens, pages, item.unit,
                                        self._kv_bytes_per_token())
                self.store.release(pages)   # index holds its own references
            except MemoryError:
                pass                         # pool full: skip registration
        if self.decoder.n_active < self.cfg.decode_slots:
            self.decoder.add(r.rid, job.cache, len(r.tokens),
                             job.first_token, max_new=r.max_new)
        job.cache = None

    def on_decode_admitted(self, sess: DecodeSession) -> None:
        res = self.results.get(sess.rid)
        if res is not None:
            res.pool = sess.pool

    def on_decode_done(self, sess: DecodeSession) -> None:
        res = self.results.get(sess.rid)
        if res is not None:
            res.tpot = sess.tpot
            res.tpot_ok = sess.tpot_ok
            res.migrations = sess.n_migrations

    # --------------------------------------------------------------- serving
    def serve(self, requests: Sequence[ServeRequest],
              decode_steps: int = 4) -> List[ServeResult]:
        for r in sorted(requests, key=lambda x: x.arrival):
            self.runtime.push_arrival(PrefillItem(
                rid=r.rid, arrival=r.arrival, n_tokens=len(r.tokens),
                slo_class=r.slo_class, out_tokens=r.max_new,
                payload=_ServeJob(req=r)))
        self.runtime.run()
        # all prefills finished: run the decode continuation (real tokens)
        for _ in range(decode_steps):
            if not self.decoder.n_active:
                break
            for rid, tok in self.decoder.step().items():
                self.results[rid].tokens.append(tok)
        return [self.results[r.rid] for r in requests]
