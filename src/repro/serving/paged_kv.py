"""Paged KV storage + content-addressed prefix index (Mooncake-style reuse).

Two storage regimes, chosen per architecture:

* **Pageable caches** (pure attention: GQA ``k``/``v``, MLA ``c``/``kr``) —
  every cache leaf is token-indexed, so the store keeps one pool array per
  leaf with the token axis reshaped to ``(n_pages, page_size)``. One logical
  page id indexes every pool simultaneously; a page is the complete
  per-token serving state, and any *page-aligned* prefix boundary is a valid
  resume point for ``Model.prefill(caches=..., pos=...)``. Boundaries are
  content-addressed by an incremental hash chain over token pages, so hot
  prefixes dedupe across requests (the paper's "hot block / victim unit"
  regime). Pages are reference-counted.

* **Snapshot caches** (SSM / hybrid / enc-dec: recurrent ``state``, ``conv``
  windows, window-cropped local-attention KV) — the serving state is O(1)
  per sequence *at a specific token position*, not token-sliceable. The
  index stores the whole (B=1) cache pytree snapshotted at end-of-prefill,
  keyed by the exact token prefix; a match resumes from that position. This
  mirrors how production stores treat linear-attention caches: cheap to
  ship (constant size — the paper's §Arch-applicability note for Mamba2),
  but only exact-prefix reusable.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PagedStore", "PrefixIndex", "PrefixEntry", "cache_has_state",
           "cache_bytes", "is_token_leaf_path"]

# cache-leaf names with a *decode-token* axis in the stacked prefill layout
# [seg_count, B, S, ...]; everything else is per-sequence state. Note
# cross-attention xk/xv are indexed by *encoder* positions — per-sequence
# constants as far as decode-token paging is concerned.
_TOKEN_LEAVES = {"k", "v", "c", "kr"}


def _leaf_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "idx", last)))


def _is_token_leaf(path) -> bool:
    return _leaf_name(path) in _TOKEN_LEAVES


def is_token_leaf_path(path) -> bool:
    """Public: does this stacked-cache leaf have a token axis (axis 2)?"""
    return _is_token_leaf(path)


def cache_has_state(cache: Any) -> bool:
    """True if any leaf is per-sequence state (forces snapshot storage)."""
    return any(not _is_token_leaf(p)
               for p, _ in jax.tree_util.tree_flatten_with_path(cache)[0])


def cache_bytes(cache: Any) -> int:
    """Total bytes of a cache pytree (sizes Stage-1/Stage-3 flows)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(cache))


class _Allocator:
    def __init__(self, n_pages: int):
        self.free: List[int] = list(range(n_pages - 1, -1, -1))
        self.refs: Dict[int, int] = {}

    def alloc(self, n: int) -> List[int]:
        if len(self.free) < n:
            raise MemoryError(f"paged KV pool exhausted ({n} pages needed, "
                              f"{len(self.free)} free)")
        pages = [self.free.pop() for _ in range(n)]
        for p in pages:
            self.refs[p] = 1
        return pages

    def retain(self, pages: Sequence[int]) -> None:
        for p in pages:
            self.refs[p] += 1

    def release(self, pages: Sequence[int]) -> None:
        for p in pages:
            self.refs[p] -= 1
            if self.refs[p] == 0:
                del self.refs[p]
                self.free.append(p)

    @property
    def n_free(self) -> int:
        return len(self.free)


class PagedStore:
    """Page pools for every token-indexed leaf of a pageable prefill cache."""

    def __init__(self, page_size: int = 16, n_pages: int = 512):
        self.page_size = page_size
        self.n_pages = n_pages
        self.alloc = _Allocator(n_pages)
        self._pools: Dict[str, jnp.ndarray] = {}
        self._treedef = None
        self._keys: List[str] = []

    def _ensure_pools(self, cache: Any) -> None:
        leaves = jax.tree_util.tree_flatten_with_path(cache)[0]
        if self._treedef is not None:
            return
        self._treedef = jax.tree_util.tree_structure(cache)
        for path, leaf in leaves:
            if not _is_token_leaf(path):
                raise ValueError(
                    f"PagedStore got a state leaf {jax.tree_util.keystr(path)}"
                    " — use snapshot storage for this architecture")
            key = jax.tree_util.keystr(path)
            self._keys.append(key)
            shp = list(leaf.shape)
            del shp[1]                               # drop B
            shp[1:2] = [self.n_pages, self.page_size]
            self._pools[key] = jnp.zeros(tuple(shp), leaf.dtype)

    def put(self, cache: Any, n_tokens: int) -> List[int]:
        """Write one request's (B=1) stacked prefill cache into fresh pages.

        Returns the page ids (len = ceil(n_tokens / page_size)); the
        trailing partial page is zero-padded.
        """
        self._ensure_pools(cache)
        ps = self.page_size
        n_pg = -(-n_tokens // ps)
        pages = self.alloc.alloc(n_pg)
        idx = jnp.asarray(pages, jnp.int32)
        for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
            key = jax.tree_util.keystr(path)
            x = leaf[:, 0]                           # [count, S, feat...]
            if x.shape[1] < n_tokens:
                raise ValueError(f"leaf {key} shorter than n_tokens — "
                                 "window-cropped caches are snapshot-only")
            x = x[:, :n_tokens]
            pad = n_pg * ps - n_tokens
            if pad:
                x = jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
            x = x.reshape(x.shape[0], n_pg, ps, *x.shape[2:])
            self._pools[key] = self._pools[key].at[:, idx].set(x)
        return pages

    def gather(self, pages: Sequence[int], n_tokens: int) -> Any:
        """Rebuild a (B=1) prefix cache pytree from pages."""
        if self._treedef is None:
            raise RuntimeError("gather before any put")
        idx = jnp.asarray(list(pages), jnp.int32)
        out = []
        for key in self._keys:
            x = jnp.take(self._pools[key], idx, axis=1)
            x = x.reshape(x.shape[0], -1, *x.shape[3:])[:, :n_tokens]
            out.append(x[:, None])                    # restore B=1
        return jax.tree_util.tree_unflatten(self._treedef, out)

    def gather_slice(self, pages: Sequence[int], start: int, end: int) -> Any:
        """Rebuild a (B=1) cache pytree covering tokens ``[start, end)``
        only — the chunk-sliced materialisation chunked prefill uses to
        build a prefix cache piecewise (one slice per arrived Stage-1
        chunk) instead of one monolithic gather. Only the pages overlapping
        the slice are touched; concatenating consecutive slices along the
        token axis reproduces :meth:`gather` exactly."""
        if self._treedef is None:
            raise RuntimeError("gather_slice before any put")
        if not 0 <= start < end:
            raise ValueError(f"bad token slice [{start}, {end})")
        ps = self.page_size
        p0, p1 = start // ps, -(-end // ps)
        idx = jnp.asarray(list(pages)[p0:p1], jnp.int32)
        off = start - p0 * ps
        out = []
        for key in self._keys:
            x = jnp.take(self._pools[key], idx, axis=1)
            x = x.reshape(x.shape[0], -1, *x.shape[3:])[:, off:off + end - start]
            out.append(x[:, None])                    # restore B=1
        return jax.tree_util.tree_unflatten(self._treedef, out)

    def release(self, pages: Sequence[int]) -> None:
        self.alloc.release(pages)

    def retain(self, pages: Sequence[int]) -> None:
        self.alloc.retain(pages)

    def pool_bytes(self) -> int:
        return sum(p.size * p.dtype.itemsize for p in self._pools.values())


# =====================================================================
# Prefix index
# =====================================================================
@dataclass
class PrefixEntry:
    pages: List[int]                 # empty for snapshot entries
    n_tokens: int
    owner_unit: int
    snapshot: Optional[Any] = None   # full cache pytree (snapshot regime)
    bytes: int = 0                   # transfer size of this prefix
    hits: int = 0


def _page_hash_chain(tokens: np.ndarray, page_size: int) -> List[bytes]:
    out: List[bytes] = []
    h = hashlib.sha256()
    for i in range(len(tokens) // page_size):
        h.update(np.ascontiguousarray(
            tokens[i * page_size:(i + 1) * page_size],
            dtype=np.int32).tobytes())
        out.append(h.digest())
    return out


def _exact_hash(tokens: np.ndarray) -> bytes:
    return hashlib.sha256(
        np.ascontiguousarray(tokens, dtype=np.int32).tobytes()).digest()


class PrefixIndex:
    """Content-addressed map: token-prefix -> reusable cached prefix."""

    def __init__(self, store: PagedStore):
        self.store = store
        self._paged: Dict[bytes, PrefixEntry] = {}
        self._snap: Dict[bytes, PrefixEntry] = {}
        self._snap_lengths: Set[int] = set()

    # ---------------------------------------------------------------- match
    def match(self, tokens: np.ndarray) -> Optional[PrefixEntry]:
        """Longest reusable prefix of ``tokens``."""
        tokens = np.asarray(tokens)
        best: Optional[PrefixEntry] = None
        chain = _page_hash_chain(tokens, self.store.page_size)
        for key in reversed(chain):
            e = self._paged.get(key)
            if e is not None:
                best = e
                break
        for n in sorted(self._snap_lengths, reverse=True):
            if best is not None and n <= best.n_tokens:
                break
            if n > len(tokens):
                continue
            e = self._snap.get(_exact_hash(tokens[:n]))
            if e is not None:
                best = e
                break
        if best is not None:
            best.hits += 1
        return best

    # --------------------------------------------------------------- insert
    def insert_paged(self, tokens: np.ndarray, pages: List[int],
                     owner_unit: int, per_token_bytes: float) -> int:
        """Register every full-page boundary of a pageable cache."""
        tokens = np.asarray(tokens)
        chain = _page_hash_chain(tokens, self.store.page_size)
        added = 0
        for i, key in enumerate(chain):
            if key in self._paged:
                continue
            pg = pages[:i + 1]
            self.store.retain(pg)
            n_tok = (i + 1) * self.store.page_size
            self._paged[key] = PrefixEntry(
                pages=list(pg), n_tokens=n_tok, owner_unit=owner_unit,
                bytes=int(n_tok * per_token_bytes))
            added += 1
        return added

    def insert_snapshot(self, tokens: np.ndarray, cache: Any,
                        owner_unit: int) -> int:
        """Register the end-of-prefill boundary of a snapshot cache."""
        tokens = np.asarray(tokens)
        key = _exact_hash(tokens)
        if key in self._snap:
            return 0
        self._snap[key] = PrefixEntry(
            pages=[], n_tokens=len(tokens), owner_unit=owner_unit,
            snapshot=cache, bytes=cache_bytes(cache))
        self._snap_lengths.add(len(tokens))
        return 1

    # ---------------------------------------------------------------- fetch
    def fetch(self, entry: PrefixEntry) -> Any:
        """Materialise the prefix cache pytree for ``Model.prefill``."""
        if entry.snapshot is not None:
            return entry.snapshot
        return self.store.gather(entry.pages, entry.n_tokens)

    def __len__(self) -> int:
        return len(self._paged) + len(self._snap)
