"""repro.serving — JAX serving substrate with MFS-scheduled transfers.

    PagedStore / PrefixIndex   — paged KV + content-addressed prefix reuse
    ServingEngine              — jitted prefill / suffix-prefill (B=1)
    DecodeBatch                — slotted continuous-batching decode
    DisaggServer               — P/D-disaggregated orchestrator; every
                                 transfer goes through submit/permit/
                                 completion with a pluggable policy (§5)
"""
from .paged_kv import (PagedStore, PrefixIndex, PrefixEntry, cache_bytes,
                       cache_has_state, is_token_leaf_path)
from .engine import ServingEngine, DecodeBatch
from .disagg import DisaggServer, DisaggConfig, ServeRequest, ServeResult

__all__ = [
    "PagedStore", "PrefixIndex", "PrefixEntry", "cache_bytes",
    "cache_has_state", "is_token_leaf_path",
    "ServingEngine", "DecodeBatch",
    "DisaggServer", "DisaggConfig", "ServeRequest", "ServeResult",
]
