"""Mamba2 SSD (state-space duality) chunked scan — Pallas TPU kernel.

The sequential recurrence

    s_t = exp(dt_t * A) * s_{t-1} + dt_t * x_t B_t^T
    y_t = C_t s_t (+ D * x_t, added outside the kernel)

is recast per chunk of Q timesteps into MXU-friendly matmuls (the "duality"):
with per-chunk cumulative log-decay cs_t = sum_{r<=t} dt_r*A,

    y_intra = ((C B^T) o L) @ x      L[t,s] = exp(cs_t - cs_s) * dt_s, s <= t
    y_inter = exp(cs)[:,None] * (C @ state^T)
    state'  = exp(cs_Q) * state + (x * (exp(cs_Q - cs)*dt)[:,None])^T @ B

The grid is ``(batch, heads, T/chunk)`` with chunks innermost (sequential on
TPU), so the [hd, N] running state persists in VMEM scratch across chunks.
cs is precomputed outside the kernel (per-chunk cumsum of dt*A) so the
kernel body is pure matmul + elementwise; all exponent differences are
<= 0 for valid (t, s) pairs, so nothing overflows.

BlockSpec tiling (per grid step, all VMEM):
    x    : (1, Q, 1, hd)    B/C : (1, Q, N)
    dt,cs: (1, 1, Q)        (time-last layout for lane alignment)
    state scratch: (hd, N) f32
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_chunked"]


def _kernel(x_ref, b_ref, c_ref, dt_ref, cs_ref, s0_ref, y_ref, sf_ref,
            state, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # [Q, hd]
    Bm = b_ref[0].astype(jnp.float32)                  # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)                  # [Q, N]
    dt = dt_ref[0]                                     # [1, Q] f32
    cs = cs_ref[0]                                     # [1, Q] f32
    cs_t = jnp.swapaxes(cs, 0, 1)                      # [Q, 1]

    # inter-chunk: contribution of the carried state
    y_inter = jnp.exp(cs_t) * jax.lax.dot_general(
        Cm, state[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # [Q, hd]

    # intra-chunk: masked (decay o gram) matmul
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, Q]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    expo = jnp.where(t_idx >= s_idx, cs_t - cs, -1e30)  # [Q, Q]
    L = jnp.exp(expo) * dt                              # row-bcast dt_s
    y = y_inter + jax.lax.dot_general(
        G * L, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update
    cq = cs[0, chunk - 1]
    w = jnp.exp(cq - cs) * dt                           # [1, Q]
    state[...] = jnp.exp(cq) * state[...] + jax.lax.dot_general(
        x * jnp.swapaxes(w, 0, 1), Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # [hd, N]

    @pl.when(ci == nc - 1)
    def _final():
        sf_ref[0, 0] = state[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked(x: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray,
                dt: jnp.ndarray, A: jnp.ndarray, D: jnp.ndarray,
                init_state: Optional[jnp.ndarray] = None, *,
                chunk: int = 128,
                interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [Bz,T,H,hd]; B/C: [Bz,T,N]; dt: [Bz,T,H]; A/D: [H].

    Returns (y [Bz,T,H,hd] f32, final_state [Bz,H,hd,N] f32).
    """
    Bz, T, H, hd = x.shape
    N = B.shape[-1]
    chunk = min(chunk, max(8, T))
    pad_t = (-T) % chunk
    if pad_t:
        # dt=0 padding preserves the state (exp(0)=1 decay, 0 input weight)
        x = jnp.pad(x, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad_t), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad_t), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_t), (0, 0)))
    Tp = T + pad_t
    nc = Tp // chunk

    dtf = dt.astype(jnp.float32)
    dal = dtf * A[None, None, :]                        # log-decay [Bz,Tp,H]
    cs = jnp.cumsum(dal.reshape(Bz, nc, chunk, H), axis=2).reshape(Bz, Tp, H)
    # time-last layout for the kernel
    dt_tl = jnp.swapaxes(dtf, 1, 2)                     # [Bz, H, Tp]
    cs_tl = jnp.swapaxes(cs, 1, 2)
    s0 = (jnp.zeros((Bz, H, hd, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    kernel = functools.partial(_kernel, chunk=chunk)
    y, sf = pl.pallas_call(
        kernel,
        grid=(Bz, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, hd, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, hd, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bz, Tp, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((Bz, H, hd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(x, B, C, dt_tl, cs_tl, s0)

    y = y[:, :T] + x[:, :T].astype(jnp.float32) * D[None, None, :, None]
    return y, sf
