"""Dispatching wrappers: Pallas TPU kernels when available, jnp oracles
otherwise.

Selection order:
  1. ``REPRO_USE_PALLAS=1`` (or running on a real TPU backend) -> pallas_call
     kernels with BlockSpec VMEM tiling;
  2. ``REPRO_PALLAS_INTERPRET=1`` -> same kernels, interpret mode (CPU CI);
  3. otherwise -> the pure-jnp reference (ref.py), which XLA fuses well and
     which the dry-run lowers through.
"""
from __future__ import annotations

import os
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref as _ref

__all__ = ["attention", "decode_attention", "ssd", "rglru", "use_pallas",
           "interpret_mode"]


def use_pallas() -> bool:
    if os.environ.get("REPRO_USE_PALLAS") == "1":
        return True
    if os.environ.get("REPRO_USE_PALLAS") == "0":
        return False
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def interpret_mode() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET") == "1"


def _pallas_enabled() -> bool:
    return use_pallas() or interpret_mode()


#: above this many score elements per head the XLA path switches to the
#: custom-VJP flash implementation (O(block^2) live scores in fwd AND bwd)
_FLASH_THRESHOLD = 2048 * 2048
if os.environ.get("REPRO_BASELINE_FULL_ATTN") == "1":   # §Perf kill-switch
    _FLASH_THRESHOLD = 1 << 62


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              q_offset: int = 0, scale: Optional[float] = None):
    """Multi-head attention, q:[B,T,H,D] k/v:[B,S,H,D] (heads already
    aligned — GQA resolution happens in the model layer)."""
    if _pallas_enabled():
        from .flash_attention import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, scale=scale,
                               interpret=interpret_mode())
    T, S = q.shape[1], k.shape[1]
    if T * S > _FLASH_THRESHOLD:
        import math
        from .flash_xla import flash_attention_xla
        s = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
        return flash_attention_xla(q, k, v, s, causal, window, q_offset)
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                    q_offset=q_offset, scale=scale)


def decode_attention(q, k, v, lengths, *, scale: Optional[float] = None):
    if _pallas_enabled():
        from .decode_attention import decode_attention as da
        return da(q, k, v, lengths, scale=scale, interpret=interpret_mode())
    return _ref.decode_attention_ref(q, k, v, lengths, scale=scale)


def ssd(x, B, C, dt, A, D, init_state=None,
        ref_fallback: Optional[Callable] = None):
    """Mamba2 SSD. Returns (y, final_state)."""
    if _pallas_enabled():
        from .ssd_scan import ssd_chunked
        return ssd_chunked(x, B, C, dt, A, D, init_state=init_state,
                           interpret=interpret_mode())
    if x.shape[1] > 16 and os.environ.get("REPRO_BASELINE_SSD_SCAN") != "1":
        # chunked dual form: O(T/Q) differentiation memory (§Perf iter. 3)
        return _ref.ssd_dual(x, B, C, dt, A, D, init_state=init_state)
    return _ref.ssd_ref(x, B, C, dt, A, D, init_state=init_state)


def rglru(a, x, init_state=None):
    """Gated linear recurrence. Returns (h, final_state)."""
    if _pallas_enabled():
        from .rglru import rglru_scan
        return rglru_scan(a, x, init_state=init_state,
                          interpret=interpret_mode())
    return _ref.rglru_ref(a, x, init_state=init_state)
