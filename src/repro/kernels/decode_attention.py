"""Decode attention over a padded KV cache — Pallas TPU kernel.

One new query token per sequence attends to its full cached context. The
grid is ``(batch, S/block_k)`` with the KV dimension innermost (sequential
on TPU); all heads of one sequence are processed together so the MXU sees
an [H, Dp] x [Dp, block_k] matmul per step instead of H rank-1 products.

BlockSpec tiling (per grid step, all VMEM):
    q       : (1, H, Dp)
    k/v     : (1, block_k, H, Dp)
    lengths : (1, 1) int32        -- valid cache slots for this sequence
    out     : (1, H, Dp)
    scratch : acc (H, Dp) f32, m/l (H, 128) f32 (lane-broadcast)

Blocks entirely beyond ``lengths[b]`` are compute-skipped.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention", "decode_attention_cost"]


def decode_attention_cost(n_seqs: int, n_heads: int, head_dim: int,
                          ctx: int, *, block_k: int = 256,
                          dtype_bytes: int = 2) -> tuple:
    """Per-layer (flops, hbm_bytes) of one batched decode-attention step,
    derived from THIS kernel's actual tiling — the measured roofline that
    ``StageProfile.decode_step_roofline`` calibrates the analytic
    ``decode_step_time`` against.

    Mirrors the launch math above exactly: the head dim pads to a multiple
    of 128 lanes, the KV axis pads to ``block_k``, and blocks entirely
    beyond ``ctx`` are compute-skipped (``@pl.when``) — so per sequence
    ``ceil(ctx / block_k)`` KV blocks are streamed from HBM and hit the
    MXU. Per touched block each head runs the [H, Dp] x [Dp, bk] logits
    matmul and the [H, bk] x [bk, Dp] update (4 * H * Dp * bk flops); HBM
    traffic is the K and V tiles plus the q read and output write. Pure
    math (no JAX), usable by the control plane.
    """
    S = max(int(ctx), 1)
    bk = min(block_k, max(128, S))
    Dp = head_dim + (-head_dim) % 128
    n_blocks = -(-S // bk)                       # compute-skip beyond ctx
    flops = n_seqs * n_blocks * 4.0 * n_heads * Dp * bk
    hbm = n_seqs * (2.0 * n_blocks * bk * n_heads * Dp * dtype_bytes  # K+V
                    + 2.0 * n_heads * Dp * dtype_bytes)               # q+out
    return flops, hbm

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_k: int):
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    length = len_ref[0, 0]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(j * block_k < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # [H, Dp]
        k = k_ref[0].astype(jnp.float32)                    # [bk, H, Dp]
        v = v_ref[0].astype(jnp.float32)
        H = q.shape[0]
        # [H, bk] logits: contract Dp, batch over H
        s = jax.lax.dot_general(
            q, jnp.swapaxes(k, 0, 1),                        # [H,Dp] x [H,bk,Dp]
            (((1,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale
        kpos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (H, block_k), 1)
        mask = kpos < length
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)         # [H, bk]
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True), l_ref.shape)
        # [H, Dp] update: contract bk, batch over H
        pv = jax.lax.dot_general(
            p, jnp.swapaxes(v, 0, 1),                        # [H,bk] x [H,bk,Dp]
            (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(j == nj - 1)
    def _finalize():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     lengths: jnp.ndarray, *, scale: Optional[float] = None,
                     block_k: int = 256,
                     interpret: bool = False) -> jnp.ndarray:
    """q: [B,H,D]; k/v: [B,S,H,D]; lengths: [B] int32."""
    B, H, D = q.shape
    S = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_k = min(block_k, max(128, S))

    pad_d = (-D) % 128
    pad_s = (-S) % block_k
    if pad_d:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_d)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    Sp, Dp = S + pad_s, D + pad_d
    len2 = lengths.astype(jnp.int32).reshape(B, 1)

    kernel = functools.partial(_kernel, scale=scale, block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid=(B, Sp // block_k),
        in_specs=[
            pl.BlockSpec((1, H, Dp), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, H, Dp), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_k, H, Dp), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, H, Dp), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((H, Dp), jnp.float32),
            pltpu.VMEM((H, 128), jnp.float32),
            pltpu.VMEM((H, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, len2)
    return out[:, :, :D]
