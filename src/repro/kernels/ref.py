"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: kernels are validated against these with
``interpret=True`` sweeps in tests/test_kernels.py, and non-TPU backends run
them in production code paths (see ops.py).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "blockwise_attention_ref",
           "decode_attention_ref", "ssd_ref", "ssd_dual", "rglru_ref"]


#: above this many score elements per head, this oracle switches to the
#: blockwise (scan) implementation so lowering stays memory-bounded (the
#: production non-TPU path with a flash custom VJP lives in flash_xla.py
#: and is selected by ops.attention; this module stays autodiff-plain).
_BLOCKWISE_THRESHOLD = 4096 * 4096


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, window: int = 0,
                        q_offset: int = 0,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """Attention oracle. q: [B,T,H,D]; k/v: [B,S,H,D].

    Small shapes materialise the full score matrix (the semantics of
    record); large shapes run the mathematically identical blockwise
    online-softmax scan, which is what the dry-run lowers through — peak
    live memory per head is O(T * block) instead of O(T * S).
    """
    B, T, H, D = q.shape
    S = k.shape[1]
    if T * S > _BLOCKWISE_THRESHOLD:
        return blockwise_attention_ref(q, k, v, causal=causal, window=window,
                                       q_offset=q_offset, scale=scale)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    logits = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    qp = jnp.arange(T)[:, None] + q_offset
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= (qp - kp) < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def blockwise_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            *, causal: bool = True, window: int = 0,
                            q_offset: int = 0, scale: Optional[float] = None,
                            block_q: int = 1024,
                            block_k: int = 1024) -> jnp.ndarray:
    """Flash attention in pure XLA: lax.scan over KV blocks with an
    online-softmax carry, vmapped over query blocks. Exact same math as
    :func:`flash_attention_ref`, O(block_q * block_k) live scores."""
    B, T, H, D = q.shape
    S = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    pad_t = (-T) % block_q
    pad_s = (-S) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_t), (0, 0), (0, 0))) if pad_t else q
    kp = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0))) if pad_s else k
    vp = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0))) if pad_s else v
    Tp, Sp = T + pad_t, S + pad_s
    nq, nk = Tp // block_q, Sp // block_k
    # [B, nq, bq, H, D] / [nk, B, bk, H, D]
    qb = qp.reshape(B, nq, block_q, H, D)
    kb = kp.reshape(B, nk, block_k, H, D).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, block_k, H, D).transpose(1, 0, 2, 3, 4)

    def one_q_block(qi, q_blk):                       # q_blk: [B, bq, H, D]
        q32 = q_blk.astype(jnp.float32) * scale

        def kv_step(carry, inp):
            m, l, acc = carry                          # [B,H,bq,1], .., [B,H,bq,D]
            kj, k_blk, v_blk = inp
            s = jnp.einsum("bthd,bshd->bhts", q32, k_blk.astype(jnp.float32))
            qpos = qi * block_q + jnp.arange(block_q)[:, None] + q_offset
            kpos = kj * block_k + jnp.arange(block_k)[None, :]
            mask = kpos < S
            if causal:
                mask &= qpos >= kpos
            if window:
                mask &= (qpos - kpos) < window
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            p = jnp.where(mask[None, None], jnp.exp(s - m_new), 0.0)
            alpha = jnp.exp(m - m_new)
            l = alpha * l + p.sum(-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhts,bshd->bhtd", p,
                                           v_blk.astype(jnp.float32))
            return (m_new, l, acc), None

        init = (jnp.full((B, H, block_q, 1), -1e30, jnp.float32),
                jnp.zeros((B, H, block_q, 1), jnp.float32),
                jnp.zeros((B, H, block_q, D), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)              # [B, H, bq, D]
        return out.transpose(0, 2, 1, 3)               # [B, bq, H, D]

    out = jax.lax.map(lambda args: one_q_block(*args),
                      (jnp.arange(nq), qb.transpose(1, 0, 2, 3, 4)))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, Tp, H, D)[:, :T]
    return out.astype(q.dtype)


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         lengths: jnp.ndarray,
                         scale: Optional[float] = None) -> jnp.ndarray:
    """Single-token decode attention over a padded KV cache.

    q: [B,H,D]; k/v: [B,S,H,D]; lengths: [B] — number of valid cache slots.
    """
    B, H, D = q.shape
    S = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    mask = jnp.arange(S)[None] < lengths[:, None]            # [B, S]
    logits = jnp.where(mask[:, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", w, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray, dt: jnp.ndarray,
            A: jnp.ndarray, D: jnp.ndarray,
            init_state: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mamba2 SSD recurrence (state-space duality), sequential over time.

        s_t = exp(dt_t * A) * s_{t-1} + dt_t * x_t B_t^T
        y_t = C_t s_t + D * x_t

    x: [Bsz,T,H,hd]; B/C: [Bsz,T,N]; dt: [Bsz,T,H]; A/D: [H].
    Returns (y [Bsz,T,H,hd], final_state [Bsz,H,hd,N]).
    """
    Bsz, T, H, hd = x.shape
    N = B.shape[-1]
    dA = jnp.exp(dt.astype(jnp.float32) * A[None, None, :])
    s0 = (jnp.zeros((Bsz, H, hd, N), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))

    def step(s, inp):
        xt, Bt, Ct, dAt, dtt = inp
        s = s * dAt[..., None, None] \
            + (dtt[..., None] * xt.astype(jnp.float32))[..., None] * Bt[:, None, None, :].astype(jnp.float32)
        yt = jnp.einsum("bhdn,bn->bhd", s, Ct.astype(jnp.float32))
        return s, yt

    xs = (x.transpose(1, 0, 2, 3), B.transpose(1, 0, 2), C.transpose(1, 0, 2),
          dA.transpose(1, 0, 2), dt.transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, s0, xs)
    y = ys.transpose(1, 0, 2, 3) + x.astype(jnp.float32) * D[None, None, :, None]
    return y, final


def ssd_dual(x: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray, dt: jnp.ndarray,
             A: jnp.ndarray, D: jnp.ndarray,
             init_state: Optional[jnp.ndarray] = None, *,
             chunk: int = 128) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mamba2 SSD via the chunked *dual* (matmul) form — the memory-safe
    training path.

    Differentiating the sequential recurrence saves the [B,H,hd,N] state at
    every timestep (33 MB x 4096 steps/layer at the train_4k shape — §Perf
    iteration 3, mamba2). The dual form computes intra-chunk outputs as
    masked matmuls and propagates chunk-boundary states with a log-depth
    associative scan, so autodiff keeps O(T/Q) states instead of O(T).
    Same math as :func:`ssd_ref` (the duality), validated in tests.
    """
    Bz, T, H, hd = x.shape
    N = B.shape[-1]
    Q = min(chunk, T)
    pad = (-T) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc = Tp // Q
    xc = x.reshape(Bz, nc, Q, H, hd).astype(jnp.float32)
    Bc = B.reshape(Bz, nc, Q, N).astype(jnp.float32)
    Cc = C.reshape(Bz, nc, Q, N).astype(jnp.float32)
    dtc = dt.reshape(Bz, nc, Q, H).astype(jnp.float32)
    cs = jnp.cumsum(dtc * A[None, None, None, :], axis=2)   # [Bz,nc,Q,H]
    cq = cs[:, :, -1]                                        # [Bz,nc,H]

    # per-chunk increment + decay of the boundary-state recurrence
    w = jnp.exp(cq[:, :, None] - cs) * dtc                   # [Bz,nc,Q,H]
    inc = jnp.einsum("bcqhd,bcqn->bchdn", xc * w[..., None], Bc)
    decay = jnp.exp(cq)                                      # [Bz,nc,H]

    def combine(l, r):
        dl, il = l
        dr, ir = r
        return dl * dr, ir + il * dr[..., None, None]

    d_all, i_all = jax.lax.associative_scan(
        combine, (decay, inc), axis=1)                       # inclusive
    s0 = (jnp.zeros((Bz, H, hd, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    # state entering chunk c = scan result of chunks < c, plus s0 decayed
    d_prev = jnp.concatenate(
        [jnp.ones_like(d_all[:, :1]), d_all[:, :-1]], axis=1)
    i_prev = jnp.concatenate(
        [jnp.zeros_like(i_all[:, :1]), i_all[:, :-1]], axis=1)
    s_in = i_prev + d_prev[..., None, None] * s0[:, None]    # [Bz,nc,H,hd,N]

    # outputs: inter-chunk + masked intra-chunk matmul
    y_inter = jnp.exp(cs)[..., None] * jnp.einsum(
        "bcqn,bchdn->bcqhd", Cc, s_in)
    G = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)                # [Bz,nc,Q,Q]
    t_i = jnp.arange(Q)[:, None]
    s_i = jnp.arange(Q)[None, :]
    expo = cs[:, :, :, None, :] - cs[:, :, None, :, :]       # [Bz,nc,Q,Q,H]
    expo = jnp.where((t_i >= s_i)[None, None, :, :, None], expo, -1e30)
    L = jnp.exp(expo) * dtc[:, :, None, :, :]                # [Bz,nc,t,s,H]
    y_intra = jnp.einsum("bcqs,bcqsh,bcshd->bcqhd", G, L, xc)
    y = (y_inter + y_intra).reshape(Bz, Tp, H, hd)[:, :T]
    y = y + x[:, :T].astype(jnp.float32) * D[None, None, :, None]
    final = i_all[:, -1] + d_all[:, -1][..., None, None] * s0
    return y, final


def rglru_ref(a: jnp.ndarray, x: jnp.ndarray,
              init_state: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Gated linear recurrence  h_t = a_t * h_{t-1} + x_t  (RG-LRU core).

    a/x: [B, T, W] (fp32). Returns (h [B,T,W], final_state [B,W]).
    """
    B, T, W = a.shape
    h0 = jnp.zeros((B, W), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)

    def step(h, inp):
        at, xt = inp
        h = at * h + xt
        return h, h

    final, hs = jax.lax.scan(
        step, h0, (a.transpose(1, 0, 2).astype(jnp.float32),
                   x.transpose(1, 0, 2).astype(jnp.float32)))
    return hs.transpose(1, 0, 2), final
