"""Memory-bounded flash attention in pure XLA with a custom VJP.

Differentiating a blockwise-attention scan with plain autodiff stores the
per-KV-block softmax carries — asymptotically the same O(T*S) footprint the
blocking was supposed to avoid (§Perf iteration 2, hypothesis refuted by
measurement). This implementation saves only (q, k, v, out, row-lse) and
*recomputes* the score blocks in the backward pass — the standard flash
backward:

    D  = rowsum(dout * out)
    p  = exp(q k^T * scale - lse)
    dv += p^T dout
    dp = dout v^T
    ds = p * (dp - D) * scale
    dq += ds k ;  dk += ds^T q

Forward and backward are double loops (lax.scan) over q/kv blocks; live
intermediates are O(block_q * block_k). Used by ops.attention on non-TPU
backends for large shapes; the Pallas kernel owns the TPU fast path; the
full-materialisation oracle in ref.py remains the semantics of record.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_xla"]

_NEG = -1e30


def _mask(qi, kj, bq, bk, S, causal, window, q_offset):
    qpos = qi * bq + jnp.arange(bq)[:, None] + q_offset
    kpos = kj * bk + jnp.arange(bk)[None, :]
    m = kpos < S
    if causal:
        m &= qpos >= kpos
    if window:
        m &= (qpos - kpos) < window
    return m


def _pad(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if not pad:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, pad)
    return jnp.pad(x, pads)


def _fwd(q, k, v, scale, causal, window, q_offset, bq, bk):
    """Returns (out [B,T,H,D] in q.dtype, lse [B,H,T] f32)."""
    B, T, H, D = q.shape
    S = k.shape[1]
    qp = _pad(q, 1, bq)
    kp = _pad(k, 1, bk)
    vp = _pad(v, 1, bk)
    Tp, Sp = qp.shape[1], kp.shape[1]
    nq, nk = Tp // bq, Sp // bk
    qb = jnp.moveaxis(qp.reshape(B, nq, bq, H, D), 1, 0)
    kb = jnp.moveaxis(kp.reshape(B, nk, bk, H, D), 1, 0)
    vb = jnp.moveaxis(vp.reshape(B, nk, bk, H, D), 1, 0)

    def q_block(_, qi_q):
        qi, q_blk = qi_q
        q32 = q_blk.astype(jnp.float32) * scale

        def kv_step(carry, kj_kv):
            m_, l_, acc = carry
            kj, k_blk, v_blk = kj_kv
            s = jnp.einsum("bthd,bshd->bhts", q32,
                           k_blk.astype(jnp.float32))
            msk = _mask(qi, kj, bq, bk, S, causal, window, q_offset)
            s = jnp.where(msk[None, None], s, _NEG)
            m_new = jnp.maximum(m_, s.max(-1, keepdims=True))
            p = jnp.where(msk[None, None], jnp.exp(s - m_new), 0.0)
            alpha = jnp.exp(m_ - m_new)
            l_ = alpha * l_ + p.sum(-1, keepdims=True)
            acc = acc * alpha + jnp.einsum(
                "bhts,bshd->bhtd", p, v_blk.astype(jnp.float32))
            return (m_new, l_, acc), None

        init = (jnp.full((B, H, bq, 1), _NEG, jnp.float32),
                jnp.zeros((B, H, bq, 1), jnp.float32),
                jnp.zeros((B, H, bq, D), jnp.float32))
        (m_, l_, acc), _ = jax.lax.scan(kv_step, init,
                                        (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l_, 1e-30)                  # [B,H,bq,D]
        lse = (m_ + jnp.log(jnp.maximum(l_, 1e-30)))[..., 0]  # [B,H,bq]
        return None, (out, lse)

    _, (ob, lseb) = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))
    out = jnp.moveaxis(ob, 0, 2).reshape(B, H, Tp, D)[:, :, :T]
    lse = jnp.moveaxis(lseb, 0, 2).reshape(B, H, Tp)[:, :, :T]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype), lse


def _bwd(scale, causal, window, q_offset, bq, bk, res, dout):
    q, k, v, out, lse = res
    B, T, H, D = q.shape
    S = k.shape[1]
    qp, op, dop = (_pad(x, 1, bq) for x in (q, out, dout))
    lsep = _pad(lse, 2, bq)
    kp, vp = _pad(k, 1, bk), _pad(v, 1, bk)
    Tp, Sp = qp.shape[1], kp.shape[1]
    nq, nk = Tp // bq, Sp // bk
    qb = jnp.moveaxis(qp.reshape(B, nq, bq, H, D), 1, 0)
    ob = jnp.moveaxis(op.reshape(B, nq, bq, H, D), 1, 0)
    dob = jnp.moveaxis(dop.reshape(B, nq, bq, H, D), 1, 0)
    lseb = jnp.moveaxis(lsep.reshape(B, H, nq, bq), 2, 0)   # [nq,B,H,bq]
    kb = jnp.moveaxis(kp.reshape(B, nk, bk, H, D), 1, 0)
    vb = jnp.moveaxis(vp.reshape(B, nk, bk, H, D), 1, 0)

    def q_block(carry, inp):
        dk_acc, dv_acc = carry                              # [nk,B,bk,H,D] f32
        qi, q_blk, o_blk, do_blk, lse_blk = inp
        q32 = q_blk.astype(jnp.float32)
        do32 = jnp.einsum("bthd->bhtd", do_blk.astype(jnp.float32))
        Drow = jnp.einsum("bthd,bthd->bht", o_blk.astype(jnp.float32),
                          do_blk.astype(jnp.float32))       # [B,H,bq]

        def kv_step(dq_acc, kj_kv):
            kj, k_blk, v_blk = kj_kv
            s = jnp.einsum("bthd,bshd->bhts", q32 * scale,
                           k_blk.astype(jnp.float32))
            msk = _mask(qi, kj, bq, bk, S, causal, window, q_offset)
            p = jnp.where(msk[None, None],
                          jnp.exp(s - lse_blk[..., None]), 0.0)
            dv_b = jnp.einsum("bhts,bhtd->bshd", p, do32)
            dp = jnp.einsum("bhtd,bshd->bhts", do32,
                            v_blk.astype(jnp.float32))
            ds = p * (dp - Drow[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bhts,bshd->bthd", ds,
                                         k_blk.astype(jnp.float32))
            dk_b = jnp.einsum("bhts,bthd->bshd", ds, q32)
            return dq_acc, (dk_b, dv_b)

        dq0 = jnp.zeros((B, bq, H, D), jnp.float32)
        dq_blk, (dk_b, dv_b) = jax.lax.scan(
            kv_step, dq0, (jnp.arange(nk), kb, vb))
        return (dk_acc + dk_b, dv_acc + dv_b), dq_blk

    zero_kv = jnp.zeros((nk, B, bk, H, D), jnp.float32)
    (dk_f, dv_f), dq_b = jax.lax.scan(
        q_block, (zero_kv, zero_kv),
        (jnp.arange(nq), qb, ob, dob, lseb))
    dq = jnp.moveaxis(dq_b, 0, 1).reshape(B, Tp, H, D)[:, :T].astype(q.dtype)
    dk = jnp.moveaxis(dk_f, 0, 1).reshape(B, Sp, H, D)[:, :S].astype(k.dtype)
    dv = jnp.moveaxis(dv_f, 0, 1).reshape(B, Sp, H, D)[:, :S].astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention_xla(q, k, v, scale: float, causal: bool, window: int,
                        q_offset: int, block_q: int = 512,
                        block_k: int = 512):
    out, _ = _fwd(q, k, v, scale, causal, window, q_offset, block_q, block_k)
    return out


def _vjp_fwd(q, k, v, scale, causal, window, q_offset, block_q, block_k):
    out, lse = _fwd(q, k, v, scale, causal, window, q_offset, block_q,
                    block_k)
    return out, (q, k, v, out, lse)


def _vjp_bwd(scale, causal, window, q_offset, block_q, block_k, res, dout):
    return _bwd(scale, causal, window, q_offset, block_q, block_k, res, dout)


flash_attention_xla.defvjp(_vjp_fwd, _vjp_bwd)
