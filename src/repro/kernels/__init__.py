"""Pallas TPU kernels for the serving substrate's compute hot spots.

Layout per the repo convention:
  flash_attention.py / decode_attention.py / ssd_scan.py / rglru.py
      — pl.pallas_call kernels with explicit BlockSpec VMEM tiling
  ops.py — jit'd dispatching wrappers (Pallas on TPU, jnp elsewhere)
  ref.py — pure-jnp oracles (semantics of record)
"""
from . import ops, ref

__all__ = ["ops", "ref"]
