"""RG-LRU gated linear recurrence — Pallas TPU kernel.

    h_t = a_t * h_{t-1} + x_t          (a, x: [B, T, W])

The grid is ``(batch, W/block_w, T/chunk)`` with time chunks innermost
(sequential on TPU); the [1, block_w] hidden state persists in VMEM scratch
across chunks. Within a chunk the recurrence is solved with a log-depth
``associative_scan`` over (a, x) pairs — combine((a1,x1),(a2,x2)) =
(a2*a1, a2*x1 + x2) — vectorised across the width lanes, with the carried
state folded into the first element.

BlockSpec tiling (per grid step, all VMEM):
    a/x  : (1, chunk, block_w)
    state scratch: (1, block_w) f32
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rglru_scan"]


def _kernel(a_ref, x_ref, s0_ref, h_ref, sf_ref, state):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = s0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)                    # [Q, bw]
    x = x_ref[0].astype(jnp.float32)
    # fold carried state into step 0: x'_0 = a_0 * h_prev + x_0
    x = jnp.concatenate([x[:1] + a[:1] * state[...], x[1:]], axis=0)

    def combine(l, r):
        al, xl = l
        ar, xr = r
        return ar * al, ar * xl + xr

    _, hs = jax.lax.associative_scan(combine, (a, x), axis=0)
    h_ref[0] = hs.astype(h_ref.dtype)
    state[...] = hs[-1:]

    @pl.when(ci == nc - 1)
    def _final():
        sf_ref[...] = state[...]


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "interpret"))
def rglru_scan(a: jnp.ndarray, x: jnp.ndarray,
               init_state: Optional[jnp.ndarray] = None, *,
               chunk: int = 256, block_w: int = 512,
               interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """a/x: [B, T, W]. Returns (h [B,T,W] f32, final_state [B,W] f32)."""
    B, T, W = a.shape
    chunk = min(chunk, max(8, T))
    block_w = min(block_w, max(128, W))
    pad_t = (-T) % chunk
    pad_w = (-W) % block_w
    if pad_t or pad_w:
        # a=1, x=0 padding keeps the carried state unchanged
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_w)),
                    constant_values=1.0)
        x = jnp.pad(x, ((0, 0), (0, pad_t), (0, pad_w)))
    Tp, Wp = T + pad_t, W + pad_w
    s0 = (jnp.zeros((B, Wp), jnp.float32) if init_state is None
          else jnp.pad(init_state.astype(jnp.float32), ((0, 0), (0, pad_w))))

    h, sf = pl.pallas_call(
        _kernel,
        grid=(B, Wp // block_w, Tp // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, block_w), lambda b, w, c: (b, c, w)),
            pl.BlockSpec((1, chunk, block_w), lambda b, w, c: (b, c, w)),
            pl.BlockSpec((1, block_w), lambda b, w, c: (b, w)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_w), lambda b, w, c: (b, c, w)),
            pl.BlockSpec((1, block_w), lambda b, w, c: (b, w)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Tp, Wp), jnp.float32),
            jax.ShapeDtypeStruct((B, Wp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(a, x, s0)
    return h[:, :T, :W], sf[:, :W]
