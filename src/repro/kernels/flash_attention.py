"""Flash attention (prefill) — Pallas TPU kernel.

Online-softmax tiling over the KV sequence: the grid is
``(batch*heads, T/block_q, S/block_k)`` with the KV dimension innermost —
on TPU the last grid dimension executes sequentially per core, so the
running (max, sum, accumulator) state lives in VMEM scratch and persists
across KV blocks.

BlockSpec tiling (per grid step, all VMEM):
    q   : (1, block_q, Dp)        -- Dp = head_dim padded to 128
    k/v : (1, block_k, Dp)
    out : (1, block_q, Dp)
    scratch: acc (block_q, Dp) f32, m/l (block_q, 128) f32 (lane-broadcast)

Causal blocks entirely above the diagonal are skipped with ``pl.when``
(compute-skip; the init/finalize epilogues still run), which removes
~half of the S-loop for causal prefill.

Supports GQA-resolved inputs (head mapping happens in the model layer),
sliding-window masks and a static ``q_offset`` for chunked prefill.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, q_offset: int,
            s_orig: int, block_q: int, block_k: int):
    i = pl.program_id(1)          # query block
    j = pl.program_id(2)          # kv block
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # --- compute-skip for blocks that are fully masked -------------------
    in_range = j * block_k < s_orig
    if causal:
        # the largest query position in this block vs smallest key position
        visible = (j * block_k) <= (i * block_q + block_q - 1 + q_offset)
        should_run = jnp.logical_and(in_range, visible)
    else:
        should_run = in_range

    @pl.when(should_run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # [bq, Dp]
        k = k_ref[0].astype(jnp.float32)              # [bk, Dp]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        qpos = (i * block_q + q_offset
                + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0))
        kpos = (j * block_k
                + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1))
        mask = kpos < s_orig
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        if window:
            mask = jnp.logical_and(mask, (qpos - kpos) < window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]                          # [bq, 1]
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)                    # fully-masked rows -> 0
        alpha = jnp.exp(m_prev - m_new)                # [bq, 1]
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nj - 1)
    def _finalize():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    target = ((n + mult - 1) // mult) * mult
    if target == n:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - n)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "scale", "block_q", "block_k",
    "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, q_offset: int = 0,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: [B,T,H,D]; k/v: [B,S,H,D] (heads already GQA-aligned)."""
    B, T, H, D = q.shape
    S = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, max(8, T))
    block_k = min(block_k, max(128, S))

    # [B,T,H,D] -> [B*H, T, Dp]
    def fold(x):
        x = jnp.swapaxes(x, 1, 2).reshape(B * H, x.shape[1], D)
        return _pad_to(x, 2, 128)

    qf, kf, vf = fold(q), fold(k), fold(v)
    qf = _pad_to(qf, 1, block_q)
    kf = _pad_to(kf, 1, block_k)
    vf = _pad_to(vf, 1, block_k)
    Tp, Sp, Dp = qf.shape[1], kf.shape[1], qf.shape[2]

    grid = (B * H, Tp // block_q, Sp // block_k)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, s_orig=S, block_q=block_q, block_k=block_k)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, Dp), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, Dp), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, Dp), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dp), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tp, Dp), q.dtype),
        scratch_shapes=[
            _vmem((block_q, Dp), jnp.float32),
            _vmem((block_q, 128), jnp.float32),
            _vmem((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)

    out = out[:, :T, :D].reshape(B, H, T, D)
    return jnp.swapaxes(out, 1, 2)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
