"""Sharding context: logical-axis annotations for the production mesh.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.

  * batch dims            -> ("pod", "data")    (pure DP across pods)
  * attention heads, d_ff,
    experts               -> "model"            (TP / EP)
  * parameters' d_model
    (first) dim           -> "data" when zero3  (ZeRO-3 / FSDP resharding)

Head-count divisibility: query heads are padded up to a multiple of the model
axis (zero-initialised W_q rows + zero W_o columns, so padded heads are exact
no-ops); KV heads are sharded when divisible by the model axis, otherwise the
KV tensor stays replicated across "model" and is broadcast into the padded
query-head layout at use (constrained so each device materialises only its
own slice).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardCtx", "pad_to_multiple"]


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclass
class ShardCtx:
    """Carries the mesh + axis names through model construction.

    ``mesh=None`` (CPU smoke tests) turns every annotation into a no-op and
    makes shard_map-based blocks fall back to their single-device path.

    ``ep_axes`` selects the expert-parallel mesh axes for MoE layers:
    ("model",) is classic EP-within-TP; ("data", "model") spreads experts
    over the full pod (DeepSeek-V3-scale models whose expert weights cannot
    fit a 16-way shard) with token dispatch over the combined axis.

    ``kv_seq_shard`` switches decode-mode KV caches to *sequence* sharding
    over the model axis (flash-decoding style): each model shard holds
    S/model_size cache slots and XLA assembles the softmax over the sharded
    length. This keeps GQA KV heads unpadded/unreplicated — the only layout
    under which 32k-context decode fits HBM for kv-light GQA archs.
    """

    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    zero3: bool = False            # shard params' d_model dim over data too
    zero3_axes: Tuple[str, ...] = ("data",)
    ep_axes: Tuple[str, ...] = ("model",)
    kv_seq_shard: bool = False
    #: heads are padded to a multiple of this REGARDLESS of the live mesh, so
    #: the parameter layout is mesh-independent: a checkpoint written on any
    #: mesh (1..16-wide model axis) reshards onto any other without reshape.
    head_pad: int = 16

    @property
    def head_multiple(self) -> int:
        m = self.model_size
        return self.head_pad * ((m + self.head_pad - 1) // self.head_pad) \
            if m > self.head_pad else self.head_pad

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def ep_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.ep_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def data_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    # ------------------------------------------------------------ activations
    def act(self, x, spec: Tuple) -> "jax.Array":
        """Constrain activation sharding; spec entries: 'batch', 'model',
        None. 'batch' expands to the (pod, data) axes tuple."""
        if self.mesh is None:
            return x
        parts = []
        for s in spec:
            if s == "batch":
                parts.append(self.batch_axes if len(self.batch_axes) > 1
                             else self.batch_axes[0])
            elif s == "model":
                parts.append(self.model_axis)
            else:
                parts.append(None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*parts)))

    # ------------------------------------------------------------- parameters
    def pspec(self, *spec) -> P:
        parts = []
        for s in spec:
            if s == "batch":
                parts.append(self.batch_axes if len(self.batch_axes) > 1
                             else self.batch_axes[0])
            elif s == "model":
                parts.append(self.model_axis)
            elif s == "zero3":
                parts.append((self.zero3_axes if len(self.zero3_axes) > 1
                              else self.zero3_axes[0]) if self.zero3 else None)
            else:
                parts.append(None)
        return P(*parts)

    def named(self, *spec) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.pspec(*spec))
