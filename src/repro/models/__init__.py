"""repro.models — JAX model zoo driven by ArchConfig."""
from .sharding import ShardCtx, pad_to_multiple
from .lm import Model, build_model, plan_segments, Segment

__all__ = ["ShardCtx", "pad_to_multiple", "Model", "build_model",
           "plan_segments", "Segment"]
