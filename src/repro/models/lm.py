"""Model assembly: CausalLM (dense / MoE / SSM / hybrid / VLM-backbone) and
EncDecLM (Seamless-style), built from repro.models.blocks per ArchConfig.

Layer stacks are grouped into homogeneous *segments* scanned with
``jax.lax.scan`` (fast compiles at 60+ layers, remat-friendly):

  * uniform models     -> one segment of L layers
  * DeepSeek (first_dense=k) -> [dense x k][moe x (L-k)]
  * RecurrentGemma (pattern rec,rec,attn) -> [superblock x L//3][tail]

Public API (returned by ``build_model``):
  init(key)                      -> params
  loss(params, batch)            -> scalar  (batch: tokens/labels[/embeds])
  prefill(params, batch)         -> (logits_last, cache)
  decode_step(params, cache, tok, pos) -> (logits, cache)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .blocks import (attn_apply, attn_init, ffn_apply, ffn_init, mla_apply,
                     mla_init, moe_apply, moe_init, rglru_apply, rglru_init,
                     ssd_apply, ssd_init, AttnDims)
from .layers import (DEFAULT_DTYPE, dense, init_dense, rmsnorm,
                     rmsnorm_params, softmax_xent)
from .sharding import ShardCtx

__all__ = ["Model", "build_model", "Segment"]


# ---------------------------------------------------------------- sublayers
_MIXER_INIT = {"attn": attn_init, "mla": mla_init, "ssm": ssd_init,
               "rec": rglru_init}
_MIXER_APPLY = {"attn": attn_apply, "mla": mla_apply, "ssm": ssd_apply,
                "rec": rglru_apply}


def _mixer_kind(cfg: ArchConfig, layer: int) -> str:
    kind = cfg.layer_kind(layer)
    if kind == "attn" and cfg.use_mla:
        return "mla"
    return kind


def _layer_init(key, cfg: ArchConfig, ctx: ShardCtx, layer: int,
                cross: bool = False):
    kind = _mixer_kind(cfg, layer)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "ln1": rmsnorm_params(cfg.d_model),
        "mix": _MIXER_INIT[kind](ks[0], cfg, ctx),
    }
    if cfg.family != "ssm":                     # mamba2 blocks have no FFN
        p["ln2"] = rmsnorm_params(cfg.d_model)
        if cfg.is_moe_layer(layer):
            p["ffn_moe"] = moe_init(ks[1], cfg, ctx)
        else:
            p["ffn"] = ffn_init(ks[1], cfg, ctx)
    if cross:
        p["ln_x"] = rmsnorm_params(cfg.d_model)
        p["xattn"] = attn_init(ks[2], cfg, ctx)
    return p


def _layer_apply(p, x, *, cfg: ArchConfig, ctx: ShardCtx, kind: str,
                 is_moe: bool, mode: str, cache=None, pos=0,
                 memory=None, window: int = 0):
    """One decoder layer. Returns (x, new_cache)."""
    h, mix_cache = _MIXER_APPLY[kind](
        p["mix"], rmsnorm(p["ln1"], x, cfg.norm_eps), cfg=cfg, ctx=ctx,
        mode=mode, cache=None if cache is None else cache.get("mix"),
        pos=pos, window=window)
    x = x + h
    new_cache: Dict[str, Any] = {}
    if mix_cache is not None:
        new_cache["mix"] = mix_cache
    if "xattn" in p and (memory is not None
                         or (cache is not None and "xk" in cache)):
        # cross-attention over encoder memory (no causal mask, no rope cache)
        from .layers import gqa_attention
        B, T, D = x.shape
        dims = AttnDims.of(cfg, ctx)
        xs = rmsnorm(p["ln_x"], x, cfg.norm_eps)
        q = dense(p["xattn"]["wq"], xs).reshape(B, T, dims.n_q, dims.hd)
        if cache is not None and "xk" in cache:
            k, v = cache["xk"], cache["xv"]
        else:
            S = memory.shape[1]
            k = dense(p["xattn"]["wk"], memory).reshape(B, S, dims.n_kv, dims.hd)
            v = dense(p["xattn"]["wv"], memory).reshape(B, S, dims.n_kv, dims.hd)
        if mode in ("prefill", "decode"):
            new_cache["xk"], new_cache["xv"] = k, v
        if dims.n_kv != dims.n_q:
            qmap = dims.q_to_kv(cfg)
            k = jnp.take(k, qmap, axis=2)
            v = jnp.take(v, qmap, axis=2)
        o = gqa_attention(q, k, v, mask=None)
        x = x + dense(p["xattn"]["wo"], o.reshape(B, T, dims.n_q * dims.hd))
    if "ffn" in p or "ffn_moe" in p:
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if is_moe:
            x = x + moe_apply(p["ffn_moe"], h2, cfg=cfg, ctx=ctx, mode=mode)
        else:
            x = x + ffn_apply(p["ffn"], h2, cfg=cfg, ctx=ctx)
    return x, (new_cache or None)


# ----------------------------------------------------------------- segments
@dataclass(frozen=True)
class Segment:
    """``count`` repetitions of the sublayer pattern ``kinds``; each entry is
    (mixer_kind, is_moe, window)."""

    count: int
    kinds: Tuple[Tuple[str, bool, int], ...]
    cross: bool = False

    @property
    def layers_per_block(self) -> int:
        return len(self.kinds)


def plan_segments(cfg: ArchConfig) -> List[Segment]:
    window = cfg.window
    if cfg.block_pattern:                                    # hybrid
        unit = tuple((_mixer_kind(cfg, i), cfg.is_moe_layer(i),
                      window if cfg.block_pattern[i % len(cfg.block_pattern)] == "attn" else 0)
                     for i in range(len(cfg.block_pattern)))
        n_units = cfg.n_layers // len(cfg.block_pattern)
        segs = [Segment(n_units, unit)] if n_units else []
        rem = cfg.n_layers - n_units * len(cfg.block_pattern)
        if rem:
            tail = tuple((_mixer_kind(cfg, i), cfg.is_moe_layer(i),
                          window if cfg.block_pattern[i % len(cfg.block_pattern)] == "attn" else 0)
                         for i in range(rem))
            segs.append(Segment(1, tail))
        return segs
    if cfg.n_experts and cfg.first_dense:
        w = window
        return [Segment(cfg.first_dense, ((_mixer_kind(cfg, 0), False, w),)),
                Segment(cfg.n_layers - cfg.first_dense,
                        ((_mixer_kind(cfg, cfg.first_dense), True, w),))]
    return [Segment(cfg.n_layers,
                    ((_mixer_kind(cfg, 0), cfg.n_experts > 0, window),),
                    cross=cfg.enc_layers > 0)]


# -------------------------------------------------------------------- model
@dataclass
class Model:
    cfg: ArchConfig
    ctx: ShardCtx
    segments: List[Segment]
    remat: bool = False
    dtype: Any = DEFAULT_DTYPE
    #: unroll the layer scan at trace time — used by the roofline analysis
    #: pass, because XLA cost_analysis counts a scan body ONCE regardless of
    #: trip count; unrolled lowering makes HLO FLOPs/bytes/collectives exact.
    unroll: bool = False

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a fixed TP multiple (Megatron-style) so the
        embedding/unembedding shard over the model axis for EVERY arch —
        unsharded full-vocab logits cost 16 GB/chip f32 at 4k x 16 batch
        (§Perf iteration 3, seamless/mamba2 whose vocabs are not
        16-divisible). Padded logits are masked to -inf: exact."""
        from .sharding import pad_to_multiple
        return pad_to_multiple(self.cfg.vocab, self.ctx.head_pad)

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict[str, Any]:
        cfg, ctx = self.cfg, self.ctx
        ks = iter(jax.random.split(key, 64))
        scale = 1.0 / math.sqrt(cfg.d_model)
        p: Dict[str, Any] = {
            "embed": (jax.random.normal(next(ks),
                                        (self.vocab_padded, cfg.d_model),
                                        jnp.float32) * scale).astype(self.dtype),
            "ln_f": rmsnorm_params(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            p["unembed"] = init_dense(next(ks), cfg.d_model,
                                      self.vocab_padded, self.dtype)
        for si, seg in enumerate(self.segments):
            keys = jax.random.split(next(ks), seg.count)
            def one(k):
                sub = jax.random.split(k, seg.layers_per_block)
                return [
                    _layer_init(sub[i], cfg, self.ctx, self._abs_layer(si, 0, i),
                                cross=seg.cross)
                    for i in range(seg.layers_per_block)]
            p[f"seg{si}"] = jax.vmap(one)(keys)
        if cfg.enc_layers:
            keys = jax.random.split(next(ks), cfg.enc_layers)
            p["encoder"] = jax.vmap(
                lambda k: _layer_init(k, cfg, self.ctx, 0))(keys)
            p["enc_ln_f"] = rmsnorm_params(cfg.d_model)
        if cfg.mtp:
            p["mtp_proj"] = init_dense(next(ks), 2 * cfg.d_model, cfg.d_model,
                                       self.dtype)
            p["mtp_layer"] = jax.vmap(
                lambda k: _layer_init(k, cfg, self.ctx, cfg.n_layers - 1)
            )(jax.random.split(next(ks), 1))
        return p

    def _abs_layer(self, si: int, block: int, i: int) -> int:
        off = sum(s.count * s.layers_per_block for s in self.segments[:si])
        return off + block * self.segments[si].layers_per_block + i

    @staticmethod
    def _remat_block(count: int) -> int:
        """Largest divisor of ``count`` not exceeding ~sqrt(count), capped
        at 8 (sqrt-remat block size). 1 disables nesting."""
        import os
        if os.environ.get("REPRO_BASELINE_FLAT_REMAT") == "1":
            return 1                                # §Perf kill-switch
        best = 1
        limit = min(8, int(count ** 0.5) + 1)
        for r in range(2, limit + 1):
            if count % r == 0:
                best = r
        return best

    # ------------------------------------------------------------- backbone
    def _run_segments(self, p, x, mode: str, caches=None, pos=0, memory=None):
        """Returns (x, new_caches: list per segment)."""
        new_caches = []
        for si, seg in enumerate(self.segments):
            seg_p = p[f"seg{si}"]
            seg_cache = None if caches is None else caches[si]

            def block(carry, xs):
                h = carry
                params_b, cache_b = xs
                outs = []
                for i in range(seg.layers_per_block):
                    kind, is_moe, window = seg.kinds[i]
                    c_i = None if cache_b is None else cache_b[i]
                    h, nc = _layer_apply(
                        params_b[i], h, cfg=self.cfg, ctx=self.ctx, kind=kind,
                        is_moe=is_moe, mode=mode, cache=c_i, pos=pos,
                        memory=memory, window=window)
                    outs.append(nc)
                return h, outs

            body = block
            if self.remat and mode == "train":
                body = jax.checkpoint(block, prevent_cse=False)
            r = self._remat_block(seg.count) if (self.remat
                                                 and mode == "train"
                                                 and not self.unroll
                                                 and seg_cache is None) else 1
            if r > 1:
                # sqrt-remat: scan over count/r checkpointed blocks of r
                # layers — the backward saves carries only at block
                # boundaries (count/r of them instead of count), trading one
                # extra forward for an r-fold cut of the carry stack
                # (§Perf iteration 2: the stacked-carry buffer dominated
                # every train cell's temp memory).
                nb = seg.count // r
                seg_p_r = jax.tree.map(
                    lambda a: a.reshape(nb, r, *a.shape[1:]), seg_p)

                def outer(c, pp_r):
                    # per-layer remat stays ON inside the rematted outer
                    # block: its backward replay then only keeps one layer's
                    # intermediates live at a time
                    return jax.lax.scan(
                        lambda c2, pp: body(c2, (pp, None)), c, pp_r)

                outer_ck = jax.checkpoint(outer, prevent_cse=False)
                x, outs = jax.lax.scan(outer_ck, x, seg_p_r)
            elif self.unroll:
                outs_list = []
                for bi in range(seg.count):
                    p_b = jax.tree.map(lambda a: a[bi], seg_p)
                    c_b = (None if seg_cache is None
                           else jax.tree.map(lambda a: a[bi], seg_cache))
                    x, o = body(x, (p_b, c_b))
                    outs_list.append(o)
                outs = (None if all(o is None for o in outs_list) else
                        jax.tree.map(lambda *ls: jnp.stack(ls), *outs_list))
            elif seg_cache is None:
                x, outs = jax.lax.scan(
                    lambda c, pp: body(c, (pp, None)), x, seg_p)
            else:
                x, outs = jax.lax.scan(body, x, (seg_p, seg_cache))
            new_caches.append(outs)
        return x, new_caches

    def _embed(self, p, batch) -> jnp.ndarray:
        if "inputs_embeds" in batch:
            x = batch["inputs_embeds"].astype(self.dtype)
        else:
            x = jnp.take(p["embed"], batch["tokens"], axis=0)
        return self.ctx.act(x, ("batch", None, None))

    def _logits(self, p, x) -> jnp.ndarray:
        x = rmsnorm(p["ln_f"], x, self.cfg.norm_eps)
        if self.cfg.tie_embeddings:
            logits = x @ p["embed"].T
        else:
            logits = dense(p["unembed"], x)
        if self.vocab_padded != self.cfg.vocab:
            # mask the padded vocab tail (stays shard-local: iota compare)
            iota = jax.lax.broadcasted_iota(
                jnp.int32, (1,) * (logits.ndim - 1) + (self.vocab_padded,),
                logits.ndim - 1)
            logits = jnp.where(iota < self.cfg.vocab, logits, -1e30)
        return self.ctx.act(logits, ("batch", None, "model"))

    def _encode(self, p, src_embeds) -> jnp.ndarray:
        x = self.ctx.act(src_embeds.astype(self.dtype), ("batch", None, None))
        def block(h, params_b):
            from .blocks import attn_apply as _aa
            hh, _ = _aa(params_b["mix"], rmsnorm(params_b["ln1"], h,
                                                 self.cfg.norm_eps),
                        cfg=self.cfg, ctx=self.ctx, mode="encode")
            h = h + hh
            h = h + ffn_apply(params_b["ffn"],
                              rmsnorm(params_b["ln2"], h, self.cfg.norm_eps),
                              cfg=self.cfg, ctx=self.ctx)
            return h, None
        x, _ = jax.lax.scan(block, x, p["encoder"])
        return rmsnorm(p["enc_ln_f"], x, self.cfg.norm_eps)

    # ----------------------------------------------------------------- train
    def loss(self, p, batch) -> jnp.ndarray:
        memory = None
        if self.cfg.enc_layers:
            memory = self._encode(p, batch["src_embeds"])
        x = self._embed(p, batch)
        x, _ = self._run_segments(p, x, "train", memory=memory)
        logits = self._logits(p, x)
        loss = softmax_xent(logits, batch["labels"])
        if self.cfg.mtp and "labels2" in batch:
            # DeepSeek-V3 multi-token prediction: one extra depth step
            emb2 = jnp.take(p["embed"], batch["labels"].clip(0), axis=0)
            h2 = dense(p["mtp_proj"],
                       jnp.concatenate([x, emb2.astype(x.dtype)], -1))
            kind, is_moe, window = self.segments[-1].kinds[0]
            mtp_p = jax.tree.map(lambda a: a[0], p["mtp_layer"])
            h2, _ = _layer_apply(mtp_p, h2, cfg=self.cfg, ctx=self.ctx,
                                 kind=kind, is_moe=is_moe, mode="train",
                                 window=window)
            loss = loss + 0.3 * softmax_xent(self._logits(p, h2),
                                             batch["labels2"])
        return loss

    # --------------------------------------------------------------- serving
    def prefill(self, p, batch, caches=None, pos=0):
        """Full prefill, or *suffix* prefill resuming from a reused prefix
        cache (``caches`` from a previous prefill of the first ``pos``
        tokens) — the data plane of Stage-1 KV reuse."""
        memory = None
        if self.cfg.enc_layers:
            memory = self._encode(p, batch["src_embeds"])
        x = self._embed(p, batch)
        x, caches = self._run_segments(p, x, "prefill", caches=caches,
                                       pos=pos, memory=memory)
        logits = self._logits(p, x[:, -1:])
        return logits, caches

    def decode_step(self, p, caches, tok, pos, memory=None):
        """tok: [B, 1] int32 (or embeds [B,1,D]); pos: scalar int32."""
        if tok.dtype in (jnp.int32, jnp.int64):
            x = jnp.take(p["embed"], tok, axis=0)
        else:
            x = tok.astype(self.dtype)
        x = self.ctx.act(x, ("batch", None, None))
        x, caches = self._run_segments(p, x, "decode", caches=caches, pos=pos,
                                       memory=memory)
        return self._logits(p, x), caches

    # ---------------------------------------------------------- cache specs
    def init_cache(self, batch_size: int, max_len: int,
                   kv_dtype=DEFAULT_DTYPE, src_len: int = 0):
        """Concrete zero-filled cache pytree (use eval_shape for abstract).

        Attention caches store the REAL kv-head count (padded MHA heads are
        exact no-ops — attn_apply crops on insert and expands on load), so
        decode HBM residency never pays for TP head padding. ``kv_dtype``
        may be int8 for HBM-bound cells. Enc-dec models additionally get
        cross-attention K/V over ``src_len`` encoder positions.
        """
        cfg, ctx = self.cfg, self.ctx
        caches = []
        for seg in self.segments:
            def one_layer(kind, window):
                if kind == "mla":
                    S = max_len
                    return {"mix": {
                        "c": jnp.zeros((batch_size, S, cfg.kv_lora_rank), kv_dtype),
                        "kr": jnp.zeros((batch_size, S, cfg.rope_head_dim), kv_dtype)}}
                if kind == "attn":
                    dims = AttnDims.of(cfg, ctx)
                    S = min(max_len, window) if window else max_len
                    return {"mix": {
                        "k": jnp.zeros((batch_size, S, cfg.n_kv, dims.hd), kv_dtype),
                        "v": jnp.zeros((batch_size, S, cfg.n_kv, dims.hd), kv_dtype)}}
                if kind == "ssm":
                    d_in = cfg.ssm_expand * cfg.d_model
                    H = d_in // cfg.ssm_head_dim
                    return {"mix": {
                        "conv": jnp.zeros((batch_size, cfg.ssm_conv - 1,
                                           d_in + 2 * cfg.ssm_state), kv_dtype),
                        "state": jnp.zeros((batch_size, H, cfg.ssm_head_dim,
                                            cfg.ssm_state), jnp.float32)}}
                w = cfg.rglru_width or cfg.d_model
                return {"mix": {
                    "conv": jnp.zeros((batch_size, cfg.ssm_conv - 1, w), kv_dtype),
                    "state": jnp.zeros((batch_size, w), jnp.float32)}}

            def with_cross(entry):
                if cfg.enc_layers and src_len:
                    dims = AttnDims.of(cfg, ctx)
                    entry["xk"] = jnp.zeros(
                        (batch_size, src_len, dims.n_kv, dims.hd), kv_dtype)
                    entry["xv"] = jnp.zeros(
                        (batch_size, src_len, dims.n_kv, dims.hd), kv_dtype)
                return entry

            layer_caches = [
                jax.tree.map(lambda a: jnp.broadcast_to(a[None], (seg.count,) + a.shape),
                             with_cross(one_layer(kind, window)))
                for (kind, _moe, window) in seg.kinds]
            caches.append(layer_caches)
        return caches


def build_model(cfg: ArchConfig, ctx: Optional[ShardCtx] = None,
                remat: bool = False) -> Model:
    return Model(cfg=cfg, ctx=ctx or ShardCtx(), segments=plan_segments(cfg),
                 remat=remat)
