"""Model blocks: attention (GQA / local / MLA), FFN (dense / MoE-EP),
Mamba2 SSD mixer, RG-LRU mixer.

Every block is a (init, apply) pair of pure functions. ``apply`` supports
three modes:
  * train    — full-sequence causal, no cache
  * prefill  — full-sequence causal, returns a decode cache
  * decode   — single-token step against a fixed-capacity cache

MoE uses an expert-parallel shard_map with explicit dispatch/combine
``all_to_all`` collectives over the "model" mesh axis — the Stage-2 traffic
MFS schedules, and the collective the roofline analysis counts. On a single
device (CPU tests) the same math runs through the local path.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ``jax.shard_map`` graduated from jax.experimental in newer releases; fall
# back to the experimental entry point (same signature) on older installs.
try:
    _shard_map = jax.shard_map
except AttributeError:                                    # jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map

from ..configs.base import ArchConfig
from .layers import (DEFAULT_DTYPE, apply_rope, dense, gqa_attention,
                     init_dense, rmsnorm, rmsnorm_params, rope, swiglu,
                     swiglu_params)
from .sharding import ShardCtx, pad_to_multiple

__all__ = [
    "AttnDims", "attn_init", "attn_apply",
    "mla_init", "mla_apply",
    "ffn_init", "ffn_apply",
    "moe_init", "moe_apply",
    "ssd_init", "ssd_apply",
    "rglru_init", "rglru_apply",
]


# =====================================================================
# KV-cache quantisation (int8 storage for HBM-bound decode cells)
# =====================================================================
_KV_QSCALE = 32.0          # static symmetric scale; clip range ~ +/-4


def _kv_store(x: jnp.ndarray, dtype) -> jnp.ndarray:
    if dtype == jnp.int8:
        return jnp.clip(jnp.round(x.astype(jnp.float32) * _KV_QSCALE),
                        -127, 127).astype(jnp.int8)
    return x.astype(dtype)


def _kv_load(x: jnp.ndarray, dtype) -> jnp.ndarray:
    if x.dtype == jnp.int8:
        return (x.astype(jnp.float32) / _KV_QSCALE).astype(dtype)
    return x


# =====================================================================
# GQA attention (with optional sliding window + QKV bias)
# =====================================================================
@dataclass(frozen=True)
class AttnDims:
    """Padded head layout for TP divisibility (see models/sharding.py).

    Query heads are padded to a multiple of the model axis and sharded; the
    padded heads are exact no-ops (zero W_o columns). KV heads:
      * MHA (n_kv == n_heads): padded alongside and sharded identically;
      * GQA: kept at their true count and replicated across the model axis —
        at compute time a static gather maps each (padded) query head to its
        KV head, and the gathered tensor is sharding-constrained so each
        device materialises only its own q-heads' copies.
    This keeps every assigned architecture (15, 24, 28, 32, 40 heads; 1-40 KV
    heads) shardable on a 16-wide model axis without semantic change.
    """

    n_q: int           # padded query heads
    n_kv: int          # stored kv heads (== n_q when MHA-sharded)
    kv_sharded: bool
    hd: int

    @staticmethod
    def of(cfg: ArchConfig, ctx: ShardCtx) -> "AttnDims":
        m = ctx.head_multiple          # mesh-independent layout (ckpt-stable)
        n_q = pad_to_multiple(cfg.n_heads, m)
        if cfg.n_kv == cfg.n_heads:                 # MHA: pad both, shard kv
            return AttnDims(n_q, n_q, True, cfg.hd)
        return AttnDims(n_q, cfg.n_kv, False, cfg.hd)

    def q_to_kv(self, cfg: ArchConfig) -> jnp.ndarray:
        """Static map: padded query head -> kv head index."""
        rep = max(1, cfg.n_heads // cfg.n_kv)
        idx = [min(h // rep, self.n_kv - 1) for h in range(self.n_q)]
        return jnp.asarray(idx, jnp.int32)


def _grouped_ok(cfg: ArchConfig, dims: AttnDims, n_store: int) -> bool:
    """True when the static q->kv map is the uniform grouping h -> h//rep,
    so grouped attention can consume the raw (unexpanded) KV heads. Holds
    for MQA (all heads -> kv 0) and whenever no padded q heads exist."""
    import os
    if os.environ.get("REPRO_BASELINE_EXPAND_KV") == "1":
        return False                      # §Perf baseline kill-switch
    if n_store <= 0 or dims.n_q % n_store != 0:
        return False
    rep = dims.n_q // n_store
    real_rep = max(1, cfg.n_heads // max(1, cfg.n_kv))
    return all(min(h // real_rep, n_store - 1) == h // rep
               for h in range(dims.n_q))


def attn_init(key, cfg: ArchConfig, ctx: ShardCtx, dtype=DEFAULT_DTYPE):
    dims = AttnDims.of(cfg, ctx)
    kq, kk, kv, ko = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "wq": init_dense(kq, d, dims.n_q * dims.hd, dtype, bias=cfg.qkv_bias),
        "wk": init_dense(kk, d, dims.n_kv * dims.hd, dtype, bias=cfg.qkv_bias),
        "wv": init_dense(kv, d, dims.n_kv * dims.hd, dtype, bias=cfg.qkv_bias),
        "wo": init_dense(ko, dims.n_q * dims.hd, d, dtype),
    }
    # zero the padded query heads' output columns => exact no-op heads
    real = cfg.n_heads * dims.hd
    if dims.n_q * dims.hd > real:
        p["wo"]["w"] = p["wo"]["w"].at[real:, :].set(0.0)
    return p


def _kv_cache_shape(cfg: ArchConfig, ctx: ShardCtx, batch: int, max_len: int,
                    dtype) -> Dict[str, Any]:
    dims = AttnDims.of(cfg, ctx)
    S = min(max_len, cfg.window) if cfg.window else max_len
    return {
        "k": jnp.zeros((batch, S, dims.n_kv, dims.hd), dtype),
        "v": jnp.zeros((batch, S, dims.n_kv, dims.hd), dtype),
    }


def attn_apply(p, x, *, cfg: ArchConfig, ctx: ShardCtx, mode: str,
               cache: Optional[Dict] = None, pos: int | jax.Array = 0,
               window: int = 0):
    """x: [B, T, D]. Returns (y, new_cache)."""
    B, T, D = x.shape
    dims = AttnDims.of(cfg, ctx)
    q = dense(p["wq"], x).reshape(B, T, dims.n_q, dims.hd)
    k = dense(p["wk"], x).reshape(B, T, dims.n_kv, dims.hd)
    v = dense(p["wv"], x).reshape(B, T, dims.n_kv, dims.hd)
    positions = pos + jnp.arange(T)[None, :]                       # [1, T]
    sin, cos = rope(positions, dims.hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    q = ctx.act(q, ("batch", None, "model", None))
    seq_shard = ctx.kv_seq_shard and mode == "decode"
    kv_spec = (("batch", "model", None, None) if seq_shard
               else ("batch", None, "model" if dims.kv_sharded else None, None))

    new_cache = None
    if mode == "decode":
        assert cache is not None and T == 1
        S = cache["k"].shape[1]
        kv_dtype = cache["k"].dtype
        n_store = cache["k"].shape[2]
        if n_store != dims.n_kv:
            # cache stores the REAL kv heads only (padded MHA heads are
            # no-ops); crop before insert, expand via q_to_kv after load
            k = k[:, :, :n_store]
            v = v[:, :, :n_store]
        if window:
            slot = jnp.asarray(pos) % S
        else:
            slot = jnp.asarray(pos)
        ck = jax.lax.dynamic_update_slice(cache["k"], _kv_store(k, kv_dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], _kv_store(v, kv_dtype),
                                          (0, slot, 0, 0))
        if ctx.mesh is not None:
            ck = ctx.act(ck, kv_spec)
            cv = ctx.act(cv, kv_spec)
        new_cache = {"k": ck, "v": cv}
        k_all, v_all = _kv_load(ck, k.dtype), _kv_load(cv, v.dtype)
        k_pos = jnp.arange(S)
        if window:
            # rolling buffer: entry i holds absolute position with i == pos%S
            age = (slot - k_pos) % S
            abs_pos = jnp.asarray(pos) - age
            valid = (abs_pos >= 0) & (age < jnp.minimum(window, jnp.asarray(pos) + 1))
            mask = valid[None, None, :]
        else:
            mask = (k_pos[None, None, :] <= jnp.asarray(pos))
        # rope for cached keys was applied at insert time
    elif cache is not None and mode == "prefill":
        # suffix prefill over a reused prefix cache (Stage-1 KV reuse): the
        # prefix holds absolute positions [pos - Pk, pos); queries start at
        # pos, so the attention kernel sees q_offset = Pk (positions are
        # contiguous and masks depend only on position differences).
        Pk = cache["k"].shape[1]
        k_all = jnp.concatenate([cache["k"], k], axis=1)
        v_all = jnp.concatenate([cache["v"], v], axis=1)
        q_offset = Pk
        mask = None                                # kernel builds the mask
        new_cache = {"k": k_all, "v": v_all}
        if window:
            W = min(window, Pk + T)
            new_cache = {"k": k_all[:, -W:], "v": v_all[:, -W:]}
    else:
        k_all, v_all = k, v
        if mode == "encode":                       # bidirectional
            mask = jnp.ones((1, T, T), bool)
        else:
            qp = positions[0][:, None]
            kp = positions[0][None, :]
            m2 = qp >= kp
            if window:
                m2 &= (qp - kp) < window
            mask = m2[None]
        if mode == "prefill":
            new_cache = {"k": k, "v": v}
            if window:
                W = min(window, T)
                new_cache = {"k": k[:, T - W:], "v": v[:, T - W:]}
    k_all = ctx.act(k_all, kv_spec)
    v_all = ctx.act(v_all, kv_spec)
    grouped = (mode == "decode"
               and _grouped_ok(cfg, dims, k_all.shape[2]))
    if k_all.shape[2] != dims.n_q and not grouped:
        # non-uniform q->kv map (padded q heads straddle groups): expand KV
        # to the padded head count. Uniform cases skip this — the grouped
        # attention path reads each KV head once instead of rep times
        # (§Perf iteration 1: HBM term of decode cells).
        qmap = jnp.minimum(dims.q_to_kv(cfg), k_all.shape[2] - 1)
        k_all = jnp.take(k_all, qmap, axis=2)      # static gather -> [B,S,nq,hd]
        v_all = jnp.take(v_all, qmap, axis=2)
        post_spec = (("batch", "model", None, None) if seq_shard
                     else ("batch", None, "model", None))
        k_all = ctx.act(k_all, post_spec)
        v_all = ctx.act(v_all, post_spec)
    from ..kernels import ops as kops
    if mode == "decode":
        out = gqa_attention(q, k_all, v_all, mask=mask)
    else:
        q_off = q_offset if (cache is not None and mode == "prefill") else 0
        out = kops.attention(q, k_all, v_all, causal=(mode != "encode"),
                             window=window, q_offset=q_off)
    out = ctx.act(out, ("batch", None, "model", None))
    y = dense(p["wo"], out.reshape(B, T, dims.n_q * dims.hd))
    return ctx.act(y, ("batch", None, None)), new_cache


# =====================================================================
# MLA — Multi-head Latent Attention (DeepSeek-V3)
# =====================================================================
def mla_init(key, cfg: ArchConfig, ctx: ShardCtx, dtype=DEFAULT_DTYPE):
    ks = jax.random.split(key, 6)
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv, r, qr = (cfg.nope_head_dim, cfg.rope_head_dim,
                         cfg.v_head_dim, cfg.kv_lora_rank, cfg.q_lora_rank)
    return {
        "wq_a": init_dense(ks[0], d, qr, dtype),
        "q_norm": rmsnorm_params(qr),
        "wq_b": init_dense(ks[1], qr, H * (dn + dr), dtype),
        "wkv_a": init_dense(ks[2], d, r + dr, dtype),
        "kv_norm": rmsnorm_params(r),
        "wk_b": init_dense(ks[3], r, H * dn, dtype),
        "wv_b": init_dense(ks[4], r, H * dv, dtype),
        "wo": init_dense(ks[5], H * dv, d, dtype),
    }


def mla_apply(p, x, *, cfg: ArchConfig, ctx: ShardCtx, mode: str,
              cache: Optional[Dict] = None, pos: int | jax.Array = 0,
              window: int = 0):
    B, T, D = x.shape
    H = cfg.n_heads
    dn, dr, dv, r = (cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim,
                     cfg.kv_lora_rank)
    q = dense(p["wq_b"], rmsnorm(p["q_norm"], dense(p["wq_a"], x)))
    q = q.reshape(B, T, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv = dense(p["wkv_a"], x)                       # [B, T, r + dr]
    c_kv = rmsnorm(p["kv_norm"], kv[..., :r])       # latent (this IS the cache)
    k_rope = kv[..., r:]                            # shared rope key, 1 "head"
    positions = pos + jnp.arange(T)[None, :]
    sin, cos = rope(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    k_rope = apply_rope(k_rope[:, :, None, :], sin, cos)[:, :, 0, :]

    new_cache = None
    if mode == "decode":
        assert cache is not None and T == 1
        slot = jnp.asarray(pos)
        cc = jax.lax.dynamic_update_slice(cache["c"], c_kv, (0, slot, 0))
        cr = jax.lax.dynamic_update_slice(cache["kr"], k_rope, (0, slot, 0))
        if ctx.mesh is not None and ctx.kv_seq_shard:
            # flash-decoding layout: latent cache sequence-sharded over the
            # model axis; XLA assembles the softmax across shards
            cc = ctx.act(cc, ("batch", "model", None))
            cr = ctx.act(cr, ("batch", "model", None))
        new_cache = {"c": cc, "kr": cr}
        c_all, kr_all = cc, cr
        S = cc.shape[1]
        mask = (jnp.arange(S)[None, None, :] <= slot)
    elif cache is not None and mode == "prefill":
        # suffix prefill over a reused latent prefix (Stage-1 KV reuse)
        Pk = cache["c"].shape[1]
        c_all = jnp.concatenate([cache["c"], c_kv], axis=1)
        kr_all = jnp.concatenate([cache["kr"], k_rope], axis=1)
        qp = positions[0][:, None]
        kp = (jnp.asarray(pos) - Pk + jnp.arange(Pk + T))[None, :]
        mask = (qp >= kp)[None]
        new_cache = {"c": c_all, "kr": kr_all}
    else:
        c_all, kr_all = c_kv, k_rope
        m2 = causal = (positions[0][:, None] >= positions[0][None, :])
        mask = m2[None]
        if mode == "prefill":
            new_cache = {"c": c_kv, "kr": k_rope}

    # absorbed attention: score = q_nope · (W_kb^T c) + q_rope · k_rope
    wk = p["wk_b"]["w"].reshape(r, H, dn)
    q_lat = jnp.einsum("bthn,rhn->bthr", q_nope.astype(jnp.float32),
                       wk.astype(jnp.float32))      # [B,T,H,r]
    q_lat = ctx.act(q_lat, ("batch", None, "model", None))
    scale = 1.0 / math.sqrt(dn + dr)
    s1 = jnp.einsum("bthr,bsr->bhts", q_lat, c_all.astype(jnp.float32))
    s2 = jnp.einsum("bthr,bsr->bhts", q_rope.astype(jnp.float32),
                    kr_all.astype(jnp.float32))
    logits = (s1 + s2) * scale
    logits = jnp.where(mask[:, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    ctx_lat = jnp.einsum("bhts,bsr->bthr", w, c_all.astype(jnp.float32))
    wv = p["wv_b"]["w"].reshape(r, H, dv)
    out = jnp.einsum("bthr,rhv->bthv", ctx_lat, wv.astype(jnp.float32))
    out = ctx.act(out.astype(x.dtype), ("batch", None, "model", None))
    y = dense(p["wo"], out.reshape(B, T, H * dv))
    return ctx.act(y, ("batch", None, None)), new_cache


# =====================================================================
# Dense FFN
# =====================================================================
def ffn_init(key, cfg: ArchConfig, ctx: ShardCtx, d_ff: Optional[int] = None,
             dtype=DEFAULT_DTYPE):
    return swiglu_params(key, cfg.d_model, d_ff or cfg.d_ff, dtype)


def ffn_apply(p, x, *, cfg: ArchConfig, ctx: ShardCtx):
    h = jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x)
    h = ctx.act(h, ("batch", None, "model"))
    y = dense(p["wo"], h)
    return ctx.act(y, ("batch", None, None))


# =====================================================================
# MoE FFN — expert parallel over the "model" axis with explicit all_to_all
# =====================================================================
def moe_init(key, cfg: ArchConfig, ctx: ShardCtx, dtype=DEFAULT_DTYPE):
    ks = jax.random.split(key, 5)
    d, E, F = cfg.d_model, cfg.n_experts, cfg.d_expert or cfg.d_ff
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * scale),
        "w_in": (jax.random.normal(ks[1], (E, d, F), jnp.float32) * scale).astype(dtype),
        "w_gate": (jax.random.normal(ks[2], (E, d, F), jnp.float32) * scale).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (E, F, d), jnp.float32)
                  * (1.0 / math.sqrt(F))).astype(dtype),
    }
    if cfg.n_shared:
        p["shared"] = swiglu_params(ks[4], d, cfg.n_shared * F, dtype)
    return p


def _expert_ffn(w_in, w_gate, w_out, x, group_sizes):
    """Grouped SwiGLU over tokens sorted by expert (ragged_dot)."""
    h = jax.nn.silu(jax.lax.ragged_dot(x, w_gate, group_sizes)) * \
        jax.lax.ragged_dot(x, w_in, group_sizes)
    return jax.lax.ragged_dot(h, w_out, group_sizes)


def _route(x_flat, router, top_k):
    probs = jax.nn.softmax(x_flat.astype(jnp.float32) @ router, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)                # [N, K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def _moe_token_gather(p, x, cfg: ArchConfig):
    """Per-token expert GEMV via weight gather — the decode path (few
    tokens, top-k experts each). vmap-friendly (no ragged_dot), which the
    slotted decode engine relies on."""
    B, T, D = x.shape
    xf = x.reshape(-1, D)
    gates, idx = _route(xf, p["router"], cfg.top_k)          # [N,K]
    w_in = p["w_in"][idx]                                    # [N,K,D,F]
    w_g = p["w_gate"][idx]
    w_o = p["w_out"][idx]                                    # [N,K,F,D]
    h = jax.nn.silu(jnp.einsum("nd,nkdf->nkf", xf, w_g)) * \
        jnp.einsum("nd,nkdf->nkf", xf, w_in)
    y = jnp.einsum("nkf,nkfd->nd", h * gates[..., None].astype(h.dtype), w_o)
    return y.reshape(B, T, D).astype(x.dtype)


def _moe_local(p, x, cfg: ArchConfig):
    """Single-device MoE: sort-by-expert + ragged grouped matmuls."""
    B, T, D = x.shape
    xf = x.reshape(-1, D)
    N = xf.shape[0]
    K, E = cfg.top_k, cfg.n_experts
    gates, idx = _route(xf, p["router"], K)
    flat_e = idx.reshape(-1)                                # [N*K]
    order = jnp.argsort(flat_e)
    toks = xf[order // K]
    gs = jnp.bincount(flat_e, length=E)
    y = _expert_ffn(p["w_in"], p["w_gate"], p["w_out"], toks, gs)
    y = y * gates.reshape(-1)[order][:, None].astype(y.dtype)
    out = jnp.zeros_like(xf).at[order // K].add(y)
    return out.reshape(B, T, D)   # shared experts are added by moe_apply


def _one_axis_size(a: str) -> int:
    if hasattr(jax.lax, "axis_size"):          # jax >= 0.6
        return jax.lax.axis_size(a)
    return jax.lax.psum(1, a)                  # classic spelling


def _axis_size(axis) -> int:
    if isinstance(axis, str):
        return _one_axis_size(axis)
    n = 1
    for a in axis:
        n *= _one_axis_size(a)
    return n


def _axis_index(axis):
    """Row-major linearised index over a (possibly tuple) axis name."""
    if isinstance(axis, str):
        return jax.lax.axis_index(axis)
    idx = 0
    for a in axis:
        idx = idx * _one_axis_size(a) + jax.lax.axis_index(a)
    return idx


def _moe_ep_body(xf, router, w_in, w_gate, w_out, *, cfg: ArchConfig,
                 axis, capacity_factor: float):
    """Per-shard EP body. xf: [N_loc, D] local tokens; expert weights local
    [E_loc, ...]. Dispatch/combine are explicit all_to_all over ``axis`` —
    the paper's Stage-2 collectives."""
    ep = _axis_size(axis)
    E_loc = w_in.shape[0]
    N, D = xf.shape
    K = cfg.top_k
    gates, idx = _route(xf, router, K)                      # global expert ids
    dest = idx // E_loc                                     # [N, K] shard id
    e_loc = idx % E_loc
    cap = max(1, int(math.ceil(N * K / ep * capacity_factor)))
    # position of each (token, k) within its destination buffer
    d_flat = dest.reshape(-1)
    onehot = jax.nn.one_hot(d_flat, ep, dtype=jnp.int32)    # [N*K, ep]
    pos = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(N * K), d_flat]
    valid = pos < cap
    tok_src = jnp.arange(N * K) // K
    safe_d = jnp.where(valid, d_flat, 0)
    safe_p = jnp.where(valid, pos, 0)
    send_x = jnp.zeros((ep, cap, D), xf.dtype)
    send_x = send_x.at[safe_d, safe_p].set(
        jnp.where(valid[:, None], xf[tok_src], 0.0))
    send_e = jnp.zeros((ep, cap), jnp.int32)
    send_e = send_e.at[safe_d, safe_p].set(
        jnp.where(valid, e_loc.reshape(-1), 0))
    # ---- Stage-2 dispatch ----
    recv_x = jax.lax.all_to_all(send_x, axis, 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(send_e, axis, 0, 0, tiled=False)
    rx = recv_x.reshape(ep * cap, D)
    re = recv_e.reshape(ep * cap)
    order = jnp.argsort(re)
    gs = jnp.bincount(re, length=E_loc)
    y_sorted = _expert_ffn(w_in, w_gate, w_out, rx[order], gs)
    y = jnp.zeros_like(rx).at[order].set(y_sorted)
    # ---- Stage-2 combine ----
    back = jax.lax.all_to_all(y.reshape(ep, cap, D), axis, 0, 0, tiled=False)
    picked = back[safe_d, safe_p]                           # [N*K, D]
    picked = jnp.where(valid[:, None], picked, 0.0)
    w = gates.reshape(-1)[:, None].astype(picked.dtype)
    out = jnp.zeros_like(xf).at[tok_src].add(picked * w)
    return out


def moe_apply(p, x, *, cfg: ArchConfig, ctx: ShardCtx,
              capacity_factor: float = 1.25, mode: str = "train"):
    """Expert-parallel MoE over ``ctx.ep_axes``.

    * ``("model",)`` — classic EP: experts sharded 16-way, all_to_all over
      the model axis (the paper's Stage-2 traffic).
    * ``("data", "model")`` — pod-wide 2D EP for models whose expert bank
      cannot fit a 16-way shard (DeepSeek-V3): experts spread over all 256
      chips, token dispatch over the combined axis. Prefill/train token
      grids are (batch x seq)-distinct per chip, so the same dispatch code
      serves both regimes; decode replicates the token batch inside the EP
      domain and combines partial expert outputs with a psum.
    """
    B, T, D = x.shape
    m = ctx.model_size
    ep = ctx.ep_size
    ep_axes = ctx.ep_axes if len(ctx.ep_axes) > 1 else ctx.ep_axes[0]
    batch = (ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0])
    expert_spec = P(ep_axes)
    if ctx.mesh is None or ep == 1 or cfg.n_experts % ep != 0:
        local = _moe_token_gather if mode == "decode" else _moe_local
        y = ctx.act(local(p, x, cfg), ("batch", None, None))
    elif T % m == 0:
        # prefill/train: sequence-split tokens, explicit dispatch+combine a2a
        def body(xl, router, w_in, w_gate, w_out):
            xf = xl.reshape(-1, D)
            out = _moe_ep_body(xf, router, w_in, w_gate, w_out, cfg=cfg,
                               axis=ep_axes,
                               capacity_factor=capacity_factor)
            return out.reshape(xl.shape)

        mapped = _shard_map(
            body, mesh=ctx.mesh,
            in_specs=(P(batch, ctx.model_axis, None),
                      P(), expert_spec, expert_spec, expert_spec),
            out_specs=P(batch, ctx.model_axis, None))
        y = mapped(x, p["router"], p["w_in"], p["w_gate"], p["w_out"])
        y = ctx.act(y, ("batch", None, None))
    else:
        # decode: tokens replicated inside the EP domain, masked local
        # compute + psum over the EP axes (Stage-2 combine)
        dec_batch_axes = tuple(a for a in ctx.batch_axes
                               if a not in ctx.ep_axes)
        dec_batch = (dec_batch_axes if len(dec_batch_axes) > 1 else
                     (dec_batch_axes[0] if dec_batch_axes else None))

        def body_dec(xl, router, w_in, w_gate, w_out):
            xf = xl.reshape(-1, D)
            N, K = xf.shape[0], cfg.top_k
            E_loc = w_in.shape[0]
            gates, idx = _route(xf, router, K)
            lo = _axis_index(ctx.ep_axes) * E_loc
            local = (idx >= lo) & (idx < lo + E_loc)
            flat_local = local.reshape(-1)
            e_loc = jnp.where(flat_local, (idx - lo).reshape(-1), E_loc)
            xin = jnp.where(flat_local[:, None], jnp.repeat(xf, K, axis=0), 0.0)
            order = jnp.argsort(e_loc)
            gs_full = jnp.bincount(e_loc, length=E_loc + 1)
            gs = jnp.concatenate([gs_full[:E_loc],
                                  gs_full[E_loc:E_loc + 1]])
            w_in_p = jnp.concatenate([w_in, jnp.zeros_like(w_in[:1])], 0)
            w_g_p = jnp.concatenate([w_gate, jnp.zeros_like(w_gate[:1])], 0)
            w_o_p = jnp.concatenate([w_out, jnp.zeros_like(w_out[:1])], 0)
            y_sorted = _expert_ffn(w_in_p, w_g_p, w_o_p, xin[order], gs)
            y = jnp.zeros_like(xin).at[order].set(y_sorted)
            wgt = gates.reshape(-1)[:, None].astype(y.dtype)
            out = jnp.zeros_like(xf).at[jnp.arange(N * K) // K].add(y * wgt)
            out = jax.lax.psum(out, ctx.ep_axes)            # Stage-2 combine
            return out.reshape(xl.shape)

        mapped = _shard_map(
            body_dec, mesh=ctx.mesh,
            in_specs=(P(dec_batch, None, None),
                      P(), expert_spec, expert_spec, expert_spec),
            out_specs=P(dec_batch, None, None))
        y = mapped(x, p["router"], p["w_in"], p["w_gate"], p["w_out"])
        y = ctx.act(y, ("batch", None, None))
    if "shared" in p:
        y = y + ffn_apply({"wi": p["shared"]["wi"], "wg": p["shared"]["wg"],
                           "wo": p["shared"]["wo"]}, x, cfg=cfg, ctx=ctx)
    return y


# =====================================================================
# Mamba2 (SSD) mixer
# =====================================================================
def ssd_init(key, cfg: ArchConfig, ctx: ShardCtx, dtype=DEFAULT_DTYPE):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    ks = jax.random.split(key, 4)
    return {
        # fused input projection: [z, x, B, C, dt]
        "w_in": init_dense(ks[0], d, 2 * d_in + 2 * N + H, dtype),
        "conv": (jax.random.normal(ks[1], (cfg.ssm_conv, d_in + 2 * N),
                                   jnp.float32) * 0.2).astype(dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": rmsnorm_params(d_in),
        "w_out": init_dense(ks[3], d_in, d, dtype),
    }


def _ssd_scan(xbc_dt, cfg: ArchConfig, init_state=None):
    """Sequential SSD recurrence via lax.scan over time (reference path; the
    Pallas chunked kernel is the TPU fast path). Returns (y, final_state)."""
    x, Bm, Cm, dt, A, D = xbc_dt                      # shapes below
    Bsz, T, H, hd = x.shape
    N = Bm.shape[-1]
    dA = jnp.exp(dt * A)                              # [B, T, H]
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, hd, N), jnp.float32)

    def step(s, inp):
        xt, Bt, Ct, dAt, dtt = inp                    # [B,H,hd],[B,N],[B,N],[B,H],[B,H]
        s = s * dAt[..., None, None] + (dtt[..., None] * xt)[..., None] * Bt[:, None, None, :]
        yt = jnp.einsum("bhdn,bn->bhd", s, Ct)
        return s, yt

    xs = (x.transpose(1, 0, 2, 3), Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2),
          dA.transpose(1, 0, 2), dt.transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, init_state, xs)
    y = ys.transpose(1, 0, 2, 3) + x * D[None, None, :, None]
    return y, final


def ssd_apply(p, x, *, cfg: ArchConfig, ctx: ShardCtx, mode: str,
              cache: Optional[Dict] = None, pos=0, window: int = 0):
    B, T, D = x.shape
    d_in = cfg.ssm_expand * D
    H = d_in // cfg.ssm_head_dim
    hd = cfg.ssm_head_dim
    N = cfg.ssm_state
    zxbcdt = dense(p["w_in"], x)
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)     # [B, T, d_in+2N]
    W = cfg.ssm_conv
    if mode == "decode":
        prev = cache["conv"]                              # [B, W-1, d_in+2N]
        window_seq = jnp.concatenate([prev, conv_in], axis=1)
        new_conv = window_seq[:, 1:]
    elif cache is not None and mode == "prefill":
        # suffix prefill: resume the conv window + SSD state from the prefix
        window_seq = jnp.concatenate([cache["conv"], conv_in], axis=1)
        new_conv = window_seq[:, T:]
    else:
        pad = jnp.zeros((B, W - 1, conv_in.shape[-1]), conv_in.dtype)
        window_seq = jnp.concatenate([pad, conv_in], axis=1)
        new_conv = window_seq[:, T:]                      # last W-1 entries
    kernel = p["conv"].astype(jnp.float32)                # [W, C]
    idx = jnp.arange(T)[:, None] + jnp.arange(W)[None, :]
    win = window_seq.astype(jnp.float32)[:, idx]          # [B, T, W, C]
    conv_out = jax.nn.silu(jnp.einsum("btwc,wc->btc", win, kernel))
    xc, Bc, Cc = jnp.split(conv_out, [d_in, d_in + N], axis=-1)
    xh = xc.reshape(B, T, H, hd)
    A = -jnp.exp(p["A_log"])                              # [H]
    dt_s = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    prev_state = cache["state"] if cache is not None else None
    from ..kernels import ops as kops
    y, state = kops.ssd(xh, Bc, Cc, dt_s, A, p["D"], init_state=prev_state,
                        ref_fallback=partial(_ssd_scan, cfg=cfg))
    y = y.reshape(B, T, d_in).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = dense(p["w_out"], y)
    new_cache = {"conv": new_conv, "state": state} \
        if mode in ("prefill", "decode") else None
    return ctx.act(out, ("batch", None, None)), new_cache


# =====================================================================
# RG-LRU mixer (RecurrentGemma / Griffin recurrent block)
# =====================================================================
_RGLRU_BLOCKS = 16          # Griffin's block-diagonal gate heads; also the
                            # width-sharding granularity over "model"


def rglru_init(key, cfg: ArchConfig, ctx: ShardCtx, dtype=DEFAULT_DTYPE):
    d = cfg.d_model
    w = cfg.rglru_width or d
    nb = _RGLRU_BLOCKS if w % _RGLRU_BLOCKS == 0 else 1
    kb = w // nb
    ks = jax.random.split(key, 6)
    c = 8.0
    scale = 1.0 / math.sqrt(kb)
    return {
        "w_x": init_dense(ks[0], d, w, dtype),
        "w_gate_branch": init_dense(ks[1], d, w, dtype),
        "conv": (jax.random.normal(ks[2], (cfg.ssm_conv, w), jnp.float32)
                 * 0.2).astype(dtype),
        # block-diagonal gates (Griffin): [nb, kb, kb] — shards over the
        # model axis with zero gate collectives (§Perf iteration: the dense
        # [w, w] gates forced either 16x replicated compute or per-layer
        # all-reduces of [B,T,w])
        "gate_in": (jax.random.normal(ks[3], (nb, kb, kb), jnp.float32)
                    * scale).astype(dtype),
        "gate_rec": (jax.random.normal(ks[4], (nb, kb, kb), jnp.float32)
                     * scale).astype(dtype),
        # Lambda parametrised per-channel in (softplus space)
        "a_param": jnp.log(jnp.expm1(
            jnp.linspace(0.9, 0.999, w) ** (1.0 / c))).astype(jnp.float32),
        "w_out_rg": init_dense(jax.random.fold_in(key, 9), w, d, dtype),
    }


def rglru_apply(p, x, *, cfg: ArchConfig, ctx: ShardCtx, mode: str,
                cache: Optional[Dict] = None, pos=0, window: int = 0):
    B, T, D = x.shape
    w = cfg.rglru_width or D
    c = 8.0
    branch = ctx.act(dense(p["w_x"], x), ("batch", None, "model"))
    gate_branch = jax.nn.gelu(dense(p["w_gate_branch"], x))
    gate_branch = ctx.act(gate_branch, ("batch", None, "model"))
    # temporal conv on the branch
    W = cfg.ssm_conv
    if mode == "decode":
        seq = jnp.concatenate([cache["conv"], branch], axis=1)
        new_conv = seq[:, 1:]
    elif cache is not None and mode == "prefill":
        # suffix prefill: resume conv window + recurrent state from prefix
        seq = jnp.concatenate([cache["conv"], branch], axis=1)
        new_conv = seq[:, T:]
    else:
        pad = jnp.zeros((B, W - 1, w), branch.dtype)
        seq = jnp.concatenate([pad, branch], axis=1)
        new_conv = seq[:, T:]
    idx = jnp.arange(T)[:, None] + jnp.arange(W)[None, :]
    win = seq.astype(jnp.float32)[:, idx]
    xt = jnp.einsum("btwc,wc->btc", win, p["conv"].astype(jnp.float32))
    xt = ctx.act(xt, ("batch", None, "model"))
    # block-diagonal gates: shard-local einsum over the width blocks
    nb, kb = p["gate_rec"].shape[0], p["gate_rec"].shape[1]
    xtb = xt.astype(x.dtype).reshape(B, T, nb, kb)
    rt = jax.nn.sigmoid(jnp.einsum("btnk,nkj->btnj", xtb, p["gate_rec"])
                        .reshape(B, T, w).astype(jnp.float32))
    it = jax.nn.sigmoid(jnp.einsum("btnk,nkj->btnj", xtb, p["gate_in"])
                        .reshape(B, T, w).astype(jnp.float32))
    log_a = -c * rt * jax.nn.softplus(p["a_param"])        # [B, T, w]
    a = jnp.exp(log_a)
    gated_x = xt * it
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    prev = cache["state"] if cache is not None else None
    from ..kernels import ops as kops
    h, state = kops.rglru(a, beta * gated_x, init_state=prev)
    y = dense(p["w_out_rg"], (h.astype(x.dtype) * gate_branch))
    new_cache = {"conv": new_conv, "state": state} \
        if mode in ("prefill", "decode") else None
    return ctx.act(y, ("batch", None, None)), new_cache
