"""Shared building blocks for the JAX model zoo.

Pure-functional modules: every block is (params pytree, apply fn). Parameter
initialisation takes an explicit PRNG key and abstract=True support so the
dry-run can build ShapeDtypeStruct parameter trees without allocating.

Sharding convention (logical axes annotated with jax.lax.with_sharding_constraint
at the model level, not here): weight matrices are stored as
  [d_model, heads*hd] / [d_model, d_ff] etc. with the *second* dim sharded on
  the "model" mesh axis and the first dim optionally sharded on "data"
  (ZeRO-3); activations are [batch, seq, d_model] with batch on
  ("pod","data").
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Param", "init_dense", "dense", "rmsnorm_params", "rmsnorm",
    "rope", "apply_rope", "mrope_positions", "swiglu_params", "swiglu",
    "gqa_attention", "causal_mask", "local_mask", "softmax_xent",
]

Param = Any
DEFAULT_DTYPE = jnp.bfloat16


def _maybe(key, shape, scale, dtype, abstract):
    if abstract:
        return jax.ShapeDtypeStruct(shape, dtype)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_dense(key, d_in: int, d_out: int, dtype=DEFAULT_DTYPE,
               bias: bool = False, abstract: bool = False) -> Dict[str, Param]:
    scale = 1.0 / math.sqrt(d_in)
    p = {"w": _maybe(key, (d_in, d_out), scale, dtype, abstract)}
    if bias:
        if abstract:
            p["b"] = jax.ShapeDtypeStruct((d_out,), dtype)
        else:
            p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Dict[str, Param], x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_params(d: int, dtype=jnp.float32, abstract: bool = False):
    if abstract:
        return {"g": jax.ShapeDtypeStruct((d,), dtype)}
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * p["g"]).astype(dt)


# ---------------------------------------------------------------------- RoPE
def rope(positions: jnp.ndarray, dim: int, theta: float = 1e4) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(sin, cos) tables for ``positions`` [..., T] over ``dim`` channels."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs   # [..., T, half]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x: [B, T, H, D]; sin/cos: [B, T, D/2] (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., None, :]
    c = cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def mrope_positions(batch: int, seq: int, sections=(16, 24, 24)) -> jnp.ndarray:
    """Qwen2-VL M-RoPE stand-in position ids: [3, B, T] (temporal, h, w).

    For text-only / pre-embedded input the three components coincide, which
    is exactly Qwen2-VL's behaviour for text tokens.
    """
    pos = jnp.broadcast_to(jnp.arange(seq)[None], (batch, seq))
    return jnp.stack([pos, pos, pos], axis=0)


# -------------------------------------------------------------------- SwiGLU
def swiglu_params(key, d: int, d_ff: int, dtype=DEFAULT_DTYPE, abstract=False):
    k1, k2, k3 = jax.random.split(key, 3) if not abstract else (None,) * 3
    return {
        "wi": init_dense(k1, d, d_ff, dtype, abstract=abstract),
        "wg": init_dense(k2, d, d_ff, dtype, abstract=abstract),
        "wo": init_dense(k3, d_ff, d, dtype, abstract=abstract),
    }


def swiglu(p, x: jnp.ndarray) -> jnp.ndarray:
    return dense(p["wo"], jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x))


# ----------------------------------------------------------------- attention
def causal_mask(q_len: int, kv_len: int, q_offset: int = 0) -> jnp.ndarray:
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    return q_pos >= k_pos


def local_mask(q_len: int, kv_len: int, window: int, q_offset: int = 0) -> jnp.ndarray:
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    return (q_pos >= k_pos) & (q_pos - k_pos < window)


def gqa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """Grouped-query attention.

    q: [B, T, Hq, D], k/v: [B, S, Hkv, D'], mask: [T, S] or [B, T, S].
    Uses the XLA path; the Pallas flash kernel (repro.kernels) replaces this
    on TPU via repro.kernels.ops.attention.
    """
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, T, Hkv, rep, D)
    logits = jnp.einsum("bthrd,bshd->bhrts", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if mask is not None:
        m = mask if mask.ndim == 3 else mask[None]
        logits = jnp.where(m[:, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhrts,bshe->bthre", w, v.astype(jnp.float32))
    return out.reshape(B, T, Hq, v.shape[-1]).astype(q.dtype)


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 ignore_index: int = -100) -> jnp.ndarray:
    """Mean token cross-entropy in fp32, masking ``ignore_index`` labels.

    The gold logit is extracted with a one-hot select-reduce instead of
    ``take_along_axis``: a dynamic gather along the vocab axis defeats SPMD
    when the vocab is TP-sharded (XLA all-gathers the full [B,T,V] f32
    logits — measured 33.6 GB/step on seamless train_4k, §Perf iteration
    3), while compare+select+reduce stays shard-local and meets the labels
    with one tiny [B,T] all-reduce.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    valid = labels != ignore_index
    safe = jnp.where(valid, labels, 0)
    vocab_iota = jax.lax.broadcasted_iota(
        safe.dtype, (1,) * safe.ndim + (logits.shape[-1],), safe.ndim)
    onehot = safe[..., None] == vocab_iota
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    loss = jnp.where(valid, lse - gold, 0.0)
    return loss.sum() / jnp.maximum(valid.sum(), 1)
