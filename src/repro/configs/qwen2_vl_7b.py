"""qwen2-vl-7b — VLM backbone, 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064, M-RoPE; the vision frontend is a stub providing patch
embeddings (input_specs). [arXiv:2409.12191; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv=4, d_ff=18944,
    vocab=152064, qkv_bias=True, rope_theta=1e6,
    source="arXiv:2409.12191",
)

SMOKE = ArchConfig(
    name="qwen2-vl-7b-smoke", family="vlm",
    n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
    qkv_bias=True, source="reduced",
)
