"""ArchConfig — the single source of truth for every architecture.

Consumed by three layers:
  * repro.models      — builds the JAX module graph from it;
  * repro.simcluster  — derives FLOPs / KV-bytes / collective volumes for the
                        event-driven serving simulation (Vidur-style analytic
                        latency model);
  * repro.launch      — input_specs + sharding for the multi-pod dry-run.

Analytic accounting conventions:
  * params are real parameter counts (embeddings included once when tied);
  * flops_per_token counts the standard 2*params_active matmul FLOPs plus the
    attention score/value term for the given context length;
  * kv_bytes_per_token_layer is the per-layer per-token KV-cache footprint —
    the quantity Stage-1/Stage-3 flows carry. MLA stores the compressed
    latent (kv_lora_rank + rope head) instead of full K/V; SSM/hybrid layers
    store O(1) state instead of per-token KV.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

__all__ = ["ArchConfig", "ShapeCell", "SHAPES"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0              # shared (always-on) experts
    d_expert: int = 0              # per-expert FFN width (fine-grained MoE)
    first_dense: int = 0           # leading dense layers (DeepSeek style)

    # --- MLA (DeepSeek-V3) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (Mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4

    # --- hybrid (RecurrentGemma / Griffin) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    window: int = 0                        # local-attention window
    rglru_width: int = 0                   # recurrent block width (lru_width)

    # --- encoder-decoder (Seamless-M4T) ---
    enc_layers: int = 0

    # --- misc ---
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    rope_theta: float = 1e4
    mtp: bool = False              # multi-token prediction head (DSv3)
    source: str = ""

    # ------------------------------------------------------------- derived
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kind(self, layer: int) -> str:
        """'attn' | 'rec' | 'ssm' — the sequence-mixing kind of a layer."""
        if self.family == "ssm":
            return "ssm"
        if self.block_pattern:
            return self.block_pattern[layer % len(self.block_pattern)]
        return "attn"

    def n_attn_layers(self) -> int:
        return sum(1 for l in range(self.n_layers) if self.layer_kind(l) == "attn")

    def is_moe_layer(self, layer: int) -> bool:
        return self.n_experts > 0 and layer >= self.first_dense

    # ------------------------------------------------------ param accounting
    def attn_params(self) -> int:
        d, hd = self.d_model, self.hd
        if self.use_mla:
            # q: d->q_lora->heads*(nope+rope); kv: d->kv_lora(+rope); o.
            qr = self.q_lora_rank or self.d_model
            p = d * qr + qr * self.n_heads * (self.nope_head_dim + self.rope_head_dim)
            p += d * (self.kv_lora_rank + self.rope_head_dim)
            p += self.kv_lora_rank * self.n_heads * (self.nope_head_dim + self.v_head_dim)
            p += self.n_heads * self.v_head_dim * d
            return p
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv * hd
        o = self.n_heads * hd * d
        return q + kv + o

    def rec_params(self) -> int:
        # Griffin recurrent block: in-proj d->2w (branch + gate), temporal
        # conv, block-diagonal RG-LRU input/recurrence gates, out-proj w->d.
        w = self.rglru_width or self.d_model
        gates = 2 * w * max(1, w // 16)   # block-diagonal gate matrices
        return 2 * self.d_model * w + self.ssm_conv * w + gates + w * self.d_model

    def ssm_params(self) -> int:
        d_in = self.ssm_expand * self.d_model
        # Mamba2: in_proj (z,x,B,C,dt) + out_proj + conv
        n_g = 1
        proj = self.d_model * (2 * d_in + 2 * n_g * self.ssm_state + d_in // self.ssm_head_dim)
        return proj + d_in * self.d_model + self.ssm_conv * (d_in + 2 * self.ssm_state)

    def ffn_params_dense(self) -> int:
        return 3 * self.d_model * self.d_ff       # SwiGLU

    def ffn_params_expert(self) -> int:
        return 3 * self.d_model * self.d_expert

    def moe_layer_params(self) -> int:
        p = (self.n_experts + self.n_shared) * self.ffn_params_expert()
        p += self.d_model * self.n_experts        # router
        return p

    def params(self) -> int:
        """Total parameters (approximate, embedding included once if tied)."""
        p = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        layers = self.n_layers + self.enc_layers
        for l in range(self.n_layers):
            kind = self.layer_kind(l)
            if kind == "attn":
                p += self.attn_params()
            elif kind == "rec":
                p += self.rec_params()
            else:
                p += self.ssm_params()
            if self.family == "ssm":
                continue                           # mamba2 has no separate FFN
            if self.is_moe_layer(l):
                p += self.moe_layer_params()
            else:
                p += self.ffn_params_dense()
            if self.enc_layers and l < self.enc_layers:
                p += self.attn_params()            # decoder cross-attention
        for _ in range(self.enc_layers):           # encoder stack
            p += self.attn_params() + self.ffn_params_dense()
        p += 2 * self.d_model * layers             # norms
        return p

    def params_active(self) -> int:
        """Active parameters per token (MoE: top_k + shared experts only)."""
        if self.n_experts == 0:
            return self.params()
        p = self.params()
        moe_layers = sum(1 for l in range(self.n_layers) if self.is_moe_layer(l))
        inactive = (self.n_experts - self.top_k) * self.ffn_params_expert()
        return p - moe_layers * inactive

    # ------------------------------------------------------ flops accounting
    def flops_per_token(self, ctx: int = 0) -> float:
        """Forward FLOPs per token: 2*active-params + attention scores.

        ``ctx`` is the average attended context length (0 = ignore the
        quadratic term). Local-attention layers cap ctx at the window; rec /
        ssm layers have linear state updates already counted in params.
        """
        f = 2.0 * self.params_active()
        if ctx > 0:
            for l in range(self.n_layers):
                kind = self.layer_kind(l)
                if kind == "attn":
                    eff = min(ctx, self.window) if self.window else ctx
                    dim = (self.n_heads * (self.nope_head_dim + self.rope_head_dim)
                           if self.use_mla else self.n_heads * self.hd)
                    f += 4.0 * eff * dim          # QK^T + AV
        return f

    # ------------------------------------------------------ KV accounting
    def kv_bytes_per_token_layer(self, dtype_bytes: int = 2, layer: int = 0) -> float:
        kind = self.layer_kind(layer)
        if kind == "ssm":
            return 0.0                             # state is O(1), see state_bytes
        if kind == "rec":
            return 0.0
        if self.use_mla:
            return (self.kv_lora_rank + self.rope_head_dim) * dtype_bytes
        return 2.0 * self.n_kv * self.hd * dtype_bytes

    def kv_bytes_per_token(self, dtype_bytes: int = 2, window_cap: int = 0) -> float:
        """Per-token KV bytes summed over layers (local-attn layers included;
        the *cache* for them is capped at the window — handled by caller)."""
        return sum(self.kv_bytes_per_token_layer(dtype_bytes, l)
                   for l in range(self.n_layers))

    def state_bytes(self, dtype_bytes: int = 2) -> float:
        """Fixed-size recurrent state per sequence (SSM / RG-LRU layers)."""
        total = 0.0
        for l in range(self.n_layers):
            kind = self.layer_kind(l)
            if kind == "ssm":
                d_in = self.ssm_expand * self.d_model
                heads = d_in // self.ssm_head_dim
                total += heads * self.ssm_head_dim * self.ssm_state * dtype_bytes
                total += self.ssm_conv * d_in * dtype_bytes
            elif kind == "rec":
                total += (self.rglru_width or self.d_model) * dtype_bytes
        return total


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode
    needs_subquadratic: bool = False


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode", needs_subquadratic=True),
)
