"""seamless-m4t-medium — encoder-decoder, 12L(dec) + 12L(enc) d_model=1024
16H (kv=16) d_ff=4096 vocab=256206; the speech frontend is a stub providing
frame embeddings. [arXiv:2308.11596; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv=16, d_ff=4096,
    vocab=256206, enc_layers=12,
    source="arXiv:2308.11596",
)

SMOKE = ArchConfig(
    name="seamless-m4t-medium-smoke", family="audio",
    n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
    enc_layers=2, source="reduced",
)
