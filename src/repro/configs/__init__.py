"""repro.configs — assigned architectures (exact public configs + reduced
smoke variants) and the shape-cell matrix."""
from .base import ArchConfig, ShapeCell, SHAPES
from . import (qwen1_5_32b, minitron_8b, starcoder2_3b, smollm_360m,
               recurrentgemma_9b, deepseek_moe_16b, deepseek_v3_671b,
               mamba2_1_3b, qwen2_vl_7b, seamless_m4t_medium)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (qwen1_5_32b, minitron_8b, starcoder2_3b, smollm_360m,
              recurrentgemma_9b, deepseek_moe_16b, deepseek_v3_671b,
              mamba2_1_3b, qwen2_vl_7b, seamless_m4t_medium)
}
SMOKES = {
    m.CONFIG.name: m.SMOKE
    for m in (qwen1_5_32b, minitron_8b, starcoder2_3b, smollm_360m,
              recurrentgemma_9b, deepseek_moe_16b, deepseek_v3_671b,
              mamba2_1_3b, qwen2_vl_7b, seamless_m4t_medium)
}


def get_arch(name: str) -> ArchConfig:
    if name in ARCHS:
        return ARCHS[name]
    from ..simcluster.papermodels import PAPER_MODELS
    if name in PAPER_MODELS:
        return PAPER_MODELS[name]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


__all__ = ["ArchConfig", "ShapeCell", "SHAPES", "ARCHS", "SMOKES", "get_arch"]
