"""deepseek-moe-16b — fine-grained MoE, 28L d_model=2048 16H (kv=16, MHA)
d_ff=1408(expert), vocab=102400, 64 routed top-6 + 2 shared, first layer
dense. [arXiv:2401.06066; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv=16, d_ff=10944,
    vocab=102400,
    n_experts=64, top_k=6, n_shared=2, d_expert=1408, first_dense=1,
    source="arXiv:2401.06066",
)

SMOKE = ArchConfig(
    name="deepseek-moe-16b-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
    n_experts=8, top_k=2, n_shared=1, d_expert=64, first_dense=1,
    source="reduced",
)
