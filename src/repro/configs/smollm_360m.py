"""smollm-360m — dense llama-arch small, 32L d_model=960 15H (GQA kv=5)
d_ff=2560 vocab=49152. [hf:HuggingFaceTB/SmolLM-360M; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv=5, d_ff=2560,
    vocab=49152, tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-360M",
)

SMOKE = ArchConfig(
    name="smollm-360m-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=3, n_kv=1, d_ff=192, vocab=512,
    tie_embeddings=True, source="reduced",
)
