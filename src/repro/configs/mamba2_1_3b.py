"""mamba2-1.3b — SSM (SSD / state-space duality), 48L d_model=2048
attention-free, vocab=50280, ssm_state=128. [arXiv:2405.21060; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)

SMOKE = ArchConfig(
    name="mamba2-1.3b-smoke", family="ssm",
    n_layers=2, d_model=128, n_heads=0, n_kv=0, d_ff=0, vocab=512,
    ssm_state=16, ssm_expand=2, ssm_head_dim=32, tie_embeddings=True,
    source="reduced",
)
