"""minitron-8b — dense (pruned nemotron), 32L d_model=4096 32H (GQA kv=8)
d_ff=16384 vocab=256000. [arXiv:2407.14679; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=16384,
    vocab=256000,
    source="arXiv:2407.14679",
)

SMOKE = ArchConfig(
    name="minitron-8b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv=2, d_ff=256, vocab=512,
    source="reduced",
)
