"""recurrentgemma-9b — hybrid RG-LRU + local attention (2 recurrent : 1
attn), 38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000, window 2048.
[arXiv:2402.19427; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv=1, d_ff=12288,
    vocab=256000, head_dim=256,
    block_pattern=("rec", "rec", "attn"), window=2048, rglru_width=4096,
    source="arXiv:2402.19427",
)

SMOKE = ArchConfig(
    name="recurrentgemma-9b-smoke", family="hybrid",
    n_layers=3, d_model=128, n_heads=2, n_kv=1, d_ff=256, vocab=512,
    head_dim=64, block_pattern=("rec", "rec", "attn"), window=16,
    rglru_width=128, source="reduced",
)
