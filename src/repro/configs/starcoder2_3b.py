"""starcoder2-3b — dense, 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152, GQA + RoPE. [arXiv:2402.19173; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv=2, d_ff=12288,
    vocab=49152,
    source="arXiv:2402.19173",
)

SMOKE = ArchConfig(
    name="starcoder2-3b-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv=2, d_ff=192, vocab=512,
    source="reduced",
)
