"""deepseek-v3-671b — MLA + fine-grained MoE, 61L d_model=7168 128H
d_ff=2048(expert), vocab=129280, 1 shared + 256 routed top-8, first 3 dense,
MTP head. [arXiv:2412.19437; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv=128, d_ff=18432,
    vocab=129280,
    n_experts=256, top_k=8, n_shared=1, d_expert=2048, first_dense=3,
    use_mla=True, kv_lora_rank=512, q_lora_rank=1536,
    rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
    mtp=True,
    source="arXiv:2412.19437",
)

SMOKE = ArchConfig(
    name="deepseek-v3-671b-smoke", family="moe",
    n_layers=3, d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
    n_experts=8, top_k=2, n_shared=1, d_expert=64, first_dense=1,
    use_mla=True, kv_lora_rank=32, q_lora_rank=48,
    rope_head_dim=16, nope_head_dim=32, v_head_dim=32,
    mtp=True,
    source="reduced",
)
