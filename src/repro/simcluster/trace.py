"""Workload traces — Qwen production-derived and Mooncake-style (§6.2/§6.3).

Statistical shape follows the paper's descriptions:
  * QwenA-Conv   — conversation: ~2k-token prompts, ~50% prefix reuse;
  * QwenB-Agent  — agent: ~1k-token prompts, ~65% reuse, many concurrent
                   requests sharing identical hot prefixes (one-to-many
                   victim contention, §6.3);
  * Mooncake-Conv / Mooncake-Agent — same access patterns with long contexts
                   (~15k / ~9k tokens, ~40% / ~65% reuse).

Prompt lengths are lognormal (heavy upper tail — the paper's "small fraction
of tail requests necessitating large KV movements"); prefix popularity is
Zipf so hot blocks concentrate on victim units; arrivals are Poisson.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

__all__ = ["Request", "WorkloadSpec", "WORKLOADS", "generate_trace"]


@dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    reuse_len: int
    prefix_id: int
    # filled by the simulator:
    deadline: float = 0.0
    unit: int = -1
    batch: int = -1
    ideal_ttft: float = 0.0
    ttft: Optional[float] = None
    prefill_done: Optional[float] = None
    stalls: float = 0.0


@dataclass(frozen=True)
class WorkloadSpec:
    name: str
    mean_prompt: int
    reuse_mean: float          # mean fraction of the prompt that is reusable
    reuse_beta: float = 8.0    # Beta concentration for per-request reuse
    sigma: float = 0.6         # lognormal shape for prompt lengths
    n_prefixes: int = 64
    zipf_a: float = 1.2        # prefix popularity skew (agent = hotter)
    max_prompt: int = 0        # 0 = 8x mean


WORKLOADS = {
    "qwen-conv": WorkloadSpec("qwen-conv", mean_prompt=2048, reuse_mean=0.50,
                              zipf_a=1.1),
    "qwen-agent": WorkloadSpec("qwen-agent", mean_prompt=1024, reuse_mean=0.65,
                               zipf_a=1.6, n_prefixes=32),
    "mooncake-conv": WorkloadSpec("mooncake-conv", mean_prompt=15360,
                                  reuse_mean=0.40, zipf_a=1.1, sigma=0.5),
    "mooncake-agent": WorkloadSpec("mooncake-agent", mean_prompt=9216,
                                   reuse_mean=0.65, zipf_a=1.6, sigma=0.5,
                                   n_prefixes=32),
}


def generate_trace(spec: WorkloadSpec, n_requests: int, rps: float,
                   seed: int = 0, warmup: int = 0) -> List[Request]:
    """Poisson arrivals at ``rps`` requests/second, ``n_requests`` total.

    ``warmup`` extra leading requests are generated and flagged by negative
    rid so callers can exclude them from metrics (the paper clips the first
    512 trace entries as warm-up).
    """
    rng = np.random.default_rng(seed)
    total = n_requests + warmup
    gaps = rng.exponential(1.0 / rps, size=total)
    arrivals = np.cumsum(gaps)
    mu = np.log(spec.mean_prompt) - spec.sigma ** 2 / 2.0
    lengths = rng.lognormal(mu, spec.sigma, size=total)
    cap = spec.max_prompt or 8 * spec.mean_prompt
    lengths = np.clip(lengths, 64, cap).astype(int)
    a = spec.reuse_mean * spec.reuse_beta
    b = (1.0 - spec.reuse_mean) * spec.reuse_beta
    reuse_frac = rng.beta(a, b, size=total)
    # Zipf over a fixed prefix pool; hot prefixes pile onto few owner units.
    ranks = np.arange(1, spec.n_prefixes + 1, dtype=np.float64)
    pmf = ranks ** (-spec.zipf_a)
    pmf /= pmf.sum()
    prefixes = rng.choice(spec.n_prefixes, size=total, p=pmf)

    out: List[Request] = []
    for i in range(total):
        rid = i - warmup            # warm-up requests get negative ids
        out.append(Request(
            rid=rid,
            arrival=float(arrivals[i]),
            prompt_len=int(lengths[i]),
            reuse_len=int(lengths[i] * reuse_frac[i]),
            prefix_id=int(prefixes[i]),
        ))
    return out
