"""Workload traces — Qwen production-derived and Mooncake-style (§6.2/§6.3).

Statistical shape follows the paper's descriptions:
  * QwenA-Conv   — conversation: ~2k-token prompts, ~50% prefix reuse;
  * QwenB-Agent  — agent: ~1k-token prompts, ~65% reuse, many concurrent
                   requests sharing identical hot prefixes (one-to-many
                   victim contention, §6.3);
  * Mooncake-Conv / Mooncake-Agent — same access patterns with long contexts
                   (~15k / ~9k tokens, ~40% / ~65% reuse).

Prompt lengths are lognormal (heavy upper tail — the paper's "small fraction
of tail requests necessitating large KV movements"); prefix popularity is
Zipf so hot blocks concentrate on victim units.

Arrival processes (``ArrivalSpec``) extend the paper's Poisson default to the
regimes related work sweeps (SLOs-Serve's multi-SLO workloads, Ascendra's
dynamic-load prioritisation):

  * ``poisson`` — memoryless, CV = 1 (the paper's large-scale sims);
  * ``gamma``   — i.i.d. Gamma inter-arrivals with a chosen CV > 1
                  (heavy-tailed gaps: clustered arrivals + lulls);
  * ``mmpp``    — 2-state Markov-modulated Poisson process: a quiet state
                  and a burst state whose rate is ``burst_factor``x higher,
                  occupied ``burst_frac`` of the time, with exponentially
                  distributed dwell times (mean episode cycle ``dwell``
                  seconds). Mean rate stays ``rps`` so attainment-vs-rate
                  curves remain comparable across processes.

Multi-tenant SLO classes: an ``slo_mix`` maps class names (``tight`` /
``standard`` / ``loose``, see ``SLO_CLASSES``) to probabilities; sampled
per request and carried as ``Request.slo_scale``, which the runtime uses in
place of the cluster-wide ``slo_scale`` when deriving the TTFT deadline.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

__all__ = ["Request", "WorkloadSpec", "ArrivalSpec", "WORKLOADS",
           "SLO_CLASSES", "generate_trace", "prefix_chain"]


@dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    reuse_len: int
    prefix_id: int
    # hierarchical prefix chain ((node_id, tokens), ...) — the reusable
    # prefix as a path through the workload's prefix tree, so requests
    # sharing ancestors share the chain's leading segments (partial-prefix
    # hits in the KV-reuse plane). Derived deterministically from
    # (prefix_id, reuse_len): no extra RNG draws, traces stay bit-identical.
    prefix_chain: tuple = ()
    # multi-tenant SLO class (0.0 = defer to the cluster-wide slo_scale)
    slo_class: str = "standard"
    slo_scale: float = 0.0
    out_len: int = 0           # decode output tokens (0 = plane samples)
    # filled by the simulator:
    deadline: float = 0.0
    unit: int = -1
    batch: int = -1
    ideal_ttft: float = 0.0
    ttft: Optional[float] = None
    prefill_done: Optional[float] = None
    stalls: float = 0.0


@dataclass(frozen=True)
class WorkloadSpec:
    name: str
    mean_prompt: int
    reuse_mean: float          # mean fraction of the prompt that is reusable
    reuse_beta: float = 8.0    # Beta concentration for per-request reuse
    sigma: float = 0.6         # lognormal shape for prompt lengths
    n_prefixes: int = 64
    zipf_a: float = 1.2        # prefix popularity skew (agent = hotter)
    max_prompt: int = 0        # 0 = 8x mean
    mean_out: int = 256        # decode output length (lognormal mean)
    out_sigma: float = 0.8     # lognormal shape for output lengths
    max_out: int = 0           # 0 = 8x mean_out
    # prefix-tree shape for the KV-reuse plane: prefix ``p``'s reusable
    # tokens follow its lineage root->...->p (parent(p) = (p-1)//branch);
    # every ancestor contributes ``chain_node_tokens`` tokens, the leaf
    # takes the remainder — so siblings share exactly their ancestors'
    # token spans (partial-prefix hits)
    chain_branch: int = 4
    chain_node_tokens: int = 512


@dataclass(frozen=True)
class ArrivalSpec:
    """Arrival-process shape at a fixed mean rate (``rps`` stays the knob)."""

    process: str = "poisson"   # poisson | gamma | mmpp
    cv: float = 2.0            # gamma: inter-arrival coefficient of variation
    burst_factor: float = 8.0  # mmpp: burst-state rate / quiet-state rate
    burst_frac: float = 0.1    # mmpp: long-run fraction of time in burst
    dwell: float = 4.0         # mmpp: mean seconds per quiet+burst cycle


#: per-request SLO budget multipliers over the calibration base (tenant mix)
SLO_CLASSES: Dict[str, float] = {"tight": 1.5, "standard": 3.0, "loose": 6.0}


WORKLOADS = {
    "qwen-conv": WorkloadSpec("qwen-conv", mean_prompt=2048, reuse_mean=0.50,
                              zipf_a=1.1),
    "qwen-agent": WorkloadSpec("qwen-agent", mean_prompt=1024, reuse_mean=0.65,
                               zipf_a=1.6, n_prefixes=32),
    "mooncake-conv": WorkloadSpec("mooncake-conv", mean_prompt=15360,
                                  reuse_mean=0.40, zipf_a=1.1, sigma=0.5),
    "mooncake-agent": WorkloadSpec("mooncake-agent", mean_prompt=9216,
                                   reuse_mean=0.65, zipf_a=1.6, sigma=0.5,
                                   n_prefixes=32),
    # Mooncake long-context tail: ~22k-token prompts with a heavy upper
    # tail (sigma 0.9 => the "small fraction of tail requests necessitating
    # large KV movements"), deep shared system prefixes
    # (chain_node_tokens=1024) — the KV-reuse-plane sweep's workload.
    "mooncake-tail": WorkloadSpec("mooncake-tail", mean_prompt=22528,
                                  reuse_mean=0.55, zipf_a=1.4, sigma=0.9,
                                  n_prefixes=48, chain_node_tokens=1024),
}


def prefix_chain(prefix_id: int, reuse_len: int,
                 spec: WorkloadSpec) -> tuple:
    """Hierarchical prefix chain for one request: ``((node, tokens), ...)``.

    The chain walks prefix ``prefix_id``'s lineage from the tree root; each
    ancestor contributes exactly ``spec.chain_node_tokens`` tokens and the
    leaf absorbs whatever of ``reuse_len`` remains, so two prefixes with a
    common ancestor share identical leading (node, tokens) spans — which
    the block-granular KV store turns into partial-prefix hits. Pure
    function of already-sampled trace fields: adding chains changes no RNG
    draw, so fixed-seed traces stay bit-identical.
    """
    lineage = []
    p = int(prefix_id)
    while True:
        lineage.append(p)
        if p <= 0:
            break
        p = (p - 1) // max(spec.chain_branch, 2)
    lineage.reverse()
    out = []
    left = int(reuse_len)
    for i, q in enumerate(lineage):
        last = i == len(lineage) - 1
        t = left if last else min(spec.chain_node_tokens, left)
        if t <= 0:
            break
        out.append((q, t))
        left -= t
    return tuple(out)


# ------------------------------------------------------------ arrival draws
def _gaps_poisson(rng: np.random.Generator, rps: float, n: int) -> np.ndarray:
    return rng.exponential(1.0 / rps, size=n)


def _gaps_gamma(rng: np.random.Generator, rps: float, n: int,
                cv: float) -> np.ndarray:
    """Gamma inter-arrivals: shape k = 1/cv^2 keeps the mean at 1/rps while
    setting the coefficient of variation to ``cv`` (cv=1 == Poisson)."""
    k = 1.0 / (cv * cv)
    return rng.gamma(shape=k, scale=1.0 / (rps * k), size=n)


def _arrivals_mmpp(rng: np.random.Generator, rps: float, n: int,
                   spec: ArrivalSpec) -> np.ndarray:
    """2-state MMPP arrivals. Quiet rate r0 and burst rate f*r0 are solved
    from the long-run mean ``rps = (1-p)*r0 + p*f*r0`` so burstiness is a
    pure *shape* change; state dwell times are exponential with means
    ``dwell*(1-p)`` (quiet) and ``dwell*p`` (burst)."""
    p, f = spec.burst_frac, spec.burst_factor
    r0 = rps / (1.0 - p + p * f)
    rates = (r0, f * r0)
    dwells = (max(spec.dwell * (1.0 - p), 1e-9), max(spec.dwell * p, 1e-9))
    out = np.empty(n)
    t, i = 0.0, 0
    state = 0                                  # start quiet
    state_end = rng.exponential(dwells[state])
    while i < n:
        gap = rng.exponential(1.0 / rates[state])
        if t + gap < state_end:
            t += gap
            out[i] = t
            i += 1
        else:                                  # switch state, keep the clock
            t = state_end
            state = 1 - state
            state_end = t + rng.exponential(dwells[state])
    return out


def generate_trace(spec: WorkloadSpec, n_requests: int, rps: float,
                   seed: int = 0, warmup: int = 0,
                   arrival: Optional[ArrivalSpec] = None,
                   slo_mix: Optional[Dict[str, float]] = None,
                   decode_lens: bool = False) -> List[Request]:
    """``n_requests`` requests at mean rate ``rps`` requests/second.

    ``warmup`` extra leading requests are generated and flagged by negative
    rid so callers can exclude them from metrics (the paper clips the first
    512 trace entries as warm-up).

    ``arrival`` selects the arrival process (default Poisson — identical
    draws to the historical generator, so fixed seeds reproduce old traces).
    ``slo_mix`` maps SLO class names from :data:`SLO_CLASSES` to sampling
    probabilities; ``None`` leaves every request on the cluster default.
    ``decode_lens`` samples per-request output lengths (lognormal over
    ``mean_out``/``out_sigma``) into ``Request.out_len`` for decode-plane
    runs — drawn from a *separate* RNG stream so the base trace stays
    bit-identical for a fixed seed whether or not lengths are requested.
    """
    rng = np.random.default_rng(seed)
    total = n_requests + warmup
    arrival = arrival or ArrivalSpec()
    if arrival.process == "poisson":
        arrivals = np.cumsum(_gaps_poisson(rng, rps, total))
    elif arrival.process == "gamma":
        arrivals = np.cumsum(_gaps_gamma(rng, rps, total, arrival.cv))
    elif arrival.process == "mmpp":
        arrivals = _arrivals_mmpp(rng, rps, total, arrival)
    else:
        raise ValueError(f"unknown arrival process {arrival.process!r}")
    mu = np.log(spec.mean_prompt) - spec.sigma ** 2 / 2.0
    lengths = rng.lognormal(mu, spec.sigma, size=total)
    cap = spec.max_prompt or 8 * spec.mean_prompt
    lengths = np.clip(lengths, 64, cap).astype(int)
    a = spec.reuse_mean * spec.reuse_beta
    b = (1.0 - spec.reuse_mean) * spec.reuse_beta
    reuse_frac = rng.beta(a, b, size=total)
    # Zipf over a fixed prefix pool; hot prefixes pile onto few owner units.
    ranks = np.arange(1, spec.n_prefixes + 1, dtype=np.float64)
    pmf = ranks ** (-spec.zipf_a)
    pmf /= pmf.sum()
    prefixes = rng.choice(spec.n_prefixes, size=total, p=pmf)
    if slo_mix:
        unknown = set(slo_mix) - set(SLO_CLASSES)
        if unknown:
            raise ValueError(f"unknown SLO classes {sorted(unknown)}; "
                             f"choose from {sorted(SLO_CLASSES)}")
        names = sorted(slo_mix)
        probs = np.array([slo_mix[c] for c in names], dtype=np.float64)
        probs /= probs.sum()
        classes = [names[j] for j in rng.choice(len(names), size=total, p=probs)]
    else:
        classes = None
    if decode_lens:
        out_rng = np.random.default_rng(seed + 7919)   # independent stream
        mu_o = np.log(spec.mean_out) - spec.out_sigma ** 2 / 2.0
        cap_o = spec.max_out or 8 * spec.mean_out
        out_lens = np.clip(out_rng.lognormal(mu_o, spec.out_sigma, size=total),
                           1, cap_o).astype(int)
    else:
        out_lens = None

    out: List[Request] = []
    for i in range(total):
        rid = i - warmup            # warm-up requests get negative ids
        cls = classes[i] if classes else "standard"
        reuse_len = int(lengths[i] * reuse_frac[i])
        out.append(Request(
            rid=rid,
            arrival=float(arrivals[i]),
            prompt_len=int(lengths[i]),
            reuse_len=reuse_len,
            prefix_id=int(prefixes[i]),
            prefix_chain=prefix_chain(int(prefixes[i]), reuse_len, spec),
            slo_class=cls,
            slo_scale=SLO_CLASSES[cls] if classes else 0.0,
            out_len=int(out_lens[i]) if out_lens is not None else 0,
        ))
    return out
