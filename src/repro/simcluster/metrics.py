"""Serving metrics — TTFT, SLO attainment, CCT, earliness (§6.1).

SLO definition follows the paper: threshold = ``slo_scale`` (default 3x) times
the TTFT measured under low-load (contention-free) conditions for the same
request — computed analytically per request by the simulator's ideal path.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np

__all__ = ["CoflowRecord", "SimMetrics"]


@dataclass
class CoflowRecord:
    cid: int
    unit: int
    layer: int
    started: float
    finished: float
    size: float
    ideal: float            # serialised transfer time at full line rate

    @property
    def cct(self) -> float:
        return self.finished - self.started

    @property
    def slowdown(self) -> float:
        return self.cct / max(self.ideal, 1e-12)


@dataclass
class SimMetrics:
    policy: str = ""
    ttft: Dict[int, float] = field(default_factory=dict)
    deadline: Dict[int, float] = field(default_factory=dict)
    arrival: Dict[int, float] = field(default_factory=dict)
    ideal_ttft: Dict[int, float] = field(default_factory=dict)
    stall_time: Dict[int, float] = field(default_factory=dict)
    coflows: List[CoflowRecord] = field(default_factory=list)
    pruned: int = 0

    # ------------------------------------------------------------- summaries
    def _rids(self):
        return [r for r in self.ttft if r >= 0]      # exclude warm-up

    def slo_attainment(self) -> float:
        rids = self._rids()
        if not rids:
            return float("nan")
        ok = sum(1 for r in rids if self.ttft[r] <= self.deadline[r] + 1e-9)
        return ok / len(rids)

    def ttft_stats(self):
        v = np.array([self.ttft[r] for r in self._rids()])
        if v.size == 0:
            return {}
        return {"mean": float(v.mean()), "p50": float(np.percentile(v, 50)),
                "p90": float(np.percentile(v, 90)), "p99": float(np.percentile(v, 99))}

    def normalized_ttft(self) -> float:
        """Mean TTFT / mean ideal TTFT (contention inflation factor)."""
        rids = self._rids()
        if not rids:
            return float("nan")
        num = np.mean([self.ttft[r] for r in rids])
        den = np.mean([self.ideal_ttft[r] for r in rids])
        return float(num / max(den, 1e-12))

    def mean_cct(self) -> float:
        if not self.coflows:
            return float("nan")
        return float(np.mean([c.cct for c in self.coflows]))

    def cct_slowdown(self) -> float:
        if not self.coflows:
            return float("nan")
        return float(np.mean([c.slowdown for c in self.coflows]))

    def earliness(self) -> np.ndarray:
        """deadline - TTFT per request; positive = early, negative = miss."""
        rids = self._rids()
        return np.array([self.deadline[r] - self.ttft[r] for r in rids])

    def positive_earliness(self) -> float:
        e = self.earliness()
        pos = e[e > 0]
        return float(pos.mean()) if pos.size else 0.0

    def summary(self) -> Dict[str, float]:
        s = {"policy": self.policy, "n": len(self._rids()),
             "slo_attainment": self.slo_attainment(),
             "norm_ttft": self.normalized_ttft(),
             "cct_slowdown": self.cct_slowdown(),
             "pos_earliness": self.positive_earliness(),
             "pruned": self.pruned}
        s.update({f"ttft_{k}": v for k, v in self.ttft_stats().items()})
        return s
