"""Serving metrics — TTFT, SLO attainment, CCT, earliness (§6.1), plus the
decode plane's TPOT/TBT attainment per pool and per SLO class.

With admission control active (router plane), attainment is reported two
ways: ``slo_attainment`` counts every arrival (a shed request is a miss —
rejecting hard requests cannot inflate it) while ``admitted_attainment``
covers served requests only; both exist overall and per SLO class.

SLO definition follows the paper: threshold = ``slo_scale`` (default 3x) times
the TTFT measured under low-load (contention-free) conditions for the same
request — computed analytically per request by the simulator's ideal path.
Decode TPOT attainment compares each request's mean time-per-output-token
(== mean TBT after the first token) against its pool's per-class budget.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

__all__ = ["CoflowRecord", "SimMetrics"]


@dataclass
class CoflowRecord:
    cid: int
    unit: int
    layer: int
    started: float
    finished: float
    size: float
    ideal: float            # serialised transfer time at full line rate

    @property
    def cct(self) -> float:
        return self.finished - self.started

    @property
    def slowdown(self) -> float:
        return self.cct / max(self.ideal, 1e-12)


@dataclass
class SimMetrics:
    policy: str = ""
    ttft: Dict[int, float] = field(default_factory=dict)
    deadline: Dict[int, float] = field(default_factory=dict)
    arrival: Dict[int, float] = field(default_factory=dict)
    ideal_ttft: Dict[int, float] = field(default_factory=dict)
    stall_time: Dict[int, float] = field(default_factory=dict)
    prompt_tokens: Dict[int, int] = field(default_factory=dict)
    coflows: List[CoflowRecord] = field(default_factory=list)
    pruned: int = 0
    # --- decode plane (empty when no DecodePlane is attached) ---
    slo_class: Dict[int, str] = field(default_factory=dict)
    pool_of: Dict[int, str] = field(default_factory=dict)
    tpot: Dict[int, float] = field(default_factory=dict)      # mean TBT
    tbt_max: Dict[int, float] = field(default_factory=dict)   # worst gap
    tpot_budget: Dict[int, float] = field(default_factory=dict)
    decode_stats: Dict[str, float] = field(default_factory=dict)
    # --- KV-reuse plane (empty when no KVStore is attached) ---
    kv_hit_tokens: Dict[int, int] = field(default_factory=dict)
    kv_prompt_tokens: Dict[int, int] = field(default_factory=dict)
    kv_tier_tokens: Dict[str, int] = field(default_factory=dict)
    kvstore_stats: Dict[str, float] = field(default_factory=dict)
    # --- router/admission plane (empty when admission control is off) ---
    shed: Dict[int, str] = field(default_factory=dict)   # rid -> slo_class
    n_deferred: int = 0                                  # defer retries total
    # rows the bounded stage log dropped on overflow (0 = trace complete;
    # nonzero means parity/attribution over the log would be partial)
    stage_log_dropped: int = 0

    # ------------------------------------------------------------- summaries
    def _rids(self):
        return [r for r in self.ttft if r >= 0]      # exclude warm-up

    def _shed_rids(self, slo_class: Optional[str] = None):
        return [r for r, c in self.shed.items()
                if r >= 0 and (slo_class is None or c == slo_class)]

    def slo_attainment(self) -> float:
        """All-arrivals TTFT attainment: a shed request never got a first
        token, so it counts as a miss in the denominator — admission
        control cannot inflate this number by rejecting hard requests."""
        rids = self._rids()
        n_shed = len(self._shed_rids())
        if not rids and not n_shed:
            return float("nan")
        ok = sum(1 for r in rids if self.ttft[r] <= self.deadline[r] + 1e-9)
        return ok / (len(rids) + n_shed)

    def admitted_attainment(self) -> float:
        """TTFT attainment over admitted (served) requests only — what the
        accepted traffic experienced. With admission off this equals
        :meth:`slo_attainment`."""
        rids = self._rids()
        if not rids:
            return float("nan")
        ok = sum(1 for r in rids if self.ttft[r] <= self.deadline[r] + 1e-9)
        return ok / len(rids)

    def ttft_stats(self):
        v = np.array([self.ttft[r] for r in self._rids()])
        if v.size == 0:
            return {}
        return {"mean": float(v.mean()), "p50": float(np.percentile(v, 50)),
                "p90": float(np.percentile(v, 90)), "p99": float(np.percentile(v, 99))}

    def long_prompt_stats(self, min_tokens: int) -> Dict[str, float]:
        """Mean TTFT + SLO attainment of the long-prompt class (prompts of
        at least ``min_tokens``) — the head-of-line-blocking victims chunked
        prefill exists to help."""
        rids = [r for r in self._rids()
                if self.prompt_tokens.get(r, 0) >= min_tokens]
        if not rids:
            return {"n": 0, "ttft_mean": float("nan"),
                    "ttft_p99": float("nan"), "attainment": float("nan")}
        v = np.array([self.ttft[r] for r in rids])
        ok = sum(1 for r in rids if self.ttft[r] <= self.deadline[r] + 1e-9)
        return {"n": len(rids), "ttft_mean": float(v.mean()),
                "ttft_p99": float(np.percentile(v, 99)),
                "attainment": ok / len(rids)}

    def normalized_ttft(self) -> float:
        """Mean TTFT / mean ideal TTFT (contention inflation factor)."""
        rids = self._rids()
        if not rids:
            return float("nan")
        num = np.mean([self.ttft[r] for r in rids])
        den = np.mean([self.ideal_ttft[r] for r in rids])
        return float(num / max(den, 1e-12))

    def mean_cct(self) -> float:
        if not self.coflows:
            return float("nan")
        return float(np.mean([c.cct for c in self.coflows]))

    def cct_slowdown(self) -> float:
        if not self.coflows:
            return float("nan")
        return float(np.mean([c.slowdown for c in self.coflows]))

    def earliness(self) -> np.ndarray:
        """deadline - TTFT per request; positive = early, negative = miss."""
        rids = self._rids()
        return np.array([self.deadline[r] - self.ttft[r] for r in rids])

    def positive_earliness(self) -> float:
        e = self.earliness()
        pos = e[e > 0]
        return float(pos.mean()) if pos.size else 0.0

    # --------------------------------------------------------- decode plane
    def _tpot_rids(self, pool: Optional[str] = None,
                   slo_class: Optional[str] = None) -> List[int]:
        return [r for r in self.tpot
                if r >= 0
                and (pool is None or self.pool_of.get(r) == pool)
                and (slo_class is None or self.slo_class.get(r) == slo_class)]

    def tpot_attainment(self, pool: Optional[str] = None,
                        slo_class: Optional[str] = None) -> float:
        """Fraction of decoded requests whose mean TBT met their budget."""
        rids = self._tpot_rids(pool, slo_class)
        if not rids:
            return float("nan")
        ok = sum(1 for r in rids
                 if self.tpot[r] <= self.tpot_budget.get(r, np.inf) + 1e-12)
        return ok / len(rids)

    def tpot_attainment_by_pool(self) -> Dict[str, float]:
        pools = sorted({self.pool_of[r] for r in self._tpot_rids()})
        return {p: self.tpot_attainment(pool=p) for p in pools}

    def tpot_attainment_by_class(self) -> Dict[str, float]:
        classes = sorted({self.slo_class.get(r, "standard")
                          for r in self._tpot_rids()})
        return {c: self.tpot_attainment(slo_class=c) for c in classes}

    def slo_attainment_by_class(self) -> Dict[str, float]:
        """All-arrivals attainment per SLO class (shed counts as a miss
        against its class)."""
        by: Dict[str, List[int]] = {}
        for r in self._rids():
            by.setdefault(self.slo_class.get(r, "standard"), []).append(r)
        shed_by: Dict[str, int] = {}
        for r in self._shed_rids():
            shed_by[self.shed[r]] = shed_by.get(self.shed[r], 0) + 1
        classes = sorted(set(by) | set(shed_by))
        return {c: sum(1 for r in by.get(c, ())
                       if self.ttft[r] <= self.deadline[r] + 1e-9)
                / (len(by.get(c, ())) + shed_by.get(c, 0))
                for c in classes}

    def admitted_attainment_by_class(self) -> Dict[str, float]:
        """Admitted-only attainment per SLO class."""
        by: Dict[str, List[int]] = {}
        for r in self._rids():
            by.setdefault(self.slo_class.get(r, "standard"), []).append(r)
        return {c: sum(1 for r in rids
                       if self.ttft[r] <= self.deadline[r] + 1e-9) / len(rids)
                for c, rids in sorted(by.items())}

    def tpot_stats(self) -> Dict[str, float]:
        v = np.array([self.tpot[r] for r in self._tpot_rids()])
        if v.size == 0:
            return {}
        return {"mean": float(v.mean()), "p50": float(np.percentile(v, 50)),
                "p99": float(np.percentile(v, 99)),
                "tbt_max": float(max((g for r, g in self.tbt_max.items()
                                      if r >= 0), default=0.0))}

    # ------------------------------------------------------- KV-reuse plane
    def kv_hit_rate(self) -> float:
        """Reused tokens / prompt tokens over measured (non-warmup)
        requests — the live-store hit rate the sweeps report."""
        tot = sum(self.kv_prompt_tokens.values())
        if not tot:
            return float("nan")
        return sum(self.kv_hit_tokens.values()) / tot

    def kv_tier_mix(self) -> Dict[str, float]:
        """Share of hit tokens served per storage tier."""
        tot = sum(self.kv_tier_tokens.values())
        if not tot:
            return {}
        return {t: v / tot for t, v in sorted(self.kv_tier_tokens.items())}

    def summary(self) -> Dict[str, float]:
        s = {"policy": self.policy, "n": len(self._rids()),
             "slo_attainment": self.slo_attainment(),
             "norm_ttft": self.normalized_ttft(),
             "cct_slowdown": self.cct_slowdown(),
             "pos_earliness": self.positive_earliness(),
             "pruned": self.pruned}
        s.update({f"ttft_{k}": v for k, v in self.ttft_stats().items()})
        if self.tpot:            # decode plane attached: report TPOT side
            s["tpot_attainment"] = self.tpot_attainment()
            s["tpot_by_pool"] = self.tpot_attainment_by_pool()
            s.update({f"tpot_{k}": v for k, v in self.tpot_stats().items()})
            s.update({f"decode_{k}": v for k, v in self.decode_stats.items()})
        if self.kv_prompt_tokens:   # KV-reuse plane attached
            s["kv_hit_rate"] = self.kv_hit_rate()
            s["kv_tier_mix"] = self.kv_tier_mix()
            s.update({f"kv_{k}": v for k, v in self.kvstore_stats.items()})
        if self.shed or self.n_deferred:   # admission control acted
            s["n_shed"] = len(self._shed_rids())
            s["n_deferred"] = self.n_deferred
            s["admitted_attainment"] = self.admitted_attainment()
            s["attainment_by_class"] = self.slo_attainment_by_class()
            s["admitted_by_class"] = self.admitted_attainment_by_class()
        if self.stage_log_dropped:   # bounded stage trace overflowed
            s["stage_log_dropped"] = self.stage_log_dropped
        return s
