"""ClusterSim — event-driven disaggregated-serving simulator (Vidur+flowsim).

One event queue carries request arrivals, per-(super)layer computation
completions, fluid-model flow completions and periodic promotion ticks, so
computation and network interact exactly as in the paper's methodology
(§6.1: "both computation events and network events are processed within a
single event queue").

A *prefill unit* hosts one model replica on ``gpus_per_unit`` endpoints with
one of three parallelism modes:

  * ``ep`` — attention is request-level data parallel across EP ranks; every
    MoE layer issues a dispatch+combine all-to-all (Stage 2), NIC-aggregated
    into one fat flow per (source endpoint, destination server);
  * ``sp`` — the whole batch is sequence-sharded; every layer ring-exchanges
    KV shards between neighbouring SP ranks (Stage 2), striped across each
    rank's TP endpoints;
  * ``tp`` — collectives stay on the scale-up fabric (§7: TP does not contend
    for inter-node bandwidth); Stages 1/3 still traverse the network.

Per batch and super-layer g the unit: (wait for Stage-1 flows targeting
groups <= g) -> compute C_g -> emit Stage-3 P2D flows for g (+ Stage-2
coflow, which must finish before group g+1 computes). Reused prefix tokens
skip computation but their KV must arrive (Stage 1) before the consuming
layer group runs — late arrivals stall the GPU, which is precisely the
contention -> TTFT coupling the paper measures.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..configs.base import ArchConfig
from ..core import (
    BatchLoad, Coflow, Flow, FlowState, MFSScheduler, Policy, Stage,
    inter_request_schedule, new_flow_id,
)
from ..netsim import EventQueue, FatTree, FluidNet, SingleToR, Topology
from .hw import HW, A100
from .metrics import CoflowRecord, SimMetrics
from .trace import Request

__all__ = ["ParallelismSpec", "ClusterSpec", "ClusterSim"]


@dataclass(frozen=True)
class ParallelismSpec:
    mode: str = "ep"        # ep | sp | tp
    tp: int = 1
    ep: int = 1
    sp: int = 1

    @property
    def gpus(self) -> int:
        return self.tp * max(self.ep, 1) * max(self.sp, 1)


@dataclass
class ClusterSpec:
    model: ArchConfig
    par: ParallelismSpec
    hw: HW = A100
    n_units: int = 2
    gpus_per_server: int = 4
    topology: str = "tor"              # tor | fattree
    decode_ratio: float = 1.0          # decode endpoints per prefill endpoint
    max_batch_tokens: int = 8192
    slo_scale: float = 3.0
    slo_mode: str = "fixed"            # fixed: slo_scale x mean low-load TTFT
    #                                    per-request: slo_scale x own ideal
    kv_dtype_bytes: int = 2
    act_dtype_bytes: int = 2
    layer_groups: int = 0              # 0 = auto (clamp L into [8, 16])
    tick_interval: float = 2e-3
    drop_budget: int = 32              # Algorithm 1 global drop budget B
    hosts_per_rack: int = 8

    def n_groups(self) -> int:
        if self.layer_groups:
            return self.layer_groups
        L = self.model.n_layers
        return max(1, min(L, 16 if L >= 16 else L))


@dataclass
class _BatchState:
    bid: int
    unit: int
    requests: List[Request]
    group_time: List[float]            # compute seconds per super-layer group
    started: float = 0.0
    cur_group: int = 0
    phase: str = "wait_s1"             # wait_s1 | compute | wait_coll | drain
    stall_begin: Optional[float] = None
    s1_pending: Dict[int, Set[int]] = field(default_factory=dict)  # group -> fids
    coll: Optional[Coflow] = None
    coll_started: float = 0.0
    p2d_pending: Dict[int, Set[int]] = field(default_factory=dict)  # rid -> fids
    recompute_extra: float = 0.0       # legacy aggregate (kept for estimates)
    recomputed: Set[Tuple[int, int]] = field(default_factory=set)   # (rid, group)
    compute_done_at: Optional[float] = None


class _View:
    """SchedView implementation handed to policies."""

    def __init__(self, sim: "ClusterSim"):
        self.sim = sim

    @property
    def now(self) -> float:
        return self.sim.net.now

    def bottleneck(self, flow: Flow) -> Tuple[float, float]:
        return self.sim.net.bottleneck(flow)

    def mlu_inputs(self, flow: Flow, level: int) -> Tuple[float, float]:
        # Protected = traffic strictly more urgent than this flow would be at
        # ``level``: anything at a higher level, plus early-stage flows at the
        # same level (band precedence, §4.5). Early-stage flows at *lower*
        # levels would be preempted by the promotion, so they don't raise rho.
        def protected(other: Flow) -> bool:
            k = other.priority_key
            return k[0] < level or (k[0] == level and len(k) >= 2 and k[1] == 0)
        return self.sim.net.bottleneck_protected(flow, protected)

    def l_curr(self, unit: int) -> int:
        b = self.sim.active_batch.get(unit)
        return b.cur_group if b else 0

    def computing(self, rid: int) -> bool:
        b = self.sim.batch_of_request.get(rid)
        return bool(b and b.compute_done_at is None)

    def red_rank(self, rid: int) -> int:
        return self.sim.red_ranks.get(rid, 0)

    def downstream_estimate(self, flow: Flow) -> float:
        """Time until the data carried by ``flow`` is actually consumed."""
        b = self.sim.batch_of_request.get(flow.rid)
        if b is None or b.compute_done_at is not None:
            return 0.0
        if flow.stage == Stage.COLLECTIVE:
            return 0.0                      # blocks the very next step
        if flow.stage == Stage.KV_REUSE:    # needed when its group starts
            return sum(b.group_time[b.cur_group:flow.target_layer])
        rem = len(b.group_time) - b.cur_group
        return sum(b.group_time[b.cur_group:]) + b.recompute_extra * rem


class ClusterSim:
    def __init__(self, spec: ClusterSpec, policy: Policy, seed: int = 0,
                 contention_free: bool = False):
        self.spec = spec
        self.policy = policy
        policy.reset()
        self.rng = np.random.default_rng(seed)
        self.contention_free = contention_free

        par = spec.par
        n_prefill = spec.n_units * par.gpus
        n_decode = int(math.ceil(n_prefill * spec.decode_ratio))
        total = n_prefill + n_decode
        if spec.topology == "tor":
            self.topo: Topology = SingleToR(
                total, nic_bw=spec.hw.nic_bw,
                gpus_per_server=spec.gpus_per_server,
                scaleup_bw=spec.hw.scaleup_bw)
        else:
            racks = max(1, math.ceil(total / spec.hosts_per_rack))
            self.topo = FatTree(racks, spec.hosts_per_rack,
                                nic_bw=spec.hw.nic_bw,
                                gpus_per_server=spec.gpus_per_server,
                                scaleup_bw=spec.hw.scaleup_bw)
        self.net = FluidNet(self.topo)
        self.evq = EventQueue()
        self.view = _View(self)

        self.unit_eps: List[List[int]] = [
            list(range(u * par.gpus, (u + 1) * par.gpus))
            for u in range(spec.n_units)]
        self.decode_eps = list(range(n_prefill, total))

        # --- per-unit serving state ---
        self.queues: List[List[Request]] = [[] for _ in range(spec.n_units)]
        self.active_batch: Dict[int, _BatchState] = {}
        self.batch_of_request: Dict[int, _BatchState] = {}
        self.backlog_tokens = [0.0] * spec.n_units
        self._bid = itertools.count()
        self._decode_rr = 0

        # --- scheduler state ---
        self.flows: Dict[int, Flow] = {}
        self.red_ranks: Dict[int, int] = {}
        self.pruned_rids: Set[int] = set()
        self.metrics = SimMetrics(policy=policy.name)
        self._epoch = 0
        self._slo_budget: Optional[float] = None
        self._tick_armed = False
        self._G = spec.n_groups()
        self._layers_per_group = self._lpg()
        self._t_first_decode = self._first_decode_time()

    # ------------------------------------------------------------ model math
    def _lpg(self) -> List[List[int]]:
        L, G = self.spec.model.n_layers, self._G
        bounds = np.linspace(0, L, G + 1).astype(int)
        return [list(range(bounds[g], bounds[g + 1])) for g in range(G)]

    def _kv_bytes_group(self, g: int) -> float:
        m, b = self.spec.model, self.spec.kv_dtype_bytes
        return sum(m.kv_bytes_per_token_layer(b, l) for l in self._layers_per_group[g])

    def _group_compute_time(self, requests: Sequence[Request], g: int) -> float:
        """Analytic compute latency of one super-layer group for a batch."""
        m, hw, par = self.spec.model, self.spec.hw, self.spec.par
        L = m.n_layers
        flops = 0.0
        for r in requests:
            new = max(1, r.prompt_len - r.reuse_len)
            ctx = r.reuse_len + new / 2.0
            flops += new * m.flops_per_token(ctx) / L * len(self._layers_per_group[g])
        return flops / (par.gpus * hw.flops * hw.mfu)

    def _first_decode_time(self) -> float:
        m, hw, par = self.spec.model, self.spec.hw, self.spec.par
        return 2.0 * m.params_active() / (par.gpus * hw.flops * hw.mfu * 0.3)

    def _stage2_volume_per_ep(self, tokens: float, g: int) -> float:
        """Bytes leaving ONE endpoint for group g's collectives (network)."""
        m, par, d = self.spec.model, self.spec.par, self.spec.act_dtype_bytes
        nlayers = len(self._layers_per_group[g])
        if par.mode == "ep":
            moe_layers = sum(1 for l in self._layers_per_group[g] if m.is_moe_layer(l))
            per_layer = 2.0 * (tokens / par.ep) * m.top_k * m.d_model * d
            return per_layer * moe_layers    # cross-fabric share applied by caller
        if par.mode == "sp":
            vol = 0.0
            for l in self._layers_per_group[g]:
                kvb = m.kv_bytes_per_token_layer(self.spec.act_dtype_bytes, l)
                vol += (par.sp - 1) * (tokens / par.sp) * kvb
            return vol / par.tp              # striped across TP endpoints
        # tp: 2 all-reduce per layer, ring cost, scale-up only
        return 2.0 * 2.0 * (par.tp - 1) / par.tp * tokens * m.d_model * d * nlayers / par.tp

    # ----------------------------------------------------------- ideal TTFT
    def _ideal_ttft(self, r: Request) -> float:
        """Low-load (contention-free) TTFT for SLO calibration (§6.1)."""
        spec, par, hw = self.spec, self.spec.par, self.spec.hw
        total = 0.0
        for g in range(self._G):
            total += self._group_compute_time([r], g)
            if par.mode == "ep":
                eps_per_server = min(spec.gpus_per_server, par.gpus)
                cross = 1.0 - eps_per_server / max(par.gpus, 1)
                v = self._stage2_volume_per_ep(r.prompt_len - r.reuse_len, g) * cross
                total += v / hw.nic_bw
            elif par.mode == "sp":
                v = self._stage2_volume_per_ep(r.prompt_len, g)
                total += v / hw.nic_bw
        # stage-1 of group 0 cannot be hidden even without contention
        if r.reuse_len:
            total += r.reuse_len * self._kv_bytes_group(0) / hw.nic_bw
        # last group's P2D is never overlapped with compute
        total += r.prompt_len * self._kv_bytes_group(self._G - 1) / hw.nic_bw
        return total + self._t_first_decode

    # ------------------------------------------------------------- plumbing
    def _submit(self, flow: Flow) -> None:
        flow.created = self.net.now
        self.flows[flow.fid] = flow
        self.net.add(flow)
        if flow.rid in self.pruned_rids and flow.stage != Stage.COLLECTIVE:
            flow.state = FlowState.PRUNED
        self.policy.on_flow_submitted(flow, self.view)

    def _resched(self, trigger: Tuple = ("event",)) -> None:
        active = list(self.net.flows.values())
        self.policy.assign(active, self.view, trigger)
        if self.contention_free:
            for f in active:
                route = self.net.routes[f.fid]
                f.rate = min((self.topo.capacity[l] for l in route), default=2e12)
            self.net._link_rate = {}
        else:
            self.net.reallocate()
        self._epoch += 1
        nxt = self.net.next_completion()
        if nxt is not None:
            self.evq.push(nxt[0], "net", None, epoch=self._epoch)

    # ---------------------------------------------------------- unit driver
    def _owner_unit(self, prefix_id: int) -> int:
        return prefix_id % self.spec.n_units

    def _route_request(self, r: Request) -> int:
        owner = self._owner_unit(r.prefix_id)
        best, best_score = 0, -math.inf
        for u in range(self.spec.n_units):
            aff = r.reuse_len if u == owner else 0
            score = 2.0 * aff - self.backlog_tokens[u]
            if score > best_score:
                best, best_score = u, score
        return best

    def _maybe_start_batch(self, u: int) -> None:
        if u in self.active_batch or not self.queues[u]:
            return
        spec = self.spec
        batch: List[Request] = []
        tokens = 0
        while self.queues[u]:
            r = self.queues[u][0]
            if batch and tokens + r.prompt_len > spec.max_batch_tokens:
                break
            batch.append(self.queues[u].pop(0))
            tokens += r.prompt_len
        bs = _BatchState(
            bid=next(self._bid), unit=u, requests=batch,
            group_time=[self._group_compute_time(batch, g) for g in range(self._G)],
            started=self.net.now)
        self.active_batch[u] = bs
        for i, r in enumerate(batch):
            r.batch = bs.bid
            self.batch_of_request[r.rid] = bs
            bs.p2d_pending[r.rid] = set()
        self._emit_stage1(bs)
        if self.policy.uses_inter_request:
            self._run_inter_request()
        self._try_start_group(bs)
        self._resched(("submit",))

    def _rank_endpoint(self, bs: _BatchState, r: Request, g: int) -> int:
        """Endpoint that owns request ``r``'s activations for group g."""
        eps = self.unit_eps[bs.unit]
        par = self.spec.par
        if par.mode == "ep":
            idx = bs.requests.index(r) % len(eps)
            return eps[idx]
        # sp / tp: stripe across endpoints by group for multi-NIC egress
        return eps[g % len(eps)]

    def _emit_stage1(self, bs: _BatchState) -> None:
        spec = self.spec
        for r in bs.requests:
            if r.reuse_len <= 0:
                continue
            owner = self._owner_unit(r.prefix_id)
            src_eps = self.unit_eps[owner]
            for g in range(self._G):
                size = r.reuse_len * self._kv_bytes_group(g)
                if size <= 0:
                    continue
                if spec.par.mode == "sp":
                    dsts = [self.unit_eps[bs.unit][(g + i) % len(self.unit_eps[bs.unit])]
                            for i in range(spec.par.sp)]
                    sizes = [size / spec.par.sp] * spec.par.sp
                else:
                    dsts = [self._rank_endpoint(bs, r, g)]
                    sizes = [size]
                for dst, sz in zip(dsts, sizes):
                    f = Flow(new_flow_id(), r.rid, bs.unit, Stage.KV_REUSE, sz,
                             src=src_eps[g % len(src_eps)], dst=dst,
                             target_layer=g, n_layers=self._G)
                    bs.s1_pending.setdefault(g, set()).add(f.fid)
                    self._submit(f)

    def _try_start_group(self, bs: _BatchState) -> None:
        g = bs.cur_group
        blocking = set()
        for gg in range(g + 1):
            for fid in bs.s1_pending.get(gg, ()):  # still outstanding
                fl = self.flows[fid]
                # scavenged (pruned) Stage-1 flows do NOT block the batch:
                # their reuse is abandoned and recomputed instead (§5:
                # "requests can be pruned ... to suppress communication")
                if fl.state not in (FlowState.DONE, FlowState.PRUNED):
                    blocking.add(fid)
        if blocking:
            bs.phase = "wait_s1"
            if bs.stall_begin is None:
                bs.stall_begin = self.net.now
            return
        if bs.stall_begin is not None:
            dt = self.net.now - bs.stall_begin
            for r in bs.requests:
                r.stalls += dt
            bs.stall_begin = None
        bs.phase = "compute"
        dur = bs.group_time[g] + self._recompute_penalty(bs, g)
        self.evq.push(self.net.now + dur, "compute", (bs.bid, bs.unit, g))

    def _recompute_penalty(self, bs: _BatchState, g: int) -> float:
        """Compute time to re-derive reused KV that pruning left undelivered.

        Charged once per (request, group), proportional to the undelivered
        fraction; the stale flow is cancelled to free its bandwidth."""
        m, hw, par = self.spec.model, self.spec.hw, self.spec.par
        extra = 0.0
        for gg in range(g + 1):
            for fid in list(bs.s1_pending.get(gg, ())):
                fl = self.flows[fid]
                if fl.state != FlowState.PRUNED or fl.remaining <= 0:
                    continue
                if (fl.rid, gg) in bs.recomputed:
                    continue
                bs.recomputed.add((fl.rid, gg))
                r = next(rr for rr in bs.requests if rr.rid == fl.rid)
                frac = fl.remaining / max(fl.size, 1e-9)
                nlayers = len(self._layers_per_group[gg])
                flops = frac * r.reuse_len * m.flops_per_token(r.reuse_len / 2) \
                    / m.n_layers * nlayers
                extra += flops / (par.gpus * hw.flops * hw.mfu)
                bs.s1_pending[gg].discard(fid)
                if fid in self.net.flows:
                    self.net.remove(fl)
                self.policy.on_flow_completed(fl, self.view)
        return extra

    def _emit_stage2(self, bs: _BatchState) -> Optional[Coflow]:
        spec, par = self.spec, self.spec.par
        g = bs.cur_group
        tokens = sum(max(1, r.prompt_len - r.reuse_len) for r in bs.requests)
        eps = self.unit_eps[bs.unit]
        co = Coflow(cid=new_flow_id(), rid=bs.requests[0].rid, unit=bs.unit,
                    stage=Stage.COLLECTIVE, layer=g)
        if par.mode == "ep":
            vol_per_ep = self._stage2_volume_per_ep(tokens, g)
            if vol_per_ep <= 0:
                return None
            servers: Dict[int, List[int]] = {}
            for e in eps:
                servers.setdefault(self.topo.server_of(e), []).append(e)
            for e in eps:
                my_srv = self.topo.server_of(e)
                for srv, members in servers.items():
                    if srv == my_srv:
                        continue
                    dst = members[eps.index(e) % len(members)]
                    sz = vol_per_ep * len(members) / len(eps)
                    fl = Flow(new_flow_id(), co.rid, bs.unit, Stage.COLLECTIVE,
                              sz, src=e, dst=dst, target_layer=g,
                              n_layers=self._G, )
                    fl.coflow = co.cid
                    co.flows.append(fl)
        elif par.mode == "sp":
            vol = self._stage2_volume_per_ep(
                sum(r.prompt_len for r in bs.requests), g)
            if vol <= 0:
                return None
            sp, tp = par.sp, par.tp
            for rank in range(sp):
                nxt_rank = (rank + 1) % sp
                for t in range(tp):
                    src = eps[rank * tp + t]
                    dst = eps[nxt_rank * tp + t]
                    fl = Flow(new_flow_id(), co.rid, bs.unit, Stage.COLLECTIVE,
                              vol, src=src, dst=dst, target_layer=g,
                              n_layers=self._G)
                    fl.coflow = co.cid
                    co.flows.append(fl)
        else:   # tp: scale-up all-reduce flows between neighbouring endpoints
            vol = self._stage2_volume_per_ep(tokens, g)
            if vol <= 0:
                return None
            for i, e in enumerate(eps):
                dst = eps[(i + 1) % len(eps)]
                if dst == e:
                    continue
                fl = Flow(new_flow_id(), co.rid, bs.unit, Stage.COLLECTIVE,
                          vol, src=e, dst=dst, target_layer=g, n_layers=self._G)
                fl.coflow = co.cid
                co.flows.append(fl)
        if not co.flows:
            return None
        co.started = self.net.now
        for fl in co.flows:
            self._submit(fl)
        return co

    def _emit_stage3(self, bs: _BatchState, g: int) -> None:
        kvb = self._kv_bytes_group(g)
        state_b = self.spec.model.state_bytes(self.spec.kv_dtype_bytes) / self._G
        for r in bs.requests:
            size = r.prompt_len * kvb + state_b
            if size <= 0:
                continue
            dst = self.decode_eps[(r.rid + g) % len(self.decode_eps)] \
                if self.decode_eps else self._rank_endpoint(bs, r, g)
            # Flow-level deadline = TTFT deadline minus remaining downstream
            # work (the first decode step) — the paper's "global TTFT
            # materialises into an explicit flow-level bound" (§3.2).
            f = Flow(new_flow_id(), r.rid, bs.unit, Stage.P2D, size,
                     src=self._rank_endpoint(bs, r, g), dst=dst,
                     target_layer=g, n_layers=self._G,
                     deadline=r.deadline - self._t_first_decode)
            bs.p2d_pending[r.rid].add(f.fid)
            self._submit(f)

    # --------------------------------------------------------- event handlers
    def _on_arrival(self, r: Request) -> None:
        r.ideal_ttft = self._ideal_ttft(r)
        if self.spec.slo_mode == "fixed" and self._slo_budget is not None:
            # §6.1: one workload-level SLO threshold = slo_scale x the mean
            # low-load TTFT — long-prompt requests are inherently tight.
            r.deadline = r.arrival + self._slo_budget
        else:
            r.deadline = r.arrival + self.spec.slo_scale * r.ideal_ttft
        u = self._route_request(r)
        r.unit = u
        self.queues[u].append(r)
        self.backlog_tokens[u] += r.prompt_len
        self.metrics.arrival[r.rid] = r.arrival
        # metrics store the *relative* TTFT budget (deadline - arrival) so it
        # compares directly against the recorded (relative) TTFT
        self.metrics.deadline[r.rid] = r.deadline - r.arrival
        self.metrics.ideal_ttft[r.rid] = r.ideal_ttft
        self._maybe_start_batch(u)

    def _on_compute_done(self, bid: int, unit: int, g: int) -> None:
        bs = self.active_batch.get(unit)
        if bs is None or bs.bid != bid or bs.cur_group != g or bs.phase != "compute":
            return   # stale
        self._emit_stage3(bs, g)
        co = self._emit_stage2(bs)
        if co is not None:
            bs.coll = co
            bs.coll_started = self.net.now
            bs.phase = "wait_coll"
            self._resched(("layer", unit))
            return
        self._advance_group(bs)
        self._resched(("layer", unit))

    def _advance_group(self, bs: _BatchState) -> None:
        bs.cur_group += 1
        bs.coll = None
        if bs.cur_group >= self._G:
            bs.compute_done_at = self.net.now
            for r in bs.requests:
                r.prefill_done = self.net.now
                self._maybe_finish_request(r, bs)
            bs.phase = "drain"
            del self.active_batch[bs.unit]
            self.backlog_tokens[bs.unit] = max(
                0.0, self.backlog_tokens[bs.unit]
                - sum(r.prompt_len for r in bs.requests))
            self._arm_tick()
            if self.policy.uses_inter_request:
                self._run_inter_request()
            self._maybe_start_batch(bs.unit)
        else:
            self._try_start_group(bs)

    def _maybe_finish_request(self, r: Request, bs: _BatchState) -> None:
        if r.ttft is not None or r.prefill_done is None:
            return
        pending = bs.p2d_pending.get(r.rid, set())
        done_p2d = all(self.flows[f].state == FlowState.DONE for f in pending) \
            and len(pending) == self._G
        if done_p2d:
            last = max((self.flows[f].finished or 0.0) for f in pending) \
                if pending else r.prefill_done
            r.ttft = max(r.prefill_done, last) - r.arrival + self._t_first_decode
            self.metrics.ttft[r.rid] = r.ttft
            self.metrics.stall_time[r.rid] = r.stalls
            self.batch_of_request.pop(r.rid, None)

    def _on_flow_done(self, f: Flow) -> None:
        self.policy.on_flow_completed(f, self.view)
        bs = self.batch_of_request.get(f.rid)
        if f.stage == Stage.KV_REUSE:
            if bs is not None:
                bs.s1_pending.get(f.target_layer, set()).discard(f.fid)
                if bs.phase == "wait_s1":
                    self._try_start_group(bs)
        elif f.stage == Stage.COLLECTIVE:
            if bs is not None and bs.coll is not None and f.coflow == bs.coll.cid:
                if bs.coll.done():
                    bs.coll.finished = self.net.now
                    ideal = self._coflow_ideal(bs.coll)
                    self.metrics.coflows.append(CoflowRecord(
                        bs.coll.cid, bs.unit, bs.coll.layer, bs.coll.started,
                        self.net.now, bs.coll.size, ideal))
                    if bs.phase == "wait_coll":
                        self._advance_group(bs)
        else:  # P2D
            if bs is not None:
                self._maybe_finish_request(
                    next(r for r in bs.requests if r.rid == f.rid), bs)

    def _coflow_ideal(self, co: Coflow) -> float:
        worst = 0.0
        for f in co.flows:
            route = self.topo.route(f.src, f.dst, f.fid)
            cap = min((self.topo.capacity[l] for l in route), default=2e12)
            worst = max(worst, f.size / cap)
        return worst

    def _arm_tick(self) -> None:
        if not self._tick_armed:
            self._tick_armed = True
            self.evq.push(self.net.now + self.spec.tick_interval, "tick", None)

    def _on_tick(self) -> None:
        self._tick_armed = False
        post = [f for f in self.net.flows.values()
                if f.stage == Stage.P2D and not self.view.computing(f.rid)]
        if post:
            self._resched(("tick",))
            self._arm_tick()

    # ------------------------------------------------- Algorithm 1 coupling
    def _run_inter_request(self) -> None:
        batches: List[BatchLoad] = []
        n_ports = 2 * self.topo.n_nodes       # NIC up/down links
        for bs in self.active_batch.values():
            loads: Dict[int, np.ndarray] = {}
            deadlines: Dict[int, float] = {}
            for r in bs.requests:
                v = np.zeros(n_ports)
                for fid_set in list(bs.s1_pending.values()):
                    for fid in fid_set:
                        fl = self.flows[fid]
                        if fl.rid != r.rid or fl.state == FlowState.DONE:
                            continue
                        for lid in self.topo.route(fl.src, fl.dst, fl.fid):
                            if lid < n_ports:
                                v[lid] += fl.remaining
                rem_kv = r.prompt_len * sum(
                    self._kv_bytes_group(g) for g in range(bs.cur_group, self._G))
                ep = self._rank_endpoint(bs, r, bs.cur_group)
                v[2 * ep] += rem_kv           # future P2D leaves via this NIC
                loads[r.rid] = v
                deadlines[r.rid] = r.deadline
            rem_groups = len(bs.group_time) - bs.cur_group
            comp = sum(bs.group_time[bs.cur_group:]) + bs.recompute_extra * rem_groups
            batches.append(BatchLoad(bs.bid, loads, deadlines, comp))
        if not batches:
            return
        port_bw = np.array([self.topo.capacity[l] for l in range(n_ports)])
        # Algorithm 1 takes a GLOBAL total drop budget; spend it across the
        # whole run so overload control cannot death-spiral the cluster.
        budget_left = max(0, self.spec.drop_budget - self.metrics.pruned)
        sched = inter_request_schedule(batches, port_bw, now=self.net.now,
                                       drop_budget=budget_left)
        rank_of_batch = {bid: i for i, bid in enumerate(sched.order)}
        newly_pruned = {rid for (_, rid) in sched.pruned}
        for bs in self.active_batch.values():
            for r in bs.requests:
                self.red_ranks[r.rid] = rank_of_batch.get(bs.bid, 0)
        # soft enforcement: demote pruned requests' flows, abandon their reuse
        for bs in self.active_batch.values():
            for r in bs.requests:
                if r.rid in newly_pruned and r.rid not in self.pruned_rids:
                    self.pruned_rids.add(r.rid)
                    self.metrics.pruned += 1
                    self._apply_prune(bs, r)
        # re-admission: requests no longer in the pruned set
        for rid in list(self.pruned_rids):
            if rid not in newly_pruned and rid in self.batch_of_request:
                self.pruned_rids.discard(rid)
                for f in self.net.flows.values():
                    if f.rid == rid and f.state == FlowState.PRUNED:
                        f.state = FlowState.ACTIVE
                        if isinstance(self.policy, MFSScheduler):
                            self.policy.readmit(f, self.view)

    def _apply_prune(self, bs: _BatchState, r: Request) -> None:
        """Soft enforcement (Appendix B Step 3): demote the request's
        KV-reuse and P2D flows to the scavenger class. Scavenged Stage-1
        flows no longer block the batch; whatever has not arrived by the time
        its layer group runs is recomputed (paid in _recompute_penalty)."""
        for f in list(self.net.flows.values()):
            if f.rid != r.rid or f.stage == Stage.COLLECTIVE:
                continue
            f.state = FlowState.PRUNED
            if isinstance(self.policy, MFSScheduler):
                self.policy.prune(f)
        if bs.phase == "wait_s1":
            self._try_start_group(bs)

    # ------------------------------------------------------------------ run
    def run(self, requests: Sequence[Request], max_events: int = 5_000_000) -> SimMetrics:
        import copy
        if self.spec.slo_mode == "fixed" and requests:
            low_load = float(np.mean([self._ideal_ttft(r) for r in requests]))
            self._slo_budget = self.spec.slo_scale * low_load
        else:
            self._slo_budget = None
        for r in requests:
            # Requests carry runtime state; copy so one trace can be replayed
            # across policies/seeds without cross-contamination.
            self.evq.push(r.arrival, "arr", copy.copy(r))
        n_ev = 0
        while self.evq and n_ev < max_events:
            item = self.evq.pop()
            if item is None:
                break
            t, kind, payload, epoch = item
            n_ev += 1
            done = self.net.advance(t)
            for f in done:
                self._on_flow_done(f)
            if kind == "arr":
                self._on_arrival(payload)
                self._resched(("submit",))
            elif kind == "compute":
                self._on_compute_done(*payload)
            elif kind == "tick":
                self._on_tick()
            elif kind == "net":
                if done:
                    self._resched(("event",))
                elif epoch == self._epoch:
                    # numerically-stalled prediction; force refresh
                    self._resched(("event",))
        return self.metrics
