"""ClusterSim — event-driven disaggregated-serving simulator (Vidur+flowsim).

Thin host over the shared MsFlow runtime (``repro.core.runtime``): the
event loop, stage emission (per-layer-group Stage-1 KV-reuse flows, Stage-2
ep/sp/tp coflows, Stage-3 P2D with deadline derivation), SLO calibration
and the SchedView handed to policies all live in the runtime and are shared
verbatim with the real-JAX serving path (``repro.serving.disagg``). This
module contributes only what is simulation-specific:

  * cluster sizing — units, parallelism spec, ToR / fat-tree topology,
    decode-endpoint pool (optionally partitioned into named multi-decode
    pools driven by a ``DecodeSpec`` — the decode plane with per-token
    progress, TPOT metrics and D2D rebalancing flows);
  * KV-affinity routing over synthetic prefix ids (Zipf traces), which
    also pins each request to its decode pool;
  * metrics collection into :class:`SimMetrics`.

A *prefill unit* hosts one model replica on ``gpus_per_unit`` endpoints with
one of three parallelism modes (``ep`` — request-level DP attention + MoE
all-to-all; ``sp`` — sequence-sharded ring KV exchange; ``tp`` — scale-up
collectives only, §7), exactly as described in the stage-emission layer.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from typing import Optional

from ..configs.base import ArchConfig
from ..core import Coflow, Policy
from ..core.decode import (DecodePlane, DecodeSession, DecodeSpec,
                           partition_pools)
from ..core.kvstore import KVStore, KVStoreSpec, chain_keys
from ..core.router import AdmissionSpec, RouterSpec
from ..core.runtime import MsFlowRuntime, RuntimeHost
from ..core.stages import (BatchState, ChunkSpec, GroupPlan, ParallelismSpec,
                           PrefillItem, StageEmitter, StageProfile)
from ..core.monitor import Monitor, MonitorSpec
from ..core.telemetry import Telemetry, TelemetrySpec
from ..netsim import EventQueue, FatTree, FluidNet, SingleToR, Topology
from .hw import HW, A100
from .metrics import CoflowRecord, SimMetrics
from .trace import Request

__all__ = ["ParallelismSpec", "ClusterSpec", "ClusterSim", "ChunkSpec",
           "DecodeSpec", "KVStoreSpec", "RouterSpec", "AdmissionSpec",
           "TelemetrySpec", "MonitorSpec"]


@dataclass
class ClusterSpec:
    model: ArchConfig
    par: ParallelismSpec
    hw: HW = A100
    n_units: int = 2
    gpus_per_server: int = 4
    topology: str = "tor"              # tor | fattree
    decode_ratio: float = 1.0          # decode endpoints per prefill endpoint
    max_batch_tokens: int = 8192
    slo_scale: float = 3.0
    slo_mode: str = "fixed"            # fixed: slo_scale x mean low-load TTFT
    #                                    per-request: slo_scale x own ideal
    kv_dtype_bytes: int = 2
    act_dtype_bytes: int = 2
    layer_groups: int = 0              # 0 = auto (clamp L into [8, 16])
    tick_interval: float = 2e-3
    drop_budget: int = 32              # Algorithm 1 global drop budget B
    hosts_per_rack: int = 8
    # decode plane (None = legacy behavior: requests end at the first token
    # and the sim is bit-identical to pre-decode-plane runs)
    decode: Optional[DecodeSpec] = None
    # KV-reuse plane (None = legacy behavior: the trace's pre-sampled
    # reuse_len + static prefix_id%n_units owner, bit-identical to
    # pre-kvstore runs). With a spec attached, hits resolve at route time
    # against the live tiered store, S1 becomes multi-source, and prefill
    # completion emits Stage-WB writeback flows.
    kvstore: Optional[KVStoreSpec] = None
    # chunked prefill (None = legacy group-granular schedule, bit-identical
    # to pre-chunking runs). With a spec attached every super-layer group's
    # compute is split into token-budgeted chunks and S1/S2/S3 are emitted
    # per chunk (chunk-c P2D overlaps chunk-c+1 compute; RLI tightens to
    # remaining-chunk compute). ``ChunkSpec(chunk_tokens=0)`` is also legacy.
    chunk: Optional[ChunkSpec] = None
    # router + admission plane (None = the default ``kv_affinity`` policy
    # with admission off, which reproduces the historical placement
    # bit-for-bit). A spec picks the placement policy from the router
    # registry and may attach overload-triggered admission control.
    router: Optional[RouterSpec] = None
    # telemetry plane (None = off, the legacy zero-overhead path — stage
    # traces, TTFTs and benchmark sections stay byte-identical). With a spec
    # attached the runtime records request-lifecycle spans, the RMLQ/
    # Algorithm-1 decision audit and per-link contention telemetry; read
    # them via ``ClusterSim.telemetry`` (ttft_breakdown / slo_miss_report /
    # link_report / to_chrome_trace).
    telemetry: Optional[TelemetrySpec] = None
    # online monitor plane (None = off, zero-overhead like telemetry). With
    # a spec attached the runtime streams event-clock estimators — rolling
    # link utilization/contended share, slack-loss rates, TTFT/TPOT
    # quantile sketches — onto a SignalBus that overload detectors and
    # router policies read live; see ``ClusterSim.monitor``.
    monitor: Optional[MonitorSpec] = None

    def chunk_tokens(self) -> int:
        return self.chunk.chunk_tokens if self.chunk is not None else 0

    def n_groups(self) -> int:
        if self.layer_groups:
            return self.layer_groups
        L = self.model.n_layers
        return max(1, min(L, 16 if L >= 16 else L))


class ClusterSim(RuntimeHost):
    def __init__(self, spec: ClusterSpec, policy: Policy, seed: int = 0,
                 contention_free: bool = False):
        self.spec = spec
        self.policy = policy
        policy.reset()
        self.rng = np.random.default_rng(seed)

        par = spec.par
        n_prefill = spec.n_units * par.gpus
        n_decode = int(math.ceil(n_prefill * spec.decode_ratio))
        n_store = spec.kvstore.n_store_nodes() if spec.kvstore else 0
        total = n_prefill + n_decode + n_store
        if spec.topology == "tor":
            self.topo: Topology = SingleToR(
                total, nic_bw=spec.hw.nic_bw,
                gpus_per_server=spec.gpus_per_server,
                scaleup_bw=spec.hw.scaleup_bw)
        else:
            racks = max(1, math.ceil(total / spec.hosts_per_rack))
            self.topo = FatTree(racks, spec.hosts_per_rack,
                                nic_bw=spec.hw.nic_bw,
                                gpus_per_server=spec.gpus_per_server,
                                scaleup_bw=spec.hw.scaleup_bw)

        plan = GroupPlan.build(spec.model.n_layers, spec.n_groups())
        self.profile = StageProfile(
            model=spec.model, hw=spec.hw, par=par, plan=plan,
            kv_dtype_bytes=spec.kv_dtype_bytes,
            act_dtype_bytes=spec.act_dtype_bytes,
            gpus_per_server=spec.gpus_per_server)
        unit_eps = [list(range(u * par.gpus, (u + 1) * par.gpus))
                    for u in range(spec.n_units)]
        decode_eps = list(range(n_prefill, n_prefill + n_decode))
        store_eps = list(range(n_prefill + n_decode, total))
        self.kvstore: Optional[KVStore] = None
        if spec.kvstore is not None:
            pooled = spec.kvstore.pooled_tier()
            if pooled is not None and pooled.fetch_bw > 0:
                # the pooled tier's nodes expose its fetch bandwidth as
                # their NIC capacity (store egress/ingress bound)
                for e in store_eps:
                    self.topo.capacity[2 * e] = pooled.fetch_bw
                    self.topo.capacity[2 * e + 1] = pooled.fetch_bw
            self.kvstore = KVStore(
                spec.kvstore, self.profile.kv_bytes_per_token(),
                unit_eps, store_eps, nic_bw=spec.hw.nic_bw)
        self.decode_plane: Optional[DecodePlane] = None
        pool_eps = None
        if spec.decode is not None:
            pool_eps = partition_pools(spec.decode.pools, decode_eps)
            self.decode_plane = DecodePlane(spec.decode, self.profile,
                                            pool_eps, seed=seed)
        emitter = StageEmitter(self.profile, unit_eps, decode_eps, self.topo,
                               pool_eps=pool_eps,
                               chunk_tokens=spec.chunk_tokens())
        rspec = spec.router
        self.telemetry: Optional[Telemetry] = \
            Telemetry(spec.telemetry) if spec.telemetry is not None \
            and spec.telemetry.enabled else None
        self.monitor: Optional[Monitor] = \
            Monitor(spec.monitor) if spec.monitor is not None \
            and spec.monitor.enabled else None
        self.runtime = MsFlowRuntime(
            self.topo, FluidNet(self.topo), EventQueue(), policy,
            self.profile, emitter, host=self, n_units=spec.n_units,
            max_batch_tokens=spec.max_batch_tokens, slo_scale=spec.slo_scale,
            slo_mode=spec.slo_mode, tick_interval=spec.tick_interval,
            drop_budget=spec.drop_budget, contention_free=contention_free,
            decode=self.decode_plane, kvstore=self.kvstore,
            router=rspec.build() if rspec is not None else None,
            admission=rspec.build_admission() if rspec is not None else None,
            telemetry=self.telemetry, monitor=self.monitor)
        self.metrics = SimMetrics(policy=policy.name)

    # kept as properties so tooling (and tests) can poke at the shared state
    @property
    def net(self) -> FluidNet:
        return self.runtime.net

    @property
    def view(self):
        return self.runtime.view

    # ------------------------------------------------------------ host hooks
    # Placement lives in the runtime's router plane now: trace items arrive
    # with the legacy (reuse, owner_unit) oracle pre-filled, so the default
    # no-op ``prepare_route`` suffices — the ``kv_affinity`` policy reads
    # the oracle (store off) or live store residency (store on), and the
    # runtime resolves the winner's block plan. Pool selection still rides
    # on routing: the runtime fills ``item.pool`` via
    # ``DecodePlane.pick_pool`` right after placement.

    def kv_chain_keys(self, item: PrefillItem):
        # the keys the router plane scores and the runtime resolves, also
        # used by store-aware SLO calibration
        r: Request = item.payload
        return chain_keys(r.prefix_chain, self.kvstore.spec.block_tokens) \
            if self.kvstore is not None else ()

    def on_shed(self, item: PrefillItem) -> None:
        # shed requests never ran: no TTFT, but they count as SLO misses in
        # all-arrivals attainment (SimMetrics.slo_attainment)
        r: Request = item.payload
        self.metrics.shed[r.rid] = r.slo_class

    def on_admitted(self, item: PrefillItem) -> None:
        r: Request = item.payload
        r.unit = item.unit
        r.deadline = item.deadline
        r.ideal_ttft = item.ideal_ttft
        self.metrics.arrival[r.rid] = r.arrival
        self.metrics.prompt_tokens[r.rid] = item.n_tokens
        # metrics store the *relative* TTFT budget (deadline - arrival) so it
        # compares directly against the recorded (relative) TTFT
        self.metrics.deadline[r.rid] = item.deadline - item.arrival
        self.metrics.ideal_ttft[r.rid] = item.ideal_ttft
        self.metrics.slo_class[r.rid] = r.slo_class
        if item.hit_plan is not None and r.rid >= 0:
            self.metrics.kv_hit_tokens[r.rid] = item.hit_plan.tokens
            self.metrics.kv_prompt_tokens[r.rid] = item.n_tokens
            for tier, tok in item.hit_plan.tier_tokens().items():
                self.metrics.kv_tier_tokens[tier] = \
                    self.metrics.kv_tier_tokens.get(tier, 0) + tok

    def on_batch_started(self, bs: BatchState) -> None:
        for it in bs.items:
            it.payload.batch = bs.bid

    def on_request_done(self, item: PrefillItem, bs: BatchState) -> None:
        r: Request = item.payload
        r.prefill_done = item.prefill_done
        r.stalls = item.stalls
        r.ttft = item.ttft
        self.metrics.ttft[r.rid] = item.ttft
        self.metrics.stall_time[r.rid] = item.stalls

    def on_coflow_done(self, bs: BatchState, co: Coflow, ideal: float) -> None:
        self.metrics.coflows.append(CoflowRecord(
            co.cid, bs.unit, co.layer, co.started, self.runtime.net.now,
            co.size, ideal))

    def on_decode_admitted(self, sess: DecodeSession) -> None:
        self.metrics.pool_of[sess.rid] = sess.pool

    def on_decode_done(self, sess: DecodeSession) -> None:
        self.metrics.tpot[sess.rid] = sess.tpot
        self.metrics.tbt_max[sess.rid] = sess.gap_max
        self.metrics.tpot_budget[sess.rid] = sess.tpot_budget

    # ------------------------------------------------------------------ run
    def build_items(self, requests: Sequence[Request]) -> List[PrefillItem]:
        """Trace requests -> runtime items (the exact objects ``run()``
        pushes), with SLO calibration applied. Exposed so offline analyses
        — e.g. the max-flow yardstick's demand replay — see the same
        deadlines/reuse the live run would."""
        import copy
        items: List[PrefillItem] = []
        for r in requests:
            # Requests carry runtime state; copy so one trace can be replayed
            # across policies/seeds without cross-contamination.
            r = copy.copy(r)
            # legacy (store-off) reuse model: pre-sampled reuse_len + static
            # modulo owner; with the KV-reuse plane attached, route()
            # overrides both from the live store
            items.append(PrefillItem(
                rid=r.rid, arrival=r.arrival, n_tokens=r.prompt_len,
                reuse=r.reuse_len,
                owner_unit=r.prefix_id % self.spec.n_units,
                slo_scale=getattr(r, "slo_scale", 0.0),
                slo_class=getattr(r, "slo_class", "standard"),
                out_tokens=getattr(r, "out_len", 0), payload=r))
        self.runtime.calibrate_slo(items)
        return items

    def run(self, requests: Sequence[Request], max_events: int = 5_000_000) -> SimMetrics:
        items = self.build_items(requests)
        for it in items:
            self.runtime.push_arrival(it)
        self.runtime.run(max_events=max_events)
        self.metrics.pruned = self.runtime.n_pruned
        self.metrics.n_deferred = self.runtime.n_deferred
        self.metrics.stage_log_dropped = self.runtime.stage_log.dropped
        if self.decode_plane is not None:
            self.metrics.decode_stats = self.decode_plane.summary()
        if self.kvstore is not None:
            self.metrics.kvstore_stats = self.kvstore.summary()
        return self.metrics
