"""repro.simcluster — Vidur-style event-driven disaggregated-serving simulator."""
from .hw import HW, A100, RTX3090, TPU_V5E
from .trace import Request, WorkloadSpec, WORKLOADS, generate_trace
from .metrics import SimMetrics, CoflowRecord
from .sim import ParallelismSpec, ClusterSpec, ClusterSim
from .papermodels import PAPER_MODELS

__all__ = [
    "HW", "A100", "RTX3090", "TPU_V5E",
    "Request", "WorkloadSpec", "WORKLOADS", "generate_trace",
    "SimMetrics", "CoflowRecord",
    "ParallelismSpec", "ClusterSpec", "ClusterSim",
    "PAPER_MODELS",
]
