"""Hardware profiles for the analytic latency model.

The paper calibrates its simulator against NVIDIA A100 operator profiles; we
additionally provide the TPU v5e profile used by the roofline analysis so the
simulator and the dry-run share constants. ``mfu`` is the sustained fraction
of peak compute the latency model assumes for dense prefill operators (Vidur
profiles encode the same information empirically).
"""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["HW", "A100", "TPU_V5E", "RTX3090"]

GB = 1e9
Gb = 1e9 / 8


@dataclass(frozen=True)
class HW:
    name: str
    flops: float          # peak matmul FLOP/s (bf16)
    hbm_bw: float         # bytes/s
    nic_bw: float         # bytes/s per endpoint (network share per GPU)
    scaleup_bw: float     # bytes/s intra-server fabric per endpoint
    mfu: float = 0.45     # sustained fraction of peak for prefill GEMMs
    hbm_eff: float = 0.75


# Simulation default (§6.1: latency profiles calibrated on A100; 8 NICs per
# 8-GPU server at 200 Gbps; NVSwitch 900 GB/s).
A100 = HW("a100", flops=312e12, hbm_bw=2039 * GB, nic_bw=200 * Gb,
          scaleup_bw=900 * GB)

# Testbed (§6.1): RTX 3090 + 2x100G NICs shared by 4 GPUs => 50 Gbps/GPU,
# PCIe Gen3 x16 intra-server (~16 GB/s).
RTX3090 = HW("rtx3090", flops=71e12, hbm_bw=936 * GB, nic_bw=50 * Gb,
             scaleup_bw=16 * GB, mfu=0.35)

# Roofline target hardware (per brief): TPU v5e.
TPU_V5E = HW("tpu_v5e", flops=197e12, hbm_bw=819 * GB, nic_bw=50 * GB,
             scaleup_bw=50 * GB, mfu=0.5)
