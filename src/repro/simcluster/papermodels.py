"""The paper's evaluation models (§6.2/§6.3) as ArchConfigs.

All hyperparameters come from public model cards / tech reports:
  * Mixtral-8x7B / 8x22B  — mistralai HF cards
  * DBRX                  — databricks blog (16 experts, top-4)
  * Grok                  — xai-org/grok-1 open release (Grok-2 internals are
                            unpublished; the paper cites x.ai/news/grok-2 —
                            we use the open Grok-1 config as the stand-in and
                            label it "grok")
  * Qwen3-Coder           — QwenLM tech report (30B-A3B: 128 experts, top-8)
  * Llama3-8B             — meta-llama HF card (1M-token SP deployment à la
                            §6.3 long-context setup)
"""
from __future__ import annotations

from ..configs.base import ArchConfig

__all__ = ["PAPER_MODELS"]

MIXTRAL_8X7B = ArchConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, d_ff=14336, vocab=32000,
    n_experts=8, top_k=2, d_expert=14336,
    source="hf:mistralai/Mixtral-8x7B-Instruct-v0.1",
)

MIXTRAL_8X22B = ArchConfig(
    name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
    n_heads=48, n_kv=8, d_ff=16384, vocab=32768,
    n_experts=8, top_k=2, d_expert=16384,
    source="hf:mistralai/Mixtral-8x22B-Instruct-v0.1",
)

DBRX = ArchConfig(
    name="dbrx", family="moe", n_layers=40, d_model=6144,
    n_heads=48, n_kv=8, d_ff=10752, vocab=100352,
    n_experts=16, top_k=4, d_expert=10752,
    source="databricks:dbrx",
)

GROK = ArchConfig(
    name="grok", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv=8, d_ff=32768, vocab=131072,
    n_experts=8, top_k=2, d_expert=32768,
    source="hf:xai-org/grok-1 (stand-in for Grok-2)",
)

QWEN3_CODER = ArchConfig(
    name="qwen3-coder", family="moe", n_layers=48, d_model=2048,
    n_heads=32, n_kv=4, d_ff=6144, vocab=151936, head_dim=128,
    n_experts=128, top_k=8, d_expert=768,
    source="qwen3-coder-30b-a3b tech report",
)

LLAMA3_8B = ArchConfig(
    name="llama3-8b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, d_ff=14336, vocab=128256,
    source="hf:meta-llama/Meta-Llama-3-8B",
)

PAPER_MODELS = {
    m.name: m
    for m in (MIXTRAL_8X7B, MIXTRAL_8X22B, DBRX, GROK, QWEN3_CODER, LLAMA3_8B)
}
